"""Shared pytest config: the ``slow`` marker + per-test timeouts.

Timeouts are applied only when the ``pytest-timeout`` plugin is installed
(it is in requirements-dev.txt / CI; the marker degrades to a no-op in a
bare checkout) — hung cluster/subprocess tests fail in minutes instead of
wedging the whole tier-1 run.
"""
import pytest

FAST_TIMEOUT = 120   # seconds, per ordinary test
SLOW_TIMEOUT = 300   # seconds, per @pytest.mark.slow test


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long multi-process/cluster tests (bigger timeout)")


def pytest_collection_modifyitems(config, items):
    if not config.pluginmanager.hasplugin("timeout"):
        return
    for item in items:
        if item.get_closest_marker("timeout") is not None:
            continue  # explicit per-test timeout wins
        limit = SLOW_TIMEOUT if item.get_closest_marker("slow") else FAST_TIMEOUT
        item.add_marker(pytest.mark.timeout(limit))
