"""SQL front-end (ISSUE 9): parser, compiler, error contract, and
byte-identity of ``repro.sql(q)`` against the hand-built Pipeline on every
engine — SQL is a parser over the shared logical plan, never a second
execution path."""
import json
import math
import os
import random

import pytest

import repro
import repro.api as dj
from repro.api.sql import (
    SQLError, compile_query, parse_sql, sql,
)
from cluster_harness import wait_for


def _write_corpus(path, n=40, seed=5):
    rng = random.Random(seed)
    words = "alpha beta gamma delta epsilon zeta eta theta".split()
    with open(path, "w", encoding="utf-8") as f:
        for i in range(n):
            text = " ".join(rng.choice(words)
                            for _ in range(rng.randrange(2, 60)))
            if i % 9 == 0:
                text = "你好世界 " * 30  # non-en rows for lang predicates
            f.write(json.dumps({"text": text, "meta": {"i": i}}) + "\n")
    return path


# ---------------------------------------------------------------------------
# parser
# ---------------------------------------------------------------------------


def test_parse_full_clause_set():
    q = parse_sql("SELECT text FROM ds WHERE lang = 'en' AND words > 50 "
                  "AND words <= 400 ORDER BY words DESC LIMIT 7")
    assert q.star is False and [it.column for it in q.select] == ["text"]
    assert q.source == "ds" and not q.source_is_path
    assert [(p.column, p.op, p.value) for p in q.where] == [
        ("lang", "=", "en"), ("words", ">", 50), ("words", "<=", 400)]
    assert q.order_by == "words" and q.order_desc and q.limit == 7


def test_parse_star_path_group_and_in():
    q = parse_sql("SELECT * FROM 'data.jsonl' WHERE lang IN ('en', 'zh') "
                  "GROUP BY lang")
    assert q.star and q.source == "data.jsonl" and q.source_is_path
    assert q.where[0].op == "in" and q.where[0].value == ("en", "zh")
    assert q.group_by == "lang"


def test_parse_aggregate_function():
    q = parse_sql("SELECT KEYWORDS(text, 5) FROM ds GROUP BY lang")
    it = q.select[0]
    assert it.func == "keywords" and it.column == "text" and it.arg == 5


@pytest.mark.parametrize("bad,kind", [
    ("SELECT", "syntax"),
    ("SELCT text FROM ds", "syntax"),
    ("SELECT text FROM ds WHERE lang != 'en'", "unsupported"),
    ("SELECT text FROM ds WHERE words > 1 OR words < 9", "unsupported"),
    ("SELECT text FROM ds LIMIT 3", "unsupported"),
    ("SELECT text FROM ds GROUP BY lang ORDER BY words", "unsupported"),
    ("SELECT CONCAT(text) FROM ds", "syntax"),  # aggregate needs GROUP BY
    ("SELECT text FROM ds WHERE words > 'hi'", "syntax"),
])
def test_rejections_carry_kind(bad, kind):
    with pytest.raises(SQLError) as ei:
        compile_query(parse_sql(bad))
    assert ei.value.kind == kind


def test_unknown_column_reuses_did_you_mean():
    from repro.core.registry import did_you_mean

    with pytest.raises(SQLError) as ei:
        compile_query(parse_sql("SELECT text FROM ds WHERE wrods > 5"))
    e = ei.value
    assert e.kind == "unknown_column"
    assert e.suggestions == did_you_mean("wrods", ["words"]) == ["words"]
    assert "did you mean words?" in str(e)


# ---------------------------------------------------------------------------
# compiler lowering
# ---------------------------------------------------------------------------


def test_predicates_merge_per_column_with_strict_bounds():
    ops, _ = compile_query(parse_sql(
        "SELECT text FROM ds WHERE words > 50 AND words <= 400 "
        "AND words >= 10"))
    assert ops == [{"name": "words_num_filter",
                    "min_val": math.nextafter(50.0, math.inf),
                    "max_val": 400.0}]


def test_group_by_stat_injects_compute_filter():
    ops, info = compile_query(parse_sql(
        "SELECT CONCAT(text) FROM ds GROUP BY lang"))
    assert [o["name"] for o in ops] == [
        "language_heuristic_filter", "key_value_grouper",
        "concat_text_aggregator"]
    # the injected lang filter keeps every language — compute, don't filter
    assert set(ops[0]["keep_langs"]) == {"en", "zh", "other", "unknown"}
    assert ops[1] == {"name": "key_value_grouper", "key": "lang",
                      "source": "stats"}
    assert info["injected"] == ["lang"]


def test_order_by_lowers_to_selector_with_sql_sort_semantics():
    # SQL default ASC -> ascending selector; stat filter auto-injected
    ops, _ = compile_query(parse_sql(
        "SELECT text FROM ds ORDER BY text_len LIMIT 4"))
    assert ops == [{"name": "text_length_filter"},
                   {"name": "topk_stat_selector", "stat_key": "text_len",
                    "descending": False, "k": 4}]
    # no injection when WHERE already computes the stat
    ops2, info2 = compile_query(parse_sql(
        "SELECT text FROM ds WHERE text_len > 5 ORDER BY text_len DESC"))
    assert [o["name"] for o in ops2] == ["text_length_filter",
                                        "topk_stat_selector"]
    assert ops2[1]["descending"] is True and ops2[1]["fraction"] == 1.0
    assert info2["injected"] == []


def test_projection_lowers_to_select_fields_mapper():
    ops, _ = compile_query(parse_sql("SELECT text, words FROM ds "
                                     "WHERE words > 1"))
    assert ops[-1] == {"name": "select_fields_mapper",
                      "fields": ["text", "stats"]}
    # SELECT text / SELECT * add no projection
    for q in ("SELECT text FROM ds WHERE words > 1",
              "SELECT * FROM ds WHERE words > 1"):
        ops2, _ = compile_query(parse_sql(q))
        assert [o["name"] for o in ops2] == ["words_num_filter"]


# ---------------------------------------------------------------------------
# FROM resolution
# ---------------------------------------------------------------------------


def test_from_resolution_paths(tmp_path):
    src = _write_corpus(str(tmp_path / "in.jsonl"))
    by_arg = sql("SELECT text FROM whatever WHERE words > 3", src)
    by_kwarg = sql("SELECT text FROM whatever WHERE words > 3",
                   dataset_path=src)
    by_literal = sql(f"SELECT text FROM '{src}' WHERE words > 3")
    my_dataset = src  # resolved from the caller's scope by name
    by_scope = sql("SELECT text FROM my_dataset WHERE words > 3")
    recipes = [p.to_recipe() for p in (by_arg, by_kwarg, by_literal, by_scope)]
    assert all(r == recipes[0] for r in recipes)
    with pytest.raises(SQLError) as ei:
        sql("SELECT text FROM not_bound_anywhere")
    assert ei.value.kind == "unknown_source"


# ---------------------------------------------------------------------------
# byte-identity vs hand-built Pipeline, across engines
# ---------------------------------------------------------------------------


QUERY = ("SELECT text FROM ds WHERE lang = 'en' AND words > 10 "
         "AND text_len < 5000")


def _hand_built(src, out):
    return (dj.read_jsonl(src)
            .filter("language_heuristic_filter", keep_langs=["en"])
            .filter("words_num_filter",
                    min_val=math.nextafter(10.0, math.inf))
            .filter("text_length_filter",
                    max_val=math.nextafter(5000.0, -math.inf))
            .write_jsonl(out))


@pytest.mark.parametrize("engine,np", [("local", 1), ("parallel", 2)])
def test_sql_byte_identical_to_pipeline(tmp_path, engine, np):
    src = _write_corpus(str(tmp_path / "in.jsonl"))
    a = str(tmp_path / "sql.jsonl")
    b = str(tmp_path / "hand.jsonl")
    _, rep = sql(QUERY, dataset_path=src, export_path=a,
                 engine=engine, np=np).execute()
    _, rep2 = _hand_built(src, b).options(engine=engine, np=np).execute()
    assert rep.n_out == rep2.n_out > 0
    with open(a, "rb") as fa, open(b, "rb") as fb:
        assert fa.read() == fb.read()


def test_sql_byte_identical_on_two_runner_cluster(tmp_path):
    src = _write_corpus(str(tmp_path / "in.jsonl"))
    a = str(tmp_path / "sql.jsonl")
    b = str(tmp_path / "hand.jsonl")
    mgr = dj.JobManager(max_workers=2, cluster_dir=str(tmp_path / "c"))
    try:
        ja = mgr.submit(sql(QUERY, dataset_path=src, export_path=a))
        jb = mgr.submit(_hand_built(src, b))
        wait_for(lambda: ja.done() and jb.done(), 60,
                 message="cluster jobs finish")
        assert ja.status()["state"] == jb.status()["state"] == "succeeded"
    finally:
        mgr.shutdown(wait=True)
    with open(a, "rb") as fa, open(b, "rb") as fb:
        assert fa.read() == fb.read()


def test_sql_group_by_runs_end_to_end(tmp_path):
    src = _write_corpus(str(tmp_path / "in.jsonl"))
    out = str(tmp_path / "g.jsonl")
    _, rep = repro.sql("SELECT KEYWORDS(text, 3) FROM ds GROUP BY lang",
                       dataset_path=src, export_path=out).execute()
    rows = [json.loads(l) for l in open(out, encoding="utf-8")]
    assert rep.n_out == len(rows) == 2  # en + zh groups
    assert all(r["text"].startswith("summary keywords:") for r in rows)


# ---------------------------------------------------------------------------
# REST + CLI surfaces
# ---------------------------------------------------------------------------


def _post(port, route, body):
    import urllib.error
    import urllib.request

    from repro.core.storage import json_dumps

    req = urllib.request.Request(
        f"http://127.0.0.1:{port}{route}", data=json_dumps(body),
        method="POST", headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req) as resp:
            return resp.status, json.loads(resp.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def test_rest_sql_route_contract(tmp_path):
    from repro.interface.server import serve

    src = _write_corpus(str(tmp_path / "in.jsonl"))
    srv = serve(port=0)
    port = srv.server_address[1]
    try:
        code, ok = _post(port, "/sql", {
            "query": "SELECT text FROM ds WHERE words > 10",
            "dataset_path": src,
            "export_path": str(tmp_path / "out.jsonl")})
        assert code == 200 and ok["status"] == "ok" and ok["n_out"] > 0
        assert ok["plan"] == ["words_num_filter"]

        # unknown column: same 404-with-suggestions contract as /jobs
        code, err = _post(port, "/sql", {
            "query": "SELECT text FROM ds WHERE wrods > 10",
            "dataset_path": src})
        assert code == 404 and err["error"]["type"] == "unknown_column"
        assert err["error"]["suggestions"] == ["words"]
        code_op, err_op = _post(port, "/jobs", {
            "dataset_path": src,
            "process": [{"name": "wrods_num_filter"}]})
        assert code_op == code == 404
        assert "did you mean" in err_op["error"]["message"]

        code, err = _post(port, "/sql", {"query": "SELCT text FROM ds",
                                         "dataset_path": src})
        assert code == 400 and err["error"]["type"] == "syntax"
        assert _post(port, "/sql", {})[0] == 400
    finally:
        srv.server_close()


def test_rest_run_route_still_lowers_single_ops(tmp_path):
    from repro.interface.server import serve

    src = _write_corpus(str(tmp_path / "in.jsonl"))
    srv = serve(port=0)
    port = srv.server_address[1]
    try:
        code, ok = _post(
            port, f"/run/text_length_filter?dataset_path={src}",
            {"min_val": 30})
        assert code == 200 and ok["status"] == "ok"
        assert ok["n_out"] > 0 and ok["errors"] == 0
        assert _post(port, f"/run/nope_filter?dataset_path={src}", {})[0] \
            == 404
    finally:
        srv.server_close()


def test_cli_sql_and_explain(tmp_path, capsys):
    from repro.interface.cli import main

    src = _write_corpus(str(tmp_path / "in.jsonl"))
    assert main(["sql", "SELECT text FROM ds WHERE words > 10",
                 "--dataset_path", src,
                 "--export_path", str(tmp_path / "out.jsonl")]) == 0
    out = capsys.readouterr().out
    assert "words_num_filter" in out and "exported ->" in out
    assert os.path.exists(str(tmp_path / "out.jsonl"))

    assert main(["explain", "--sql",
                 "SELECT text FROM ds WHERE words > 10 AND text_len < 900",
                 "--dataset_path", src]) == 0
    out = capsys.readouterr().out
    assert "rule probe_cost_reorder" in out and "rule filter_fusion" in out
    assert "reads=text" in out

    assert main(["sql", "SELECT text FROM ds WHERE wrods > 10",
                 "--dataset_path", src]) == 1
    assert "did you mean words?" in capsys.readouterr().err
    assert main(["explain", "--config", "x.yaml", "--sql", "SELECT 1"]) == 1
