"""Streaming incremental MinHash-LSH dedup subsystem: component unit tests,
keep-first/exact semantics vs the barriered oracle, end-to-end streaming
equivalence, cancellation, checkpoint/resume across a dedup segment, the
per-segment insight recorder, the reservoir probe, and job persistence."""
import os
import time

import numpy as np
import pytest

from repro.core.dataset import ExecutionCancelled
from repro.core.dedup.minhash import (
    candidate_pairs_hash_agg, jaccard, jaccard_unique, lsh_bands,
    make_permutations, minhash_dedup_indices, shingle_hashes, signature_ref,
    signatures_batch_vectorized,
)
from repro.core.dedup.streaming import (
    LSHBandIndex, ShingleStore, SignatureBatcher, StreamingMinHashState,
    StreamingUnionFind,
)
from repro.core.executor import Executor
from repro.core.fusion import plan_segments
from repro.core.recipes import Recipe
from repro.core.registry import create_op
from repro.core.storage import (
    SampleBlock, read_jsonl, reservoir_sample, write_jsonl,
)
from repro.data.synthetic import make_corpus


@pytest.fixture(scope="module")
def corpus():
    return make_corpus(400, seed=13, dup_frac=0.25, near_dup_frac=0.15)


def dedup_recipe(src, out, mode, engine="local", **extra):
    return Recipe(
        name=f"t-{mode}", dataset_path=src, export_path=out,
        process=[
            {"name": "whitespace_normalization_mapper"},
            {"name": "text_length_filter", "min_val": 30},
            {"name": "document_minhash_deduplicator",
             "jaccard_threshold": 0.6, "streaming": mode, "super_batch": 128},
            {"name": "alnum_ratio_filter", "min_val": 0.6},
        ],
        block_bytes=4096, engine=engine, **extra)


# ---------------------------------------------------------------------------
# components
# ---------------------------------------------------------------------------


def test_signature_batcher_matches_reference(corpus):
    texts = [s["text"] for s in corpus[:60]]
    a, b = make_permutations(64)
    batcher = SignatureBatcher(n_perm=64, super_batch=16)
    sigs, payloads = [], []
    for i, t in enumerate(texts):
        batcher.add(t, i)
        while batcher.ready:
            p, _, s = batcher.flush()
            payloads.extend(p)
            sigs.append(s)
    p, _, s = batcher.flush()
    payloads.extend(p)
    sigs.append(s)
    got = np.concatenate([s for s in sigs if s.size])
    ref = np.stack([signature_ref(shingle_hashes(t), a, b) for t in texts])
    assert np.array_equal(got, ref), "super-batching must not change values"
    assert payloads == list(range(len(texts))), "payload order must survive"
    assert batcher.dispatches < len(texts), "batching must amortize dispatches"


def test_vectorized_signatures_bit_exact(corpus):
    a, b = make_permutations(128)
    docs = [shingle_hashes(s["text"]) for s in corpus[:50]] + [
        np.zeros(0, np.uint64)]
    vec = signatures_batch_vectorized(docs, a, b)
    ref = np.stack([signature_ref(d, a, b) for d in docs])
    assert np.array_equal(vec, ref)


def test_band_index_reproduces_hash_agg_pairs(corpus):
    texts = [s["text"] for s in corpus[:80]]
    a, b = make_permutations(32)
    sigs = np.stack([signature_ref(shingle_hashes(t), a, b) for t in texts])
    keys = lsh_bands(sigs, 8)
    ref_pairs = set(candidate_pairs_hash_agg(keys))
    idx = LSHBandIndex(8)
    got = set()
    for i, t in enumerate(texts):
        for _, head, doc in idx.insert(i, keys[i], shingle_hashes(t)):
            got.add((head, doc))
    assert got == ref_pairs, "incremental insert must find the same candidates"


def test_jaccard_unique_equals_set_jaccard(corpus):
    for s, t in zip(corpus[:20], corpus[1:21]):
        da, db = shingle_hashes(s["text"]), shingle_hashes(t["text"])
        assert jaccard_unique(np.unique(da), np.unique(db)) == \
            pytest.approx(jaccard(da, db))


def test_shingle_store_spills_and_reloads():
    store = ShingleStore(max_resident=4)
    arrays = {i: np.arange(i + 1, dtype=np.uint64) * 7 for i in range(12)}
    for i, arr in arrays.items():
        store.put(i, arr)
    assert store.spilled > 0, "past the resident budget entries must spill"
    for i, arr in arrays.items():
        assert np.array_equal(store.get(i), arr), f"doc {i} corrupted by spill"
    assert store.reloads > 0
    store.close()
    assert store._path is None


def test_streaming_union_find_keep_first():
    uf = StreamingUnionFind()
    for x in range(6):
        uf.add(x)
    uf.union(3, 5)
    uf.union(1, 3)
    assert uf.component_min(5) == 1
    assert uf.component_min(0) == 0
    uf.union(0, 5)
    assert uf.component_min(3) == 0
    assert not uf.union(1, 5), "already connected"


# ---------------------------------------------------------------------------
# planning
# ---------------------------------------------------------------------------


def test_plan_segments_streaming_dedup_is_stateful():
    mk = lambda mode: [
        create_op({"name": "whitespace_normalization_mapper"}),
        create_op({"name": "document_minhash_deduplicator", "streaming": mode}),
        create_op({"name": "text_length_filter", "min_val": 1}),
    ]
    segs = plan_segments(mk("keep_first"))
    assert [(s.barrier, s.stateful) for s in segs] == [
        (False, False), (False, True), (False, False)]
    segs_off = plan_segments(mk("off"))
    assert [(s.barrier, s.stateful) for s in segs_off] == [
        (False, False), (True, False), (False, False)]


def test_explain_reports_stateful_segments(tmp_path, corpus):
    src = str(tmp_path / "in.jsonl")
    write_jsonl(src, corpus[:50])
    r = dedup_recipe(src, str(tmp_path / "o.jsonl"), "keep_first")
    info = Executor(r).explain()
    flags = [(tuple(s["ops"]), s["barrier"], s["stateful"])
             for s in info["segments"]]
    assert any(st and not b for _, b, st in flags), f"no stateful seg: {flags}"


def test_streaming_op_validates_mode():
    with pytest.raises(ValueError, match="streaming"):
        create_op({"name": "document_minhash_deduplicator", "streaming": "bogus"})
    op = create_op({"name": "streaming_minhash_deduplicator"})
    assert op.supports_streaming()


# ---------------------------------------------------------------------------
# keep-first vs exact semantics (oracle = minhash_dedup_indices)
# ---------------------------------------------------------------------------


def run_state(texts, **kw):
    """Drive texts through a StreamingMinHashState; returns kept indices."""
    samples = [{"text": t, "meta": {"i": i}, "stats": {}}
               for i, t in enumerate(texts)]
    blocks = [SampleBlock(samples[i:i + 7]) for i in range(0, len(samples), 7)]
    state = StreamingMinHashState(**kw)
    kept = []
    for blk, _ in state.stream_blocks(iter(blocks)):
        kept.extend(s["meta"]["i"] for s in blk.samples)
    return kept


def test_exact_mode_equals_barriered_oracle(corpus):
    texts = [s["text"] for s in corpus[:150]]
    kw = dict(n_perm=64, n_bands=8, jaccard_threshold=0.5, super_batch=32)
    keep_mask, _ = minhash_dedup_indices(texts, n_perm=64, n_bands=8,
                                         jaccard_threshold=0.5)
    exact = run_state(texts, exact=True, **kw)
    assert exact == [i for i in range(len(texts)) if keep_mask[i]]


def test_keep_first_superset_of_exact(corpus):
    texts = [s["text"] for s in corpus[:150]]
    kw = dict(n_perm=64, n_bands=8, jaccard_threshold=0.5, super_batch=32)
    keep_mask, comp = minhash_dedup_indices(texts, n_perm=64, n_bands=8,
                                            jaccard_threshold=0.5)
    kf = set(run_state(texts, exact=False, **kw))
    exact = {i for i in range(len(texts)) if keep_mask[i]}
    assert exact <= kf, "exact keep set must be contained in keep-first's"
    # every final component's first member is kept by both policies
    firsts = {}
    for i, c in enumerate(comp):
        firsts.setdefault(int(c), i)
    assert set(firsts.values()) <= kf


def test_keep_first_containment_property():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    vocab = ["alpha", "beta", "gamma", "delta", "eps", "zeta", "eta", "theta"]
    doc = st.lists(st.sampled_from(vocab), min_size=0, max_size=12).map(" ".join)

    @settings(max_examples=25, deadline=None)
    @given(st.lists(doc, min_size=0, max_size=30))
    def check(texts):
        kw = dict(n_perm=16, n_bands=4, ngram=3, jaccard_threshold=0.4,
                  super_batch=5)
        keep_mask, comp = minhash_dedup_indices(
            texts, n_perm=16, n_bands=4, ngram=3, jaccard_threshold=0.4)
        exact = {i for i in range(len(texts)) if keep_mask[i]}
        kf = set(run_state(texts, exact=False, **kw))
        # (1) containment: keep-first retains everything exact retains
        assert exact <= kf
        # (2) superset-consistency: anything keep-first drops, exact drops
        #     for the same reason (same final component as an earlier doc)
        for i in set(range(len(texts))) - kf:
            earlier = [j for j in range(i) if comp[j] == comp[i]]
            assert earlier, f"doc {i} dropped without an earlier duplicate"

    check()


def test_windowed_containment_property():
    """Windowed keep-first sits between exact and keep_first: a bounded
    retroactive-merge horizon can only IMPROVE on keep_first (later pair
    evidence arrives before the emit decision) while never dropping a doc
    exact keeps — and an unbounded window degenerates to the exact keep
    set (emit decisions see the full union-find)."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    vocab = ["alpha", "beta", "gamma", "delta", "eps", "zeta", "eta", "theta"]
    doc = st.lists(st.sampled_from(vocab), min_size=0, max_size=12).map(" ".join)

    @settings(max_examples=25, deadline=None)
    @given(st.lists(doc, min_size=0, max_size=30),
           st.integers(min_value=0, max_value=12))
    def check(texts, window):
        kw = dict(n_perm=16, n_bands=4, ngram=3, jaccard_threshold=0.4,
                  super_batch=5)
        keep_mask, _ = minhash_dedup_indices(
            texts, n_perm=16, n_bands=4, ngram=3, jaccard_threshold=0.4)
        exact = {i for i in range(len(texts)) if keep_mask[i]}
        kf = set(run_state(texts, exact=False, **kw))
        wi = run_state(texts, windowed=True, window=window, **kw)
        assert wi == sorted(wi), "windowed must preserve arrival order"
        assert exact <= set(wi) <= kf, \
            f"containment violated at window={window}"
        # unbounded horizon == exact keep set (decisions see all pairs)
        full = run_state(texts, windowed=True, window=len(texts) + 1, **kw)
        assert set(full) == exact

    check()


# ---------------------------------------------------------------------------
# end-to-end through Executor.run
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("engine", ["local", "parallel"])
def test_streaming_dedup_e2e_exact_byte_identical(tmp_path, corpus, engine):
    src = str(tmp_path / "in.jsonl")
    write_jsonl(src, corpus)
    out_s = str(tmp_path / f"s-{engine}.jsonl")
    out_b = str(tmp_path / f"b-{engine}.jsonl")
    np_kw = {"np": 2} if engine == "parallel" else {}
    _, rep = Executor(dedup_recipe(src, out_s, "exact", engine, **np_kw)).run()
    assert rep.streaming, "streaming dedup must keep the streaming path"
    Executor(dedup_recipe(src, out_b, "off", engine, **np_kw)).run_barriered()
    with open(out_s, "rb") as f_s, open(out_b, "rb") as f_b:
        assert f_s.read() == f_b.read()
    assert [e["op"] for e in rep.per_op] == rep.plan
    assert rep.per_op[2]["in"] == rep.per_op[1]["out"] > 0


def test_streaming_dedup_e2e_keep_first_contract(tmp_path, corpus):
    src = str(tmp_path / "in.jsonl")
    write_jsonl(src, corpus)
    out_kf = str(tmp_path / "kf.jsonl")
    out_ex = str(tmp_path / "ex.jsonl")
    _, rep = Executor(dedup_recipe(src, out_kf, "keep_first")).run()
    assert rep.streaming
    Executor(dedup_recipe(src, out_ex, "exact")).run()
    kf = [s["text"] for s in read_jsonl(out_kf)]
    ex = [s["text"] for s in read_jsonl(out_ex)]
    assert set(ex) <= set(kf)
    # keep-first preserves arrival order of survivors
    pos = {t: i for i, t in enumerate(kf)}
    assert [pos[t] for t in ex if t in pos] == sorted(
        pos[t] for t in ex if t in pos)


def test_mid_dedup_cancellation_cleans_spills(tmp_path, corpus):
    src = str(tmp_path / "in.jsonl")
    write_jsonl(src, corpus)
    spill = tmp_path / "spill"
    out = str(tmp_path / "o.jsonl")
    r = Recipe(
        name="cancel", dataset_path=src, export_path=out,
        process=[
            {"name": "whitespace_normalization_mapper"},
            {"name": "document_minhash_deduplicator", "streaming": "exact",
             "super_batch": 16, "spill_dir": str(spill)},
            {"name": "text_length_filter", "min_val": 1},
        ],
        block_bytes=2048, use_fusion=False, use_reordering=False)
    calls = {"n": 0}

    def cancel():
        calls["n"] += 1
        return calls["n"] > 4

    with pytest.raises(ExecutionCancelled):
        Executor(r).run(cancel=cancel)
    # the stage's finally-close must remove its spill files
    assert not os.path.exists(out), "cancelled run must not publish an export"
    leftovers = list(spill.glob("*")) if spill.exists() else []
    assert leftovers == [], f"spill files leaked: {leftovers}"


def test_checkpoint_resume_across_streaming_dedup_segment(tmp_path, corpus):
    src = str(tmp_path / "in.jsonl")
    write_jsonl(src, corpus[:120])
    out = str(tmp_path / "o.jsonl")
    r = dedup_recipe(src, out, "keep_first",
                     checkpoint_dir=str(tmp_path / "ckpt"),
                     use_fusion=False, use_reordering=False)
    _, rep1 = Executor(r).run_streaming()
    assert rep1.resumed_at == 0 and rep1.streaming
    with open(out, "rb") as f:
        first = f.read()
    # segments: [mapper+filter][dedup][filter] -> stages at {2, 3, 4}
    _, rep2 = Executor(r).run_streaming()
    assert rep2.resumed_at == 4, "resume must land on the final dedup-crossing stage"
    assert rep2.n_out == rep1.n_out and rep2.n_in == rep1.n_in == 120
    with open(out, "rb") as f:
        assert f.read() == first, "resumed export must be identical"


def test_streaming_insight_records_per_segment(tmp_path, corpus):
    src = str(tmp_path / "in.jsonl")
    write_jsonl(src, corpus[:100])
    r = dedup_recipe(src, str(tmp_path / "o.jsonl"), "keep_first",
                     insight=True)
    _, rep = Executor(r).run()
    assert rep.streaming and rep.insight
    assert "load ->" in rep.insight
    assert "document_minhash_deduplicator" in rep.insight


def test_pipeline_dedup_streaming_kwarg(tmp_path, corpus):
    from repro.api import Pipeline

    src = str(tmp_path / "in.jsonl")
    write_jsonl(src, corpus[:80])
    out = str(tmp_path / "o.jsonl")
    p = (Pipeline.read_jsonl(src)
         .filter("text_length_filter", min_val=10)
         .dedup(streaming="keep_first")
         .write_jsonl(out))
    info = p.explain()
    assert any(s["stateful"] for s in info["segments"])
    _, rep = p.execute()
    assert rep.streaming and rep.n_out > 0
    with pytest.raises(TypeError):
        Pipeline.read_jsonl(src).dedup(streaming_mode="keep_first")


# ---------------------------------------------------------------------------
# reservoir probe
# ---------------------------------------------------------------------------


def test_reservoir_sample_uniform_and_deterministic():
    items = list(range(10_000))
    a = reservoir_sample(iter(items), 100, seed=7)
    b = reservoir_sample(iter(items), 100, seed=7)
    assert a == b, "same seed must reproduce the same sample"
    assert a == sorted(a), "selected items keep first-seen order"
    assert len(set(a)) == 100
    assert np.mean(a) == pytest.approx(np.mean(items), rel=0.25), \
        "sample must not be head-biased"
    assert reservoir_sample(iter(range(5)), 100) == list(range(5))
    assert reservoir_sample(iter([]), 3) == []


def test_probe_blocks_replays_scanned_blocks(tmp_path, corpus):
    src = str(tmp_path / "in.jsonl")
    write_jsonl(src, corpus)
    r = Recipe(name="p", dataset_path=src, process=[
        {"name": "text_length_filter", "min_val": 1}], block_bytes=2048)
    ex = Executor(r)
    from repro.core.storage import iter_sample_blocks

    blocks = iter_sample_blocks(src, block_bytes=2048)
    probe, stream = ex._probe_blocks(blocks)
    assert 0 < len(probe) <= 1000
    replayed = [s["meta"]["id"] for b in stream for s in b.samples]
    assert replayed == [s["meta"]["id"] for s in corpus], \
        "probe must not consume or reorder the stream"


# ---------------------------------------------------------------------------
# job persistence
# ---------------------------------------------------------------------------


def test_job_manager_persists_and_restores(tmp_path, corpus):
    from repro.api import Pipeline
    from repro.api.jobs import JobManager

    src = str(tmp_path / "in.jsonl")
    write_jsonl(src, corpus[:60])
    jd = str(tmp_path / "jobs")
    m = JobManager(max_workers=1, job_dir=jd)
    try:
        p = (Pipeline.read_jsonl(src)
             .filter("text_length_filter", min_val=10)
             .dedup(streaming="keep_first"))
        job = m.submit(p)
        deadline = time.time() + 30
        while not job.done() and time.time() < deadline:
            time.sleep(0.05)
        assert job.state == "succeeded"
    finally:
        m.shutdown(wait=True)

    m2 = JobManager(max_workers=1, job_dir=jd)
    st = m2.get(job.id).status()
    assert st["restored"] and st["state"] == "succeeded"
    assert st["progress"]["ops_total"] == 2
    assert st["report"]["n_out"] > 0
    m2.shutdown()


def test_job_manager_marks_interrupted_jobs_failed(tmp_path):
    from repro.api.jobs import JobManager
    from repro.core.storage import json_dumps

    jd = tmp_path / "jobs"
    jd.mkdir()
    with open(jd / "jobs.jsonl", "wb") as f:
        f.write(json_dumps({"job_id": "j-run", "state": "running",
                            "created_at": 1.0}) + b"\n")
        f.write(b"{torn line\n")
    m = JobManager(job_dir=str(jd))
    job = m.get("j-run")
    assert job.state == "failed"
    assert "interrupted" in job.error
    m.shutdown()
