"""JobManager JSONL-snapshot persistence edge cases PR 3 left untested:
torn/corrupt snapshot lines, submit racing the persist path, and restoring a
snapshot larger than the configured ``max_jobs`` bound."""
import json
import os
import threading
import time

import repro.api as dj
from repro.api.jobs import JobManager
from cluster_harness import wait_for, write_corpus


def _snapshot_line(job_id, state="succeeded", created_at=None):
    return json.dumps({
        "job_id": job_id, "state": state,
        "created_at": created_at or time.time(),
        "started_at": None, "finished_at": time.time(),
        "error": None,
        "progress": {"per_op": [], "ops_started": 0, "ops_total": 0},
    })


def _write_snapshot(job_dir, lines):
    os.makedirs(job_dir, exist_ok=True)
    with open(os.path.join(job_dir, "jobs.jsonl"), "w") as f:
        f.write("\n".join(lines) + "\n")


def test_restore_skips_corrupt_and_truncated_lines(tmp_path):
    """A crash mid-rewrite can tear a line; a disk hiccup can corrupt one.
    Restore must keep every parseable record and drop the garbage — not
    raise, and not discard the whole snapshot."""
    job_dir = str(tmp_path / "jobs")
    good_a = _snapshot_line("aaa111")
    good_b = _snapshot_line("bbb222", state="failed")
    truncated = _snapshot_line("ccc333")[:25]  # torn mid-object
    _write_snapshot(job_dir, [good_a, "{not json at all", truncated,
                              "", good_b])
    mgr = JobManager(job_dir=job_dir)
    try:
        ids = {j["job_id"] for j in mgr.list()}
        assert ids == {"aaa111", "bbb222"}
        assert mgr.get("aaa111").status()["restored"] is True
        assert mgr.get("bbb222").state == "failed"
    finally:
        mgr.shutdown()


def test_restore_trims_snapshot_larger_than_max_jobs(tmp_path):
    """A restarted server may be configured with a smaller store than the one
    that wrote the snapshot; the bound must hold after restore, evicting
    oldest-first exactly like the live store does."""
    job_dir = str(tmp_path / "jobs")
    t0 = time.time()
    _write_snapshot(job_dir, [
        _snapshot_line(f"job{i}", created_at=t0 + i) for i in range(6)])
    mgr = JobManager(max_jobs=3, job_dir=job_dir)
    try:
        ids = [j["job_id"] for j in mgr.list()]
        assert len(ids) == 3, "restore must honour max_jobs"
        assert ids == ["job3", "job4", "job5"], \
            "eviction must drop the OLDEST snapshot records"
        # the bounded store still accepts new work after a trimmed restore
        src = write_corpus(str(tmp_path / "c.jsonl"), n=30)
        job = mgr.submit(dj.read_jsonl(src)
                         .map("whitespace_normalization_mapper"))
        wait_for(job.done, 30, message="post-restore submit")
        assert len(mgr.list()) <= 3
    finally:
        mgr.shutdown(wait=True)


def test_concurrent_submits_during_persist_are_snapshot_consistent(tmp_path):
    """submit() persists outside its store lock; hammer it from threads and
    verify no submission is lost, the store stays bounded, and the final
    snapshot on disk is valid JSONL containing every terminal job."""
    job_dir = str(tmp_path / "jobs")
    src = write_corpus(str(tmp_path / "c.jsonl"), n=20)
    mgr = JobManager(max_workers=2, max_jobs=64, job_dir=job_dir)
    pipe = (dj.read_jsonl(src).map("whitespace_normalization_mapper")
            .options(use_reordering=False, use_fusion=False))
    ids, errors = [], []
    lock = threading.Lock()

    def hammer(k):
        try:
            for i in range(4):
                job = mgr.submit(pipe, job_id=f"t{k}-{i}")
                with lock:
                    ids.append(job.id)
        except Exception as e:  # noqa: BLE001 — surfaced as test failure
            errors.append(e)

    threads = [threading.Thread(target=hammer, args=(k,)) for k in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    try:
        assert not errors
        assert len(ids) == 16
        wait_for(lambda: all(mgr.get(i).done() for i in ids), 60,
                 message="all concurrent jobs finish")
        # every line of the final snapshot parses; every job is present
        mgr._persist()
        with open(os.path.join(job_dir, "jobs.jsonl"), "rb") as f:
            records = [json.loads(line) for line in f if line.strip()]
        assert {r["job_id"] for r in records} == set(ids)
        assert all(r["state"] == "succeeded" for r in records)

        # and a restart restores exactly that view
        mgr2 = JobManager(job_dir=job_dir)
        try:
            assert {j["job_id"] for j in mgr2.list()} == set(ids)
        finally:
            mgr2.shutdown()
    finally:
        mgr.shutdown(wait=True)
