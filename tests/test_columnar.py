"""ColumnBlock struct-of-arrays format: round-trip byte identity (the
invariant every export/spill/checkpoint path rests on), columnar transform
semantics, filter equivalence with the row path, predicate pushdown, the
memory-pressure dispatch window, and end-to-end row-vs-columnar exports."""
import os
import pickle

import numpy as np
import pytest

from repro.core.columnar import (
    ColumnBlock, maybe_compress, maybe_decompress, utf8_char_counts,
)
from repro.core.executor import Executor
from repro.core.recipes import Recipe
from repro.core.registry import create_op
from repro.core.storage import json_dumps, write_jsonl
from repro.data.synthetic import make_corpus

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def lines_of(rows):
    return [json_dumps(r) for r in rows]


# ---------------------------------------------------------------------------
# round-trip byte identity
# ---------------------------------------------------------------------------

# one list per schema "field group" the paper pipelines actually ship:
# text-only, text+stats, multimodal path lists, nested meta, plus the nasty
# encodings (astral plane, CJK, escapes) and numeric edge cases
ROUND_TRIP_CASES = [
    [{"text": "plain ascii"}, {"text": ""}],
    [{"text": "quote \" backslash \\ newline \n tab \t"},
     {"text": "café — \U0001f600 中文"}],
    [{"text": "t", "stats": {"len": 1.5, "alnum": 0.25}},
     {"text": "u", "stats": {}}],
    [{"text": "a", "images": ["i/1.png", "i/2.png"], "audios": []},
     {"text": "b", "images": []}],
    [{"text": "a", "meta": {"source": "web", "nested": {"deep": [1, 2]}}},
     {"text": "b", "meta": None}],
    # mixed / ragged schema across rows of ONE block
    [{"text": "a", "score": 1}, {"score": 2.5, "text": "b"},
     {"text": "c"}, {"extra": True, "text": "d"}],
    # bool must not collapse into i64, None and huge ints stay exact
    [{"flag": True, "n": 3}, {"flag": False, "n": -(1 << 70)},
     {"flag": None, "n": (1 << 63) - 1}, {"flag": True, "n": -(1 << 63)}],
    [{"f": 0.1}, {"f": -0.0}, {"f": 1e300}, {"f": 3}],  # f64 -> obj promotion
    [],
    [{}, {"text": "after empty dict row"}],
]


@pytest.mark.parametrize("rows", ROUND_TRIP_CASES,
                         ids=[f"case{i}" for i in range(len(ROUND_TRIP_CASES))])
def test_round_trip_byte_identity(rows):
    blk = ColumnBlock.from_samples(rows)
    assert list(blk.iter_json_lines()) == lines_of(rows)
    assert blk.decode_rows() == rows
    # decoded rows re-encode to the same bytes as the originals
    assert [json_dumps(r) for r in blk.decode_rows()] == lines_of(rows)


def test_samples_cache_and_private_decode_are_independent():
    rows = [{"text": "x", "stats": {"a": 1.0}}]
    blk = ColumnBlock.from_samples(rows)
    private = blk.decode_rows()
    private[0]["text"] = "mutated"
    assert not blk.materialized
    assert blk.samples[0]["text"] == "x"  # cache decodes fresh
    blk.samples[0]["text"] = "owned"
    assert blk.samples[0]["text"] == "owned"  # cached dicts authoritative
    assert blk.materialized


def test_transforms_reject_materialized_blocks():
    blk = ColumnBlock.from_samples([{"text": "a"}])
    _ = blk.samples
    with pytest.raises(RuntimeError):
        blk.take(np.array([True]))
    with pytest.raises(RuntimeError):
        blk.with_stat("s", np.array([1.0]))


def test_take_with_stat_with_py_column_match_row_path():
    rows = [{"text": "aa", "stats": {"old": 2.0}}, {"text": "bbb"},
            {"text": "c", "stats": {}}]
    blk = ColumnBlock.from_samples(rows)
    vals = np.array([1.0, 2.0, 3.0])
    ref = [dict(r, stats=dict(r.get("stats") or {})) for r in rows]
    for r, v in zip(ref, vals):
        r.setdefault("stats", {})["len"] = float(v)
    got = blk.with_stat("len", vals)
    assert list(got.iter_json_lines()) == lines_of(ref)

    mask = np.array([True, False, True])
    assert list(blk.take(mask).iter_json_lines()) == [
        lines_of(rows)[0], lines_of(rows)[2]]

    carriers = [np.arange(3), np.arange(1), np.arange(2)]
    pyb = blk.with_py_column("__sig__", carriers)
    assert pyb.column_values("__sig__")[1] is carriers[1]
    # py columns are excluded from exports, never silently dumped
    with pytest.raises(TypeError):
        list(pyb.iter_json_lines())
    assert list(pyb.iter_json_lines(exclude=("__sig__",))) == lines_of(rows)


def test_pickle_round_trip_drops_cache():
    rows = [{"text": "abc", "stats": {"x": 1.0}}, {"text": "d"}]
    blk = ColumnBlock.from_samples(rows)
    _ = blk.samples
    clone = pickle.loads(pickle.dumps(blk))
    assert not clone.materialized
    assert list(clone.iter_json_lines()) == lines_of(rows)


def test_utf8_char_counts_exact():
    texts = ["", "ascii", "café", "中文 mixed",
             "\U0001f600\U0001f601", "aé中\U0001f600"]
    blk = ColumnBlock.from_samples([{"text": t} for t in texts])
    offs, buf = blk.str_column("text")
    assert utf8_char_counts(offs, buf).tolist() == [len(t) for t in texts]


def test_maybe_compress_round_trip():
    raw = b"x" * 4096 + json_dumps({"text": "payload"})
    codec, payload = maybe_compress(raw)
    assert codec in ("raw", "zstd")
    assert maybe_decompress(codec, payload) == raw
    if codec == "zstd":
        assert len(payload) < len(raw)


# ---------------------------------------------------------------------------
# hypothesis: arbitrary rows survive JSONL -> ColumnBlock -> JSONL
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    _text = st.text(
        alphabet=st.characters(codec="utf-8",
                               categories=("L", "N", "P", "Zs", "S")),
        max_size=60)
    _scalar = st.one_of(
        _text, st.booleans(), st.none(),
        st.integers(min_value=-(1 << 66), max_value=1 << 66),
        st.floats(allow_nan=False, allow_infinity=False))
    _value = st.recursive(
        _scalar,
        lambda leaf: st.one_of(
            st.lists(leaf, max_size=4),
            st.dictionaries(_text, leaf, max_size=4)),
        max_leaves=8)
    _row = st.dictionaries(_text, _value, max_size=6)

    @given(st.lists(_row, max_size=12))
    @settings(max_examples=120, deadline=None)
    def test_round_trip_property(rows):
        blk = ColumnBlock.from_samples(rows)
        assert list(blk.iter_json_lines()) == lines_of(rows)
        assert blk.decode_rows() == rows


# ---------------------------------------------------------------------------
# columnar filters == row filters
# ---------------------------------------------------------------------------


def _apply_rows(op, rows):
    import copy

    op.setup()
    return op.process_batch([copy.deepcopy(r) for r in rows])


@pytest.mark.parametrize("cfg", [
    {"name": "text_length_filter", "min_len": 8, "max_len": 60},
    {"name": "alnum_ratio_filter", "min_ratio": 0.5},
    {"name": "minhash_signature_mapper", "num_permutations": 16},
])
def test_columnar_op_matches_row_path(cfg):
    rows = [{"text": s["text"]} for s in make_corpus(80, seed=11)]
    op = create_op(dict(cfg))
    assert op.supports_columns()
    blk = ColumnBlock.from_samples(rows)
    op.setup()
    got = op.process_columns(blk)
    ref = _apply_rows(create_op(dict(cfg)), rows)
    if cfg["name"] == "minhash_signature_mapper":
        dec = got.decode_rows()
        assert [list(r.keys()) for r in dec] == [list(r.keys()) for r in ref]
        for g, r in zip(dec, ref):
            assert (g["__mh_sig__"] == r["__mh_sig__"]).all()
            assert (g["__mh_doc__"] == r["__mh_doc__"]).all()
    else:
        assert list(got.iter_json_lines()) == lines_of(ref)


# ---------------------------------------------------------------------------
# memory-pressure dispatch window
# ---------------------------------------------------------------------------


def test_dispatcher_mem_budget_shrinks_window():
    import concurrent.futures as cf

    from repro.core.dispatch import WindowedDispatcher
    from repro.core.storage import SampleBlock

    items = [SampleBlock([{"text": "x"}], nbytes=1000) for _ in range(40)]
    log = []
    with cf.ThreadPoolExecutor(4) as pool:
        d = WindowedDispatcher(pool, 4, mem_budget=2500, speculate=False,
                               log=log, label="membudget")
        results = list(d.run(items, lambda b: len(b.samples), lambda b: (b,)))
    assert len(results) == 40
    assert all(err is None and payload == 1 for _, payload, err in results)
    summary = log[-1]
    assert summary["mem_shrinks"] >= 1, summary
    assert summary["resident_peak"] >= 1000
    # budget bounds admission: never more than budget + one block in flight
    assert summary["resident_peak"] <= 2500 + 1000, summary


# ---------------------------------------------------------------------------
# end-to-end: pushdown + row-vs-columnar export byte identity
# ---------------------------------------------------------------------------

E2E_PROCESS = [
    {"name": "whitespace_normalization_mapper"},
    {"name": "text_length_filter", "min_len": 5, "max_len": 10000},
    {"name": "alnum_ratio_filter", "min_ratio": 0.1},
]


def _export(tmp_path, tag, fmt, engine, np_, process, fuse=True):
    out = str(tmp_path / f"out-{tag}.jsonl")
    r = Recipe(name=tag, dataset_path=str(tmp_path / "in.jsonl"),
               export_path=out, process=process, engine=engine, np=np_,
               use_fusion=fuse, use_reordering=fuse, block_format=fmt,
               block_bytes=16 * 1024)
    Executor(r).run_streaming(materialize=False)
    with open(out, "rb") as f:
        return f.read()


@pytest.fixture()
def corpus(tmp_path):
    write_jsonl(str(tmp_path / "in.jsonl"), make_corpus(400, seed=5))
    return tmp_path


def test_explain_reports_pushdown(corpus):
    r = Recipe(name="push", dataset_path=str(corpus / "in.jsonl"),
               process=[{"name": "text_length_filter", "min_len": 5},
                        {"name": "lowercase_mapper"}],
               use_fusion=False, use_reordering=False)
    segs = Executor(r).explain()["segments"]
    assert segs[0]["pushdown"] >= 1  # leading text_length_filter pushes down


def test_streaming_export_columnar_matches_row(corpus):
    ref = _export(corpus, "row-ref", "row", "local", 1, E2E_PROCESS)
    assert ref
    for engine, np_ in (("local", 1), ("parallel", 2)):
        got = _export(corpus, f"col-{engine}{np_}", "columnar", engine, np_,
                      E2E_PROCESS)
        assert got == ref, (engine, np_)


@pytest.mark.slow
def test_streaming_dedup_export_columnar_matches_row(corpus):
    proc = E2E_PROCESS[:2] + [
        {"name": "document_minhash_deduplicator", "streaming": "exact",
         "super_batch": 128},
    ] + E2E_PROCESS[2:]
    ref = _export(corpus, "dd-row", "row", "local", 1, proc)
    assert ref
    for fmt, engine, np_ in (("columnar", "local", 1),
                             ("columnar", "parallel", 2)):
        got = _export(corpus, f"dd-{fmt}-{engine}{np_}", fmt, engine, np_, proc)
        assert got == ref, (fmt, engine, np_)
