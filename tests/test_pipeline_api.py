"""Lazy Pipeline API: lowering equivalence, explain, validation, jobs."""
import time

import pytest

import repro.api as dj
from repro.api.jobs import JobManager, JobState
from repro.core.dataset import DJDataset
from repro.core.executor import Executor
from repro.core.ops_base import Mapper
from repro.core.recipes import Recipe
from repro.core.registry import register
from repro.core.storage import write_jsonl
from repro.data.synthetic import make_corpus


@register("snail_mapper")
class SnailMapper(Mapper):
    """Test-only slow mapper: sleeps per sample to make runs cancellable."""

    def __init__(self, delay: float = 0.002, **kw):
        super().__init__(delay=delay, **kw)
        self.delay = delay

    def process_single(self, sample):
        time.sleep(self.delay)
        return sample


RECIPE_PROCESS = [
    {"name": "whitespace_normalization_mapper"},
    {"name": "text_length_filter", "min_val": 100},
    {"name": "words_num_filter", "min_val": 5},
    {"name": "document_minhash_deduplicator", "jaccard_threshold": 0.7},
]


def _fixture(tmp_path, n=300, seed=0):
    src = str(tmp_path / "corpus.jsonl")
    write_jsonl(src, make_corpus(n, seed=seed))
    return src


def _pipeline(src, out):
    return (dj.read_jsonl(src)
            .map("whitespace_normalization_mapper")
            .filter("text_length_filter", min_val=100)
            .filter("words_num_filter", min_val=5)
            .dedup(jaccard_threshold=0.7)
            .write_jsonl(out))


def test_lowering_equivalence_with_recipe_run(tmp_path):
    """A fluent pipeline must produce the SAME optimized plan and
    byte-identical export as the equivalent recipe through Executor.run.

    Reordering is pinned off: it sorts commutative filters by wall-clock
    probed speed, so two independent probe runs can legitimately swap
    near-equal filters — that nondeterminism belongs to the scheduler, not
    to the lowering under test (fusion stays on)."""
    src = _fixture(tmp_path)
    out_a = str(tmp_path / "a.jsonl")
    out_b = str(tmp_path / "b.jsonl")

    recipe = Recipe.from_dict({"name": "fixture", "dataset_path": src,
                               "export_path": out_a, "use_reordering": False,
                               "process": RECIPE_PROCESS})
    pipe = _pipeline(src, out_b).options(use_reordering=False)

    # the lowering itself is the identity on the op chain
    assert pipe.to_recipe().process == RECIPE_PROCESS
    assert pipe.to_recipe().dataset_path == src

    _, rep_recipe = Executor(recipe).run()
    _, rep_pipe = pipe.execute()

    assert rep_pipe.plan == rep_recipe.plan
    assert any(op.startswith("fused<") for op in rep_pipe.plan)
    assert rep_pipe.n_out == rep_recipe.n_out
    with open(out_a, "rb") as fa, open(out_b, "rb") as fb:
        assert fa.read() == fb.read()


def test_pipeline_is_lazy_and_immutable(tmp_path):
    src = _fixture(tmp_path, n=50)
    base = dj.read_jsonl(src)
    chained = base.filter("text_length_filter", min_val=100)
    assert base._steps == ()  # chaining returned a NEW pipeline
    assert len(chained._steps) == 1
    # nothing has executed: no export file, no blocks decoded
    assert not (tmp_path / "o.jsonl").exists()


def test_explain_reports_segments_without_running(tmp_path):
    src = _fixture(tmp_path)
    info = _pipeline(src, str(tmp_path / "never_written.jsonl")).explain()
    assert info["streaming"] is True
    assert info["requested"][0] == "whitespace_normalization_mapper"
    # fusion folded the two adjacent filters
    assert any(op.startswith("fused<") for op in info["plan"])
    assert info["segments"][-1] == {
        "ops": ["document_minhash_deduplicator"], "barrier": True,
        "stateful": False, "pushdown": 0}
    assert not (tmp_path / "never_written.jsonl").exists()


def test_iter_blocks_streams_matching_output(tmp_path):
    src = _fixture(tmp_path)
    pipe = (dj.read_jsonl(src)
            .map("whitespace_normalization_mapper")
            .filter("text_length_filter", min_val=100))
    ds, rep = pipe.execute()
    streamed = [s for b in pipe.iter_blocks() for s in b.samples]
    assert len(streamed) == rep.n_out
    assert streamed == ds.samples()


def test_kwarg_and_type_validation():
    with pytest.raises(KeyError, match="did you mean"):
        dj.Pipeline().op("text_lenght_filter")
    with pytest.raises(TypeError, match="unexpected parameter"):
        dj.Pipeline().filter("text_length_filter", min_len=10)
    with pytest.raises(TypeError, match="not a Filter"):
        dj.Pipeline().filter("lowercase_mapper")
    with pytest.raises(TypeError, match="use .filter"):
        dj.Pipeline().map("text_length_filter")
    with pytest.raises(TypeError, match="unknown option"):
        dj.Pipeline().options(engien="local")


def test_from_samples_and_recipe_roundtrip(tmp_path):
    samples = make_corpus(80, seed=4)
    pipe = dj.from_samples(samples).filter("text_length_filter", min_val=200)
    ds, rep = pipe.execute()
    assert rep.n_in == 80 and len(ds) == rep.n_out
    assert all(len(s["text"]) >= 200 for s in ds)
    # the caller's samples were not mutated by the run (no ctx, no stats)
    assert all("__ctx__" not in s for s in samples)
    assert all(not s.get("stats") for s in samples)

    for fname in ("frozen.json", "frozen.yaml"):
        path = str(tmp_path / fname)
        pipe.save_recipe(path, name="frozen")
        rec = Recipe.load(path)
        assert rec.name == "frozen"
        assert rec.process == [{"name": "text_length_filter", "min_val": 200}]
        assert dj.from_recipe(rec)._steps == tuple(rec.process)

    # strings the YAML subset would reload as a different value are refused
    bad = dj.Pipeline().map("text_formatter", text_key="123")
    with pytest.raises(ValueError, match="simple-YAML"):
        bad.save_recipe(str(tmp_path / "bad.yaml"))
    bad.save_recipe(str(tmp_path / "bad.json"))  # JSON handles it fine


def test_from_dataset_carries_engine():
    from repro.core.engine import make_engine

    ds = DJDataset.from_samples(make_corpus(20, seed=14),
                                engine=make_engine("parallel", n_workers=2))
    rec = dj.from_dataset(ds).filter("text_length_filter", min_val=10).to_recipe()
    assert rec.engine == "parallel" and rec.np == 2
    # explicit override still wins
    rec2 = dj.from_dataset(ds).with_engine("local").to_recipe()
    assert rec2.engine == "local"


def test_job_manager_lifecycle(tmp_path):
    src = _fixture(tmp_path, n=200, seed=5)
    out = str(tmp_path / "job_out.jsonl")
    # fusion/reordering off -> no adapter probe -> the slow op only ever
    # runs inside the stream, where cancellation is polled per block
    pipe = (dj.read_jsonl(src).op("snail_mapper", delay=0.02)
            .write_jsonl(out)
            .options(block_bytes=512, use_fusion=False, use_reordering=False))

    jm = JobManager(max_workers=1, max_jobs=8)
    try:
        t0 = time.time()
        job = jm.submit(pipe)
        assert time.time() - t0 < 0.5  # submit never blocks on the run
        assert job.state in (JobState.QUEUED, JobState.RUNNING)

        # live per-op progress: rows fill in while the job runs
        deadline = time.time() + 30
        while time.time() < deadline:
            st = jm.get(job.id).status()
            rows = st["progress"]["per_op"]
            if st["state"] == JobState.RUNNING and rows and rows[0]["in"] > 0:
                break
            time.sleep(0.02)
        else:
            pytest.fail("job never reported per-op progress")
        assert rows[0]["op"] == "snail_mapper"
        assert 0 < rows[0]["in"] < 200  # genuinely mid-run

        jm.cancel(job.id)
        deadline = time.time() + 30
        while time.time() < deadline and not jm.get(job.id).done():
            time.sleep(0.02)
        st = jm.get(job.id).status()
        assert st["state"] == JobState.CANCELLED
        # cancelled export never became visible
        assert not (tmp_path / "job_out.jsonl").exists()

        # a fresh fast job completes and reports
        job2 = jm.submit(dj.read_jsonl(src)
                         .filter("text_length_filter", min_val=100))
        deadline = time.time() + 30
        while time.time() < deadline and not jm.get(job2.id).done():
            time.sleep(0.02)
        st2 = jm.get(job2.id).status()
        assert st2["state"] == JobState.SUCCEEDED
        assert st2["report"]["n_in"] == 200
        assert st2["report"]["plan"] == ["text_length_filter"]
    finally:
        jm.shutdown()


def test_job_pool_reaches_max_workers(tmp_path):
    """Two slow jobs must run concurrently with max_workers=2, even when the
    second is submitted after the first already started."""
    src = _fixture(tmp_path, n=100, seed=11)
    slow = (dj.read_jsonl(src).op("snail_mapper", delay=0.01)
            .options(block_bytes=512, use_fusion=False, use_reordering=False))
    jm = JobManager(max_workers=2, max_jobs=8)
    try:
        a = jm.submit(slow)
        time.sleep(0.2)  # a is mid-run before b is submitted
        b = jm.submit(slow)
        deadline = time.time() + 10
        while time.time() < deadline:
            if (jm.get(a.id).state == JobState.RUNNING
                    and jm.get(b.id).state == JobState.RUNNING):
                break
            time.sleep(0.02)
        else:
            pytest.fail("second worker never picked up the queued job")
    finally:
        for j in (a, b):
            jm.cancel(j.id)
        jm.shutdown()


def test_cancel_queued_job_never_runs(tmp_path):
    src = _fixture(tmp_path, n=100, seed=12)
    slow = (dj.read_jsonl(src).op("snail_mapper", delay=0.01)
            .options(block_bytes=512, use_fusion=False, use_reordering=False))
    jm = JobManager(max_workers=1, max_jobs=8)
    try:
        blocker = jm.submit(slow)
        queued = jm.submit(slow.write_jsonl(str(tmp_path / "never.jsonl")))
        jm.cancel(queued.id)
        assert jm.get(queued.id).state == JobState.CANCELLED
        jm.cancel(blocker.id)
        deadline = time.time() + 10
        while time.time() < deadline and not jm.get(blocker.id).done():
            time.sleep(0.02)
        # the cancelled-while-queued job never executed
        assert jm.get(queued.id).state == JobState.CANCELLED
        assert not (tmp_path / "never.jsonl").exists()
    finally:
        jm.shutdown()


def test_barriered_jobs_seed_full_plan(tmp_path):
    """checkpointing forces the barriered path (insight rides the stream
    now); ops_total must reflect the whole plan from the start, not just
    completed ops."""
    src = _fixture(tmp_path, n=60, seed=13)
    pipe = (dj.read_jsonl(src)
            .map("whitespace_normalization_mapper")
            .filter("text_length_filter", min_val=100)
            .checkpoint(str(tmp_path / "ckpt")))
    monitor = []
    _, rep = pipe.execute(monitor=monitor)
    assert not rep.streaming
    assert [r["op"] for r in monitor] == rep.plan
    assert monitor is not rep.per_op or len(monitor) == len(rep.plan)


def test_job_store_is_bounded(tmp_path):
    src = _fixture(tmp_path, n=20, seed=6)
    jm = JobManager(max_workers=1, max_jobs=2)
    try:
        fast = dj.read_jsonl(src).map("lowercase_mapper")
        a = jm.submit(fast)
        deadline = time.time() + 30
        while time.time() < deadline and not jm.get(a.id).done():
            time.sleep(0.02)
        jm.submit(fast)
        jm.submit(fast)  # evicts the finished oldest instead of failing
        with pytest.raises(KeyError):
            jm.get(a.id)
    finally:
        jm.shutdown()
