"""Training-substrate system tests: optimizer, microbatching equivalence,
bf16-params mode, checkpoint round-trip + elastic resume, sharding rules."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch import sharding as sh
from repro.models.model_zoo import build_model
from repro.train.optimizer import OptConfig, adamw_update, global_norm, init_opt_state
from repro.train.train_step import TrainConfig, init_state, make_train_step, state_specs

CFG = ModelConfig(arch_id="t", family="dense", n_layers=2, d_model=32, n_heads=4,
                  n_kv_heads=2, d_ff=64, vocab_size=128)
SHAPE = ShapeConfig("t", 16, 4, "train")


def _batch(seed=0):
    rng = np.random.default_rng(seed)
    t = rng.integers(0, CFG.vocab_size, (4, 16)).astype(np.int32)
    return {"tokens": jnp.asarray(t), "labels": jnp.asarray(np.roll(t, -1, 1)),
            "loss_mask": jnp.ones((4, 16), jnp.float32)}


def test_adamw_decreases_loss():
    model = build_model(CFG, remat_policy="none")
    state = init_state(model, jax.random.PRNGKey(0), OptConfig(lr=1e-2))
    step = jax.jit(make_train_step(model, TrainConfig(opt=OptConfig(lr=1e-2))))
    batch = _batch()
    losses = []
    for _ in range(12):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert int(state["step"]) == 12


def test_microbatch_equivalence():
    model = build_model(CFG, remat_policy="none")
    batch = _batch(1)
    s1 = init_state(model, jax.random.PRNGKey(1), OptConfig(lr=1e-3))
    s2 = jax.tree.map(jnp.copy, s1)
    step1 = jax.jit(make_train_step(model, TrainConfig(opt=OptConfig(lr=1e-3))))
    step2 = jax.jit(make_train_step(model, TrainConfig(opt=OptConfig(lr=1e-3),
                                                       n_microbatches=2)))
    s1, m1 = step1(s1, batch)
    s2, m2 = step2(s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-4)
    # bf16 forward noise is amplified by Adam's 1/sqrt(v) normalisation, so
    # compare post-update params at update-scale (lr=1e-3) tolerance
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), s1["params"], s2["params"])
    assert max(jax.tree.leaves(d)) < 5e-3, "microbatched step must match full batch"


def test_bf16_params_mode():
    model = build_model(CFG, remat_policy="none")
    tc = TrainConfig(opt=OptConfig(lr=1e-2), bf16_params=True)
    state = init_state(model, jax.random.PRNGKey(2), tc.opt, tc)
    assert jax.tree.leaves(state["params"])[0].dtype == jnp.bfloat16
    assert jax.tree.leaves(state["master"])[0].dtype == jnp.float32
    step = jax.jit(make_train_step(model, tc))
    batch = _batch(2)
    losses = []
    for _ in range(12):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    # specs match state structure
    specs = state_specs(model, tc)
    assert set(specs) == set(state)


def test_grad_clipping():
    params = {"w": jnp.ones((4,)) * 2.0}
    grads = {"w": jnp.ones((4,)) * 100.0}
    opt = init_opt_state(params, OptConfig())
    _, _, gnorm = adamw_update(params, grads, opt, OptConfig(clip_norm=1.0))
    assert float(gnorm) == pytest.approx(200.0)
    assert float(global_norm(grads)) == pytest.approx(200.0)


def test_checkpoint_round_trip_and_elastic(tmp_path):
    from repro.train.checkpointing import load_state, save_state

    model = build_model(CFG, remat_policy="none")
    state = init_state(model, jax.random.PRNGKey(3), OptConfig())
    path = str(tmp_path / "s.npz")
    save_state(path, state)
    like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
    restored = load_state(path, like)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # elastic: restore onto an explicit (n,1) mesh with the param rules
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from repro.models import module as mod
    from repro.train.train_step import state_specs as sspecs

    shardings = sh.tree_shardings(sspecs(model), mesh, sh.PARAM_RULES)
    resharded = load_state(path, like, shardings=shardings)
    assert jax.tree.leaves(resharded)[0].sharding.mesh.shape["data"] == 1


def test_divisibility_guard():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # 8 kv heads on 1-way axis: fine; simulate 16-way via fake mesh is heavy,
    # so test the pure function directly with a fabricated mesh-shape stub
    spec = sh.partition_spec((8, 128), ("kv_heads", "mlp"), mesh, sh.ACT_RULES)
    assert spec == jax.sharding.PartitionSpec("model", None) or spec is not None


def test_rule_table_guards_non_divisible():
    import math

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    spec = sh.partition_spec((8, 4096), ("kv_heads", "kv_seq"), FakeMesh(), sh.ACT_RULES)
    assert spec[0] is None, "8 kv heads must not shard over 16-way model axis"
    assert spec[1] == "model"
    spec2 = sh.partition_spec((50280,), ("vocab",), FakeMesh(), sh.ACT_RULES)
    assert spec2[0] is None, "non-divisible vocab must fall back to replication"
    spec3 = sh.partition_spec((256, 4096), ("batch", "seq"), FakeMesh(), sh.ACT_RULES)
    assert spec3[0] == "data"
