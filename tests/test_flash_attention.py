"""Flash attention (pure-jax custom_vjp) vs materialized-softmax oracle:
forward + gradients, sweeping shapes, GQA ratios, causal/window."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L


def _mk(b, sq, skv, hq, hkv, hd, seed=0, dtype=jnp.float32):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((b, sq, hq, hd)), dtype)
    k = jnp.asarray(rng.standard_normal((b, skv, hkv, hd)), dtype)
    v = jnp.asarray(rng.standard_normal((b, skv, hkv, hd)), dtype)
    return q, k, v


@pytest.mark.parametrize(
    "b,s,hq,hkv,hd,causal,window,chunk",
    [
        (2, 64, 4, 4, 16, True, None, 16),
        (2, 64, 4, 2, 16, True, None, 16),
        (1, 96, 8, 1, 8, True, None, 32),   # MQA, non-divisible pad (96 % 32 == 0)
        (2, 60, 4, 2, 16, True, None, 16),  # skv % chunk != 0 -> padding
        (2, 64, 4, 2, 16, False, None, 16),  # non-causal (encoder/cross)
        (2, 64, 4, 2, 16, True, 24, 16),    # sliding window
        (1, 128, 2, 2, 32, True, 32, 64),
    ],
)
def test_flash_forward_matches_reference(b, s, hq, hkv, hd, causal, window, chunk):
    q, k, v = _mk(b, s, s, hq, hkv, hd)
    out = L.attention_chunked(q, k, v, causal=causal, window=window, chunk=chunk)
    ref = L.attention_reference(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize(
    "hq,hkv,causal,window",
    [(4, 4, True, None), (4, 2, True, None), (4, 1, True, 24), (4, 2, False, None)],
)
def test_flash_grads_match_reference(hq, hkv, causal, window):
    b, s, hd = 2, 48, 16
    q, k, v = _mk(b, s, s, hq, hkv, hd, seed=3)

    def f_flash(q, k, v):
        return jnp.sum(
            L.attention_chunked(q, k, v, causal=causal, window=window, chunk=16) ** 2
        )

    def f_ref(q, k, v):
        return jnp.sum(L.attention_reference(q, k, v, causal=causal, window=window) ** 2)

    g_flash = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(g_flash, g_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=5e-2, atol=5e-2)


def test_flash_bf16_grads_finite():
    q, k, v = _mk(2, 64, 64, 4, 2, 16, seed=5, dtype=jnp.bfloat16)
    g = jax.grad(
        lambda q, k, v: jnp.sum(
            L.attention_chunked(q, k, v, causal=True, chunk=16).astype(jnp.float32)
        ),
        argnums=(0, 1, 2),
    )(q, k, v)
    for t in g:
        assert np.isfinite(np.asarray(t, np.float32)).all()
