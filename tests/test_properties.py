"""Property-based tests (hypothesis) on the system's invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import schema as S
from repro.core.dedup.minhash import (
    jaccard, lsh_bands, make_permutations, shingle_hashes, signature_ref,
)
from repro.core.dedup.unionfind import BalancedUnionFind, naive_components, partitioned_union
from repro.core.fusion import harmonic_speed
from repro.core.recipes import parse_simple_yaml
from repro.data.packing import pack_documents
from repro.data.tokenizer import ByteTokenizer, HashWordTokenizer

TEXT = st.text(alphabet=st.characters(codec="utf-8", categories=("L", "N", "P", "Zs")),
               min_size=0, max_size=300)


# ---------------------------------------------------------------------------
# schema
# ---------------------------------------------------------------------------


@given(TEXT, st.integers(0, 3))
@settings(max_examples=50, deadline=None)
def test_schema_alignment_invariant(text, n_img):
    s = S.new_sample((S.IMAGE_TOKEN + " ") * n_img + text.replace(S.IMAGE_TOKEN, ""))
    s["images"] = [f"i{k}" for k in range(n_img)]
    ok, _ = S.check_alignment(s)
    assert ok
    e = S.empty_like(s)
    assert S.is_empty(e)
    ok_e, _ = S.check_alignment(e)
    assert ok_e  # empty samples are schema-valid


@given(TEXT, TEXT)
@settings(max_examples=50, deadline=None)
def test_alpaca_round_trip(q, r):
    s = S.new_sample("", query=q, response=r, history=[])
    back = S.from_alpaca(S.to_alpaca(s))
    assert back["query"] == q and back["response"] == r


# ---------------------------------------------------------------------------
# minhash: Pr[sig_a == sig_b] ~= jaccard(a, b)
# ---------------------------------------------------------------------------


@given(st.integers(0, 2**32 - 1))
@settings(max_examples=20, deadline=None)
def test_minhash_estimates_jaccard(seed):
    rng = np.random.default_rng(seed)
    base = rng.integers(0, 2**63, 200, dtype=np.uint64)
    overlap = rng.integers(10, 190)
    other = np.concatenate([base[:overlap],
                            rng.integers(0, 2**63, 200 - overlap, dtype=np.uint64)])
    true_j = jaccard(base, other)
    a, b = make_permutations(256, seed=7)
    sa, sb = signature_ref(base, a, b), signature_ref(other, a, b)
    est = float(np.mean(sa == sb))
    assert abs(est - true_j) < 0.15, (est, true_j)


@given(TEXT)
@settings(max_examples=50, deadline=None)
def test_identical_texts_identical_signatures(text):
    a, b = make_permutations(64)
    s1 = signature_ref(shingle_hashes(text), a, b)
    s2 = signature_ref(shingle_hashes(text), a, b)
    np.testing.assert_array_equal(s1, s2)
    keys = lsh_bands(np.stack([s1, s2]), 8)
    assert (keys[0] == keys[1]).all()


# ---------------------------------------------------------------------------
# union-find: all backends agree on connectivity
# ---------------------------------------------------------------------------


@given(st.integers(2, 60), st.lists(st.tuples(st.integers(0, 59), st.integers(0, 59)),
                                    max_size=80))
@settings(max_examples=60, deadline=None)
def test_union_find_backends_agree(n, edges):
    edges = [(a % n, b % n) for a, b in edges]
    uf = BalancedUnionFind(n)
    uf.add_edges(edges)
    c1 = uf.components()
    c2 = naive_components(n, edges)
    c3 = partitioned_union(n, edges, n_partitions=4).components()
    # same partition structure (labels may differ)
    for c_other in (c2, c3):
        for i in range(n):
            for j in range(i + 1, n):
                assert (c1[i] == c1[j]) == (c_other[i] == c_other[j]), (i, j)


# ---------------------------------------------------------------------------
# packing / tokenizers
# ---------------------------------------------------------------------------


@given(st.lists(st.lists(st.integers(0, 1000), min_size=0, max_size=50), max_size=8),
       st.integers(4, 32))
@settings(max_examples=60, deadline=None)
def test_packing_preserves_tokens(docs, seq_len):
    toks, labels, mask = pack_documents(docs, seq_len)
    stream = [t for d in docs for t in d]
    # next-token alignment: labels are tokens shifted by one in the stream
    flat_t = toks.reshape(-1)
    flat_l = labels.reshape(-1)
    flat_m = mask.reshape(-1)
    valid = flat_m > 0
    if valid.sum() > 0:
        n_valid = int(valid.sum())
        np.testing.assert_array_equal(flat_t[valid][:n_valid], stream[:n_valid])
        np.testing.assert_array_equal(flat_l[valid][:n_valid], stream[1 : n_valid + 1])
    assert toks.shape == labels.shape == mask.shape


@given(TEXT)
@settings(max_examples=50, deadline=None)
def test_byte_tokenizer_round_trip(text):
    tok = ByteTokenizer()
    assert tok.decode(tok.encode(text)) == text


@given(TEXT, st.integers(16, 1 << 16))
@settings(max_examples=50, deadline=None)
def test_hash_tokenizer_in_vocab(text, vocab):
    tok = HashWordTokenizer(vocab)
    ids = tok.encode(text)
    assert all(0 <= i < vocab for i in ids)
    assert tok.encode(text) == ids  # deterministic


# ---------------------------------------------------------------------------
# fusion math / recipe parser
# ---------------------------------------------------------------------------


@given(st.lists(st.floats(0.1, 1e6), min_size=1, max_size=8))
@settings(max_examples=60, deadline=None)
def test_harmonic_speed_bounds(speeds):
    v = harmonic_speed(speeds)
    assert v <= min(speeds) + 1e-6  # fused is never faster than slowest member
    assert v >= min(speeds) / len(speeds) - 1e-9


@given(st.dictionaries(st.sampled_from(["name", "np", "engine"]),
                       st.integers(0, 100), max_size=3))
@settings(max_examples=30, deadline=None)
def test_yaml_scalar_round_trip(d):
    text = "\n".join(f"{k}: {v}" for k, v in d.items())
    parsed = parse_simple_yaml(text)
    for k, v in d.items():
        assert parsed[k] == v
