"""Observability units (repro.core.obs + repro.core.clock +
repro.api.slo): fake-clock hermeticity, span lifecycle/tree/merge,
Chrome-trace export, bounded metrics + cross-process merge, SLO math, the
executor/dispatcher trace surfaces, shards="auto" resolution, and the
clock-discipline lint."""
import json
import os
import subprocess
import sys

import pytest

from repro.api.slo import compute_slo, percentile
from repro.core import clock, obs

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(autouse=True)
def _clean_obs():
    obs.reset()
    clock.reset()
    yield
    obs.reset()
    clock.reset()


@pytest.fixture
def fake():
    fc = clock.FakeClock()
    clock.install(fc)
    yield fc
    clock.reset()


# ---------------------------------------------------------------------------
# clock
# ---------------------------------------------------------------------------


def test_fake_clock_advances_wall_and_monotonic_together(fake):
    w0, m0 = clock.now(), clock.monotonic()
    fake.tick(2.5)
    assert clock.now() == pytest.approx(w0 + 2.5)
    assert clock.monotonic() == pytest.approx(m0 + 2.5)
    clock.reset()
    assert clock.now() != pytest.approx(w0 + 2.5)  # back on the system clock


def test_clock_lint_is_clean_and_catches_violations(tmp_path):
    tool = os.path.join(REPO, "tools", "check_clock.py")
    ok = subprocess.run([sys.executable, tool,
                         os.path.join(REPO, "src", "repro")],
                        capture_output=True, text=True)
    assert ok.returncode == 0, ok.stdout + ok.stderr

    bad = tmp_path / "pkg"
    bad.mkdir()
    (bad / "mod.py").write_text(
        "import time\nfrom time import monotonic\n"
        "def f():\n    return time.time() + monotonic()\n")
    hit = subprocess.run([sys.executable, tool, str(bad)],
                         capture_output=True, text=True)
    assert hit.returncode == 1
    assert "time.time()" in hit.stdout and "from time import monotonic" in hit.stdout


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------


def test_span_lifecycle_and_ambient_stack(fake):
    with obs.span("t1", "outer", kind="run") as sp:
        assert obs.current_span() is sp
        fake.tick(1.0)
        with obs.span("t1", "inner", kind="op") as child:
            assert child.parent_id == sp.span_id
            fake.tick(0.5)
    assert obs.current_span() is None
    spans = obs.drain("t1")
    by_name = {s["name"]: s for s in spans}
    assert by_name["outer"]["dur"] == pytest.approx(1.5)
    assert by_name["inner"]["dur"] == pytest.approx(0.5)
    tree = obs.span_tree(spans)
    assert len(tree["roots"]) == 1 and tree["orphans"] == []


def test_start_span_returns_none_when_disabled_or_traceless():
    assert obs.start_span(None, "x") is None
    obs.disable()
    try:
        assert obs.start_span("t", "x") is None
        with obs.span("t", "x") as sp:
            assert sp is None
    finally:
        obs.enable()
    assert obs.drain() == []


def test_span_end_is_idempotent(fake):
    sp = obs.start_span("t", "once")
    fake.tick(1.0)
    sp.end()
    fake.tick(5.0)
    sp.end()  # second end must not re-record or restamp
    spans = obs.drain("t")
    assert len(spans) == 1 and spans[0]["dur"] == pytest.approx(1.0)


def test_span_buffer_is_bounded(fake):
    for i in range(obs.MAX_SPANS + 10):
        obs.start_span("t", f"s{i}").end()
    assert len(obs.drain()) == obs.MAX_SPANS
    assert obs.tracer().dropped == 10


def test_merge_spans_dedupes_reexecuted_span_ids(fake):
    a1 = {"trace_id": "t", "span_id": "A", "parent_id": None,
          "name": "job", "kind": "job", "t0": 1.0, "dur": 0.5,
          "pid": 1, "tid": 0, "attrs": {"attempt": 1}}
    a2 = dict(a1, dur=2.0, attrs={"attempt": 2})  # re-lease re-emits A
    b = dict(a1, span_id="B", parent_id="A", t0=1.2, dur=0.1, attrs={})
    merged = obs.merge_spans([a1, b, a2])
    assert [s["span_id"] for s in merged] == ["A", "B"]
    assert merged[0]["attrs"]["attempt"] == 2, "last-writer (longer dur) wins"


def test_spill_and_merge_trace_roundtrip(fake, tmp_path):
    d = str(tmp_path / "obs")
    obs.configure(d)
    obs.start_span("t1", "root", kind="job").end()
    obs.start_span("t2", "other-trace").end()
    obs.flush()
    obs.flush()  # empty buffer: must not duplicate
    spans = obs.merge_trace(d, "t1")
    assert [s["name"] for s in spans] == ["root"]
    # torn tail line from a SIGKILLed process is skipped, not fatal
    spill = [f for f in os.listdir(d) if f.startswith("spans-")][0]
    with open(os.path.join(d, spill), "ab") as f:
        f.write(b'{"trace_id": "t1", "span')
    assert [s["name"] for s in obs.merge_trace(d, "t1")] == ["root"]


def test_chrome_trace_is_valid_catapult(fake):
    obs.start_span("t", "root", kind="job").set(n=1).end()
    doc = obs.chrome_trace(obs.drain("t"))
    doc = json.loads(json.dumps(doc))  # JSON-serializable end to end
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    ms = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    assert len(xs) == 1 and len(ms) == 1
    ev = xs[0]
    assert ev["name"] == "root" and ev["cat"] == "job"
    assert ev["dur"] > 0 and {"ts", "pid", "tid", "args"} <= set(ev)
    assert ev["args"]["trace_id"] == "t" and ev["args"]["n"] == 1


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


def test_metrics_snapshot_merge_and_percentile(tmp_path):
    m = obs.MetricsRegistry()
    m.inc("jobs_total", 2)
    m.gauge_max("peak_bytes", 100)
    m.gauge_max("peak_bytes", 50)  # max-merge: stays 100
    for v in (0.002, 0.002, 0.3, 0.3):
        m.observe("wait_seconds", v)
    snap = m.snapshot()
    assert snap["counters"]["jobs_total"] == 2
    assert snap["gauges"]["peak_bytes"] == 100
    h = snap["histograms"]["wait_seconds"]
    assert h["count"] == 4 and sum(h["counts"]) == 4

    other = {"counters": {"jobs_total": 3}, "gauges": {"peak_bytes": 70},
             "histograms": {"wait_seconds": dict(h)}, "dropped": 1}
    merged = obs.MetricsRegistry.merge([snap, other])
    assert merged["counters"]["jobs_total"] == 5
    assert merged["gauges"]["peak_bytes"] == 100
    assert merged["histograms"]["wait_seconds"]["count"] == 8
    assert merged["dropped"] == 1
    p50 = obs.histogram_percentile(merged["histograms"]["wait_seconds"], 0.5)
    p95 = obs.histogram_percentile(merged["histograms"]["wait_seconds"], 0.95)
    assert p50 <= 0.005 and p95 == pytest.approx(0.5), \
        "upper-edge rule: half the samples in the 5ms bucket, rest in 0.5s"


def test_metrics_registry_is_bounded():
    m = obs.MetricsRegistry()
    for i in range(obs.MAX_METRICS + 5):
        m.inc(f"c{i}")
    assert len(m.snapshot()["counters"]) == obs.MAX_METRICS
    assert m.dropped == 5
    m.inc("c0")  # existing names still update past the cap
    assert m.snapshot()["counters"]["c0"] == 2


def test_metrics_spill_files_merge_across_processes(tmp_path):
    d = str(tmp_path / "obs")
    m = obs.MetricsRegistry()
    m.inc("x")
    os.makedirs(d, exist_ok=True)
    m.flush(os.path.join(d, "metrics-111.json"))
    m.inc("x")
    m.flush(os.path.join(d, "metrics-222.json"))
    merged = obs.merged_metrics(d)
    assert merged["counters"]["x"] == 3  # 1 + 2 across "processes"


# ---------------------------------------------------------------------------
# SLO math
# ---------------------------------------------------------------------------


def test_percentile_nearest_rank():
    assert percentile([], 0.5) == 0.0
    assert percentile([3.0], 0.95) == 3.0
    xs = [float(i) for i in range(1, 11)]
    assert percentile(xs, 0.0) == 1.0
    assert percentile(xs, 0.5) == 5.0
    assert percentile(xs, 1.0) == 10.0


def test_percentile_even_length_true_nearest_rank():
    # the old int(round(q*(n-1))) formula hit Python's banker's rounding on
    # even-length inputs: round(1.5) == 2 gave p50([1,2,3,4]) == 3.0
    assert percentile([1.0, 2.0, 3.0, 4.0], 0.5) == 2.0
    assert percentile([1.0, 2.0], 0.5) == 1.0
    assert percentile([1.0, 2.0, 3.0, 4.0], 0.95) == 4.0
    assert percentile([1.0, 2.0, 3.0, 4.0], 0.25) == 1.0
    # nearest-rank p95 over 20 values is the 19th (index 18), not the 20th
    xs = [float(i) for i in range(1, 21)]
    assert percentile(xs, 0.95) == 19.0


def test_shard_task_predicate_is_strict():
    from repro.api.cluster import is_shard_task, parent_of
    from repro.api import shards

    # the reserved grammar
    for tid in ("job~s0", "job~s12", "job~r3", "job~fin", "a~s1~r2"):
        assert is_shard_task(tid), tid
    assert parent_of("job~s0") == "job"
    assert parent_of("a~s1~r2") == "a~s1"
    # user jobs that merely contain '~' are PLAIN jobs (the old `"~" in id`
    # predicate silently dropped them from SLO queue-wait counts)
    for jid in ("nightly~v2", "job~", "job~rat", "job~final", "job~s",
                "job~r", "job~s1b", None, ""):
        assert not is_shard_task(jid), jid
    assert parent_of("nightly~v2") == "nightly~v2"
    # shards.py re-exports the same predicate — one grammar, both modules
    assert shards.is_shard_task is is_shard_task


def test_compute_slo_counts_user_jobs_with_tilde():
    evs = [
        {"event": "submitted", "job_id": "nightly~v2", "ts": 10.0},
        {"event": "claimed", "job_id": "nightly~v2", "ts": 11.0,
         "runner_id": "r1"},
        {"event": "finished", "job_id": "nightly~v2", "ts": 12.0,
         "runner_id": "r1", "state": "succeeded", "n_out": 5, "seconds": 1.0},
    ]
    s = compute_slo(evs)
    assert s["queue_wait"]["n"] == 1
    assert s["queue_wait"]["p50"] == pytest.approx(1.0)
    assert s["jobs_finished"] == 1


def test_compute_slo_folds_event_log():
    evs = [
        {"event": "submitted", "job_id": "a", "ts": 10.0},
        {"event": "claimed", "job_id": "a", "ts": 10.5, "runner_id": "r1"},
        {"event": "submitted", "job_id": "b", "ts": 11.0},
        {"event": "claimed", "job_id": "b", "ts": 13.0, "runner_id": "r2"},
        {"event": "requeued_after_expiry", "job_id": "b", "ts": 14.0},
        # second claim after failover must NOT reset b's queue-wait
        {"event": "claimed", "job_id": "b", "ts": 14.5, "runner_id": "r1"},
        {"event": "finished", "job_id": "a", "ts": 20.0, "runner_id": "r1",
         "state": "succeeded", "n_out": 100, "seconds": 2.0,
         "redispatches": 1, "preempted": 0},
        {"event": "finished", "job_id": "b", "ts": 25.0, "runner_id": "r1",
         "state": "failed", "n_out": 0, "seconds": 1.0, "preempted": 2},
        # shard task: counts toward runner throughput, not queue-wait
        {"event": "submitted", "job_id": "b~s0", "ts": 14.6},
        {"event": "claimed", "job_id": "b~s0", "ts": 20.0, "runner_id": "r2"},
        {"event": "finished", "job_id": "b~s0", "ts": 24.0, "runner_id": "r2",
         "state": "succeeded", "n_out": 25, "seconds": 0.5},
    ]
    s = compute_slo(evs)
    assert s["queue_wait"]["n"] == 2
    assert s["queue_wait"]["p50"] == pytest.approx(0.5)
    assert s["queue_wait"]["p95"] == pytest.approx(2.0)
    assert s["failovers"] == 1 and s["preempted"] == 2
    assert s["jobs_finished"] == 2 and s["jobs_failed"] == 1
    assert s["throughput"]["r1"]["jobs"] == 2
    assert s["throughput"]["r2"]["rows"] == 25
    assert s["throughput"]["r2"]["rows_per_second"] == pytest.approx(50.0)


def test_compute_slo_per_tenant_breakdowns():
    evs = [
        {"event": "submitted", "job_id": "a", "ts": 0.0, "tenant": "alice"},
        {"event": "claimed", "job_id": "a", "ts": 1.0, "runner_id": "r1"},
        {"event": "submitted", "job_id": "b", "ts": 0.0, "tenant": "bob"},
        {"event": "claimed", "job_id": "b", "ts": 4.0, "runner_id": "r1"},
        # legacy event without a tenant field folds into the default tenant
        {"event": "submitted", "job_id": "c", "ts": 0.0},
        {"event": "claimed", "job_id": "c", "ts": 2.0, "runner_id": "r1"},
        {"event": "finished", "job_id": "a", "ts": 5.0, "runner_id": "r1",
         "state": "succeeded", "n_out": 40, "seconds": 2.0},
        {"event": "finished", "job_id": "b", "ts": 9.0, "runner_id": "r1",
         "state": "failed", "n_out": 0, "seconds": 1.0},
        # alice's shard task: rows fold into ALICE's throughput (via the
        # parent), never into queue-wait
        {"event": "submitted", "job_id": "a~s0", "ts": 5.0, "tenant": "alice"},
        {"event": "claimed", "job_id": "a~s0", "ts": 6.0, "runner_id": "r1"},
        {"event": "finished", "job_id": "a~s0", "ts": 8.0, "runner_id": "r1",
         "state": "succeeded", "n_out": 10, "seconds": 1.0},
    ]
    s = compute_slo(evs)
    t = s["tenants"]
    assert set(t) == {"alice", "bob", "default"}
    assert t["alice"]["queue_wait"]["n"] == 1
    assert t["alice"]["queue_wait"]["p95"] == pytest.approx(1.0)
    assert t["alice"]["jobs_finished"] == 1 and t["alice"]["jobs_failed"] == 0
    assert t["alice"]["rows"] == 50  # parent 40 + shard task 10
    assert t["alice"]["rows_per_second"] == pytest.approx(50 / 3.0)
    assert t["bob"]["queue_wait"]["p50"] == pytest.approx(4.0)
    assert t["bob"]["jobs_failed"] == 1
    assert t["default"]["queue_wait"]["n"] == 1
    # cluster-wide view is unchanged by the breakdown
    assert s["queue_wait"]["n"] == 3


def test_compute_slo_requeued_failover_job(fake, tmp_path):
    """A job claimed, lease-expired, and re-claimed counts ONE queue wait
    (submit -> FIRST claim) and one failover — driven through the real
    ClusterQueue event log under the fake clock, not a hand-built fixture."""
    from repro.api.cluster import ClusterQueue

    q = ClusterQueue(str(tmp_path / "cluster"), lease_ttl=1.0)
    jid = q.submit({"name": "r"}, job_id="flaky")
    fake.tick(2.0)
    lease1 = q.try_claim(jid, "r1", ttl=1.0)
    assert lease1 is not None and lease1.attempt == 1
    fake.tick(5.0)  # r1 dies: lease expires without a heartbeat
    lease2 = q.try_claim(jid, "r2", ttl=1.0)
    assert lease2 is not None and lease2.attempt == 2
    fake.tick(3.0)
    assert q.complete(lease2, "succeeded",
                      report={"n_out": 7, "seconds": 3.0})
    s = compute_slo(q.read_log())
    assert s["queue_wait"]["n"] == 1, "one wait despite two claims"
    assert s["queue_wait"]["max"] == pytest.approx(2.0), \
        "wait is submit -> FIRST claim; the re-claim is failover, not wait"
    assert s["failovers"] == 1
    assert s["jobs_finished"] == 1 and s["jobs_failed"] == 0
    assert s["throughput"]["r2"]["rows"] == 7
    assert s["tenants"]["default"]["jobs_finished"] == 1


# ---------------------------------------------------------------------------
# executor / dispatcher surfaces
# ---------------------------------------------------------------------------


def _run(tmp_path, engine="local", **kw):
    from repro.core.executor import Executor
    from repro.core.recipes import Recipe
    from repro.core.storage import write_jsonl
    from repro.data.synthetic import make_corpus

    src = str(tmp_path / "in.jsonl")
    write_jsonl(src, make_corpus(60, seed=3))
    r = Recipe(name="obs-run", dataset_path=src,
               export_path=str(tmp_path / "out.jsonl"),
               process=[{"name": "whitespace_normalization_mapper"},
                        {"name": "text_length_filter", "min_val": 1}],
               engine=engine, use_fusion=False, use_reordering=False, **kw)
    return Executor(r).run()


def test_run_report_carries_trace_with_op_spans(tmp_path):
    _, rep = _run(tmp_path)
    tr = rep.trace
    assert tr and tr["trace_id"] and tr["root_span"]
    spans = tr["spans"]
    kinds = sorted(s["kind"] for s in spans)
    assert kinds.count("run") == 1 and kinds.count("op") == 2
    tree = obs.span_tree(spans)
    assert tree["roots"] == [tr["root_span"]] and tree["orphans"] == []


def test_parallel_run_ships_block_spans_over_ipc(tmp_path):
    _, rep = _run(tmp_path, engine="parallel", np=2, block_bytes=2000)
    spans = rep.trace["spans"]
    kinds = {s["kind"] for s in spans}
    assert {"run", "op", "dispatch", "block"} <= kinds
    blocks = [s for s in spans if s["kind"] == "block"]
    dispatch = [s for s in spans if s["kind"] == "dispatch"]
    assert all(b["parent_id"] == dispatch[0]["span_id"] for b in blocks), \
        "worker-side block spans must parent to the driver's dispatch span"
    assert all("queue_wait" in b["attrs"] for b in blocks)
    assert obs.span_tree(spans)["orphans"] == []
    snap = obs.metrics().snapshot()
    assert snap["counters"].get("dispatch.blocks_total", 0) >= len(blocks)
    assert "dispatch.queue_wait_seconds" in snap["histograms"]


def test_tracing_disabled_run_has_no_trace(tmp_path):
    obs.disable()
    try:
        _, rep = _run(tmp_path)
        assert rep.trace is None
        assert obs.drain() == []
    finally:
        obs.enable()


# ---------------------------------------------------------------------------
# shards="auto"
# ---------------------------------------------------------------------------


def test_resolve_shard_count_auto_by_rows(monkeypatch):
    from repro.api.shards import resolve_shard_count

    monkeypatch.setenv("REPRO_SHARD_TARGET_ROWS", "100")
    n, decision = resolve_shard_count({"shards": "auto"}, n_rows=350)
    assert n == 4 and decision["by_rows"] == 4
    assert decision["requested"] == "auto" and decision["chosen"] == 4

    n, decision = resolve_shard_count({"shards": 7}, n_rows=350)
    assert n == 7 and decision is None, "explicit counts bypass auto-tuning"


def test_resolve_shard_count_auto_caps_at_fleet_capacity(monkeypatch):
    from repro.api.shards import resolve_shard_count

    class FakeQueue:
        def runner_cards(self, live_only=True):
            return [{"capacity": 2}, {"capacity": 1}]

    monkeypatch.setenv("REPRO_SHARD_TARGET_ROWS", "10")
    n, decision = resolve_shard_count({"shards": "auto"}, n_rows=10_000,
                                      queue=FakeQueue())
    assert decision["live_capacity"] == 3
    assert n == decision["cap"] == 6, "auto shards cap at 2x live capacity"
