"""Data-Juicer core system tests: schema, OPs, engines, executor, fault
tolerance, checkpointing, fusion/reordering, insight mining."""
import math
import os

import numpy as np
import pytest

from repro.core import schema as S
from repro.core.adapter import Adapter
from repro.core.dataset import DJDataset
from repro.core.engine import LocalEngine, ParallelEngine, ShardedEngine
from repro.core.executor import Executor
from repro.core.fusion import fuse_filters, harmonic_speed, optimize, reorder
from repro.core.ops_base import Filter, FusedOP, HumanOP, Mapper, ScriptOP
from repro.core.recipes import Recipe, parse_simple_yaml
from repro.core.registry import create_op, list_ops, op_info
from repro.data.synthetic import make_corpus


@pytest.fixture(scope="module")
def corpus():
    return make_corpus(400, seed=7)


def test_schema_alignment_and_empty():
    s = S.new_sample(f"{S.IMAGE_TOKEN} a cat {S.EOC} a dog {S.IMAGE_TOKEN}")
    s["images"] = ["a.png", "b.png"]
    ok, _ = S.check_alignment(s)
    assert ok
    s["images"] = ["a.png"]
    ok, why = S.check_alignment(s)
    assert not ok and "images" in why
    e = S.empty_like(s)
    assert S.is_empty(e) and e["text"] == "" and e["images"] == []


def test_registry_round_trip():
    ops = list_ops()
    assert len(ops) >= 30, f"expected a rich OP library, got {len(ops)}"
    op = create_op({"name": "text_length_filter", "min_val": 10, "max_val": 100})
    cfg = op.config()
    op2 = create_op(cfg)
    assert op2.params["min_val"] == 10
    info = op_info("text_length_filter")
    assert info["type"] == "Filter" and info["fusible"]


def test_basic_pipeline_chainable(corpus):
    ds = DJDataset.from_samples(corpus)
    op1 = create_op({"name": "whitespace_normalization_mapper"})
    op2 = create_op({"name": "text_length_filter", "min_val": 400})
    out = ds.process(op1).process(op2)
    assert 0 < len(out) < len(ds)
    assert all(len(s["text"]) >= 400 for s in out)
    out2 = ds.process([op1, op2])
    assert len(out2) == len(out)


def test_filter_stats_recorded(corpus):
    ds = DJDataset.from_samples(corpus[:50])
    out = ds.process(create_op({"name": "alnum_ratio_filter", "min_val": 0.0}))
    assert all("alnum_ratio" in s["stats"] for s in out)


def test_fault_tolerance_empty_samples(corpus):
    class Bomb(Mapper):
        _name = "bomb"

        def process_single(self, s):
            if "juicer" in s.get("text", ""):
                raise RuntimeError("boom")
            return s

    ds = DJDataset.from_samples(corpus[:100])
    op = Bomb()
    out = ds.process(op, drop_empty=True)
    assert len(op.errors) > 0, "expected some failures"
    assert len(out) == 100 - len(op.errors)
    # keep_failed path: empties preserved
    out2 = DJDataset.from_samples(corpus[:100]).process(Bomb(), drop_empty=False)
    empties = [s for s in out2 if S.is_empty(s)]
    assert len(empties) > 0


def test_dedup_removes_duplicates(corpus):
    ds = DJDataset.from_samples(corpus)
    n0 = len(ds)
    out = ds.process(create_op({"name": "document_minhash_deduplicator",
                                "jaccard_threshold": 0.6}))
    kinds = [s["meta"].get("kind") for s in out]
    assert len(out) < n0
    # exact duplicates must be gone entirely
    texts = [s["text"] for s in out]
    assert len(set(texts)) == len(texts)


def test_grouper_aggregator(corpus):
    ds = DJDataset.from_samples(corpus[:60])
    g = create_op({"name": "key_value_grouper", "key": "domain"})
    a = create_op({"name": "keyword_summary_aggregator", "top_k": 5})
    out = ds.process([g, a])
    assert 1 <= len(out) <= 4
    assert all(s["text"].startswith("summary keywords:") for s in out)


def test_script_op_and_fused_op(corpus):
    ds = DJDataset.from_samples(corpus[:40])
    sop = ScriptOP(fn=lambda s: {**s, "text": s["text"][:10]})
    f1 = create_op({"name": "text_length_filter", "min_val": 5})
    fused = FusedOP([f1, sop])
    out = ds.process(fused)
    assert all(len(s["text"]) <= 10 for s in out)


def test_human_op_async():
    h = HumanOP(annotator=lambda s: {"label": "good" if len(s["text"]) > 5 else "bad"})
    h.submit([S.new_sample("long enough text"), S.new_sample("hi")])
    assert h.poll(max_items=1) == 1
    got = h.collect()
    assert len(got) == 1 and got[0]["meta"]["human"]["label"] == "good"
    h.poll()
    assert len(h.collect()) == 1


def test_parallel_engine_matches_local(corpus):
    cfgs = [{"name": "whitespace_normalization_mapper"},
            {"name": "words_num_filter", "min_val": 5}]
    ops_l = [create_op(c) for c in cfgs]
    ops_p = [create_op(c) for c in cfgs]
    local = DJDataset.from_samples(corpus, LocalEngine()).process(ops_l)
    par = DJDataset.from_samples(corpus, ParallelEngine(n_workers=2)).process(ops_p)
    assert sorted(s["text"] for s in local) == sorted(s["text"] for s in par)


def test_sharded_engine_vectorized(corpus):
    op = create_op({"name": "text_length_filter", "min_val": 50})
    eng = ShardedEngine()
    out = DJDataset.from_samples(corpus, eng).process(op)
    ref = DJDataset.from_samples(corpus, LocalEngine()).process(
        create_op({"name": "text_length_filter", "min_val": 50}))
    assert sorted(s["text"] for s in out) == sorted(s["text"] for s in ref)


def test_fusion_and_reorder():
    f_fast = create_op({"name": "text_length_filter", "min_val": 1})
    f_slow = create_op({"name": "word_repetition_filter", "max_val": 0.9})
    m = create_op({"name": "lowercase_mapper"})
    f_fast.probed_speed, f_slow.probed_speed = 1000.0, 10.0
    plan = fuse_filters([f_fast, f_slow, m])
    assert isinstance(plan[0], FusedOP) and plan[1] is m
    ordered = reorder([f_slow, f_fast])
    assert ordered[0] is f_fast, "faster op must run first"
    assert math.isclose(harmonic_speed([1000, 10]), 1 / (1 / 1000 + 1 / 10))


def test_adapter_probe_and_plan(corpus):
    ops = [create_op({"name": "text_length_filter", "min_val": 10}),
           create_op({"name": "word_repetition_filter", "max_val": 1.0})]
    ad = Adapter(cpu_budget=4, mem_budget=1 << 30)
    probes = ad.probe_small_batch(corpus, ops, cap=100)
    assert all(p.speed > 0 for p in probes.values())
    plan = ad.resource_plan(ops[0])
    assert 1 <= plan.n_procs <= 4


def test_executor_end_to_end(tmp_path, corpus):
    from repro.core.storage import write_jsonl

    src = str(tmp_path / "in.jsonl")
    write_jsonl(src, corpus)
    recipe = Recipe(
        name="t", dataset_path=src, export_path=str(tmp_path / "out.jsonl"),
        process=[
            {"name": "whitespace_normalization_mapper"},
            {"name": "text_length_filter", "min_val": 30},
            {"name": "alnum_ratio_filter", "min_val": 0.6},
            {"name": "document_minhash_deduplicator", "jaccard_threshold": 0.6},
        ],
        insight=True,
    )
    ds, report = Executor(recipe).run()
    assert report.n_out < report.n_in
    assert os.path.exists(tmp_path / "out.jsonl")
    assert "insight" in report.insight or report.insight
    assert len(report.per_op) >= 3


def test_checkpoint_resume(tmp_path, corpus):
    from repro.core.storage import write_jsonl

    src = str(tmp_path / "in.jsonl")
    write_jsonl(src, corpus[:100])
    procs = [
        {"name": "whitespace_normalization_mapper"},
        {"name": "text_length_filter", "min_val": 30},
    ]
    recipe = Recipe(name="t", dataset_path=src, process=procs,
                    checkpoint_dir=str(tmp_path / "ckpt"),
                    use_fusion=False, use_reordering=False)
    _, rep1 = Executor(recipe).run()
    assert rep1.resumed_at == 0
    # second run resumes from the final stage (all ops skipped)
    _, rep2 = Executor(recipe).run()
    assert rep2.resumed_at == len(procs)
    assert rep2.n_out == rep1.n_out


def test_yaml_recipe_parse():
    text = """
name: demo
np: 4
engine: parallel
process:
  - text_length_filter:
      min_val: 10
      max_val: 10000
  - lowercase_mapper
"""
    d = parse_simple_yaml(text)
    r = Recipe.from_dict(d)
    assert r.np == 4 and r.engine == "parallel"
    assert r.process[0]["name"] == "text_length_filter"
    assert r.process[0]["min_val"] == 10
    assert r.process[1]["name"] == "lowercase_mapper"


def test_insight_mining(corpus):
    from repro.core.insight import InsightMiner

    miner = InsightMiner()
    ds = DJDataset.from_samples(corpus)
    miner.record("load", ds.samples())
    ds = ds.process(create_op({"name": "text_length_filter", "min_val": 200}))
    miner.record("text_length_filter", ds.samples())
    diffs = miner.diffs()
    assert diffs and diffs[0]["volume"][0] > diffs[0]["volume"][1]
    assert isinstance(miner.report(), str)
