"""Logical-plan IR + rule optimizer (ISSUE 9 tentpole).

Covers: typed node construction and column deps, Recipe<->IR round-trip,
rule pipeline == historical list-level optimizer (byte-compat contract),
per-rule rewrite logging, annotation/runtime parity, and the per-rule
byte-identity properties (rule applied vs not) on seeded-random pipelines.
A hypothesis variant of the byte-identity property runs where hypothesis
is installed; the seeded-random variants always run.
"""
import json
import os
import random

import pytest

from repro.core.fusion import fuse_filters, plan_segments, reorder
from repro.core.plan import LogicalPlan, column_deps, kind_of_config
from repro.core.recipes import Recipe
from repro.core.registry import create_op
from repro.core.rules import RULE_NAMES, annotate_plan, optimize_plan

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


CHAIN = [
    {"name": "whitespace_normalization_mapper"},
    {"name": "text_length_filter", "min_val": 5},
    {"name": "words_num_filter", "min_val": 1},
    {"name": "exact_text_deduplicator"},
    {"name": "topk_stat_selector", "stat_key": "num_words", "fraction": 0.9},
]


def _write_corpus(path, n=60, seed=7):
    rng = random.Random(seed)
    words = "alpha beta gamma delta epsilon zeta eta theta iota kappa".split()
    with open(path, "w", encoding="utf-8") as f:
        for i in range(n):
            text = " ".join(rng.choice(words)
                            for _ in range(rng.randrange(1, 40)))
            f.write(json.dumps({"text": text, "meta": {"i": i}}) + "\n")
    return path


# ---------------------------------------------------------------------------
# IR construction
# ---------------------------------------------------------------------------


def test_node_kinds_and_column_deps():
    plan = LogicalPlan.from_op_configs(CHAIN)
    assert [n.kind for n in plan.nodes] == [
        "map", "filter", "filter", "dedup", "select"]
    tl = plan.nodes[1]
    reads, writes = column_deps(tl.bind())
    assert reads == ("text",) and writes == ("stats.text_len",)
    sel_reads, sel_writes = column_deps(plan.nodes[4].bind())
    assert sel_reads == ("stats.num_words",) and sel_writes == ()
    assert kind_of_config({"name": "fused_op", "ops": CHAIN[1:3]}) == "filter"


def test_plan_is_immutable_and_validates():
    plan = LogicalPlan.from_op_configs(CHAIN[:2])
    p2 = plan.with_op({"name": "words_num_filter", "min_val": 2})
    assert len(plan.nodes) == 2 and len(p2.nodes) == 3
    with pytest.raises(KeyError):
        plan.with_op({"name": "no_such_op"})
    with pytest.raises(TypeError):
        plan.with_op({"name": "words_num_filter", "mn_val": 2})
    with pytest.raises(TypeError):
        plan.with_options(no_such_option=1)


def test_recipe_ir_round_trip():
    r = Recipe(name="rt", dataset_path="d.jsonl", export_path="o.jsonl",
               np=2, engine="parallel", process=[dict(c) for c in CHAIN])
    plan = LogicalPlan.from_recipe(r)
    back = plan.to_recipe(name="rt")
    assert back == r


def test_describe_nodes_carry_ir_metadata(tmp_path):
    src = _write_corpus(str(tmp_path / "in.jsonl"))
    plan = LogicalPlan.from_recipe(Recipe(
        dataset_path=src, export_path=str(tmp_path / "out.jsonl"),
        process=[dict(c) for c in CHAIN[1:3]]))  # filters first: prefix marks
    nodes = annotate_plan(plan).describe()
    assert nodes[0]["kind"] == "source" and nodes[0]["format"] == "jsonl"
    assert nodes[-1]["kind"] == "sink"
    tl = next(n for n in nodes if n["name"] == "text_length_filter")
    assert tl["reads"] == ["text"] and tl["writes"] == ["stats.text_len"]
    assert tl.get("columnar") and tl.get("pushdown")


# ---------------------------------------------------------------------------
# rules == historical kernel sequence (byte-compat contract)
# ---------------------------------------------------------------------------


def _random_chain(rng):
    pool = [
        lambda: {"name": "text_length_filter",
                 "min_val": rng.randrange(0, 30)},
        lambda: {"name": "words_num_filter", "min_val": rng.randrange(0, 5)},
        lambda: {"name": "alnum_ratio_filter", "min_val": 0.0},
        lambda: {"name": "char_repetition_filter", "max_val": 0.9},
        lambda: {"name": "stopword_ratio_filter", "max_val": 1.0},
        lambda: {"name": "whitespace_normalization_mapper"},
        lambda: {"name": "lowercase_mapper"},
    ]
    return [rng.choice(pool)() for _ in range(rng.randrange(2, 7))]


def _fake_probes(cfgs, rng):
    # synthetic probe speeds keyed the way Adapter.probes are (op name)
    names = {c["name"] for c in cfgs}
    return {n: type("P", (), {"speed": rng.uniform(10.0, 10000.0),
                              "keep_ratio": rng.uniform(0.1, 1.0)})()
            for n in names}


def test_optimize_plan_matches_legacy_kernel_sequence():
    rng = random.Random(11)
    for _ in range(25):
        cfgs = _random_chain(rng)
        probes = _fake_probes(cfgs, rng)
        ops = [create_op(dict(c)) for c in cfgs]
        plan, _ = optimize_plan(LogicalPlan.from_ops(ops), probes)
        # the historical sequence on the SAME instances
        legacy = reorder(fuse_filters(reorder(ops, probes)), probes)
        assert [o.config() for o in plan.ops()] == \
            [o.config() for o in legacy]


def test_optimize_plan_preserves_op_instances():
    ops = [create_op(dict(c)) for c in CHAIN]
    plan, _ = optimize_plan(LogicalPlan.from_ops(ops))
    flat = []
    for op in plan.ops():
        flat.extend(getattr(op, "ops", [op]))
    # probed instances survive rewrites (their measured speeds stay attached)
    assert {id(o) for o in flat} == {id(o) for o in ops}


def test_rewrite_log_shape_and_order():
    ops = [create_op(dict(c)) for c in CHAIN]
    _, rewrites = optimize_plan(LogicalPlan.from_ops(ops))
    assert [rw.rule for rw in rewrites] == [
        "probe_cost_reorder", "filter_fusion", "probe_cost_reorder",
        "predicate_pushdown", "columnar_prefix"]
    assert all(rw.rule in RULE_NAMES for rw in rewrites)
    fusion_rw = rewrites[1]
    assert fusion_rw.changed
    assert any(name.startswith("fused<") for name in fusion_rw.after)
    assert fusion_rw.detail["fused"]
    d = fusion_rw.to_dict()
    assert set(d) == {"rule", "before", "after", "changed", "detail"}
    assert rewrites[2].detail.get("pass") == 2


def test_annotation_matches_runtime_segments():
    """The pushdown/columnar marks must agree with what plan_segments (the
    runtime source of truth) decides for the same op chain."""
    rng = random.Random(23)
    for _ in range(25):
        cfgs = _random_chain(rng) + [{"name": "exact_text_deduplicator"}] \
            + _random_chain(rng)
        plan = annotate_plan(LogicalPlan.from_op_configs(cfgs))
        segments = plan_segments(plan.ops())
        marked = [n.name for n in plan.nodes if n.pushdown]
        expected = []
        for seg in segments:
            if not seg.barrier and not seg.stateful:
                expected.extend(o.name for o in seg.ops[: seg.n_pushdown])
        assert marked == expected


# ---------------------------------------------------------------------------
# per-rule byte-identity (rule applied vs not)
# ---------------------------------------------------------------------------


def _export_bytes(tmp_path, tag, src, cfgs, use_fusion, use_reordering):
    from repro.core.executor import Executor

    out = str(tmp_path / f"{tag}.jsonl")
    r = Recipe(dataset_path=src, export_path=out,
               process=[dict(c) for c in cfgs],
               use_fusion=use_fusion, use_reordering=use_reordering)
    _, report = Executor(r).run()
    with open(out, "rb") as f:
        return f.read(), report


def _row_key(line):
    row = json.loads(line)
    stats = row.pop("stats", None)
    return json.dumps({**row, "stats": dict(sorted(stats.items()))
                       if stats else stats}, sort_keys=True)


def _check_rules_preserve_bytes(tmp_path, seed):
    rng = random.Random(seed)
    src = _write_corpus(str(tmp_path / f"in{seed}.jsonl"), seed=seed)
    cfgs = _random_chain(rng)

    base, _ = _export_bytes(tmp_path, f"b{seed}", src, cfgs, False, False)
    # filter_fusion (+ the annotation rules) on vs off: byte-identical —
    # a FusedOP cascades stats in chain order, so bytes can't move
    fused, _ = _export_bytes(tmp_path, f"f{seed}", src, cfgs, True, False)
    assert fused == base

    # probe_cost_reorder permutes stat-insertion order, so its guarantee is
    # (a) identical row CONTENT vs unoptimized, (b) byte-identical to a
    # hand-built pipeline submitted in the already-reordered order
    reordered, report = _export_bytes(tmp_path, f"r{seed}", src, cfgs,
                                      False, True)
    assert sorted(map(_row_key, reordered.splitlines())) == \
        sorted(map(_row_key, base.splitlines()))
    by_name = {c["name"]: c for c in cfgs}
    pre_permuted = [dict(by_name[name]) for name in report.plan]
    direct, _ = _export_bytes(tmp_path, f"d{seed}", src, pre_permuted,
                              False, False)
    assert reordered == direct


def test_rules_preserve_bytes_seeded(tmp_path):
    for seed in (3, 17, 41):
        _check_rules_preserve_bytes(tmp_path, seed)


if HAVE_HYPOTHESIS:

    @given(st.integers(0, 10_000))
    @settings(max_examples=10, deadline=None)
    def test_rules_preserve_bytes_property(tmp_path_factory, seed):
        _check_rules_preserve_bytes(
            tmp_path_factory.mktemp(f"prop{seed}"), seed)


# ---------------------------------------------------------------------------
# executor surfaces
# ---------------------------------------------------------------------------


def test_explain_exposes_nodes_and_rewrites(tmp_path):
    import repro.api as dj

    src = _write_corpus(str(tmp_path / "in.jsonl"))
    info = (dj.read_jsonl(src)
            .filter("words_num_filter", min_val=2)
            .filter("text_length_filter", min_val=5)
            .write_jsonl(str(tmp_path / "out.jsonl"))
            .explain())
    kinds = [n["kind"] for n in info["nodes"]]
    assert kinds[0] == "source" and kinds[-1] == "sink"
    assert [rw["rule"] for rw in info["rewrites"]] == [
        "probe_cost_reorder", "filter_fusion", "probe_cost_reorder",
        "predicate_pushdown", "columnar_prefix"]
    assert any(rw["changed"] for rw in info["rewrites"])
    # optimized chain in explain == the IR's op nodes
    op_names = [n["name"] for n in info["nodes"]
                if n["kind"] not in ("source", "sink")]
    assert op_names == info["plan"]


def test_plan_optimize_span_records_rewrites(tmp_path):
    from repro.core import obs
    from repro.core.executor import Executor

    src = _write_corpus(str(tmp_path / "in.jsonl"))
    r = Recipe(dataset_path=src, export_path=str(tmp_path / "out.jsonl"),
               process=[{"name": "words_num_filter", "min_val": 1},
                        {"name": "text_length_filter", "min_val": 5}])
    obs.reset()
    _, report = Executor(r).run()
    spans = [s for s in report.trace["spans"]
             if s["name"] == "plan:optimize"]
    assert spans, "plan:optimize span must be emitted on optimized runs"
    root = report.trace["root_span"]
    assert spans[0]["parent_id"] == root  # nested under the run span
    rules = [rw["rule"] for rw in spans[0]["attrs"]["rules"]]
    assert rules == ["probe_cost_reorder", "filter_fusion",
                     "probe_cost_reorder", "predicate_pushdown",
                     "columnar_prefix"]


def test_fixed_plan_skips_optimizer_and_replays_verbatim(tmp_path):
    from repro.core.executor import Executor

    src = _write_corpus(str(tmp_path / "in.jsonl"))
    pinned = [{"name": "text_length_filter", "min_val": 5},
              {"name": "words_num_filter", "min_val": 1}]
    r = Recipe(dataset_path=src, export_path=str(tmp_path / "out.jsonl"),
               process=[{"name": "lowercase_mapper"}],  # ignored when pinned
               fixed_plan=[dict(c) for c in pinned])
    ex = Executor(r)
    _, report = ex.run()
    assert report.plan == ["text_length_filter", "words_num_filter"]
    assert ex.last_rewrites == []  # no optimizer pass on pinned plans
    assert os.path.exists(str(tmp_path / "out.jsonl"))
