"""Streaming block-pipelined execution path: segment planning, streaming vs
barriered equivalence, bounded prefetch, engine chain dispatch, and the
executor's automatic path selection."""
import os

import pytest

from repro.core.dataset import DJDataset, stream_segments
from repro.core.engine import LocalEngine, ParallelEngine, run_chain
from repro.core.executor import Executor
from repro.core.fusion import Segment, is_barrier_op, plan_segments
from repro.core.recipes import Recipe
from repro.core.registry import create_op
from repro.core.storage import (
    BlockPrefetcher, BlockWriter, SampleBlock, iter_sample_blocks,
    read_jsonl, write_jsonl,
)
from repro.data.synthetic import make_corpus


@pytest.fixture(scope="module")
def corpus():
    return make_corpus(300, seed=13)


MIXED = [
    {"name": "whitespace_normalization_mapper"},
    {"name": "text_length_filter", "min_val": 30},
    {"name": "document_minhash_deduplicator", "jaccard_threshold": 0.6},
    {"name": "alnum_ratio_filter", "min_val": 0.6},
]


# ---------------------------------------------------------------------------
# segment planning
# ---------------------------------------------------------------------------


def test_plan_segments_around_barriers():
    ops = [create_op(c) for c in MIXED]
    segs = plan_segments(ops)
    assert [s.barrier for s in segs] == [False, True, False]
    assert [len(s) for s in segs] == [2, 1, 1]
    assert is_barrier_op(segs[1].ops[0])
    # all-pipelineable plan collapses to one segment
    segs2 = plan_segments([ops[0], ops[1], ops[3]])
    assert len(segs2) == 1 and not segs2[0].barrier and len(segs2[0]) == 3
    # leading/trailing barriers become their own segments
    segs3 = plan_segments([ops[2], ops[0], ops[2]])
    assert [s.barrier for s in segs3] == [True, False, True]
    assert plan_segments([]) == []


# ---------------------------------------------------------------------------
# streaming == barriered on a mixed recipe (mapper -> filter -> dedup -> filter)
# ---------------------------------------------------------------------------


def test_streaming_matches_barriered(tmp_path, corpus):
    src = str(tmp_path / "in.jsonl")
    write_jsonl(src, corpus)
    r_s = Recipe(name="s", dataset_path=src, export_path=str(tmp_path / "s.jsonl"),
                 process=MIXED, block_bytes=4096)
    ds_s, rep_s = Executor(r_s).run()
    assert rep_s.streaming, "run() must auto-select streaming"
    r_b = Recipe(name="b", dataset_path=src, export_path=str(tmp_path / "b.jsonl"),
                 process=MIXED, block_bytes=4096)
    ds_b, rep_b = Executor(r_b).run_barriered()
    assert rep_s.n_out == rep_b.n_out > 0
    with open(tmp_path / "s.jsonl", "rb") as f_s, open(tmp_path / "b.jsonl", "rb") as f_b:
        assert f_s.read() == f_b.read(), "exports must be byte-identical"
    # per-op lineage survives aggregation across blocks
    assert [e["op"] for e in rep_s.per_op] == rep_s.plan
    assert rep_s.per_op[0]["in"] == rep_s.n_in
    assert rep_s.per_op[-1]["out"] == rep_s.n_out


def test_process_streaming_matches_process(corpus):
    ds = DJDataset.from_samples(corpus, n_blocks_hint=6)
    ops_a = [create_op(c) for c in MIXED]
    ops_b = [create_op(c) for c in MIXED]
    mon = []
    out_s = ds.process_streaming(ops_a, monitor=mon)
    out_b = DJDataset.from_samples(corpus, n_blocks_hint=6).process(ops_b)
    assert [s["text"] for s in out_s] == [s["text"] for s in out_b]
    assert len(mon) == len(MIXED)


def test_parallel_chain_matches_local(corpus):
    ops_cfg = [{"name": "whitespace_normalization_mapper"},
               {"name": "words_num_filter", "min_val": 5}]
    blocks = DJDataset.from_samples(corpus, n_blocks_hint=4).blocks
    loc = list(LocalEngine().map_block_chain([create_op(c) for c in ops_cfg], blocks))
    par = list(ParallelEngine(n_workers=2).map_block_chain(
        [create_op(c) for c in ops_cfg], iter(blocks)))
    assert [s["text"] for b, _ in loc for s in b.samples] == \
           [s["text"] for b, _ in par for s in b.samples]
    # per-block stats carry every op of the chain
    assert all([st["op"] for st in stats] == [c["name"] for c in ops_cfg]
               for _, stats in par)


def test_run_chain_equivalent_to_sequential_ops(corpus):
    ops = [create_op({"name": "lowercase_mapper"}),
           create_op({"name": "text_length_filter", "min_val": 100})]
    out, stats = run_chain(ops, [dict(s) for s in corpus[:50]])
    ref = DJDataset.from_samples(corpus[:50]).process(
        [create_op({"name": "lowercase_mapper"}),
         create_op({"name": "text_length_filter", "min_val": 100})])
    assert [s["text"] for s in out] == [s["text"] for s in ref]
    assert stats[0]["in"] == 50 and stats[-1]["out"] == len(out)


# ---------------------------------------------------------------------------
# bounded prefetch
# ---------------------------------------------------------------------------


def test_prefetch_queue_bounded(corpus):
    import time

    blocks = [SampleBlock([dict(s) for s in corpus[i:i + 10]])
              for i in range(0, len(corpus), 10)]
    assert len(blocks) >= 8
    pf = BlockPrefetcher(iter(blocks), depth=3)
    seen = []
    for blk in pf:
        time.sleep(0.002)  # slow consumer: producer must hit the cap, not blow it
        seen.append(len(blk))
    assert sum(seen) == len(corpus)
    assert 0 < pf.max_depth <= 3, f"queue depth {pf.max_depth} exceeded cap 3"


def test_prefetch_close_releases_fill_thread():
    def endless():
        while True:
            yield SampleBlock([{"text": "x"}])

    pf = BlockPrefetcher(endless(), depth=2)
    it = iter(pf)
    next(it)
    it.close()  # abandon mid-stream — must not leave the fill thread stuck
    pf._t.join(timeout=2)
    assert not pf._t.is_alive(), "fill thread leaked after consumer abandoned"


def test_duplicate_op_instances_keep_separate_entries(corpus):
    ops = [create_op({"name": "text_length_filter", "min_val": 10}),
           create_op({"name": "text_length_filter", "max_val": 10_000_000})]
    mon = []
    DJDataset.from_samples(corpus[:50], n_blocks_hint=4).process_streaming(ops, monitor=mon)
    assert len(mon) == 2, "same-named ops must not merge into one monitor entry"
    assert all(e["op"] == "text_length_filter" for e in mon)


def test_prefetch_propagates_source_errors():
    def bad_source():
        yield SampleBlock([{"text": "x"}])
        raise RuntimeError("decode failed")

    pf = BlockPrefetcher(bad_source(), depth=2)
    with pytest.raises(RuntimeError, match="decode failed"):
        list(pf)


# ---------------------------------------------------------------------------
# block source / sink
# ---------------------------------------------------------------------------


def test_iter_sample_blocks_streams_and_splits(tmp_path, corpus):
    src = str(tmp_path / "in.jsonl")
    write_jsonl(src, corpus)
    blocks = list(iter_sample_blocks(src, block_bytes=8192))
    assert len(blocks) >= 8
    assert sum(len(b) for b in blocks) == len(corpus)
    assert [s["meta"]["id"] for b in blocks for s in b.samples] == \
           [s["meta"]["id"] for s in corpus]


def test_block_writer_streams_to_disk(tmp_path, corpus):
    out = str(tmp_path / "out.jsonl")
    blocks = list(iter_sample_blocks(iter(corpus[:40]), block_bytes=4096))
    with BlockWriter(out) as w:
        for b in blocks:
            w.write_block(b)
    assert w.n == 40
    assert [s["meta"]["id"] for s in read_jsonl(out)] == \
           [s["meta"]["id"] for s in corpus[:40]]


# ---------------------------------------------------------------------------
# executor policy + segment-boundary checkpointing
# ---------------------------------------------------------------------------


def test_run_auto_selection(tmp_path, corpus):
    src = str(tmp_path / "in.jsonl")
    write_jsonl(src, corpus[:60])
    base = dict(dataset_path=src, process=MIXED[:2])
    assert Executor(Recipe(name="a", **base)).streaming_eligible()
    # insight now rides the stream (SegmentInsightRecorder) — only
    # operator-level checkpointing still forces the barriered path
    assert Executor(Recipe(name="b", insight=True, **base)).streaming_eligible()
    assert not Executor(
        Recipe(name="c", checkpoint_dir=str(tmp_path / "ck"), **base)).streaming_eligible()
    _, rep = Executor(Recipe(name="d", insight=True, **base)).run()
    assert rep.streaming and rep.insight
    assert "load ->" in rep.insight, "per-segment timeline must start at load"


def test_streaming_checkpoint_at_segment_boundaries(tmp_path, corpus):
    src = str(tmp_path / "in.jsonl")
    write_jsonl(src, corpus[:100])
    r = Recipe(name="c", dataset_path=src, process=MIXED,
               checkpoint_dir=str(tmp_path / "ckpt"),
               use_fusion=False, use_reordering=False)
    _, rep1 = Executor(r).run_streaming()
    assert rep1.resumed_at == 0 and rep1.streaming
    # 3 segments -> stages at op counts {2, 3, 4}; resume lands on the last
    _, rep2 = Executor(r).run_streaming()
    assert rep2.resumed_at == len(MIXED)
    assert rep2.n_out == rep1.n_out
    assert rep2.n_in == rep1.n_in == 100, "resume must report the ORIGINAL n_in"


def test_failed_run_preserves_previous_export(tmp_path, corpus):
    src = str(tmp_path / "in.jsonl")
    write_jsonl(src, corpus[:50])
    out = str(tmp_path / "out.jsonl")
    good = Recipe(name="g", dataset_path=src, export_path=out, process=MIXED[:2])
    Executor(good).run()
    with open(out, "rb") as f:
        before = f.read()
    # corrupt the input past the probe window -> decode fails mid-stream
    with open(src, "ab") as f:
        f.write(b"{not json\n")
    with pytest.raises(Exception):
        Executor(good).run()
    with open(out, "rb") as f:
        assert f.read() == before, "failed run must not clobber the old export"


def test_empty_input_keeps_per_op_aligned_with_plan(tmp_path):
    src = str(tmp_path / "empty.jsonl")
    open(src, "w").close()
    r = Recipe(name="e", dataset_path=src, process=MIXED)
    _, rep = Executor(r).run()
    assert rep.streaming and rep.n_in == rep.n_out == 0
    assert [e["op"] for e in rep.per_op] == rep.plan


def test_streaming_no_materialize_export(tmp_path, corpus):
    src = str(tmp_path / "in.jsonl")
    write_jsonl(src, corpus[:80])
    out = str(tmp_path / "out.jsonl")
    r = Recipe(name="m", dataset_path=src, export_path=out, process=MIXED[:2])
    ds, rep = Executor(r).run_streaming(materialize=False)
    assert len(ds) == 0, "materialize=False must not hold the dataset"
    assert rep.n_out == sum(1 for _ in read_jsonl(out)) > 0
