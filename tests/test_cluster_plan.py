"""Cluster plan persistence: the first claimer pins the optimized plan
under the job's checkpoint dir, and a failover attempt replays EXACTLY the
persisted plan — closing the resume hazard that forced the fault-injection
harness to pin ``use_fusion/use_reordering`` off (the reordering probe
samples the stream, so a re-derived plan could disagree with the dead
attempt's checkpoints)."""
import json
import os
import time

import pytest

from repro.api.cluster import ClusterQueue, ClusterRunner
from repro.core.dataset import ExecutionCancelled
from repro.core.executor import Executor
from repro.core.recipes import Recipe
from repro.core.storage import write_jsonl
from repro.data.synthetic import make_corpus

pytestmark = pytest.mark.slow

PROCESS = [
    {"name": "whitespace_normalization_mapper"},
    {"name": "text_length_filter", "min_len": 5, "max_len": 10000},
    {"name": "document_minhash_deduplicator", "jaccard_threshold": 0.7},
    {"name": "alnum_ratio_filter", "min_ratio": 0.1},
]


def _submit(tmp_path, queue):
    src = str(tmp_path / "in.jsonl")
    write_jsonl(src, make_corpus(300, seed=9))
    return queue.submit({
        "name": "plan-pin-job",
        "dataset_path": src,
        "export_path": str(tmp_path / "out.jsonl"),
        "process": PROCESS,
        "use_fusion": True,
        "use_reordering": True,
    })


def _plan_path(queue, job_id):
    return os.path.join(queue.checkpoint_dir(job_id), "plan.json")


def test_plan_pinned_at_first_claim_and_reused(tmp_path):
    queue = ClusterQueue(str(tmp_path / "cluster"), lease_ttl=0.5)
    jid = _submit(tmp_path, queue)
    r1 = ClusterRunner(queue, runner_id="r1", lease_ttl=0.5)
    spec = queue.read_spec(jid)

    ex1 = r1._build_executor(jid, spec)
    assert os.path.exists(_plan_path(queue, jid)), "plan not pinned at claim"
    with open(_plan_path(queue, jid), "rb") as f:
        pinned_raw = f.read()
    pinned = json.loads(pinned_raw)["plan"]
    assert ex1.recipe.fixed_plan == pinned
    assert [c["name"] for c in pinned]  # non-empty op-config list

    # a later attempt re-reads the SAME plan instead of re-deriving one
    r2 = ClusterRunner(queue, runner_id="r2", lease_ttl=0.5)
    ex2 = r2._build_executor(jid, spec)
    assert ex2.recipe.fixed_plan == pinned
    with open(_plan_path(queue, jid), "rb") as f:
        assert f.read() == pinned_raw, "second claim rewrote the pinned plan"


def test_failover_replays_pinned_plan_byte_identical(tmp_path):
    queue = ClusterQueue(str(tmp_path / "cluster"), lease_ttl=0.4)
    jid = _submit(tmp_path, queue)
    spec = queue.read_spec(jid)

    # attempt 1: claim, pin the plan, die mid-run (cancel after a few
    # cooperative polls — the lease is left to expire, result unpublished)
    lease1 = queue.try_claim(jid, "r1", ttl=0.4)
    assert lease1 is not None and lease1.attempt == 1
    r1 = ClusterRunner(queue, runner_id="r1", lease_ttl=0.4)
    ex1 = r1._build_executor(jid, spec)
    pinned = ex1.recipe.fixed_plan
    assert pinned is not None and os.path.exists(_plan_path(queue, jid))
    polls = [0]

    def die_midway():
        polls[0] += 1
        return polls[0] > 3

    with pytest.raises(ExecutionCancelled):
        ex1.run_streaming(materialize=False, cancel=die_midway)
    assert queue.state_of(jid) != "succeeded"

    # lease expires -> attempt 2 claims and completes on another runner
    deadline = time.time() + 5.0
    while time.time() < deadline and not queue.current_lease(jid).expired():
        time.sleep(0.05)
    assert queue.current_lease(jid).expired(), "attempt-1 lease never expired"
    r2 = ClusterRunner(queue, runner_id="r2", lease_ttl=5.0)
    assert r2.run_once(), "failover runner claimed nothing"
    status = queue.status(jid)
    assert status["state"] == "succeeded", status
    assert status["attempt"] == 2

    # the completed attempt ran the pinned plan, not a re-derived one
    assert status["report"]["plan"] == [c["name"] for c in pinned]

    # and its export is byte-identical to an uninterrupted run of the
    # pinned plan (fresh single-process executor, no checkpoints)
    ref_out = str(tmp_path / "ref.jsonl")
    ref_recipe = Recipe.from_dict({**spec["recipe"], "export_path": ref_out,
                                   "fixed_plan": pinned})
    Executor(ref_recipe).run_streaming(materialize=False)
    with open(ref_out, "rb") as f:
        ref = f.read()
    with open(spec["recipe"]["export_path"], "rb") as f:
        got = f.read()
    assert ref and got == ref
