"""Per-architecture smoke tests: REDUCED configs, one forward/train step on
CPU, asserting output shapes + no NaNs; plus prefill->decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.configs.base import ShapeConfig
from repro.models.model_zoo import build_model

SMOKE_TRAIN = ShapeConfig("smoke_train", seq_len=32, global_batch=2, kind="train")
SMOKE_PREFILL = ShapeConfig("smoke_prefill", seq_len=32, global_batch=2, kind="prefill")
SMOKE_DECODE = ShapeConfig("smoke_decode", seq_len=32, global_batch=2, kind="decode")


def _materialize_inputs(model, shape, rng):
    import repro.models.module as mod

    specs = model.input_specs(shape)
    out = {}
    for name, s in specs.items():
        if s.dtype == jnp.int32:
            if name == "pos":
                out[name] = jnp.asarray(shape.seq_len - 1, jnp.int32)
            else:
                out[name] = jnp.asarray(
                    rng.integers(0, model.cfg.vocab_size, s.shape), jnp.int32
                )
        elif s.init == "ones":
            out[name] = jnp.ones(s.shape, s.dtype)
        else:
            out[name] = jnp.asarray(rng.standard_normal(s.shape), s.dtype)
    return out


@pytest.mark.parametrize("arch", list_archs())
def test_train_step_smoke(arch):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg, remat_policy="none")
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = _materialize_inputs(model, SMOKE_TRAIN, rng)

    def loss(p):
        return model.loss_fn(p, batch)[0]

    val, grads = jax.jit(jax.value_and_grad(loss))(params)
    assert np.isfinite(float(val)), f"{arch}: non-finite loss {val}"
    gnorm = jax.tree.reduce(
        lambda a, b: a + b, jax.tree.map(lambda g: jnp.sum(jnp.abs(g.astype(jnp.float32))), grads)
    )
    assert np.isfinite(float(gnorm)), f"{arch}: non-finite grads"
    assert float(gnorm) > 0, f"{arch}: zero grads"


@pytest.mark.parametrize("arch", list_archs())
def test_prefill_decode_smoke(arch):
    cfg = get_config(arch, reduced=True)
    model = build_model(cfg, remat_policy="none")
    params = model.init_params(jax.random.PRNGKey(1))
    rng = np.random.default_rng(1)
    batch = _materialize_inputs(model, SMOKE_PREFILL, rng)
    cache, logits = jax.jit(lambda p, b: model.prefill(p, b, cache_budget=4))(params, batch)
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab_size
    assert np.isfinite(np.asarray(logits, np.float32)).all(), f"{arch}: prefill NaN"

    dec_batch = {
        "token": jnp.zeros((2, 1), jnp.int32),
        "pos": jnp.asarray(SMOKE_PREFILL.seq_len, jnp.int32),
    }
    cache2, logits2 = jax.jit(model.decode_step)(params, cache, dec_batch)
    assert logits2.shape == (2, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all(), f"{arch}: decode NaN"


def test_decode_matches_prefill_continuation():
    """Decoding token t+1 after prefill(0..t) must equal prefill(0..t+1) logits."""
    cfg = get_config("phi3-medium-14b", reduced=True)
    model = build_model(cfg, remat_policy="none")
    params = model.init_params(jax.random.PRNGKey(2))
    rng = np.random.default_rng(2)
    toks = rng.integers(0, cfg.vocab_size, (2, 16)).astype(np.int32)

    cache, _ = model.prefill(params, {"tokens": jnp.asarray(toks[:, :15])}, cache_budget=4)
    _, dec_logits = model.decode_step(
        params, cache, {"token": jnp.asarray(toks[:, 15:16]), "pos": jnp.asarray(15)}
    )
    # full prefill over 16 tokens gives last-token logits for position 15
    _, full_logits = model.prefill(params, {"tokens": jnp.asarray(toks)})
    np.testing.assert_allclose(
        np.asarray(dec_logits[:, 0], np.float32),
        np.asarray(full_logits[:, 0], np.float32),
        rtol=5e-2, atol=6e-2,  # bf16 compute noise over 2 layers
    )
