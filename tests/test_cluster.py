"""Distributed job queue + multi-node runner placement (repro.api.cluster):
lease protocol units, placement policy, JobManager-as-thin-client, the REST
/cluster surface, and the subprocess fault-injection suite (SIGKILL a runner
mid-segment -> lease expiry -> re-queue -> checkpoint resume -> byte-identical
output)."""
import json
import os
import time
import urllib.request

import pytest

import repro.api as dj
from repro.api.cluster import ClusterQueue, ClusterRunner, PlacementPolicy
from cluster_harness import (
    checkpoint_stages, lease_owner, make_recipe, reference_output,
    sigkill_runner, start_runner, stop_runner, wait_for, write_corpus,
)

# multi-process lease/failover suites run real subprocess runners with
# real TTL waits — marked slow so conftest grants them the bigger timeout
pytestmark = pytest.mark.slow


# ---------------------------------------------------------------------------
# queue + lease protocol units (no subprocesses — fast)
# ---------------------------------------------------------------------------


def _spec(tmp_path, name="unit", n=40):
    src = write_corpus(str(tmp_path / f"{name}.jsonl"), n=n)
    return make_recipe(src, str(tmp_path / f"{name}.out.jsonl"),
                       slow_delay=0.0)


def test_submit_claim_complete_lifecycle(tmp_path):
    q = ClusterQueue(str(tmp_path / "c"), lease_ttl=5.0)
    jid = q.submit(_spec(tmp_path))
    assert q.state_of(jid) == "queued"
    assert q.depth() == 1

    lease = q.try_claim(jid, "r1")
    assert lease is not None and lease.attempt == 1
    assert q.state_of(jid) == "running"
    assert q.depth() == 0
    assert q.renew(lease)

    q.complete(lease, "succeeded", report={"n_out": 1})
    assert q.state_of(jid) == "succeeded"
    st = q.status(jid)
    assert st["state"] == "succeeded" and st["report"]["n_out"] == 1
    assert st["runner_id"] == "r1" and st["attempt"] == 1
    # the fsync'd event log recorded the whole lifecycle in order
    events = [e["event"] for e in q.read_log()]
    assert events == ["submitted", "claimed", "finished"]


def test_claim_is_exclusive_per_attempt(tmp_path):
    q = ClusterQueue(str(tmp_path / "c"), lease_ttl=5.0)
    jid = q.submit(_spec(tmp_path))
    assert q.try_claim(jid, "r1") is not None
    assert q.try_claim(jid, "r2") is None, "live lease must block re-claims"


def test_expired_lease_requeues_at_next_attempt(tmp_path):
    q = ClusterQueue(str(tmp_path / "c"), lease_ttl=0.1)
    jid = q.submit(_spec(tmp_path))
    first = q.try_claim(jid, "r1", ttl=0.1)
    assert first is not None
    time.sleep(0.15)
    assert q.state_of(jid) == "queued", "expired lease -> claimable again"
    assert q.expired_leases() and q.expired_leases()[0].runner_id == "r1"

    second = q.try_claim(jid, "r2")
    assert second is not None and second.attempt == 2
    # the zombie's heartbeat must fail once the job was re-claimed
    assert not q.renew(first), "a superseded lease can never renew"
    events = [e["event"] for e in q.read_log()]
    assert "requeued_after_expiry" in events


def test_cancel_blocks_claims_and_is_terminal(tmp_path):
    q = ClusterQueue(str(tmp_path / "c"))
    jid = q.submit(_spec(tmp_path))
    q.cancel(jid)
    assert q.state_of(jid) == "cancelled"
    assert q.try_claim(jid, "r1") is None
    with pytest.raises(KeyError):
        q.cancel("nope")


def test_placement_scores_throughput_capacity_quarantines():
    fast = {"runner_id": "a", "capacity": 2, "active": 0, "throughput": 100.0,
            "quarantines": 0}
    busy = dict(fast, runner_id="b", active=2)
    slow = dict(fast, runner_id="c", throughput=10.0)
    flaky = dict(fast, runner_id="d", quarantines=4)
    assert PlacementPolicy.score(busy) == 0.0, "no free slot -> never claims"
    assert PlacementPolicy.score(fast) > PlacementPolicy.score(slow)
    assert PlacementPolicy.score(fast) > PlacementPolicy.score(flaky), \
        "persisted worker-quarantine history must penalize placement"

    pol = PlacementPolicy(defer_seconds=60.0)
    cards = [fast, busy, slow, flaky]
    assert pol.should_claim("a", cards, waited=0.0)
    assert not pol.should_claim("c", cards, waited=0.0), \
        "a worse-placed runner defers to the better one"
    assert pol.should_claim("c", cards, waited=61.0), \
        "deference must expire so the queue never starves"


def test_next_job_drains_fifo(tmp_path):
    q = ClusterQueue(str(tmp_path / "c"))
    a = q.submit(_spec(tmp_path, "a"))
    time.sleep(0.01)
    b = q.submit(_spec(tmp_path, "b"))
    lease = q.next_job("r1")
    assert lease is not None and lease.job_id == a
    lease2 = q.next_job("r1")
    assert lease2 is not None and lease2.job_id == b


# ---------------------------------------------------------------------------
# JobManager as a thin client (in-process runner = single-node cluster mode)
# ---------------------------------------------------------------------------


def _pipeline(tmp_path, n=120, delay=0.0, name="corpus"):
    src = write_corpus(str(tmp_path / f"{name}.jsonl"), n=n)
    out = str(tmp_path / f"{name}.out.jsonl")
    pipe = dj.read_jsonl(src).map("whitespace_normalization_mapper")
    if delay:
        pipe = pipe.map("sleep_mapper", delay=delay)
    return (pipe.filter("text_length_filter", min_val=20)
            .write_jsonl(out).options(use_reordering=False)), out


def test_job_manager_cluster_mode_lifecycle(tmp_path):
    mgr = dj.JobManager(max_workers=2, cluster_dir=str(tmp_path / "c"))
    try:
        pipe, out = _pipeline(tmp_path)
        job = mgr.submit(pipe)
        assert isinstance(job, dj.ClusterJobHandle)
        wait_for(job.done, 60, message="cluster job finishes")
        st = job.status()
        assert st["state"] == "succeeded" and st["cluster"] is True
        # REST-contract shape: same keys the single-node Job.status() serves
        for key in ("job_id", "state", "created_at", "finished_at", "error",
                    "progress"):
            assert key in st
        assert st["report"]["n_out"] > 0
        assert os.path.exists(out)
        assert mgr.get(job.id).state == "succeeded"
        assert any(j["job_id"] == job.id for j in mgr.list())
        with pytest.raises(KeyError):
            mgr.get("missing")
    finally:
        mgr.shutdown(wait=True)


def test_cluster_progress_dispatch_counter_parity(tmp_path):
    """GET /jobs/<id> progress must expose the SAME dispatcher-counter
    shape in cluster mode as in single-node mode (redispatches,
    preemptions, ...) — the REST contract is mode-independent."""
    from repro.core.dispatch import DISPATCH_COUNTERS

    cl = dj.JobManager(max_workers=2, cluster_dir=str(tmp_path / "c"))
    sn = dj.JobManager(max_workers=2)
    try:
        pipe_c, _ = _pipeline(tmp_path, name="par-cluster")
        pipe_s, _ = _pipeline(tmp_path, name="par-single")
        jc, js = cl.submit(pipe_c), sn.submit(pipe_s)
        wait_for(jc.done, 60, message="cluster job finishes")
        wait_for(js.done, 60, message="single-node job finishes")
        dc = jc.status()["progress"]["dispatch"]
        ds = js.status()["progress"]["dispatch"]
        assert set(dc) == set(ds) == set(DISPATCH_COUNTERS)
        for d in (dc, ds):
            assert all(isinstance(v, int) and v >= 0 for v in d.values())
    finally:
        cl.shutdown(wait=True)
        sn.shutdown(wait=True)


def test_job_manager_cluster_mode_cancel(tmp_path):
    mgr = dj.JobManager(max_workers=1, cluster_dir=str(tmp_path / "c"))
    try:
        slow, _ = _pipeline(tmp_path, delay=0.02)
        blocker = mgr.submit(slow)  # occupies the only runner slot
        queued = mgr.submit(slow)
        mgr.cancel(queued.id)
        assert queued.state == "cancelled"
        wait_for(blocker.done, 60, message="blocker finishes")
    finally:
        mgr.shutdown(wait=True)


def test_cluster_submit_requires_file_source(tmp_path):
    mgr = dj.JobManager(cluster_dir=str(tmp_path / "c"), start_runner=False)
    with pytest.raises(ValueError, match="file-backed"):
        mgr.submit(dj.from_samples([{"text": "x"}]))
    mgr.shutdown()


def test_cluster_backlog_honours_max_jobs(tmp_path):
    """The 503 half of the REST contract survives cluster mode: max_jobs
    bounds the LIVE backlog (terminal results don't count)."""
    mgr = dj.JobManager(max_jobs=1, cluster_dir=str(tmp_path / "c"),
                        start_runner=False)  # nothing drains the queue
    try:
        pipe, _ = _pipeline(tmp_path)
        mgr.submit(pipe)
        with pytest.raises(dj.JobStoreFull):
            mgr.submit(pipe)
    finally:
        mgr.shutdown()


def test_stale_attempt_cannot_clobber_newer_result(tmp_path):
    """A zombie runner that never saw its lease loss must not overwrite the
    failover attempt's result: complete() is attempt-monotonic."""
    q = ClusterQueue(str(tmp_path / "c"), lease_ttl=0.1)
    jid = q.submit(_spec(tmp_path))
    zombie = q.try_claim(jid, "zombie", ttl=0.1)
    time.sleep(0.15)
    takeover = q.try_claim(jid, "survivor")
    assert takeover is not None and takeover.attempt == 2
    assert q.complete(takeover, "succeeded", report={"n_out": 5})
    assert not q.complete(zombie, "failed", error="zombie woke up late")
    st = q.status(jid)
    assert st["state"] == "succeeded" and st["runner_id"] == "survivor"
    assert any(e["event"] == "stale_result_discarded" for e in q.read_log())


def test_torn_checkpoint_manifest_resumes_from_scratch(tmp_path):
    """SIGKILL can land mid-manifest-write (pre-atomic-write snapshots, or a
    mid-replace read on a lax shared FS): the surviving attempt must treat a
    torn manifest as 'no checkpoints' and restart, never fail the job."""
    from repro.core.checkpoint import CheckpointManager

    ckpt = CheckpointManager(str(tmp_path / "ck"))
    ckpt.save_stage("sig0", 1, [{"text": "x"}])
    with open(os.path.join(str(tmp_path / "ck"), "manifest.json"), "w") as f:
        f.write('{"stages": {"torn')
    assert CheckpointManager(str(tmp_path / "ck")).load_manifest() == \
        {"stages": {}}
    n_done, samples = CheckpointManager(str(tmp_path / "ck")).resume_point(
        [{"name": "whitespace_normalization_mapper"}])
    assert n_done == 0 and samples is None


# ---------------------------------------------------------------------------
# REST surface
# ---------------------------------------------------------------------------


def _get(port, path):
    with urllib.request.urlopen(f"http://127.0.0.1:{port}{path}") as r:
        return json.loads(r.read())


def test_rest_cluster_endpoint_and_jobs_contract(tmp_path):
    from repro.interface.server import serve

    srv = serve(port=0, max_workers=1, cluster_dir=str(tmp_path / "c"))
    port = srv.server_address[1]
    try:
        ov = _get(port, "/cluster")
        assert ov["enabled"] is True
        assert ov["queue_depth"] == 0
        wait_for(lambda: any(c["runner_id"].startswith("inproc-")
                             for c in _get(port, "/cluster")["runners"]),
                 10, message="in-process runner card")

        src = write_corpus(str(tmp_path / "corpus.jsonl"), n=60)
        body = json.dumps({
            "dataset_path": src,
            "export_path": str(tmp_path / "out.jsonl"),
            "use_reordering": False,
            "process": [{"name": "whitespace_normalization_mapper"}],
        }).encode()
        req = urllib.request.Request(f"http://127.0.0.1:{port}/jobs",
                                     data=body, method="POST",
                                     headers={"Content-Type": "application/json"})
        with urllib.request.urlopen(req) as r:
            sub = json.loads(r.read())
        assert r.status == 202 and sub["poll"] == f"/jobs/{sub['job_id']}"

        wait_for(lambda: _get(port, f"/jobs/{sub['job_id']}")["state"]
                 in ("succeeded", "failed"), 60, message="REST job")
        st = _get(port, f"/jobs/{sub['job_id']}")
        assert st["state"] == "succeeded"
        assert st["report"]["n_out"] > 0
        assert _get(port, "/jobs")["jobs"][0]["job_id"] == sub["job_id"]
    finally:
        srv.server_close()


def test_rest_cluster_endpoint_disabled_in_single_node_mode():
    from repro.interface.server import serve

    srv = serve(port=0)
    try:
        assert _get(srv.server_address[1], "/cluster") == {"enabled": False}
    finally:
        srv.server_close()


# ---------------------------------------------------------------------------
# fault injection: real runner subprocesses, SIGKILL mid-segment
# ---------------------------------------------------------------------------


def test_sigkill_failover_resumes_from_checkpoint_byte_identical(tmp_path):
    """The acceptance scenario: two real runner processes share a cluster
    dir; the one holding the lease is SIGKILLed mid-segment (after the
    barrier checkpoint, inside the slow chain). The lease must expire, the
    job re-queue at attempt 2, the survivor resume from the persisted
    segment boundary (resumed_at > 0, NOT a restart), and the final export
    must be byte-identical to an uninterrupted run."""
    src = write_corpus(str(tmp_path / "corpus.jsonl"), n=120)
    out = str(tmp_path / "out.jsonl")
    recipe = make_recipe(src, out, slow_delay=0.04)
    ref = reference_output(recipe, str(tmp_path / "ref.jsonl"))

    q = ClusterQueue(str(tmp_path / "cluster"), lease_ttl=2.0)
    jid = q.submit(recipe)
    r1 = start_runner(q.dir, "runner-1", lease_ttl=2.0)
    r2 = start_runner(q.dir, "runner-2", lease_ttl=2.0)
    try:
        wait_for(lambda: lease_owner(q, jid) is not None, 60, message="claim")
        owner = lease_owner(q, jid)
        # mid-segment: the chain+barrier checkpoints exist, the slow final
        # segment is in flight — precisely the state a restart used to lose
        wait_for(lambda: len(checkpoint_stages(q, jid)) >= 2, 60,
                 message="segment-boundary checkpoints")
        time.sleep(0.3)
        sigkill_runner(r1 if owner == "runner-1" else r2)

        wait_for(lambda: q.state_of(jid) == "succeeded", 120,
                 message="failover completion")
        st = q.status(jid)
        assert st["attempt"] == 2, "job must be re-leased, not restarted in place"
        assert st["runner_id"] != owner
        assert st["report"]["resumed_at"] > 0, \
            "survivor must resume from the checkpoint, not re-run from scratch"
        with open(out, "rb") as f:
            assert f.read() == ref, "failover output must be byte-identical"
        events = [e["event"] for e in q.read_log()]
        assert "requeued_after_expiry" in events
    finally:
        for p in (r1, r2):
            try:
                stop_runner(p)
            except Exception:
                pass


def test_two_runners_split_a_multi_job_queue(tmp_path):
    """Placement sanity on real processes: N quick jobs drain across two
    runners, and both actually execute work (no claim monopolies)."""
    q = ClusterQueue(str(tmp_path / "cluster"), lease_ttl=5.0)
    jids = []
    for i in range(4):
        src = write_corpus(str(tmp_path / f"in{i}.jsonl"), n=60, seed=i)
        jids.append(q.submit(make_recipe(
            src, str(tmp_path / f"out{i}.jsonl"), slow_delay=0.01)))
    r1 = start_runner(q.dir, "runner-1", lease_ttl=5.0)
    r2 = start_runner(q.dir, "runner-2", lease_ttl=5.0)
    try:
        wait_for(lambda: all(q.state_of(j) == "succeeded" for j in jids),
                 120, message="queue drained")
        owners = {q.status(j)["runner_id"] for j in jids}
        assert owners == {"runner-1", "runner-2"}, \
            f"expected both runners to take work, got {owners}"
        for i in range(4):
            assert os.path.exists(str(tmp_path / f"out{i}.jsonl"))
    finally:
        for p in (r1, r2):
            try:
                stop_runner(p)
            except Exception:
                pass


# ---------------------------------------------------------------------------
# server-restart durability (harness reuse — no subprocesses needed)
# ---------------------------------------------------------------------------


def test_cluster_store_survives_manager_restart(tmp_path):
    """The PR-3 JSONL snapshot marked interrupted jobs failed; the cluster
    store is stronger: a RESTARTED manager (new process lifecycle, same
    cluster_dir) still serves finished jobs verbatim, and an unfinished job
    is re-leased by the new manager's runner instead of being declared dead."""
    cdir = str(tmp_path / "c")
    mgr_a = dj.JobManager(max_workers=1, cluster_dir=cdir)
    try:
        pipe, out = _pipeline(tmp_path)
        done = mgr_a.submit(pipe)
        wait_for(done.done, 60, message="first-life job")
        done_report = done.status()["report"]
    finally:
        mgr_a.shutdown(wait=True)

    # second life: a fresh manager on the same shared store
    mgr_b = dj.JobManager(max_workers=1, cluster_dir=cdir)
    try:
        st = mgr_b.get(done.id).status()
        assert st["state"] == "succeeded"
        assert st["report"] == done_report, "results must survive restarts"

        # a job submitted while no runner lived is picked up by the new one
        pipe2, out2 = _pipeline(tmp_path, n=60, name="second-life")
        orphan = mgr_b.cluster.submit(pipe2.to_recipe().to_dict())
        wait_for(lambda: mgr_b.cluster.state_of(orphan) == "succeeded", 60,
                 message="orphan job adopted after restart")
        assert os.path.exists(out2)
    finally:
        mgr_b.shutdown(wait=True)
