"""Post-tuning OP family tests (dialog schema)."""
from repro.core import schema as S
from repro.core.dataset import DJDataset
from repro.core.registry import create_op


def _qa(q, r, history=None):
    return S.new_sample("", query=q, response=r, history=history or [])


def test_calibrate_query_and_response():
    op = create_op({"name": "optimize_qa_mapper"})
    s = op.process_single(_qa("what  is   data juicer",
                              "Sure! Data Juicer is a system. Data Juicer is a system."))
    assert s["query"] == "what is data juicer?"
    assert s["response"].lower().count("data juicer is a system") == 1
    assert not s["response"].lower().startswith("sure")


def test_pair_preference_and_ratio_filter():
    ds = DJDataset.from_samples([
        _qa("why is the sky blue?", "because of rayleigh scattering of sunlight " * 3),
        _qa("explain gravity in detail please with examples", "no"),
    ])
    out = ds.process([
        create_op({"name": "pair_preference_mapper"}),
        create_op({"name": "response_length_ratio_filter", "min_val": 0.5}),
    ])
    assert len(out) == 1
    m = out.samples()[0]["meta"]
    assert m["chosen"] and len(m["rejected"].split()) <= len(m["chosen"].split())


def test_extract_and_difficulty_and_turns():
    s = S.new_sample("Einstein is famous. Gravity is universal. The value 3.14159 appears.",
                     query="compute the integral of a polynomial", response="ok",
                     history=[["hi", "hello"]])
    s = create_op({"name": "extract_keyword_mapper"}).process_single(s)
    assert "keywords" in s["meta"]
    s = create_op({"name": "extract_entity_attribute_mapper"}).process_single(s)
    assert ["Einstein", "famous"] in s["meta"]["entity_attributes"]
    s = create_op({"name": "dialog_turns_filter"}).compute_stats(s)
    assert s["stats"]["n_turns"] == 2
    s = create_op({"name": "llm_difficulty_score_filter"}).compute_stats(s)
    assert 0.0 <= s["stats"]["difficulty"] <= 1.0


def test_history_flatten():
    s = _qa("current?", "yes", history=[["q1", "a1"]])
    out = create_op({"name": "history_flatten_mapper"}).process_single(s)
    assert "user: q1" in out["text"] and "assistant: a1" in out["text"]
    assert out["text"].endswith("assistant: yes")


def test_registry_has_post_tuning_family():
    from repro.core.registry import list_ops

    ops = list_ops()
    for name in ("calibrate_query_mapper", "pair_preference_mapper",
                 "llm_difficulty_score_filter", "optimize_qa_mapper"):
        assert name in ops
    assert len(ops) >= 55
