"""Multi-tenant cluster serving (repro.api.cluster): tenant identity on
submissions, atomic O_EXCL quota/backlog admission (the TOCTOU regression
suite for the old count-then-submit 503), weighted deficit-round-robin
claiming, and the tenant surface (REST API keys, /tenants, per-tenant SLO,
CLI submit / cluster-status --tenants)."""
import json
import os
import threading
import urllib.error
import urllib.request

import pytest

import repro.api as dj
from repro.api.cluster import (
    SLOT_ORPHAN_GRACE, AdmissionDenied, ClusterQueue, validate_tenant,
)
from repro.core import clock
from cluster_harness import make_recipe, wait_for, write_corpus


@pytest.fixture(autouse=True)
def _real_clock():
    clock.reset()
    yield
    clock.reset()


@pytest.fixture
def fake():
    fc = clock.FakeClock()
    clock.install(fc)
    yield fc
    clock.reset()


def _spec(tmp_path, name="unit", n=30):
    src = write_corpus(str(tmp_path / f"{name}.jsonl"), n=n)
    return make_recipe(src, str(tmp_path / f"{name}.out.jsonl"),
                       slow_delay=0.0)


def _pipeline(tmp_path, name="p"):
    src = write_corpus(str(tmp_path / f"{name}.jsonl"), n=30)
    return (dj.read_jsonl(src)
            .op("whitespace_normalization_mapper")
            .write_jsonl(str(tmp_path / f"{name}.out.jsonl")))


def _write_tenants(cdir, cfg):
    os.makedirs(str(cdir), exist_ok=True)
    with open(os.path.join(str(cdir), "tenants.json"), "w") as f:
        json.dump(cfg, f)


# ---------------------------------------------------------------------------
# tenant identity
# ---------------------------------------------------------------------------


def test_validate_tenant_charset():
    assert validate_tenant("alice") == "alice"
    assert validate_tenant("team-a.prod_2") == "team-a.prod_2"
    assert validate_tenant("x" * 64) == "x" * 64
    for bad in ("", "_hidden", "-lead", ".dot", "a/b", "a b", "x" * 65,
                "__all__", None, 7):
        with pytest.raises(ValueError):
            validate_tenant(bad)


def test_submit_defaults_to_default_tenant(tmp_path):
    q = ClusterQueue(str(tmp_path / "c"))
    jid = q.submit(_spec(tmp_path))
    assert q.read_spec(jid)["tenant"] == "default"
    assert q.status(jid)["tenant"] == "default"
    sub = [e for e in q.read_log() if e["event"] == "submitted"][0]
    assert sub["tenant"] == "default"


def test_submit_tenant_resolution_order(tmp_path):
    q = ClusterQueue(str(tmp_path / "c"))
    spec = dict(_spec(tmp_path), tenant="from-recipe")
    assert q.read_spec(q.submit(spec, job_id="j1"))["tenant"] == "from-recipe"
    assert q.read_spec(q.submit(spec, job_id="j2",
                                tenant="explicit"))["tenant"] == "explicit"
    with pytest.raises(ValueError, match="invalid tenant"):
        q.submit(_spec(tmp_path), tenant="bad/tenant")


# ---------------------------------------------------------------------------
# atomic admission: quotas, backlog bound, TOCTOU regression
# ---------------------------------------------------------------------------


def test_tenant_quota_admission_and_lazy_reclaim(tmp_path):
    cdir = tmp_path / "c"
    _write_tenants(cdir, {"tenants": {"alice": {"max_live_jobs": 2}}})
    q = ClusterQueue(str(cdir))
    spec = _spec(tmp_path)
    a1 = q.submit(spec, job_id="a1", tenant="alice")
    q.submit(spec, job_id="a2", tenant="alice")
    with pytest.raises(AdmissionDenied) as ei:
        q.submit(spec, job_id="a3", tenant="alice")
    assert ei.value.tenant == "alice" and ei.value.scope == "tenant"
    # other tenants are unaffected by alice's quota
    q.submit(spec, job_id="b1", tenant="bob")
    # finishing a job frees its slot lazily: the next submit reclaims it
    lease = q.try_claim(a1, "r1")
    q.complete(lease, "succeeded", report={"n_out": 1})
    q.submit(spec, job_id="a4", tenant="alice")
    with pytest.raises(AdmissionDenied):
        q.submit(spec, job_id="a5", tenant="alice")


def test_concurrent_submits_respect_backlog_bound(tmp_path):
    """The TOCTOU regression: N submitters racing past a max_live bound used
    to all pass the read-then-check count — O_EXCL slots admit exactly
    max_live of them no matter the interleaving."""
    cdir = str(tmp_path / "c")
    ClusterQueue(cdir)  # create the tree once
    spec = _spec(tmp_path)
    n_threads, bound = 8, 3
    barrier = threading.Barrier(n_threads)
    outcomes = [None] * n_threads

    def submitter(i):
        q = ClusterQueue(cdir)  # each racer has its own queue object
        barrier.wait()
        try:
            q.submit(spec, job_id=f"race{i}", max_live=bound)
            outcomes[i] = "admitted"
        except AdmissionDenied as e:
            outcomes[i] = e.scope

    threads = [threading.Thread(target=submitter, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert outcomes.count("admitted") == bound
    assert outcomes.count("cluster") == n_threads - bound
    assert len(ClusterQueue(cdir).job_ids()) == bound


def test_two_jobmanagers_share_backlog_atomically(tmp_path):
    """Two JobManager front-ends over one cluster_dir see ONE shared
    backlog bound (the old per-manager live_count() check did not)."""
    cdir = str(tmp_path / "c")
    a = dj.JobManager(max_jobs=1, cluster_dir=cdir, start_runner=False)
    b = dj.JobManager(max_jobs=1, cluster_dir=cdir, start_runner=False)
    try:
        a.submit(_pipeline(tmp_path, name="ma"))
        with pytest.raises(dj.JobStoreFull):
            b.submit(_pipeline(tmp_path, name="mb"))
    finally:
        a.shutdown()
        b.shutdown()


def test_orphan_slot_reclaimed_after_grace(fake, tmp_path):
    """A submitter that crashed between slot-acquire and spec publish leaves
    an orphan slot: denied inside the grace window (the writer may still be
    mid-create), reclaimed after it."""
    cdir = tmp_path / "c"
    _write_tenants(cdir, {"tenants": {"alice": {"max_live_jobs": 1}}})
    q = ClusterQueue(str(cdir))
    sd = q.slot_dir("alice")
    os.makedirs(sd, exist_ok=True)
    with open(os.path.join(sd, "slot0.json"), "w") as f:
        json.dump({"job_id": "ghost-never-published", "ts": clock.now()}, f)
    with pytest.raises(AdmissionDenied):
        q.submit(_spec(tmp_path), job_id="denied", tenant="alice")
    fake.tick(SLOT_ORPHAN_GRACE + 1.0)
    q.submit(_spec(tmp_path), job_id="admitted", tenant="alice")
    assert q.state_of("admitted") == "queued"


# ---------------------------------------------------------------------------
# weighted deficit-round-robin claiming
# ---------------------------------------------------------------------------


def _claim_order(q, runner="r1"):
    order = []
    while True:
        lease = q.next_job(runner)
        if lease is None:
            return order
        order.append(lease.job_id)


def test_fair_share_interleaves_tenants(tmp_path):
    """A heavy tenant's pre-submitted backlog cannot starve a light
    tenant: equal weights alternate as deficits accrue."""
    q = ClusterQueue(str(tmp_path / "c"), fair_share=True)
    spec = _spec(tmp_path)
    for i in range(3):
        q.submit(spec, job_id=f"aa-{i}", tenant="aa")
    q.submit(spec, job_id="bb-0", tenant="bb")
    assert _claim_order(q) == ["aa-0", "bb-0", "aa-1", "aa-2"]


def test_fair_share_weighted_proportionality(tmp_path):
    """weight 2 earns two claims per weight-1 claim, deterministically
    (deficit = service/weight, name tie-break, FIFO within tenant)."""
    cdir = tmp_path / "c"
    _write_tenants(cdir, {"tenants": {"aa": {"weight": 1},
                                      "bb": {"weight": 2}}})
    q = ClusterQueue(str(cdir), fair_share=True)
    spec = _spec(tmp_path)
    for i in range(4):
        q.submit(spec, job_id=f"aa-{i}", tenant="aa")
    for i in range(4):
        q.submit(spec, job_id=f"bb-{i}", tenant="bb")
    assert _claim_order(q) == ["aa-0", "bb-0", "bb-1", "aa-1",
                               "bb-2", "bb-3", "aa-2", "aa-3"]


def test_fair_share_off_preserves_pure_fifo(tmp_path):
    q = ClusterQueue(str(tmp_path / "c"), fair_share=False)
    spec = _spec(tmp_path)
    q.submit(spec, job_id="j0", tenant="bb")
    q.submit(spec, job_id="j1", tenant="aa")
    q.submit(spec, job_id="j2", tenant="aa")
    # fair-share would rank aa first (both deficits 0, name tie-break);
    # FIFO keeps submit order
    assert _claim_order(q) == ["j0", "j1", "j2"]


def test_service_counter_survives_queue_restart(tmp_path):
    """Deficit state is derived from log.jsonl, so a brand-new queue object
    (failover, restarted runner) continues the rotation, not restarts it."""
    cdir = str(tmp_path / "c")
    q = ClusterQueue(cdir, fair_share=True)
    spec = _spec(tmp_path)
    for i in range(3):
        q.submit(spec, job_id=f"aa-{i}", tenant="aa")
    q.submit(spec, job_id="bb-0", tenant="bb")
    assert q.next_job("r1").job_id == "aa-0"
    fresh = ClusterQueue(cdir, fair_share=True)
    assert fresh.next_job("r2").job_id == "bb-0", \
        "restarted scheduler must see aa's granted claim in the log"


# ---------------------------------------------------------------------------
# reserved shard grammar vs user job ids containing "~"
# ---------------------------------------------------------------------------


def test_tilde_named_user_job_is_a_plain_job(tmp_path):
    cdir = tmp_path / "c"
    _write_tenants(cdir, {"tenants": {"ops": {"max_live_jobs": 1}}})
    q = ClusterQueue(str(cdir))
    spec = _spec(tmp_path)
    q.submit(spec, job_id="nightly~v2", tenant="ops")
    # not hidden from listings like a shard task would be...
    assert "nightly~v2" in q.job_ids()
    assert q.shard_tasks("nightly") == []
    # ...and it consumed an admission slot (shard tasks bypass admission)
    with pytest.raises(AdmissionDenied):
        q.submit(spec, job_id="nightly~v3", tenant="ops")


# ---------------------------------------------------------------------------
# surface: Pipeline knob, JobManager, tenant overview
# ---------------------------------------------------------------------------


def test_pipeline_tenant_flows_to_cluster_spec(tmp_path):
    mgr = dj.JobManager(cluster_dir=str(tmp_path / "c"), start_runner=False)
    try:
        job = mgr.submit(_pipeline(tmp_path).tenant("alice"))
        assert mgr.cluster.read_spec(job.id)["tenant"] == "alice"
        rows = {r["tenant"]: r for r in mgr.cluster.tenant_overview()}
        assert rows["alice"]["live_jobs"] == 1
        assert rows["alice"]["jobs"] == {"queued": 1}
        tn = mgr.tenants()
        assert tn["enabled"] is True
        assert any(r["tenant"] == "alice" for r in tn["tenants"])
    finally:
        mgr.shutdown()


def test_pipeline_tenant_validates_eagerly():
    with pytest.raises(ValueError, match="invalid tenant"):
        dj.from_samples([{"text": "x"}]).tenant("no/slashes")


# ---------------------------------------------------------------------------
# REST: API-key auth, /tenants, per-tenant SLO
# ---------------------------------------------------------------------------


def _get(port, path):
    with urllib.request.urlopen(f"http://127.0.0.1:{port}{path}") as r:
        return json.loads(r.read())


def _post_job(port, tmp_path, name, api_key=None):
    src = write_corpus(str(tmp_path / f"{name}.jsonl"), n=40)
    body = json.dumps({
        "dataset_path": src,
        "export_path": str(tmp_path / f"{name}.out.jsonl"),
        "use_reordering": False,
        "process": [{"name": "whitespace_normalization_mapper"}],
    }).encode()
    headers = {"Content-Type": "application/json"}
    if api_key is not None:
        headers["X-DJ-API-Key"] = api_key
    req = urllib.request.Request(f"http://127.0.0.1:{port}/jobs",
                                 data=body, method="POST", headers=headers)
    with urllib.request.urlopen(req) as r:
        return r.status, json.loads(r.read())


@pytest.mark.slow
def test_rest_api_key_tenants_and_per_tenant_slo(tmp_path):
    from repro.interface.server import serve

    cdir = tmp_path / "c"
    _write_tenants(cdir, {"tenants": {"alice": {"weight": 4,
                                                "api_keys": ["sk-alice-1"]}}})
    srv = serve(port=0, max_workers=1, cluster_dir=str(cdir))
    port = srv.server_address[1]
    try:
        # API key -> tenant identity on the submission
        status, sub = _post_job(port, tmp_path, "keyed", api_key="sk-alice-1")
        assert status == 202 and sub["tenant"] == "alice"
        # the default path is contract-unchanged: no tenant key at all
        status, anon = _post_job(port, tmp_path, "anon")
        assert status == 202 and "tenant" not in anon

        # unknown key -> 403, not a default-tenant submission
        with pytest.raises(urllib.error.HTTPError) as ei:
            _post_job(port, tmp_path, "bad", api_key="sk-wrong")
        assert ei.value.code == 403
        assert json.loads(ei.value.read())["error"]["type"] == \
            "unknown_api_key"

        for jid in (sub["job_id"], anon["job_id"]):
            wait_for(lambda j=jid: _get(port, f"/jobs/{j}")["state"]
                     in ("succeeded", "failed"), 60, message="REST job")
            assert _get(port, f"/jobs/{jid}")["state"] == "succeeded"

        tn = _get(port, "/tenants")
        assert tn["enabled"] is True
        rows = {r["tenant"]: r for r in tn["tenants"]}
        assert rows["alice"]["weight"] == 4.0
        assert rows["alice"]["claims_granted"] >= 1

        slo = _get(port, "/cluster/slo?tenant=alice")
        assert slo["enabled"] is True and slo["tenant"] == "alice"
        assert slo["jobs_finished"] == 1
        full = _get(port, "/cluster/slo")
        assert set(full["tenants"]) == {"alice", "default"}
        assert full["tenants"]["default"]["jobs_finished"] == 1
    finally:
        srv.server_close()


# ---------------------------------------------------------------------------
# CLI: dj submit / cluster-status --tenants
# ---------------------------------------------------------------------------


def test_cli_submit_and_tenant_status(tmp_path, capsys):
    from repro.interface import cli

    cdir = tmp_path / "c"
    _write_tenants(cdir, {"tenants": {"alice": {"weight": 2,
                                                "max_live_jobs": 5}}})
    cfg = str(tmp_path / "recipe.yaml")
    _pipeline(tmp_path, name="cli").save_recipe(cfg)
    rc = cli.main(["submit", "--config", cfg, "--cluster_dir", str(cdir),
                   "--tenant", "alice", "--job_id", "cli1"])
    assert rc == 0
    assert "submitted cli1 tenant=alice" in capsys.readouterr().out

    rc = cli.main(["cluster-status", "--cluster_dir", str(cdir), "--tenants"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "alice" in out and "weight" in out

    # over-quota submission is a clean non-zero exit, not a traceback
    _write_tenants(cdir, {"tenants": {"alice": {"max_live_jobs": 1}}})
    rc = cli.main(["submit", "--config", cfg, "--cluster_dir", str(cdir),
                   "--tenant", "alice", "--job_id", "cli2"])
    assert rc == 1
    assert "admission denied [tenant]" in capsys.readouterr().err
