"""Validates the trip-count-aware HLO profiler against XLA's own
cost_analysis on loop-free programs, and its loop multiplication on scans."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import (
    analyze_hlo, normalize_cost_analysis, parse_module, type_bytes,
)


def _compile(fn, *shapes):
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    return jax.jit(fn).lower(*args).compile()


def test_flops_match_cost_analysis_loop_free():
    comp = _compile(lambda a, b: a @ b, (64, 128), (128, 32))
    stats = analyze_hlo(comp.as_text())
    xla_flops = normalize_cost_analysis(comp.cost_analysis()).get("flops", 0)
    assert stats.flops == pytest.approx(xla_flops, rel=0.01)
    assert stats.flops == pytest.approx(2 * 64 * 128 * 32, rel=0.01)


def test_scan_trip_count_multiplied():
    def scanned(x, ws):
        def body(c, w):
            return (c @ w).astype(c.dtype), None
        x, _ = jax.lax.scan(body, x, ws)
        return x

    n = 12
    comp = _compile(scanned, (32, 32), (n, 32, 32))
    stats = analyze_hlo(comp.as_text())
    xla_flops = normalize_cost_analysis(comp.cost_analysis()).get("flops", 0)  # counts body ONCE
    assert stats.flops == pytest.approx(n * 2 * 32**3, rel=0.05)
    assert stats.flops > 5 * xla_flops, "our walker must multiply loop bodies"
    assert n in stats.while_trips


def test_type_bytes():
    assert type_bytes("f32[8,128]{1,0}") == 8 * 128 * 4
    assert type_bytes("bf16[2,3]{1,0}") == 12
    assert type_bytes("(s32[], f32[4]{0})") == 4 + 16
    assert type_bytes("pred[]") == 1


def test_parse_module_finds_entry():
    comp = _compile(lambda a: jnp.sum(a * 2.0), (16, 16))
    comps, entry = parse_module(comp.as_text())
    assert entry is not None and entry in comps
    assert len(comps[entry].instrs) > 0


def test_collectives_counted():
    if len(jax.devices()) < 1:
        pytest.skip("no devices")
    mesh = jax.make_mesh((1,), ("d",))
    from jax.sharding import NamedSharding, PartitionSpec as P

    fn = jax.jit(lambda x: x * 2, in_shardings=NamedSharding(mesh, P(None)))
    comp = fn.lower(jax.ShapeDtypeStruct((8,), jnp.float32)).compile()
    stats = analyze_hlo(comp.as_text())
    assert stats.hbm_bytes > 0
