"""Pallas kernels vs pure-jnp oracles (interpret mode), sweeping shapes and
dtypes per the deliverable."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

# ---------------------------------------------------------------------------
# minhash
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("d,s,p", [(3, 5, 7), (10, 37, 33), (64, 256, 64), (100, 513, 128)])
def test_minhash_kernel_matches_ref(d, s, p):
    from repro.kernels.minhash.ops import minhash_signatures
    from repro.kernels.minhash.ref import minhash_ref

    rng = np.random.default_rng(d * 1000 + s)
    h = rng.integers(0, 2**64, (d, s), dtype=np.uint64)
    mask = rng.random((d, s)) > 0.2
    mask[:, 0] = True  # at least one valid shingle per doc
    a = rng.integers(1, 2**32, p, dtype=np.uint64)
    b = rng.integers(0, 2**32, p, dtype=np.uint64)
    out = np.asarray(minhash_signatures(h, mask, a, b))
    h32 = (h & 0xFFFFFFFF).astype(np.uint32) ^ (h >> np.uint64(32)).astype(np.uint32)
    a32 = a.astype(np.uint32) | np.uint32(1)
    ref = np.asarray(
        minhash_ref(jnp.asarray(h32), jnp.asarray(mask), jnp.asarray(a32),
                    jnp.asarray(b.astype(np.uint32)))
    )
    np.testing.assert_array_equal(out, ref)


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "b,s,h,p,n,chunk",
    [(1, 16, 2, 8, 4, 8), (2, 64, 4, 16, 16, 16), (1, 128, 8, 32, 16, 32)],
)
def test_ssd_kernel_matches_ref(b, s, h, p, n, chunk):
    from repro.kernels.ssd_scan.ops import ssd_forward
    from repro.kernels.ssd_scan.ref import ssd_ref

    rng = np.random.default_rng(s + h)
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.standard_normal((b, s, h))) * 0.1 + 0.01, jnp.float32)
    a_log = jnp.asarray(rng.standard_normal(h) * 0.3, jnp.float32)
    bm = jnp.asarray(rng.standard_normal((b, s, 1, n)) * 0.5, jnp.float32)
    cm = jnp.asarray(rng.standard_normal((b, s, 1, n)) * 0.5, jnp.float32)

    y_k = ssd_forward(x, dt, a_log, bm, cm, chunk)
    y_r, _ = ssd_ref(x, dt, a_log, bm, cm, chunk)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_dtypes(dtype):
    from repro.kernels.ssd_scan.ops import ssd_forward
    from repro.kernels.ssd_scan.ref import ssd_ref

    rng = np.random.default_rng(0)
    b, s, h, p, n, chunk = 1, 32, 2, 16, 8, 16
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), dtype)
    dt = jnp.asarray(np.abs(rng.standard_normal((b, s, h))) * 0.1 + 0.01, jnp.float32)
    a_log = jnp.asarray(rng.standard_normal(h) * 0.3, jnp.float32)
    bm = jnp.asarray(rng.standard_normal((b, s, 1, n)) * 0.5, dtype)
    cm = jnp.asarray(rng.standard_normal((b, s, 1, n)) * 0.5, dtype)
    y_k = ssd_forward(x, dt, a_log, bm, cm, chunk)
    y_r, _ = ssd_ref(x, dt, a_log, bm, cm, chunk)
    np.testing.assert_allclose(
        np.asarray(y_k, np.float32), np.asarray(y_r, np.float32), rtol=5e-2, atol=5e-2
    )


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "b,s,hq,hkv,hd,causal,window,bq,bk",
    [
        (1, 64, 2, 2, 16, True, None, 16, 16),
        (2, 128, 4, 2, 32, True, None, 32, 32),
        (1, 96, 4, 1, 16, True, 48, 32, 32),   # MQA + window
        (2, 64, 4, 4, 16, False, None, 16, 16),  # non-causal
        (1, 100, 2, 2, 16, True, None, 32, 32),  # padding path
    ],
)
def test_flash_kernel_matches_ref(b, s, hq, hkv, hd, causal, window, bq, bk):
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_reference

    rng = np.random.default_rng(s + hq)
    q = jnp.asarray(rng.standard_normal((b, s, hq, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, hd)), jnp.float32)
    out = flash_attention(q, k, v, causal=causal, window=window, block_q=bq, block_k=bk)
    ref = attention_reference(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-3)


def test_flash_kernel_bf16():
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_reference

    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((1, 64, 4, 16)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((1, 64, 2, 16)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((1, 64, 2, 16)), jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    ref = attention_reference(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=5e-2, atol=5e-2
    )
