"""Adaptive windowed dispatcher: speculative straggler re-dispatch on the
streaming chain path, failure retries (backup wins over a failed original),
per-call redispatch deltas, failing-op attribution, and worker quarantine."""
import concurrent.futures as cf
import os
import threading
import time

import pytest

from repro.core import dispatch as D
from repro.core.dataset import DJDataset
from repro.core.engine import LocalEngine, ParallelEngine
from repro.core.executor import Executor
from repro.core.recipes import Recipe
from repro.core.registry import create_op, register
from repro.core.ops_base import Mapper
from repro.core.storage import write_jsonl
from repro.data.synthetic import make_corpus


# ---------------------------------------------------------------------------
# injected fixtures (registered so forked worker processes can rebuild them)
# ---------------------------------------------------------------------------


@register("sleep_once_mapper")
class SleepOnceMapper(Mapper):
    """Sleeps ``delay`` on a marked sample the FIRST time its block is
    attempted (atomic flag-file claim) — a speculative backup runs fast."""

    _name = "sleep_once_mapper"

    def __init__(self, flag_dir: str, delay: float = 0.8, **kw):
        super().__init__(flag_dir=flag_dir, delay=delay, **kw)

    def process_single(self, s):
        key = s.get("meta", {}).get("straggle_key")
        if key:
            try:
                os.close(os.open(os.path.join(self.params["flag_dir"], key),
                                 os.O_CREAT | os.O_EXCL | os.O_WRONLY))
                time.sleep(self.params["delay"])
            except FileExistsError:
                pass
        s["text"] = s.get("text", "").strip()
        return s


@register("io_sleep_once_mapper")
class IOSleepOnceMapper(SleepOnceMapper):
    """io_intensive variant — routes LocalEngine onto its threaded window."""

    _name = "io_sleep_once_mapper"
    io_intensive = True


@register("slow_first_attempt_mapper")
class SlowFirstAttemptMapper(Mapper):
    """Sleeps ``delay`` per marked sample on the FIRST attempt of a block
    (atomic flag-file claim per straggle_key) — a speculative backup runs
    fast and wins. The slow (losing) attempt drops a ``drained-<key>``
    marker if it ever reaches the block's last sample: the preemption
    regression test asserts that marker never appears."""

    _name = "slow_first_attempt_mapper"
    io_intensive = True  # routes LocalEngine onto its threaded window

    def __init__(self, flag_dir: str, delay: float = 0.1, **kw):
        super().__init__(flag_dir=flag_dir, delay=delay, **kw)
        self._claims = {}

    def process_single(self, s):
        key = s.get("meta", {}).get("straggle_key")
        if key:
            claimed = self._claims.get(key)
            if claimed is None:
                try:
                    os.close(os.open(os.path.join(self.params["flag_dir"], key),
                                     os.O_CREAT | os.O_EXCL | os.O_WRONLY))
                    claimed = True
                except FileExistsError:
                    claimed = False
                self._claims[key] = claimed
            if claimed:
                time.sleep(self.params["delay"])
                if s.get("meta", {}).get("last_of_block"):
                    with open(os.path.join(self.params["flag_dir"],
                                           f"drained-{key}"), "w") as f:
                        f.write("loser drained to completion")
        s["text"] = s.get("text", "").strip()
        return s


@register("prefix_once_mapper")
class PrefixOnceMapper(Mapper):
    """NON-idempotent: applied twice, the marker doubles — catches a
    speculative backup sharing (and re-mutating) the original's dicts."""

    _name = "prefix_once_mapper"

    def process_single(self, s):
        s["text"] = "X" + s.get("text", "")
        return s


@register("fail_once_setup_op")
class FailOnceSetupMapper(Mapper):
    """Worker-level failure (escapes the per-sample exception manager) on the
    first dispatch only — the retry/backup must win, not pass-through."""

    _name = "fail_once_setup_op"

    def __init__(self, flag_dir: str, **kw):
        super().__init__(flag_dir=flag_dir, **kw)

    def setup(self):
        try:
            os.close(os.open(os.path.join(self.params["flag_dir"], "failed"),
                             os.O_CREAT | os.O_EXCL | os.O_WRONLY))
        except FileExistsError:
            return
        raise RuntimeError("injected one-time worker failure")

    def process_single(self, s):
        s["text"] = s.get("text", "").upper()
        return s


@register("always_fail_setup_op")
class AlwaysFailSetupMapper(Mapper):
    _name = "always_fail_setup_op"

    def setup(self):
        raise RuntimeError("permanently broken op")

    def process_single(self, s):  # pragma: no cover — setup always raises
        return s


def _marked_blocks(n_samples=160, n_blocks=8, slow=(3,)):
    corpus = make_corpus(n_samples, seed=17)
    blocks = DJDataset.from_samples([dict(s) for s in corpus],
                                    n_blocks_hint=n_blocks).blocks
    for b in slow:
        s = dict(blocks[b].samples[0])
        s["meta"] = dict(s.get("meta", {}), straggle_key=f"blk{b}")
        blocks[b].samples[0] = s
    return blocks


# ---------------------------------------------------------------------------
# speculation on the streaming chain path
# ---------------------------------------------------------------------------


def test_chain_speculation_fires_and_output_identical(tmp_path):
    cfgs = [{"name": "sleep_once_mapper", "flag_dir": str(tmp_path), "delay": 0.8},
            {"name": "whitespace_normalization_mapper"}]
    blocks = _marked_blocks()
    # ref on UNMARKED blocks (same text output, no flag claims, no sleeping):
    # the parallel run below must see virgin flag files so originals stall
    ref = [s["text"]
           for blk, _ in LocalEngine().map_block_chain(
               [create_op(c) for c in cfgs], iter(_marked_blocks(slow=())))
           for s in blk.samples]

    eng = ParallelEngine(n_workers=2, straggler_factor=2.0, min_completions=2)
    got = [s["text"]
           for blk, _ in eng.map_block_chain([create_op(c) for c in cfgs],
                                             iter(blocks))
           for s in blk.samples]
    assert got == ref, "speculation must keep outputs byte-identical, in order"
    summary = eng.dispatch_log[-1]
    assert summary["redispatches"] >= 1, f"speculation never fired: {summary}"
    assert summary["speculation_wins"] >= 1
    assert summary["pass_throughs"] == 0
    assert eng.redispatches >= 1  # cumulative counter still maintained


def test_local_threaded_speculation_no_shared_mutation(tmp_path):
    """Thread pools share objects: a speculative backup must process its own
    copy, never re-mutating dicts the straggling original still writes."""
    cfgs = [{"name": "io_sleep_once_mapper", "flag_dir": str(tmp_path), "delay": 0.6},
            {"name": "prefix_once_mapper"}]
    ref = [s["text"]
           for blk, _ in LocalEngine().map_block_chain(
               [create_op(c) for c in cfgs], iter(_marked_blocks(slow=())))
           for s in blk.samples]
    eng = LocalEngine(n_threads=2, straggler_factor=2.0, speculate=True)
    got = [s["text"]
           for blk, _ in eng.map_block_chain([create_op(c) for c in cfgs],
                                             iter(_marked_blocks()))
           for s in blk.samples]
    assert got == ref, "threaded speculation must not double-apply mutations"
    assert all(not t.startswith("XX") for t in got)
    assert eng.dispatch_log[-1]["engine"] == "local"


def test_speculation_disabled_never_redispatches(tmp_path):
    cfgs = [{"name": "sleep_once_mapper", "flag_dir": str(tmp_path), "delay": 0.2}]
    eng = ParallelEngine(n_workers=2, speculate=False, min_completions=2,
                         straggler_factor=2.0)
    list(eng.map_block_chain([create_op(c) for c in cfgs],
                             iter(_marked_blocks())))
    assert eng.dispatch_log[-1]["redispatches"] == 0


# ---------------------------------------------------------------------------
# failure handling: retry/backup wins; pass-through only when ALL failed
# ---------------------------------------------------------------------------


def test_failed_dispatch_retries_instead_of_pass_through(tmp_path):
    op = create_op({"name": "fail_once_setup_op", "flag_dir": str(tmp_path)})
    blocks = DJDataset.from_samples(make_corpus(80, seed=5), n_blocks_hint=4).blocks
    eng = ParallelEngine(n_workers=2, speculate=False)
    out, stats = eng.map_batches(op, blocks, 64)
    texts = [s["text"] for b in out for s in b.samples]
    assert texts and all(t == t.upper() for t in texts), \
        "retried block must carry the op's REAL output, not the input pass-through"
    assert not op.errors, "a won retry is not a block failure"
    assert eng.dispatch_log[-1]["retries"] >= 1
    assert stats["redispatches"] == 0  # retries are not speculation


def test_pass_through_only_after_all_attempts_fail():
    op = create_op({"name": "always_fail_setup_op"})
    corpus = make_corpus(60, seed=9)
    blocks = DJDataset.from_samples([dict(s) for s in corpus], n_blocks_hint=3).blocks
    eng = ParallelEngine(n_workers=2, speculate=False)
    out, _ = eng.map_batches(op, blocks, 64)
    assert [s["text"] for b in out for s in b.samples] == \
           [s["text"] for s in corpus], "exhausted block passes input through"
    assert len(op.errors) == len(blocks)
    assert all("attempts" in e.error for e in op.errors)
    assert eng.dispatch_log[-1]["pass_throughs"] == len(blocks)


# ---------------------------------------------------------------------------
# per-call EngineStats delta (was: cumulative count inflating later runs)
# ---------------------------------------------------------------------------


def test_engine_stats_reports_per_call_redispatch_delta():
    eng = ParallelEngine(n_workers=2)
    eng.redispatches = 7  # as if earlier calls speculated
    op = create_op({"name": "whitespace_normalization_mapper"})
    blocks = DJDataset.from_samples(make_corpus(40, seed=2), n_blocks_hint=2).blocks
    _, stats = eng.map_batches(op, blocks, 64)
    assert stats["redispatches"] == 0, "EngineStats must report THIS call's count"
    assert eng.redispatches == 7, "cumulative counter untouched by a clean call"


# ---------------------------------------------------------------------------
# chain failures attribute the failing op (was: always pinned to ops[0])
# ---------------------------------------------------------------------------


def test_chain_failure_attributed_to_failing_op():
    cfgs = [{"name": "whitespace_normalization_mapper"},
            {"name": "always_fail_setup_op"}]
    ops = [create_op(c) for c in cfgs]
    corpus = make_corpus(40, seed=11)
    blocks = DJDataset.from_samples([dict(s) for s in corpus], n_blocks_hint=2).blocks
    eng = ParallelEngine(n_workers=2, speculate=False)
    out = list(eng.map_block_chain(ops, iter(blocks)))
    assert not ops[0].errors, "healthy op must not absorb the failure"
    assert len(ops[1].errors) == len(blocks)
    assert all("permanently broken op" in e.error for e in ops[1].errors)
    for _, stats in out:
        assert [st["errors"] for st in stats] == [0, 1], \
            "synthesized stats must pin the error to the failing op's row"
    # pass-through keeps the samples flowing
    assert sum(len(b) for b, _ in out) == len(corpus)


# ---------------------------------------------------------------------------
# worker quarantine
# ---------------------------------------------------------------------------


def test_quarantined_worker_stops_receiving_blocks():
    lock = threading.Lock()
    executed = []
    state = {"bad": None}

    def fn(item):
        wid = D._worker_id()
        with lock:
            if state["bad"] is None:
                state["bad"] = wid  # first thread to run a task goes bad
            executed.append((wid, item))
        if wid == state["bad"]:
            raise RuntimeError("wedged worker")
        time.sleep(0.005)
        return item * 2

    log = []
    with cf.ThreadPoolExecutor(2) as pool:
        disp = D.WindowedDispatcher(
            pool, 2, speculate=False, max_attempts=8, worker_failure_limit=2,
            bounce_limit=100, label="quarantine", log=log)
        results = list(disp.run(range(40), fn, lambda x: (x,)))

    assert [p for _, p, _ in results] == [x * 2 for x in range(40)]
    assert all(e is None for _, _, e in results)
    summary = log[-1]
    assert summary["quarantined"] == [state["bad"]]
    bad_execs = [i for w, i in executed if w == state["bad"]]
    # pre-quarantine in-flight submissions may still land on the bad worker;
    # once quarantined it only bounces (payload never executes there again)
    assert len(bad_execs) <= 8, f"quarantined worker kept executing: {bad_execs}"
    assert summary["bounces"] >= 1


def test_window_stays_within_bounds():
    log = []
    with cf.ThreadPoolExecutor(2) as pool:
        disp = D.WindowedDispatcher(pool, 2, speculate=False, label="w", log=log)
        results = list(disp.run(range(64), lambda x: x, lambda x: (x,)))
    assert [p for _, p, _ in results] == list(range(64))
    s = log[-1]
    assert disp.min_window <= s["window_final"] <= disp.max_window
    assert s["blocks"] == 64


# ---------------------------------------------------------------------------
# preemptive loser cancellation (ROADMAP leak: a sleeper used to occupy its
# worker until it drained the whole chain)
# ---------------------------------------------------------------------------


def test_losing_original_is_preempted_not_drained(tmp_path):
    """When a speculative backup wins, the straggling original must be
    preemptively cancelled (exit at its next batch boundary), not left
    draining on its worker: the drain marker must never appear, the summary
    must record the preempt signal, and wall-clock must beat the drain."""
    corpus = make_corpus(48, seed=23)
    blocks = DJDataset.from_samples([dict(s) for s in corpus],
                                    n_blocks_hint=6).blocks
    # block 1: every sample marked -> 24 batches x 0.12s of first-attempt
    # sleeping; the final sample drops the drain marker if ever reached
    straggler = [dict(s, meta={"straggle_key": "blk1"})
                 for s in blocks[1].samples for _ in range(3)]
    straggler[-1]["meta"] = dict(straggler[-1]["meta"], last_of_block=True)
    from repro.core.storage import SampleBlock
    blocks[1] = SampleBlock(straggler)
    total = sum(len(b.samples) for b in blocks)

    cfgs = [{"name": "slow_first_attempt_mapper", "flag_dir": str(tmp_path),
             "delay": 0.12}]
    drain_seconds = len(straggler) * 0.12  # what a drained loser would cost

    eng = LocalEngine(n_threads=2, straggler_factor=2.0, speculate=True)
    t0 = time.time()
    out = list(eng.map_block_chain([create_op(c) for c in cfgs],
                                   iter(blocks), batch_size=2))
    elapsed = time.time() - t0

    assert sum(len(b.samples) for b, _ in out) == total
    summary = eng.dispatch_log[-1]
    assert summary["speculation_wins"] >= 1, f"backup never won: {summary}"
    assert summary["preempt_signals"] >= 1, \
        f"winning backup must signal the running loser: {summary}"
    assert not os.path.exists(str(tmp_path / "drained-blk1")), \
        "the losing original drained its block instead of being preempted"
    # the engine's pool shutdown waits for the loser, so a drained loser
    # would push elapsed past drain_seconds; a preempted one exits within
    # about one batch (2 x 0.12s)
    assert elapsed < drain_seconds * 0.7, \
        f"run took {elapsed:.2f}s — the loser occupied its worker to the end"


def test_preempted_losers_are_counted():
    """Direct dispatcher check: a cooperative fn that honours should_stop is
    counted under summary['preempted'] (observed early exits)."""
    attempts = {"slow": 0}
    lock = threading.Lock()

    def fn(item, should_stop):
        if item == "slow":
            with lock:
                attempts["slow"] += 1
                first = attempts["slow"] == 1
            if first:  # the original spins until preempted; the backup is fast
                while not should_stop():
                    time.sleep(0.005)
                raise D.TaskPreempted("observed the board")
            return item
        time.sleep(0.02)  # keep the stream alive past the loser's exit
        return item

    log = []
    with cf.ThreadPoolExecutor(2) as pool:
        disp = D.WindowedDispatcher(pool, 2, straggler_factor=2.0,
                                    min_completions=2, label="preempt",
                                    log=log, preempt_board={})
        items = ["a", "b", "slow", "c", "d", "e", "f", "g"]
        results = list(disp.run(items, fn, lambda x: (x,)))
    got = [p for _, p, _ in results]
    assert got == items, "the winning backup must supply the payload"
    assert log[-1]["preempt_signals"] == 1
    assert log[-1]["preempted"] == 1
    assert log[-1]["speculation_wins"] == 1


# ---------------------------------------------------------------------------
# cross-run worker-health persistence (HealthRegistry)
# ---------------------------------------------------------------------------


def _run_dispatch(health, fail_first_n=0, n_workers=2, limit=2, items=24):
    """One dispatcher 'run' over a single REAL worker thread (slot identity
    is then deterministic: the only wid ever seen maps to slot w0) with the
    first ``fail_first_n`` executions failing."""
    calls = {"n": 0}

    def fn(item):
        calls["n"] += 1
        if calls["n"] <= fail_first_n:
            raise RuntimeError("injected worker failure")
        return item

    log = []
    with cf.ThreadPoolExecutor(1) as pool:
        disp = D.WindowedDispatcher(
            pool, n_workers, speculate=False, max_attempts=10,
            worker_failure_limit=limit, bounce_limit=3, bounce_pause=0.0,
            label="health", log=log, health=health)
        results = list(disp.run(range(items), fn, lambda x: (x,)))
    assert all(e is None for _, _, e in results)
    return log[-1]


def test_quarantine_persists_and_probation_limits_next_run(tmp_path):
    """ROADMAP item: quarantine was in-run only. A worker slot quarantined in
    run 1 must start run 2 on probation (one strike re-quarantines), and a
    clean probation run must clear it again."""
    path = str(tmp_path / "health.json")

    # run 1: two failures hit the default limit -> quarantined, persisted
    summary = _run_dispatch(D.HealthRegistry(path), fail_first_n=2, limit=2)
    assert summary["quarantined"], "run 1 must quarantine the bad worker"
    reloaded = D.HealthRegistry(path)
    assert reloaded.on_probation("w0"), \
        "quarantine must survive into the next run as probation"
    assert reloaded.total_quarantines() == 1

    # run 2: probation drops the allowance to ONE strike (limit is 3 here)
    disp = D.WindowedDispatcher(None, 2,
                                worker_failure_limit=3, health=reloaded)
    assert disp._failure_limit("some-wid") == 1, \
        "probation slot must be one-strike"
    summary2 = _run_dispatch(reloaded, fail_first_n=1, limit=3)
    assert summary2["quarantined"], \
        "a single failure must re-quarantine a probation worker"
    assert D.HealthRegistry(path).on_probation("w0")

    # run 3: a clean run recovers the slot — full allowance next time
    _run_dispatch(D.HealthRegistry(path), fail_first_n=0)
    final = D.HealthRegistry(path)
    assert not final.on_probation("w0")
    assert final.slots["w0"]["recoveries"] >= 1
    disp3 = D.WindowedDispatcher(None, 2,
                                 worker_failure_limit=3, health=final)
    assert disp3._failure_limit("any-wid") == 3


def test_health_registry_roundtrip_property(tmp_path):
    """Hypothesis property: the health file round-trips through ARBITRARY
    quarantine/failure/recovery/forgive sequences — reload always equals the
    in-memory state, and probation is exactly 'quarantined since the last
    recovery/forgive'."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    ops = st.lists(st.tuples(
        st.sampled_from(["failure", "quarantine", "recovery", "forgive"]),
        st.sampled_from(["w0", "w1", "w2"])), max_size=60)

    @settings(max_examples=60, deadline=None)
    @given(seq=ops)
    def check(seq):
        path = str(tmp_path / "h.json")
        if os.path.exists(path):
            os.remove(path)
        reg = D.HealthRegistry(path)
        expected_probation = {}
        for op, key in seq:
            getattr(reg, f"note_{op}" if op != "forgive" else "forgive")(key)
            if op == "quarantine":
                expected_probation[key] = True
            elif op in ("recovery", "forgive"):
                expected_probation[key] = False
        reg.save()
        back = D.HealthRegistry(path)
        assert back.snapshot() == reg.snapshot()
        for key, prob in expected_probation.items():
            assert back.on_probation(key) == prob
        assert back.total_quarantines() == reg.total_quarantines()

    check()


def test_corrupt_health_file_starts_fresh(tmp_path):
    path = str(tmp_path / "health.json")
    with open(path, "w") as f:
        f.write("{torn mid-write")
    reg = D.HealthRegistry(path)
    assert reg.slots == {}
    reg.note_quarantine("w0")
    reg.save()
    assert D.HealthRegistry(path).on_probation("w0")


def test_recipe_health_path_reaches_engine(tmp_path):
    """Recipe.health_path plumbs through the Executor into the engine (and
    is settable from the fluent API like any other option)."""
    from repro.api import Pipeline

    path = str(tmp_path / "health.json")
    src = str(tmp_path / "in.jsonl")
    write_jsonl(src, make_corpus(30, seed=3))
    pipe = (Pipeline.read_jsonl(src)
            .map("whitespace_normalization_mapper")
            .options(health_path=path, engine="parallel", np=2))
    eng = Executor(pipe.to_recipe())._make_engine()
    assert eng.health is not None and eng.health.path == path
    # pre-seeded probation is visible to the engine's dispatchers
    reg = D.HealthRegistry(path)
    reg.note_quarantine("w0")
    reg.save()
    eng2 = Executor(pipe.to_recipe())._make_engine()
    assert eng2.health.on_probation("w0")


# ---------------------------------------------------------------------------
# executor / report surfacing
# ---------------------------------------------------------------------------


def test_run_report_surfaces_dispatch_and_monitor_rows(tmp_path):
    src = str(tmp_path / "in.jsonl")
    write_jsonl(src, make_corpus(120, seed=21))
    r = Recipe(name="d", dataset_path=src, engine="parallel", np=2,
               process=[{"name": "whitespace_normalization_mapper"},
                        {"name": "text_length_filter", "min_val": 10}],
               block_bytes=4096)
    _, rep = Executor(r).run()
    assert rep.streaming
    assert rep.dispatch, "RunReport.dispatch must carry per-segment summaries"
    assert rep.dispatch[0]["label"] == "+".join(rep.plan)
    for key in ("redispatches", "quarantined", "window_final"):
        assert key in rep.dispatch[0]
    assert all("redispatches" in row for row in rep.per_op)
    # explain() documents the adaptive-dispatch policy without running
    ex = Executor(r).explain()
    assert ex["dispatch"]["speculation"] is True
    assert ex["dispatch"]["window"]["adaptive"] is True
