"""Recipe YAML round-trip (ISSUE 9 satellite): the simple-YAML subset must
either reload a byte-equal Recipe or refuse loudly at dump time — silent
field drops / type flips are the failure mode these tests pin down.

The property (dump -> parse == identity, or ValueError at dump) runs on
seeded-random recipes always; a hypothesis variant widens the value space
where hypothesis is installed."""
import random

import pytest

from repro.core.recipes import (
    Recipe, dump_simple_yaml, parse_simple_yaml,
)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _roundtrip(r: Recipe) -> Recipe:
    return Recipe.from_dict(parse_simple_yaml(dump_simple_yaml(r.to_dict())))


def test_previously_dropped_fields_survive():
    r = Recipe(name="t", dataset_path="d.jsonl", shards="auto",
               mem_budget=1 << 20, health_path="h.json",
               row_range=[10, 250],
               process=[{"name": "language_heuristic_filter",
                         "keep_langs": ["en", "zh"]}],
               fixed_plan=[{"name": "text_length_filter", "min_val": 10.5}])
    back = _roundtrip(r)
    assert back == r
    assert back.shards == "auto" and back.mem_budget == 1 << 20
    assert back.health_path == "h.json" and back.row_range == [10, 250]
    assert back.fixed_plan == r.fixed_plan


def test_trace_stays_runtime_internal():
    r = Recipe(name="t", trace={"trace_id": "abc", "span_id": "def"})
    assert _roundtrip(r).trace is None
    assert "trace" not in dump_simple_yaml(r.to_dict())


def test_unrepresentable_values_refuse_loudly():
    for r in (
        Recipe(fixed_plan=[{"name": "fused_op", "ops": [{"name": "a"}]}]),
        Recipe(process=[{"name": "x", "vals": ["a,b"]}]),
        Recipe(process=[{"name": "x", "arg": "  padded  "}]),
        Recipe(name="looks_like_number", dataset_path="123"),
    ):
        with pytest.raises(ValueError, match="save as .json"):
            dump_simple_yaml(r.to_dict())


def _random_recipe(rng: random.Random) -> Recipe:
    words = ["data", "out", "x1", "en", "zh", "auto", "deep/path.jsonl"]
    def scalar():
        return rng.choice([
            rng.randrange(-100, 100), rng.uniform(-5, 5) + 0.5,
            True, False, rng.choice(words),
            [rng.choice(words) for _ in range(rng.randrange(0, 3))],
            [rng.randrange(0, 9) for _ in range(rng.randrange(0, 3))],
        ])
    process = [{"name": f"op_{i}",
                **{f"a{j}": scalar() for j in range(rng.randrange(0, 3))}}
               for i in range(rng.randrange(0, 4))]
    return Recipe(
        name=rng.choice(words),
        dataset_path=rng.choice([None, "in.jsonl"]),
        export_path=rng.choice([None, "out.jsonl"]),
        np=rng.randrange(1, 8), engine=rng.choice(["local", "parallel"]),
        use_fusion=rng.random() < 0.5, use_reordering=rng.random() < 0.5,
        insight=rng.random() < 0.5,
        block_bytes=rng.choice([None, 1 << 16]),
        health_path=rng.choice([None, "h.json"]),
        mem_budget=rng.choice([None, 1 << 20]),
        shards=rng.choice([0, 3, "auto"]),
        row_range=rng.choice([None, [0, rng.randrange(1, 500)]]),
        process=process,
        fixed_plan=rng.choice([None, [dict(c) for c in process]]),
    )


def _check_roundtrip_or_loud(r: Recipe) -> None:
    try:
        text = dump_simple_yaml(r.to_dict())
    except ValueError:
        return  # refusing loudly is the allowed alternative
    back = Recipe.from_dict(parse_simple_yaml(text))
    assert back == dataclass_with_trace_dropped(r)


def dataclass_with_trace_dropped(r: Recipe) -> Recipe:
    import dataclasses
    return dataclasses.replace(r, trace=None)


def test_random_recipes_roundtrip_seeded():
    rng = random.Random(29)
    for _ in range(200):
        _check_roundtrip_or_loud(_random_recipe(rng))


def test_save_load_yaml_and_json_agree(tmp_path):
    r = Recipe(name="t", dataset_path="d.jsonl", shards="auto",
               row_range=[0, 5],
               process=[{"name": "text_length_filter", "min_val": 3}])
    yml, js = str(tmp_path / "r.yaml"), str(tmp_path / "r.json")
    r.save(yml)
    r.save(js)
    assert Recipe.load(yml) == Recipe.load(js) == r


if HAVE_HYPOTHESIS:

    _scalar_st = st.one_of(
        st.integers(-10**6, 10**6),
        st.booleans(),
        st.text(alphabet=st.characters(codec="utf-8",
                                       categories=("L", "N")),
                min_size=0, max_size=20),
        st.lists(st.integers(0, 99), max_size=4),
        st.lists(st.text(alphabet="abcxyz", min_size=1, max_size=6),
                 max_size=3),
    )

    @given(st.dictionaries(
        st.sampled_from(["name", "dataset_path", "engine", "shards",
                         "health_path", "mem_budget", "np", "row_range"]),
        _scalar_st, max_size=5))
    @settings(max_examples=100, deadline=None)
    def test_random_recipes_roundtrip_property(fields):
        try:
            r = Recipe.from_dict(fields)
        except TypeError:
            return  # field/type mismatch at construction — out of scope
        _check_roundtrip_or_loud(r)
