"""Trace continuity + SLO views across the cluster (ISSUE 8 acceptance):
a 4-shard / 2-runner dedup job with one SIGKILL failover must still merge
into ONE trace (single job root, an attempt=2 re-lease span, zero
orphans) exportable as valid Chrome-trace JSON, and GET /cluster/slo must
serve queue-wait percentiles + per-runner throughput off log.jsonl."""
import json
import time

import pytest

from repro.api.cluster import ClusterQueue
from repro.api.slo import cluster_slo
from repro.core import obs
from repro.interface.cli import main as cli_main
from cluster_harness import (
    checkpoint_stages, make_sharded_recipe, reference_output, sigkill_runner,
    start_runner, stop_runner, wait_for, write_corpus,
)

pytestmark = pytest.mark.slow


def test_trace_continuity_across_sigkill_failover(tmp_path):
    """One sharded job, one killed runner, one merged trace."""
    src = write_corpus(str(tmp_path / "corpus.jsonl"), n=120)
    out = str(tmp_path / "out.jsonl")
    recipe = make_sharded_recipe(src, out, shards=4)
    recipe["process"].insert(1, {"name": "sleep_mapper", "delay": 0.05})
    ref = reference_output(recipe, str(tmp_path / "ref.jsonl"))

    q = ClusterQueue(str(tmp_path / "cluster"), lease_ttl=2.0)
    jid = q.submit(recipe)
    tr = q.read_spec(jid)["trace"]
    assert tr["trace_id"] and tr["root_span"], \
        "cluster submit must mint the trace ids up front"

    lead = start_runner(q.dir, "lead", lease_ttl=2.0)
    victim = None
    try:
        wait_for(lambda: q.current_lease(jid) is not None, 60,
                 message="parent claim")
        wait_for(lambda: len(q.shard_tasks(jid)) >= 4, 60,
                 message="shard tasks published")
        from repro.core.dedup.sharded import MAP_DELAY_ENV

        victim = start_runner(q.dir, "victim", lease_ttl=2.0,
                              extra_env={MAP_DELAY_ENV: "30"})

        def victim_map_task():
            for t in q.shard_tasks(jid):
                if "~s" in t:
                    lease = q.current_lease(t)
                    if lease is not None and lease.runner_id == "victim":
                        return t
            return None

        wait_for(lambda: victim_map_task() is not None, 60,
                 message="victim claims a map shard")
        vt = victim_map_task()
        wait_for(lambda: len(checkpoint_stages(q, vt)) >= 1, 60,
                 message="victim prefix checkpoint")
        time.sleep(0.2)
        sigkill_runner(victim)
        victim = None

        wait_for(lambda: q.state_of(jid) == "succeeded", 180,
                 message="sharded failover completion")
        with open(out, "rb") as f:
            assert f.read() == ref
        # the lead's parent-lease span flushes moments after the result
        # lands — wait for the spill, don't race it
        wait_for(lambda: any(
            s.get("kind") == "lease" and s.get("name") == f"lease:{jid}"
            for s in obs.read_spills(q.obs_dir())), 30,
            message="parent lease span spilled")
    finally:
        for p in (lead, victim):
            if p is not None:
                try:
                    stop_runner(p)
                except Exception:
                    pass

    spans = obs.merge_trace(q.obs_dir(), tr["trace_id"])
    tree = obs.span_tree(spans)

    # ONE job root — the parent's, span_id minted at submit — and no
    # orphans: the SIGKILLed attempt's unflushed spans are simply absent
    assert tree["roots"] == [tr["root_span"]]
    root = tree["by_id"][tr["root_span"]]
    assert root["kind"] == "job" and root["attrs"]["state"] == "succeeded"
    assert tree["orphans"] == [], \
        f"orphan spans after failover: {tree['orphans']}"

    kinds = {s["kind"] for s in spans}
    assert {"job", "shards", "lease", "run", "op"} <= kinds

    # the killed shard was re-leased: its accepted attempt is 2, and the
    # lease span from attempt 2 made it into the merged trace
    lease_attempts = [s["attrs"].get("attempt") for s in spans
                      if s["kind"] == "lease" and s["name"] == f"lease:{vt}"]
    assert 2 in lease_attempts, \
        f"re-lease span (attempt=2) missing for {vt}: {lease_attempts}"
    # every shard task's root span hangs off the parent job span
    task_roots = [s for s in spans
                  if s["kind"] == "job" and s["span_id"] != tr["root_span"]]
    assert task_roots and all(
        s["parent_id"] == tr["root_span"] for s in task_roots)
    # the shard-plan span recorded how the job was split
    plan = next(s for s in spans if s["kind"] == "shards")
    assert plan["attrs"]["n_shards"] == 4

    # CLI export: valid catapult JSON, loadable span tree
    trace_path = str(tmp_path / "TRACE_job.json")
    assert cli_main(["trace", jid, "--cluster_dir", q.dir,
                     "--out", trace_path]) == 0
    doc = json.load(open(trace_path))
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert len(xs) == len(spans)
    assert all({"name", "cat", "ts", "dur", "pid", "tid"} <= set(e)
               for e in xs)

    # SLO view off the same cluster dir: the failover shows up as a
    # failover count, both runners show up in throughput
    slo = cluster_slo(q.dir)
    assert slo["failovers"] >= 1
    assert slo["queue_wait"]["n"] >= 1
    assert slo["queue_wait"]["p95"] >= slo["queue_wait"]["p50"] >= 0.0
    assert "lead" in slo["throughput"]
    assert slo["throughput"]["lead"]["rows_per_second"] > 0
