"""MoE layer: scatter dispatch vs einsum oracle; capacity semantics; grads."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import moe_layer, moe_capacity


def _mk(b=2, s=16, d=8, e=4, f=12, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((b, s, d)), jnp.float32)
    router = jnp.asarray(rng.standard_normal((d, e)) * 0.5, jnp.float32)
    wg = jnp.asarray(rng.standard_normal((e, d, f)) * 0.2, jnp.float32)
    wu = jnp.asarray(rng.standard_normal((e, d, f)) * 0.2, jnp.float32)
    wd = jnp.asarray(rng.standard_normal((e, f, d)) * 0.2, jnp.float32)
    return x, router, wg, wu, wd


@pytest.mark.parametrize("top_k,cf", [(1, 2.0), (2, 1.25), (2, 4.0)])
def test_scatter_matches_einsum(top_k, cf):
    x, router, wg, wu, wd = _mk()
    o1, a1 = moe_layer(x, router, wg, wu, wd, top_k, cf, dispatch="scatter")
    o2, a2 = moe_layer(x, router, wg, wu, wd, top_k, cf, dispatch="einsum")
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-5)


def test_grads_match():
    x, router, wg, wu, wd = _mk(seed=3)

    def loss(disp):
        def f(x, router, wg, wu, wd):
            o, a = moe_layer(x, router, wg, wu, wd, 2, 1.5, dispatch=disp)
            return jnp.sum(o * o) + 0.01 * a
        return jax.grad(f, argnums=(0, 1, 2, 3, 4))(x, router, wg, wu, wd)

    g1, g2 = loss("scatter"), loss("einsum")
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-3, atol=1e-4)


def test_capacity_drops_tokens():
    """With a tiny capacity factor, overflow tokens are dropped (not NaN)."""
    x, router, wg, wu, wd = _mk(s=64, seed=5)
    o, _ = moe_layer(x, router, wg, wu, wd, 2, 0.1, dispatch="scatter")
    assert np.isfinite(np.asarray(o)).all()
    # some token outputs must be exactly zero (fully dropped)
    norms = np.abs(np.asarray(o)).sum(-1)
    assert (norms == 0).any() or moe_capacity(64, 2, 4, 0.1) >= 4


def test_full_capacity_keeps_all():
    x, router, wg, wu, wd = _mk(s=8)
    o, _ = moe_layer(x, router, wg, wu, wd, 1, 8.0, dispatch="scatter")
    norms = np.abs(np.asarray(o)).sum(-1)
    assert (norms > 0).all()
