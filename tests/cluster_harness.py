"""Fault-injection utilities for the cluster subsystem tests and bench.

Spawns REAL runner processes (``python -m repro.interface.cli runner``)
against a shared ``cluster_dir``, lets tests SIGKILL one mid-segment, and
provides the polling/assertion helpers the failover tests (and the
server-restart tests) share. Importable from both ``tests/`` and
``benchmarks/`` — no pytest dependency.
"""
from __future__ import annotations

import os
import signal
import subprocess
import sys
import time
from typing import Callable, Dict, List, Optional

REPO_SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")


def runner_env() -> Dict[str, str]:
    env = dict(os.environ)
    env["PYTHONPATH"] = REPO_SRC + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    return env


def start_runner(cluster_dir: str, runner_id: str, *, lease_ttl: float = 2.0,
                 poll: float = 0.1, capacity: int = 1,
                 defer: Optional[float] = None,
                 once: bool = False,
                 extra_env: Optional[Dict[str, str]] = None) -> subprocess.Popen:
    """Spawn a real runner subprocess leasing from ``cluster_dir``.
    ``extra_env`` injects per-runner env vars (e.g. the shard-map delay
    knob that widens the SIGKILL window in fault-injection tests)."""
    cmd = [sys.executable, "-m", "repro.interface.cli", "runner",
           "--cluster_dir", cluster_dir, "--runner_id", runner_id,
           "--lease_ttl", str(lease_ttl), "--poll", str(poll),
           "--capacity", str(capacity)]
    if defer is not None:
        cmd += ["--defer", str(defer)]
    if once:
        cmd.append("--once")
    env = runner_env()
    if extra_env:
        env.update(extra_env)
    return subprocess.Popen(cmd, env=env,
                            stdout=subprocess.PIPE, stderr=subprocess.STDOUT)


def stop_runner(proc: subprocess.Popen, timeout: float = 5.0) -> None:
    if proc.poll() is None:
        proc.terminate()
        try:
            proc.wait(timeout=timeout)
        except subprocess.TimeoutExpired:
            proc.kill()
            proc.wait(timeout=timeout)
    if proc.stdout:
        proc.stdout.close()


def sigkill_runner(proc: subprocess.Popen) -> None:
    """The fault injection: no cleanup, no lease release, no goodbye."""
    os.kill(proc.pid, signal.SIGKILL)
    proc.wait(timeout=10)
    if proc.stdout:
        proc.stdout.close()


def wait_for(pred: Callable[[], bool], timeout: float = 30.0,
             interval: float = 0.05, message: str = "condition") -> None:
    deadline = time.time() + timeout
    while time.time() < deadline:
        if pred():
            return
        time.sleep(interval)
    raise AssertionError(f"timed out after {timeout}s waiting for {message}")


def make_recipe(src: str, out: str, *, slow_delay: float = 0.02,
                fast_delay: float = 0.0, min_len: int = 20) -> Dict:
    """Multi-segment job recipe for kill-mid-job tests: a fast mapper chain,
    a dedup BARRIER (forcing a segment-boundary checkpoint), then a slow
    chain the test kills a runner inside. Fusion/reordering are pinned off
    so every attempt derives the identical plan — the resume contract keys
    checkpoints to the optimized plan's prefix signatures."""
    process: List[Dict] = [{"name": "whitespace_normalization_mapper"}]
    if fast_delay:
        process.append({"name": "sleep_mapper", "delay": fast_delay})
    process += [
        {"name": "document_minhash_deduplicator", "jaccard_threshold": 0.7},
        {"name": "sleep_mapper", "delay": slow_delay},
        {"name": "text_length_filter", "min_val": min_len},
    ]
    return {
        "name": "cluster-harness-job",
        "dataset_path": src,
        "export_path": out,
        "process": process,
        "use_fusion": False,
        "use_reordering": False,
    }


def make_sharded_recipe(src: str, out: str, *, shards: int = 3,
                        streaming: str = "exact", min_len: int = 20) -> Dict:
    """Recipe for intra-job scale-out tests: a cheap mapper prefix, a
    STREAMING minhash dedup (the band-partitioned shard core), then a
    suffix filter that runs after the reconciliation barrier. Exact mode
    must be byte-identical to the unsharded run."""
    return {
        "name": "cluster-sharded-job",
        "dataset_path": src,
        "export_path": out,
        "shards": shards,
        "process": [
            {"name": "whitespace_normalization_mapper"},
            {"name": "document_minhash_deduplicator",
             "jaccard_threshold": 0.7, "streaming": streaming},
            {"name": "text_length_filter", "min_val": min_len},
        ],
        "use_fusion": False,
        "use_reordering": False,
    }


def write_corpus(path: str, n: int = 120, seed: int = 0) -> str:
    from repro.core.storage import write_jsonl
    from repro.data.synthetic import make_corpus

    write_jsonl(path, make_corpus(n, seed=seed))
    return path


def reference_output(recipe: Dict, out: str) -> bytes:
    """Uninterrupted single-process run of the same recipe — the
    byte-identity oracle for failover tests."""
    from repro.core.executor import Executor
    from repro.core.recipes import Recipe

    ref = dict(recipe, export_path=out, checkpoint_dir=None)
    Executor(Recipe.from_dict(ref)).run_streaming(materialize=False)
    with open(out, "rb") as f:
        return f.read()


def checkpoint_stages(queue, job_id: str) -> List[str]:
    """Names of persisted stage files for a job (mid-run progress signal)."""
    d = queue.checkpoint_dir(job_id)
    try:
        return sorted(n for n in os.listdir(d)
                      if n.startswith("stage-") and n.endswith(".jsonl"))
    except FileNotFoundError:
        return []


def lease_owner(queue, job_id: str) -> Optional[str]:
    lease = queue.current_lease(job_id)
    return None if lease is None else lease.runner_id
