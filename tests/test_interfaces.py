"""Interface layer: RESTful server, NL agent, CLI."""
import json
import urllib.request

import pytest

from repro.core.dataset import DJDataset
from repro.core.storage import write_jsonl
from repro.data.synthetic import make_corpus
from repro.interface.nl import parse_intent, run_request


def test_nl_intent_parsing():
    turns = parse_intent("Please filter out too short text samples, minimum 120 chars")
    assert turns[0].function == "text_length_filter"
    assert turns[0].arguments["min_val"] == 120
    turns = parse_intent("deduplicate the corpus and lowercase everything")
    fns = {t.function for t in turns}
    assert "document_minhash_deduplicator" in fns and "lowercase_mapper" in fns
    turns = parse_intent("make me a sandwich")
    assert turns[0].function is None


def test_nl_executes_ops():
    ds = DJDataset.from_samples(make_corpus(100, seed=1))
    out, turns = run_request("filter out short text samples, minimum 300", ds)
    assert turns[0].result["status"] == "SUCCESS"
    assert len(out) < len(ds)
    assert all(len(s["text"]) >= 300 for s in out)


def test_restful_server(tmp_path):
    from repro.interface.server import serve

    src = str(tmp_path / "d.jsonl")
    write_jsonl(src, make_corpus(80, seed=2))
    srv = serve(port=0)
    port = srv.server_address[1]
    try:
        with urllib.request.urlopen(f"http://127.0.0.1:{port}/ops") as r:
            ops = json.loads(r.read())["ops"]
        assert any(o["name"] == "text_length_filter" for o in ops)

        req = urllib.request.Request(
            f"http://127.0.0.1:{port}/run/text_length_filter?dataset_path={src}",
            data=json.dumps({"min_val": 300}).encode(),
            headers={"Content-Type": "application/json"},
        )
        with urllib.request.urlopen(req) as r:
            out = json.loads(r.read())
        assert out["status"] == "ok" and out["n_out"] < 80

        req = urllib.request.Request(
            f"http://127.0.0.1:{port}/process?dataset_path={src}",
            data=json.dumps({
                "process": [
                    {"name": "whitespace_normalization_mapper"},
                    {"name": "words_num_filter", "min_val": 10},
                ]
            }).encode(),
        )
        with urllib.request.urlopen(req) as r:
            out = json.loads(r.read())
        assert out["status"] == "ok" and out["n_out"] <= 80 and len(out["plan"]) >= 1
    finally:
        srv.shutdown()


def test_cli(tmp_path, capsys):
    from repro.core.recipes import Recipe
    from repro.interface.cli import main

    src = str(tmp_path / "d.jsonl")
    write_jsonl(src, make_corpus(60, seed=3))
    assert main(["list-ops"]) == 0
    assert "text_length_filter" in capsys.readouterr().out

    rec = tmp_path / "r.json"
    rec.write_text(json.dumps({
        "name": "cli-test", "dataset_path": src,
        "export_path": str(tmp_path / "o.jsonl"),
        "process": [{"name": "text_length_filter", "min_val": 100}],
    }))
    assert main(["process", "--config", str(rec)]) == 0
    out = capsys.readouterr().out
    assert "cli-test" in out

    assert main(["analyze", "--dataset_path", src]) == 0
    assert "text_len" in capsys.readouterr().out
