"""Interface layer: RESTful server, NL agent, CLI."""
import json
import time
import urllib.error
import urllib.request

import pytest

from repro.core.dataset import DJDataset
from repro.core.storage import write_jsonl
from repro.data.synthetic import make_corpus
from repro.interface.nl import build_pipeline, parse_intent, run_request


from repro.core.ops_base import Mapper
from repro.core.registry import register


@register("sleepy_mapper")
class SleepyMapper(Mapper):
    """Test-only slow mapper: makes async jobs observably long-running."""

    def __init__(self, delay: float = 0.002, **kw):
        super().__init__(delay=delay, **kw)
        self.delay = delay

    def process_single(self, sample):
        time.sleep(self.delay)
        return sample


def _get(url):
    with urllib.request.urlopen(url) as r:
        return r.status, json.loads(r.read())


def _req(url, data=None, method="POST"):
    req = urllib.request.Request(url, data=data, method=method,
                                 headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req) as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def test_nl_intent_parsing():
    turns = parse_intent("Please filter out too short text samples, minimum 120 chars")
    assert turns[0].function == "text_length_filter"
    assert turns[0].arguments["min_val"] == 120
    turns = parse_intent("deduplicate the corpus and lowercase everything")
    fns = {t.function for t in turns}
    assert "document_minhash_deduplicator" in fns and "lowercase_mapper" in fns
    turns = parse_intent("make me a sandwich")
    assert turns[0].function is None


def test_nl_executes_ops():
    ds = DJDataset.from_samples(make_corpus(100, seed=1))
    out, turns = run_request("filter out short text samples, minimum 300", ds)
    assert turns[0].result["status"] == "SUCCESS"
    assert len(out) < len(ds)
    assert all(len(s["text"]) >= 300 for s in out)


def test_restful_server(tmp_path):
    from repro.interface.server import serve

    src = str(tmp_path / "d.jsonl")
    write_jsonl(src, make_corpus(80, seed=2))
    srv = serve(port=0)
    port = srv.server_address[1]
    try:
        with urllib.request.urlopen(f"http://127.0.0.1:{port}/ops") as r:
            ops = json.loads(r.read())["ops"]
        assert any(o["name"] == "text_length_filter" for o in ops)

        req = urllib.request.Request(
            f"http://127.0.0.1:{port}/run/text_length_filter?dataset_path={src}",
            data=json.dumps({"min_val": 300}).encode(),
            headers={"Content-Type": "application/json"},
        )
        with urllib.request.urlopen(req) as r:
            out = json.loads(r.read())
        assert out["status"] == "ok" and out["n_out"] < 80

        req = urllib.request.Request(
            f"http://127.0.0.1:{port}/process?dataset_path={src}",
            data=json.dumps({
                "process": [
                    {"name": "whitespace_normalization_mapper"},
                    {"name": "words_num_filter", "min_val": 10},
                ]
            }).encode(),
        )
        with urllib.request.urlopen(req) as r:
            out = json.loads(r.read())
        assert out["status"] == "ok" and out["n_out"] <= 80 and len(out["plan"]) >= 1
    finally:
        srv.shutdown()


def test_nl_span_aware_number_binding():
    turns = parse_intent("drop short text under 50 and dedup at threshold 0.8")
    by_fn = {t.function: t.arguments for t in turns}
    assert by_fn["text_length_filter"]["min_val"] == 50
    assert by_fn["document_minhash_deduplicator"]["jaccard_threshold"] == 0.8
    # no cross-contamination: the 0.8 never reached the text filter
    assert "threshold" not in by_fn["text_length_filter"]
    assert by_fn["document_minhash_deduplicator"]["jaccard_threshold"] != 50

    # a greedy intent regex spanning the whole request must not steal a
    # bare number from the nearer intent
    turns = parse_intent("filter low quality below 0.6 and drop short text")
    by_fn = {t.function: t.arguments for t in turns}
    assert by_fn["quality_score_filter"]["min_val"] == 0.6
    assert by_fn["text_length_filter"]["min_val"] == 80  # default kept


def test_nl_emits_pipeline():
    pipe, turns = build_pipeline("lowercase everything then dedup the corpus")
    names = [s["name"] for s in pipe._steps]
    assert names == ["lowercase_mapper", "document_minhash_deduplicator"]
    info = pipe.explain()  # lazy plan, explainable without a source
    assert info["segments"][-1]["barrier"] is True


def test_restful_error_codes(tmp_path):
    from repro.interface.server import serve

    src = str(tmp_path / "d.jsonl")
    write_jsonl(src, make_corpus(20, seed=7))
    srv = serve(port=0)
    port = srv.server_address[1]
    base = f"http://127.0.0.1:{port}"
    try:
        # unknown op name -> 404 structured payload (was a 500 KeyError)
        code, out = _req(f"{base}/run/nope_mapper?dataset_path={src}", b"{}")
        assert code == 404 and out["error"]["type"] == "unknown_op"
        # malformed JSON body -> 400 (was a 500)
        code, out = _req(f"{base}/run/lowercase_mapper?dataset_path={src}",
                         b"{not json")
        assert code == 400 and out["error"]["type"] == "malformed_json"
        # bad kwargs -> 400 with the typed-signature message
        code, out = _req(f"{base}/run/text_length_filter?dataset_path={src}",
                         json.dumps({"min_len": 5}).encode())
        assert code == 400 and out["error"]["type"] == "invalid_params"
        # unknown op inside a recipe -> 404
        code, out = _req(f"{base}/process?dataset_path={src}",
                         json.dumps({"process": [{"name": "bogus_op"}]}).encode())
        assert code == 404 and out["error"]["type"] == "unknown_op"
        # op metadata now exposes the typed signature
        code, out = _get(f"{base}/ops/text_length_filter")
        assert code == 200
        assert {p["name"] for p in out["params"]} == {"min_val", "max_val"}
    finally:
        srv.shutdown()
        srv.server_close()


def test_restful_job_lifecycle(tmp_path):
    from repro.interface.server import serve

    src = str(tmp_path / "d.jsonl")
    write_jsonl(src, make_corpus(200, seed=8))
    out_path = str(tmp_path / "job.jsonl")
    srv = serve(port=0)
    port = srv.server_address[1]
    base = f"http://127.0.0.1:{port}"
    try:
        spec = {
            "dataset_path": src, "export_path": out_path,
            "process": [{"name": "sleepy_mapper", "delay": 0.02}],
            "block_bytes": 512, "use_fusion": False, "use_reordering": False,
        }
        t0 = time.time()
        # typed fields in the query string must be ignored (np=9 as the
        # STRING "9" used to pass validation and crash the worker)
        code, out = _req(f"{base}/jobs?np=9&use_fusion=true",
                         json.dumps(spec).encode())
        submit_seconds = time.time() - t0
        assert code == 202 and out["state"] in ("queued", "running")
        assert submit_seconds < 1.0  # returns immediately; the run takes ~4s
        job_id = out["job_id"]

        # poll: per-op progress rows fill while the job runs
        deadline = time.time() + 30
        rows = []
        while time.time() < deadline:
            code, st = _get(f"{base}/jobs/{job_id}")
            rows = st["progress"]["per_op"]
            if st["state"] == "running" and rows and rows[0]["in"] > 0:
                break
            time.sleep(0.02)
        else:
            pytest.fail("job never reported per-op progress")
        assert rows[0]["op"] == "sleepy_mapper" and rows[0]["in"] < 200

        # cancel mid-run
        code, out = _req(f"{base}/jobs/{job_id}", method="DELETE")
        assert code == 202
        deadline = time.time() + 30
        while time.time() < deadline:
            code, st = _get(f"{base}/jobs/{job_id}")
            if st["state"] not in ("queued", "running"):
                break
            time.sleep(0.02)
        assert st["state"] == "cancelled"

        # job appears in the listing; unknown ids 404
        code, listing = _get(f"{base}/jobs")
        assert any(j["job_id"] == job_id for j in listing["jobs"])
        code, out = _req(f"{base}/jobs/missing", method="DELETE")
        assert code == 404 and out["error"]["type"] == "unknown_job"
        code, out = _req(f"{base}/jobs",
                         json.dumps({"dataset_path": src,
                                     "process": [{"name": "no_such"}]}).encode())
        assert code == 404 and out["error"]["type"] == "unknown_op"
        code, out = _req(f"{base}/jobs", json.dumps({"dataset_path": src}).encode())
        assert code == 400 and out["error"]["type"] == "missing_param"
    finally:
        srv.shutdown()
        srv.server_close()


def test_cli(tmp_path, capsys):
    from repro.core.recipes import Recipe
    from repro.interface.cli import main

    src = str(tmp_path / "d.jsonl")
    write_jsonl(src, make_corpus(60, seed=3))
    assert main(["list-ops"]) == 0
    assert "text_length_filter" in capsys.readouterr().out

    rec = tmp_path / "r.json"
    rec.write_text(json.dumps({
        "name": "cli-test", "dataset_path": src,
        "export_path": str(tmp_path / "o.jsonl"),
        "process": [{"name": "text_length_filter", "min_val": 100}],
    }))
    assert main(["process", "--config", str(rec)]) == 0
    out = capsys.readouterr().out
    assert "cli-test" in out

    assert main(["analyze", "--dataset_path", src]) == 0
    assert "text_len" in capsys.readouterr().out


def test_cli_explain_and_auto_analyze(tmp_path, capsys):
    from repro.interface.cli import main

    src = str(tmp_path / "d.jsonl")
    write_jsonl(src, make_corpus(40, seed=9))
    rec = tmp_path / "r.json"
    rec.write_text(json.dumps({
        "name": "explain-test", "dataset_path": src,
        "process": [
            {"name": "text_length_filter", "min_val": 100},
            {"name": "words_num_filter", "min_val": 5},
            {"name": "document_minhash_deduplicator"},
        ],
    }))
    assert main(["explain", "--config", str(rec)]) == 0
    out = capsys.readouterr().out
    assert "optimized:" in out and "segment" in out
    assert "fused<" in out  # the two filters were fused
    assert "[barrier]: document_minhash_deduplicator" in out

    # --auto used to be parsed but silently ignored; now it widens the
    # stat-op set beyond the 4 defaults
    assert main(["analyze", "--dataset_path", src]) == 0
    default_out = capsys.readouterr().out
    assert main(["analyze", "--dataset_path", src, "--auto"]) == 0
    auto_out = capsys.readouterr().out
    assert "text_len" in auto_out
    assert len(auto_out.splitlines()) > len(default_out.splitlines())


def test_analyze_does_not_mutate_samples():
    from repro.api import analyze

    samples = make_corpus(30, seed=10)
    before = [json.dumps(s, sort_keys=True) for s in samples]
    res = analyze(samples)
    assert res["n"] == 30 and "text_len" in res["numeric"]
    assert [json.dumps(s, sort_keys=True) for s in samples] == before
