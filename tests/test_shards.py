"""Intra-job scale-out (repro.api.shards + repro.core.dedup.sharded):
shard-task protocol units, in-process sharded execution vs the unsharded
oracle (dedup/chain/barrier modes, byte identity), band-partitioned reduce
idempotence, the zero-copy columnar hand-off in ShardedEngine, the
observability surfaces, and the N-shard SIGKILL failover suite."""
import json
import os
import time

import pytest

from repro.api import shards as shards_mod
from repro.api.cluster import ClusterQueue, ClusterRunner
from repro.api.shards import (
    finalize_task_id, is_shard_task, map_task_id, parent_of, reduce_task_id,
    shard_ranges, split_plan, task_sort_key,
)
from cluster_harness import (
    checkpoint_stages, make_sharded_recipe, reference_output, sigkill_runner,
    start_runner, stop_runner, wait_for, write_corpus,
)


# ---------------------------------------------------------------------------
# protocol units (no execution)
# ---------------------------------------------------------------------------


def test_task_id_helpers_and_sort_key():
    assert map_task_id("j", 2) == "j~s2"
    assert reduce_task_id("j", 0) == "j~r0"
    assert finalize_task_id("j") == "j~fin"
    assert is_shard_task("j~s0") and is_shard_task("j~fin")
    assert not is_shard_task("plain-job")
    assert parent_of("j~s0") == parent_of("j~r1") == parent_of("j~fin") == "j"
    ids = ["j~fin", "j~r1", "j~s10", "j~s2", "j~r0", "j~s0"]
    assert sorted(ids, key=task_sort_key) == \
        ["j~s0", "j~s2", "j~s10", "j~r0", "j~r1", "j~fin"], \
        "maps before reduces before finalize, numeric within kind"


def test_shard_ranges_cover_contiguously():
    for n_rows, n_shards in [(10, 3), (7, 7), (100, 4), (5, 2), (1, 1)]:
        ranges = shard_ranges(n_rows, n_shards)
        assert ranges[0][0] == 0 and ranges[-1][1] == n_rows
        for (a, b), (c, d) in zip(ranges, ranges[1:]):
            assert b == c and a < b and c < d, \
                "ranges must be contiguous, ordered, non-empty"


def test_split_plan_classifies_modes():
    dd = split_plan([
        {"name": "whitespace_normalization_mapper"},
        {"name": "document_minhash_deduplicator", "streaming": "exact"},
        {"name": "text_length_filter", "min_val": 1},
    ])
    assert dd == {"mode": "dedup", "n_prefix": 1}
    ch = split_plan([
        {"name": "whitespace_normalization_mapper"},
        {"name": "text_length_filter", "min_val": 1},
    ])
    assert ch["mode"] == "chain"
    ba = split_plan([
        {"name": "whitespace_normalization_mapper"},
        {"name": "exact_text_deduplicator"},
    ])
    assert ba == {"mode": "barrier", "n_prefix": 1}


# ---------------------------------------------------------------------------
# in-process sharded execution == unsharded oracle
# ---------------------------------------------------------------------------


def _drain(cluster_dir, runner_id="r0", max_steps=100):
    """Single in-process runner drains the queue (parent supervises its own
    shard tasks inline — the single-runner liveness guarantee)."""
    runner = ClusterRunner(cluster_dir, runner_id=runner_id,
                           lease_ttl=30.0, poll=0.05)
    for _ in range(max_steps):
        if not runner.run_once():
            return
    raise AssertionError("queue did not drain")


def _run_sharded(tmp_path, recipe, tag="job"):
    cdir = str(tmp_path / f"cluster-{tag}")
    q = ClusterQueue(cdir)
    jid = q.submit(recipe)
    _drain(cdir)
    st = q.status(jid, verbose=True)
    assert st["state"] == "succeeded", st.get("error")
    with open(recipe["export_path"], "rb") as f:
        return f.read(), q, jid, st


def test_sharded_dedup_exact_byte_identical(tmp_path):
    src = write_corpus(str(tmp_path / "in.jsonl"), n=120)
    recipe = make_sharded_recipe(src, str(tmp_path / "out.jsonl"), shards=3)
    ref = reference_output(recipe, str(tmp_path / "ref.jsonl"))
    out, q, jid, st = _run_sharded(tmp_path, recipe)
    assert out == ref, "sharded exact dedup must be byte-identical"

    # observability: per-shard rows on the verbose status + the overview
    rows = st["shards"]
    kinds = [r["kind"] for r in rows]
    assert kinds.count("map") == 3 and kinds.count("reduce") >= 1
    assert kinds[-1] == "finalize"
    assert all(r["state"] == "succeeded" and r["attempt"] == 1 for r in rows)
    assert jid in q.overview()["sharded"]
    # the parent report records the shard fan-out
    sharded = st["report"]["sharded"]
    assert sharded["n_shards"] == 3 and sharded["mode"] == "dedup"

    # shard tasks are plumbing: hidden from the user-facing job list
    assert q.job_ids() == [jid]
    assert len(q.job_ids(include_shards=True)) == len(rows) + 1


@pytest.mark.parametrize("mode", ["chain", "barrier"])
def test_sharded_chain_and_barrier_byte_identical(tmp_path, mode):
    src = write_corpus(str(tmp_path / "in.jsonl"), n=140, seed=1)
    process = [{"name": "whitespace_normalization_mapper"}]
    if mode == "barrier":
        process.append({"name": "exact_text_deduplicator"})
    process.append({"name": "text_length_filter", "min_val": 20})
    recipe = {
        "name": f"{mode}-job", "dataset_path": src,
        "export_path": str(tmp_path / "out.jsonl"), "shards": 4,
        "process": process, "use_fusion": False, "use_reordering": False,
    }
    ref = reference_output(recipe, str(tmp_path / "ref.jsonl"))
    out, _, _, st = _run_sharded(tmp_path, recipe, tag=mode)
    assert out == ref, f"sharded {mode} must splice parts byte-identically"
    assert st["report"]["sharded"]["mode"] == mode


def test_shards_auto_resolves_records_decision_byte_identical(
        tmp_path, monkeypatch):
    """shards="auto" picks the count from row targets + live fleet, stays
    byte-identical to the unsharded oracle, and persists the decision in
    shardmeta / the "sharded" event so failover re-leases reuse it."""
    monkeypatch.setenv("REPRO_SHARD_TARGET_ROWS", "40")
    src = write_corpus(str(tmp_path / "in.jsonl"), n=120)
    recipe = make_sharded_recipe(src, str(tmp_path / "out.jsonl"),
                                 shards="auto")
    ref = reference_output(recipe, str(tmp_path / "ref.jsonl"))
    out, q, jid, st = _run_sharded(tmp_path, recipe, tag="auto")
    assert out == ref, "auto-sharded run must stay byte-identical"

    n_shards = st["report"]["sharded"]["n_shards"]
    assert n_shards >= 2, "auto must actually shard a 3x-target corpus"
    ev = next(e for e in q.read_log()
              if e["event"] == "sharded" and e["job_id"] == jid)
    auto = ev["auto"]
    assert auto["requested"] == "auto" and auto["chosen"] == n_shards
    assert auto["by_rows"] == 3, "120 rows / 40-row target"
    # decision is persisted: a re-claimed lead reuses it, never re-tunes
    with open(os.path.join(shards_mod.shard_dir_for(q, jid),
                           "shardmeta.json")) as f:
        assert json.load(f)["auto"]["chosen"] == n_shards


@pytest.mark.parametrize("streaming", ["keep_first", "windowed"])
def test_sharded_relaxed_modes_match_exact_keep_set(tmp_path, streaming):
    """Sharded keep_first/windowed run behind the reconciliation barrier, so
    emit decisions see the COMPLETE pair set: the kept texts equal the exact
    keep set (order preserved), a strictly stronger guarantee than the
    single-runner keep_first superset contract."""
    src = write_corpus(str(tmp_path / "in.jsonl"), n=120, seed=2)
    recipe = make_sharded_recipe(src, str(tmp_path / "out.jsonl"),
                                 shards=3, streaming=streaming)
    exact = dict(recipe, streaming=None,
                 process=[dict(c) for c in recipe["process"]])
    exact["process"][1] = dict(exact["process"][1], streaming="exact")
    ref = reference_output(exact, str(tmp_path / "ref.jsonl"))
    out, _, _, _ = _run_sharded(tmp_path, recipe, tag=streaming)
    texts = lambda b: [json.loads(l)["text"]
                       for l in b.decode().splitlines() if l]
    assert texts(out) == texts(ref)


def test_shards_clamp_and_single_shard_fallback(tmp_path):
    """shards > n_rows clamps; shards<=1 (or a non-file source) falls back
    to the plain single-runner path with no shard tasks published."""
    src = write_corpus(str(tmp_path / "tiny.jsonl"), n=3)
    recipe = make_sharded_recipe(src, str(tmp_path / "out.jsonl"), shards=8)
    ref = reference_output(recipe, str(tmp_path / "ref.jsonl"))
    out, q, jid, _ = _run_sharded(tmp_path, recipe, tag="clamp")
    assert out == ref
    n_maps = sum(1 for t in q.job_ids(include_shards=True) if "~s" in t)
    assert 0 < n_maps <= 3, "shards must clamp to the row count"

    recipe1 = make_sharded_recipe(src, str(tmp_path / "out1.jsonl"), shards=1)
    out1, q1, jid1, st1 = _run_sharded(tmp_path, recipe1, tag="one")
    assert out1 == ref
    assert q1.job_ids(include_shards=True) == [jid1], \
        "shards=1 must not publish shard tasks"
    assert "sharded" not in (st1["report"] or {})


def test_reduce_task_is_idempotent(tmp_path):
    """Zombie-replay safety: re-running a reduce over the published map
    state must reproduce the identical pairs file (atomic replace of
    deterministic content — a stale attempt can never corrupt a result)."""
    src = write_corpus(str(tmp_path / "in.jsonl"), n=120)
    recipe = make_sharded_recipe(src, str(tmp_path / "out.jsonl"), shards=3)
    _, q, jid, st = _run_sharded(tmp_path, recipe, tag="idem")
    from repro.core.dedup import sharded as core

    sd = os.path.join(q.checkpoint_dir(jid), "shards")
    with open(os.path.join(sd, "shardmeta.json")) as f:
        meta = json.load(f)
    with open(core.pairs_path(sd, 0), "rb") as f:
        before = f.read()
    rep = core.run_reduce(sd, 0, meta["n_shards"], meta["n_reducers"],
                          meta["dedup"]["num_bands"],
                          meta["dedup"]["jaccard_threshold"])
    assert rep["owner"] == 0 and rep["n_docs"] == 120
    with open(core.pairs_path(sd, 0), "rb") as f:
        assert f.read() == before, "replayed reduce must be byte-identical"


# ---------------------------------------------------------------------------
# zero-copy columnar hand-off (ShardedEngine fast path)
# ---------------------------------------------------------------------------


def test_sharded_engine_zero_copy_columnar_byte_identical(tmp_path, monkeypatch):
    """A fully column-capable chain must take the zero-copy path (ColumnBlock
    columns flow into the vectorized ops without the row-shim decode) and
    still export byte-identically to the row-path local run."""
    from repro.core.engine import ShardedEngine
    from repro.core.executor import Executor
    from repro.core.recipes import Recipe

    src = write_corpus(str(tmp_path / "in.jsonl"), n=200, seed=3)
    # every op must be column-capable: the hand-off is all-or-nothing (a
    # partial columnar prefix would strand rows between representations)
    process = [
        {"name": "text_length_filter", "min_len": 5, "max_len": 10000},
        {"name": "alnum_ratio_filter", "min_ratio": 0.1},
    ]

    def run(tag, fmt, engine):
        out = str(tmp_path / f"out-{tag}.jsonl")
        r = Recipe(name=tag, dataset_path=src, export_path=out,
                   process=[dict(c) for c in process], engine=engine,
                   block_format=fmt, block_bytes=8 * 1024,
                   use_fusion=False, use_reordering=False)
        Executor(r).run_streaming(materialize=False)
        with open(out, "rb") as f:
            return f.read()

    ref = run("row-ref", "row", "local")

    hits = {"n": 0}
    orig = ShardedEngine._full_columnar

    def counting(self, ops, blk):
        res = orig(self, ops, blk)
        if res is not None:
            hits["n"] += 1
        return res

    monkeypatch.setattr(ShardedEngine, "_full_columnar", counting)
    got = run("col-sharded", "columnar", "sharded")
    assert got == ref, "zero-copy hand-off must not change export bytes"
    assert hits["n"] > 0, "column-capable chain must take the zero-copy path"


# ---------------------------------------------------------------------------
# fault injection: SIGKILL one of N shard runners mid-dedup
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_sigkill_shard_runner_failover_byte_identical(tmp_path):
    """The sharded acceptance scenario: a lead runner supervises the shard
    DAG while a second runner (slowed inside the dedup map by the injected
    per-block delay) holds one map shard's lease. SIGKILL the victim
    mid-dedup: its lease expires, the lead re-claims that shard at attempt
    2 and resumes from the prefix segment checkpoint (resumed_at > 0 on
    exactly that shard), and the merged export is byte-identical to an
    uninterrupted unsharded run."""
    src = write_corpus(str(tmp_path / "corpus.jsonl"), n=120)
    out = str(tmp_path / "out.jsonl")
    recipe = make_sharded_recipe(src, out, shards=3)
    # a small per-row sleep in the prefix keeps maps claimable long enough
    # for the late-starting victim to win one
    recipe["process"].insert(1, {"name": "sleep_mapper", "delay": 0.05})
    ref = reference_output(recipe, str(tmp_path / "ref.jsonl"))

    q = ClusterQueue(str(tmp_path / "cluster"), lease_ttl=2.0)
    jid = q.submit(recipe)
    lead = start_runner(q.dir, "lead", lease_ttl=2.0)
    victim = None
    try:
        wait_for(lambda: q.current_lease(jid) is not None, 60,
                 message="parent claim")
        wait_for(lambda: len(q.shard_tasks(jid)) >= 3, 60,
                 message="shard tasks published")
        from repro.core.dedup.sharded import MAP_DELAY_ENV

        victim = start_runner(q.dir, "victim", lease_ttl=2.0,
                              extra_env={MAP_DELAY_ENV: "30"})

        def victim_map_task():
            for t in q.shard_tasks(jid):
                if "~s" in t:
                    lease = q.current_lease(t)
                    if lease is not None and lease.runner_id == "victim":
                        return t
            return None

        wait_for(lambda: victim_map_task() is not None, 60,
                 message="victim claims a map shard")
        vt = victim_map_task()
        # mid-dedup: the prefix segment checkpoint exists, the map state is
        # sleeping inside the injected per-block delay
        wait_for(lambda: len(checkpoint_stages(q, vt)) >= 1, 60,
                 message="victim prefix checkpoint")
        time.sleep(0.2)
        sigkill_runner(victim)
        victim = None

        wait_for(lambda: q.state_of(jid) == "succeeded", 180,
                 message="sharded failover completion")
        with open(out, "rb") as f:
            assert f.read() == ref, \
                "merged export must be byte-identical after shard failover"

        rows = {r["task_id"]: r for r in q.shard_rows(jid)}
        assert rows[vt]["attempt"] == 2, "killed shard must be re-leased"
        assert rows[vt]["resumed_at"] > 0, \
            "re-claimed shard must resume from its checkpoint, not restart"
        for tid, r in rows.items():
            if tid != vt and r["kind"] == "map":
                assert r["attempt"] == 1, "surviving shards must not re-run"
        assert all(r["state"] == "succeeded" for r in rows.values())
    finally:
        for p in (lead, victim):
            if p is not None:
                try:
                    stop_runner(p)
                except Exception:
                    pass
