"""Quickstart: process a corpus with a YAML recipe through the full
adaptive runtime (probe -> fuse/reorder -> fault-tolerant execution ->
insight report).

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import tempfile

from repro.core.executor import Executor
from repro.core.recipes import Recipe, parse_simple_yaml
from repro.core.storage import write_jsonl
from repro.data.synthetic import make_corpus

RECIPE_YAML = """
name: quickstart
np: 1
engine: local
use_fusion: true
use_reordering: true
insight: true
process:
  - fix_unicode_mapper
  - whitespace_normalization_mapper
  - text_length_filter:
      min_val: 120
  - alnum_ratio_filter:
      min_val: 0.55
  - words_num_filter:
      min_val: 10
  - quality_score_filter:
      min_val: 0.25
  - document_minhash_deduplicator:
      jaccard_threshold: 0.7
"""


def main():
    with tempfile.TemporaryDirectory() as tmp:
        src = os.path.join(tmp, "corpus.jsonl")
        out = os.path.join(tmp, "clean.jsonl")
        write_jsonl(src, make_corpus(2000, seed=0))

        recipe = Recipe.from_dict(parse_simple_yaml(RECIPE_YAML))
        recipe.dataset_path, recipe.export_path = src, out

        ds, report = Executor(recipe).run()
        print(f"\nplan (after fusion+reordering): {report.plan}")
        print(f"{report.n_in} -> {report.n_out} samples in {report.seconds:.2f}s "
              f"({report.errors} sample errors tolerated)")
        for row in report.per_op:
            print(f"  {row['op'][:58]:58s} {row['seconds']:.3f}s {row['in']}->{row['out']}")
        print("\n" + report.insight)
        print(f"\nexported: {out} ({os.path.getsize(out)} bytes)")


if __name__ == "__main__":
    main()
