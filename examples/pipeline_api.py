"""Unified lazy Pipeline API demo: one fluent chain behind every front-end.

    PYTHONPATH=src python examples/pipeline_api.py

Builds a pipeline, explains its optimized plan without running, executes it
through the adaptive runtime (fusion + streaming segments), streams blocks
lazily, and drives the same run as an async job with live progress + cancel.
"""
import os
import tempfile
import time

import repro.api as dj
from repro.api.jobs import JobManager
from repro.core.storage import write_jsonl
from repro.data.synthetic import make_corpus


def main():
    with tempfile.TemporaryDirectory() as tmp:
        src = os.path.join(tmp, "corpus.jsonl")
        out = os.path.join(tmp, "clean.jsonl")
        write_jsonl(src, make_corpus(2000, seed=0))

        pipe = (dj.read_jsonl(src)
                .map("whitespace_normalization_mapper")
                .filter("text_length_filter", min_val=120)
                .filter("alnum_ratio_filter", min_val=0.55)
                .filter("words_num_filter", min_val=10)
                .dedup(jaccard_threshold=0.7)
                .write_jsonl(out))

        # ------------------------------------------------------- explain
        info = pipe.explain()
        print("optimized plan:", " -> ".join(info["plan"]))
        for i, seg in enumerate(info["segments"]):
            kind = "barrier" if seg["barrier"] else "stream"
            print(f"  segment {i} [{kind}]: {', '.join(seg['ops'])}")

        # ------------------------------------------------------- execute
        ds, report = pipe.execute()
        print(f"\nexecute: {report.n_in} -> {report.n_out} samples "
              f"in {report.seconds:.2f}s (streaming={report.streaming})")

        # --------------------------------------------------- lazy stream
        n = sum(len(b) for b in pipe.iter_blocks())
        print(f"iter_blocks: streamed {n} samples without materializing")

        # ----------------------------------------------------- async job
        jm = JobManager(max_workers=1)
        job = jm.submit(pipe)
        print(f"\njob {job.id} submitted (state={job.state})")
        while not jm.get(job.id).done():
            st = jm.get(job.id).status()
            started = st["progress"]["ops_started"]
            total = st["progress"]["ops_total"]
            print(f"  poll: state={st['state']} ops_started={started}/{total}")
            time.sleep(0.2)
        final = jm.get(job.id).status()
        print(f"job finished: state={final['state']} "
              f"n_out={final['report']['n_out']}")
        jm.shutdown()

        # ---------------------------------------------------- NL -> same API
        from repro.interface.nl import build_pipeline

        nl_pipe, turns = build_pipeline(
            "drop short text under 150 and dedup at threshold 0.8", src)
        print("\nNL agent emitted:", nl_pipe)
        for t in turns:
            print("  thought:", t.thought)
        _, nl_report = nl_pipe.execute()
        print(f"NL run: {nl_report.n_in} -> {nl_report.n_out} "
              f"(plan {nl_report.plan})")


if __name__ == "__main__":
    main()
