"""Distributed fuzzy dedup (RayDeduplicator analogue): chunked signature
computation + hash-aggregated LSH + load-balanced union-find, verified
against exact brute force on a seeded corpus.

    PYTHONPATH=src python examples/distributed_dedup.py
"""
import time

import numpy as np

from repro.core.dataset import DJDataset
from repro.core.dedup.minhash import jaccard, shingle_hashes
from repro.core.registry import create_op
from repro.data.synthetic import make_corpus


def brute_force_components(texts, threshold=0.7):
    docs = [shingle_hashes(t) for t in texts]
    n = len(texts)
    comp = list(range(n))

    def find(x):
        while comp[x] != x:
            comp[x] = comp[comp[x]]
            x = comp[x]
        return x

    for i in range(n):
        for j in range(i + 1, n):
            if jaccard(docs[i], docs[j]) >= threshold:
                ri, rj = find(i), find(j)
                if ri != rj:
                    comp[max(ri, rj)] = min(ri, rj)
    return [find(i) for i in range(n)]


def main():
    corpus = make_corpus(800, seed=42, dup_frac=0.3, near_dup_frac=0.1,
                         multimodal_frac=0.0)
    texts = [s["text"] for s in corpus]

    op = create_op({
        "name": "distributed_minhash_deduplicator",
        "jaccard_threshold": 0.7, "n_workers": 4, "backend": "balanced",
    })
    ds = DJDataset.from_samples(corpus)
    t0 = time.time()
    kept = ds.process(op)
    t_lsh = time.time() - t0
    print(f"LSH dedup: {len(ds)} -> {len(kept)} in {t_lsh:.2f}s")

    t0 = time.time()
    comp = brute_force_components(texts, 0.7)
    n_exact = len(set(comp))
    t_bf = time.time() - t0
    print(f"brute force: {n_exact} exact components in {t_bf:.2f}s "
          f"({t_bf / t_lsh:.1f}x slower)")

    err = abs(len(kept) - n_exact) / n_exact
    print(f"LSH kept {len(kept)} vs exact {n_exact} ({err:.1%} deviation)")
    assert err < 0.05, "LSH dedup deviates too much from exact dedup"
    print("OK: distributed minhash matches brute force within 5%")


if __name__ == "__main__":
    main()
