"""Serve a small model with batched requests: prefill + decode through the
model substrate's cache machinery (the same code paths the decode_32k
dry-run cells exercise), then use the served model as a data-processing OP.

    PYTHONPATH=src python examples/serve_batched.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.tokenizer import HashWordTokenizer
from repro.models.model_zoo import build_model


def main():
    cfg = get_config("phi3-medium-14b", reduced=True)
    model = build_model(cfg, remat_policy="none")
    params = model.init_params(jax.random.PRNGKey(0))
    tok = HashWordTokenizer(cfg.vocab_size)

    requests = [
        "data juicer processes multimodal corpora at cloud scale",
        "adaptive operators probe the workload and reorder themselves",
        "the union find merges duplicate documents into components",
        "tpu pods shard the kv cache across the model axis",
    ]
    batch = len(requests)
    prompt_len = 16
    toks = np.zeros((batch, prompt_len), np.int32)
    for i, r in enumerate(requests):
        ids = tok.encode(r)[:prompt_len]
        toks[i, : len(ids)] = ids

    prefill = jax.jit(lambda p, b: model.prefill(p, b, cache_budget=32))
    decode = jax.jit(model.decode_step)

    t0 = time.time()
    cache, logits = prefill(params, {"tokens": jnp.asarray(toks)})
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    print(f"prefill: batch={batch} x {prompt_len} tokens in {t_prefill * 1e3:.1f} ms")

    generated = [[] for _ in range(batch)]
    next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    t0 = time.time()
    n_steps = 24
    for step in range(n_steps):
        cache, logits = decode(
            params, cache, {"token": next_tok, "pos": jnp.asarray(prompt_len + step)}
        )
        next_tok = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)[:, None]
        for i in range(batch):
            generated[i].append(int(next_tok[i, 0]))
    jax.block_until_ready(next_tok)
    dt = time.time() - t0
    print(f"decode: {n_steps} steps x batch {batch} = {n_steps * batch} tokens "
          f"in {dt * 1e3:.1f} ms ({n_steps * batch / dt:.0f} tok/s)")
    for i, r in enumerate(requests):
        print(f"  req[{i}] '{r[:40]}...' -> token ids {generated[i][:8]}...")

    assert all(len(g) == n_steps for g in generated)
    print("OK: batched prefill+decode served", batch, "requests")


if __name__ == "__main__":
    main()
