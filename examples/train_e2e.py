"""End-to-end driver: Data-Juicer pipeline -> packed loader -> JAX training
with checkpoint/restart + elastic data-parallel resume — data-model
co-development in one script (paper §5.3 sandbox workflow).

    PYTHONPATH=src python examples/train_e2e.py               # CPU-sized model
    PYTHONPATH=src python examples/train_e2e.py --steps 300
    PYTHONPATH=src python examples/train_e2e.py --model-scale 100m   # full-size

The pipeline's quality/dedup OPs produce the corpus; the trained checkpoint
can then power ``lm_perplexity_filter`` (params_path=...) — the data
flywheel the paper describes.
"""
import argparse
import dataclasses
import os
import tempfile
import time

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.dataset import DJDataset
from repro.core.registry import create_op
from repro.data.loader import PackedDataLoader
from repro.data.synthetic import make_corpus
from repro.launch.mesh import make_host_mesh
from repro.launch import sharding as sh
from repro.models.model_zoo import build_model
from repro.train.checkpointing import load_state, save_state
from repro.train.optimizer import OptConfig
from repro.train.train_step import TrainConfig, init_state, make_train_step


def model_config(scale: str) -> ModelConfig:
    if scale == "100m":
        return ModelConfig(
            arch_id="dj-lm-100m", family="dense", n_layers=10, d_model=640,
            n_heads=10, n_kv_heads=10, d_ff=2560, vocab_size=32000,
        )
    return ModelConfig(  # cpu: ~2M params
        arch_id="dj-lm-tiny", family="dense", n_layers=4, d_model=128,
        n_heads=4, n_kv_heads=2, d_ff=384, vocab_size=4096,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--model-scale", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--restart-at", type=int, default=100,
                    help="simulate a failure+restart at this step")
    args = ap.parse_args()

    # ---- 1. data pipeline (the paper's system) -------------------------
    corpus = make_corpus(3000, seed=0)
    ds = DJDataset.from_samples(corpus)
    ops = [
        create_op({"name": "whitespace_normalization_mapper"}),
        create_op({"name": "text_length_filter", "min_val": 80}),
        create_op({"name": "alnum_ratio_filter", "min_val": 0.6}),
        create_op({"name": "document_minhash_deduplicator", "jaccard_threshold": 0.7}),
    ]
    t0 = time.time()
    clean = ds.process(ops)
    print(f"pipeline: {len(ds)} -> {len(clean)} samples in {time.time() - t0:.2f}s")

    # ---- 2. tokenize / pack / shard ------------------------------------
    cfg = model_config(args.model_scale)
    mesh = make_host_mesh()
    sh.set_sharding_context(mesh)
    loader = PackedDataLoader(
        clean, seq_len=args.seq_len, global_batch=args.batch,
        vocab_size=cfg.vocab_size, mesh=mesh,
    )
    print(f"packed: {len(loader.tokens)} sequences of {args.seq_len} tokens")

    # ---- 3. train with checkpoint/restart ------------------------------
    model = build_model(cfg, remat_policy="none")
    tc = TrainConfig(opt=OptConfig(lr=1e-3, weight_decay=0.01))
    step_fn = jax.jit(make_train_step(model, tc), donate_argnums=(0,))
    state = init_state(model, jax.random.PRNGKey(0), tc.opt)
    n_params = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(state["params"]))
    print(f"model: {cfg.arch_id} ({n_params / 1e6:.1f}M params)")

    ckpt_dir = tempfile.mkdtemp(prefix="dj_train_")
    ckpt_path = os.path.join(ckpt_dir, "state.npz")
    losses = []
    it = loader.batches(epochs=1000)
    step = 0
    restarted = False
    t0 = time.time()
    while step < args.steps:
        if step == args.restart_at and not restarted:
            # simulate node failure: drop everything, restore from checkpoint
            print(f"step {step}: simulating failure -> restart from {ckpt_path}")
            like = jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), state)
            state = load_state(ckpt_path, like)
            restarted = True
        batch = next(it)
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        step = int(state["step"])
        if step % args.ckpt_every == 0:
            save_state(ckpt_path, state)
        if step % 20 == 0:
            print(f"step {step:4d} loss {loss:.4f} "
                  f"({(step) / (time.time() - t0):.2f} steps/s)")

    first, last = np.mean(losses[:10]), np.mean(losses[-10:])
    print(f"\nloss: {first:.3f} -> {last:.3f} "
          f"({'DECREASED' if last < first else 'no decrease'})")
    save_state(ckpt_path, state)
    print(f"final checkpoint: {ckpt_path}")
    print("use it for data-model co-development, e.g.\n"
          "  lm_perplexity_filter(params_path=...) to score the next corpus")
    assert last < first, "training failed to reduce loss"


if __name__ == "__main__":
    main()
