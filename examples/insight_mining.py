"""Insight mining walkthrough: track per-OP stat distributions, diff
consecutive OPs, and surface lineage-level flags (paper §5.2 / Fig. 8).

    PYTHONPATH=src python examples/insight_mining.py
"""
from repro.core.dataset import DJDataset
from repro.core.insight import InsightMiner, snapshot
from repro.core.registry import create_op
from repro.data.synthetic import make_corpus


def main():
    corpus = make_corpus(1500, seed=5)
    ds = DJDataset.from_samples(corpus)
    miner = InsightMiner(volume_flag=0.05, mean_shift_flag=0.10)
    miner.record("load", ds.samples())

    pipeline = [
        {"name": "language_heuristic_filter", "keep_langs": ["en"]},
        {"name": "text_length_filter", "min_val": 150},
        {"name": "special_char_ratio_filter", "max_val": 0.02},
        {"name": "quality_score_filter", "min_val": 0.35},
    ]
    for cfg in pipeline:
        op = create_op(cfg)
        ds = ds.process(op)
        miner.record(op.name, ds.samples())

    print(miner.report())

    snap = snapshot(ds.samples())
    print("\nfinal numeric stats:")
    for k, st in sorted(snap["numeric"].items()):
        print(f"  {k:22s} mean={st.mean:8.2f} p5={st.p5:8.2f} p95={st.p95:8.2f}")
    print("\nfinal tags:", snap["tags"])
    # the special-char filter should visibly shift the quality distribution
    diffs = miner.diffs()
    assert any(d["flags"] for d in diffs), "expected at least one lineage flag"
    print("\nOK: lineage flags raised:", sum(len(d['flags']) for d in diffs))


if __name__ == "__main__":
    main()
