"""Logical-axis sharding rules with a divisibility guard.

Arrays carry *logical* axis names (via ``ParamSpec.axes`` or explicit calls to
``logical_constraint``). A rule table maps each logical name to an ordered
tuple of mesh axes; axes that do not divide the dimension (or are already
used by another dim of the same array) are dropped. This keeps every
(arch x shape x mesh) cell compilable — e.g. 8 KV heads on a 16-way ``model``
axis fall back to replication, granite's 49155 vocab falls back likewise —
while big dims get full sharding.

Two rule sets:
  * PARAM_RULES  — weight storage. ``embed`` -> ``data`` gives ZeRO/FSDP
    sharding of params & optimizer state; ``mlp``/``heads``/``vocab`` ->
    ``model`` is tensor parallelism.
  * ACT_RULES    — activations. ``batch`` -> ('pod','data') is DP;
    ``kv_seq`` -> ``model`` shards decode KV caches along sequence
    (XLA then emits flash-decoding-style partial reductions).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import module as mod

Rules = Dict[str, Tuple[str, ...]]

PARAM_RULES: Rules = {
    "embed": ("data",),       # FSDP / ZeRO-3 storage sharding
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "vocab": ("model", "expert"),
    # expert parallelism when the mesh has an `expert` axis (make_production_
    # mesh(ep=...)); otherwise tries `model` and is guarded off (8/40 experts
    # do not divide 16)
    "expert": ("expert", "model"),
    "layers": (),
    "lru": ("model",),
    "ssm_inner": ("model",),
    "ssm_heads": ("model",),
    "state": (),
    "conv": (),
    "src": (),
}

ACT_RULES: Rules = {
    "batch": ("pod", "data"),
    # decode KV caches keep their own batch axis so serving experiments can
    # reshard activations (e.g. weight-stationary 2D TP) without touching
    # the resident cache layout
    "cache_batch": ("pod", "data"),
    "seq": (),
    "embed": (),
    "heads": ("model",),
    "kv_heads": ("model",),
    # query-parallel attention: inside flash attention the score tensors are
    # sharded over the query-sequence dim on the `model` axis whenever the
    # KV-head dim cannot use it (GQA kv_heads < 16 on every assigned arch) —
    # zero redundant head compute, small q all-to-all + dk/dv reduce instead.
    # (`expert` joins in on EP meshes so attention keeps full 16-way width.)
    "attn_sq": ("model", "expert"),
    "mlp": ("model",),
    "vocab": ("model", "expert"),
    "expert": ("expert", "model"),
    "kv_seq": ("model", "expert"),  # decode cache: shard seq -> flash-decoding
    "lru": ("model",),
    "ssm_inner": ("model",),
    "ssm_heads": ("model",),
    "state": (),
    "layers": (),
    "src": (),
}


def partition_spec(
    shape: Sequence[int], axes: Sequence[Optional[str]], mesh: Mesh, rules: Rules
) -> P:
    assignment = []
    used = set()
    for dim, name in zip(shape, axes):
        chosen = []
        if name:
            for ax in rules.get(name, ()):
                if ax in used or ax not in mesh.shape:
                    continue
                size = mesh.shape[ax]
                cur = math.prod(mesh.shape[a] for a in chosen) if chosen else 1
                if dim % (cur * size) == 0:
                    chosen.append(ax)
                    used.add(ax)
        if not chosen:
            assignment.append(None)
        elif len(chosen) == 1:
            assignment.append(chosen[0])
        else:
            assignment.append(tuple(chosen))
    return P(*assignment)


def named_sharding(shape, axes, mesh: Mesh, rules: Rules) -> NamedSharding:
    return NamedSharding(mesh, partition_spec(shape, axes, mesh, rules))


def tree_shardings(spec_tree, mesh: Mesh, rules: Rules = PARAM_RULES):
    """ParamSpec tree -> NamedSharding tree."""
    return mod.tree_map_specs(
        lambda s: named_sharding(s.shape, s.axes, mesh, rules), spec_tree
    )


# ---------------------------------------------------------------------------
# In-model activation constraints (context-scoped; no-op outside launch code)
# ---------------------------------------------------------------------------

_CTX: dict = {"mesh": None, "rules": None}


def set_sharding_context(mesh: Optional[Mesh], rules: Optional[Rules] = None) -> None:
    _CTX["mesh"] = mesh
    _CTX["rules"] = dict(rules or ACT_RULES)


def get_context_rules() -> Optional[Rules]:
    return _CTX["rules"]


def update_context_rules(**overrides) -> None:
    """Hillclimbing hook: override individual logical-axis rules."""
    if _CTX["rules"] is None:
        _CTX["rules"] = dict(ACT_RULES)
    for k, v in overrides.items():
        _CTX["rules"][k] = tuple(v)


def logical_constraint(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """Apply with_sharding_constraint per the active context (no-op if unset)."""
    mesh = _CTX["mesh"]
    if mesh is None:
        return x
    s = named_sharding(x.shape, axes, mesh, _CTX["rules"] or ACT_RULES)
    return jax.lax.with_sharding_constraint(x, s)
