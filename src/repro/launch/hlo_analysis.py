"""Trip-count-aware HLO cost analysis.

XLA's built-in ``compiled.cost_analysis()`` counts a ``while`` body ONCE,
which undercounts scan-over-layers models by ~n_layers x. This module walks
the optimized (post-SPMD, per-device) HLO text and computes:

  * flops        — 2 * prod(out dims) * prod(contracting dims) per dot /
                   convolution, recursing into fusions, call and while
                   bodies, multiplying by ``known_trip_count`` from the
                   while's backend_config.
  * hbm_bytes    — op-boundary traffic: every executed top-level instruction
                   reads its operands and writes its outputs (fusions are
                   opaque), i.e. a perfect-fusion HBM traffic model.
  * collectives  — per-kind operand bytes and instruction counts
                   (all-gather / all-reduce / reduce-scatter / all-to-all /
                   collective-permute), trip-count multiplied.

All quantities are PER DEVICE (the module analysed is the SPMD-partitioned
per-device program).
"""
from __future__ import annotations

import dataclasses
import json
import math
import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def normalize_cost_analysis(ca) -> Dict[str, float]:
    """Normalize ``Compiled.cost_analysis()`` across JAX versions.

    Older JAX returns a single dict; newer JAX returns a list with one dict
    per executable (summed here). Always returns a plain ``{key: float}``.
    """
    if ca is None:
        return {}
    if isinstance(ca, dict):
        items = [ca]
    elif isinstance(ca, (list, tuple)):
        items = list(ca)
    else:  # unknown container — best effort, never raise
        try:
            items = [dict(ca)]
        except Exception:
            return {}
    out: Dict[str, float] = {}
    for d in items:
        for k, v in (d or {}).items():
            if isinstance(v, (int, float)):
                out[k] = out.get(k, 0.0) + float(v)
    return out


def type_bytes(type_str: str) -> int:
    """Bytes of an HLO type string (handles tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            n = math.prod(int(d) for d in dims.split(","))
        total += DTYPE_BYTES[dt] * n
    return total


def type_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return []
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Instr:
    name: str
    out_type: str
    op: str
    operands: List[str]
    attrs: str
    line: str
    is_root: bool = False


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    by_name: Dict[str, Instr]


# one instruction:  "  %name = TYPE op(...), attrs" / "  ROOT %name = ..."
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^=]*?\))|(?:[\w\[\]{},\s]+?))\s+([\w\-]+)\((.*)$"
)
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    comment_re = re.compile(r"/\*.*?\*/")
    for raw in text.splitlines():
        line = comment_re.sub("", raw).rstrip()
        if cur is None:
            m = _COMP_HDR_RE.match(line)
            if m:
                cur = Computation(m.group(1), [], {})
                if line.startswith("ENTRY"):
                    entry = cur.name
            continue
        if line == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name, out_type, op, rest = m.groups()
        # split operands (top-level of the first paren group) from attrs
        depth, idx = 1, 0
        for idx, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        operand_str = rest[:idx]
        attrs = rest[idx + 1 :]
        operands = re.findall(r"%([\w.\-]+)", operand_str)
        ins = Instr(name, out_type.strip(), op, operands, attrs, line,
                    is_root=line.lstrip().startswith("ROOT"))
        cur.instrs.append(ins)
        cur.by_name[name] = ins
    return comps, entry


def _operand_type(comp: Computation, operand: str) -> str:
    ins = comp.by_name.get(operand)
    return ins.out_type if ins else ""


_TRIP_RE = re.compile(r'"known_trip_count"\s*:\s*\{\s*"n"\s*:\s*"(\d+)"')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _dot_flops(comp: Computation, ins: Instr) -> float:
    out = type_dims(ins.out_type)
    lhs_t = _operand_type(comp, ins.operands[0]) if ins.operands else ""
    lhs = type_dims(lhs_t)
    m = _CONTRACT_RE.search(ins.attrs)
    contract = 1
    if m and m.group(1):
        for d in m.group(1).split(","):
            di = int(d)
            if di < len(lhs):
                contract *= lhs[di]
    return 2.0 * math.prod(out or [0]) * contract


_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "iota",
}


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_count: Dict[str, int] = dataclasses.field(default_factory=dict)
    dot_count: int = 0
    while_trips: List[int] = dataclasses.field(default_factory=list)
    bytes_by_op: Dict[str, float] = dataclasses.field(default_factory=dict)
    # pure dtype-conversion fusions: an XLA *CPU* artifact (bf16 dot operands
    # get mirrored to f32 — TPU has native bf16 MXU paths). Tracked separately
    # and excluded from hbm_bytes.
    mirror_bytes: float = 0.0

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll_bytes.values())

    def to_dict(self) -> dict:
        return {
            "flops": self.flops,
            "hbm_bytes": self.hbm_bytes,
            "coll_bytes": dict(self.coll_bytes),
            "coll_count": dict(self.coll_count),
            "total_coll_bytes": self.total_coll_bytes,
            "dot_count": self.dot_count,
            "while_trips": list(self.while_trips),
            "mirror_bytes": self.mirror_bytes,
            "bytes_by_op": dict(
                sorted(self.bytes_by_op.items(), key=lambda kv: -kv[1])[:12]
            ),
        }


def _flops_of_computation(
    comps: Dict[str, Computation], name: str, cache: Dict[str, float]
) -> float:
    """Recursive flop count (dots + convs) of one computation incl. callees."""
    if name in cache:
        return cache[name]
    comp = comps.get(name)
    if comp is None:
        return 0.0
    total = 0.0
    cache[name] = 0.0  # cycle guard
    for ins in comp.instrs:
        if ins.op == "dot":
            total += _dot_flops(comp, ins)
        elif ins.op == "convolution":
            # approx: 2 * out elems * (in_ch * prod(kernel spatial)) — rare here
            total += 2.0 * math.prod(type_dims(ins.out_type) or [0])
        elif ins.op == "while":
            trip = 1
            m = _TRIP_RE.search(ins.attrs)
            if m:
                trip = int(m.group(1))
            b = _BODY_RE.search(ins.attrs)
            c = _COND_RE.search(ins.attrs)
            if b:
                total += trip * _flops_of_computation(comps, b.group(1), cache)
            if c:
                total += trip * _flops_of_computation(comps, c.group(1), cache)
        elif ins.op in ("fusion", "call", "conditional", "map", "reduce", "sort"):
            m = _CALLS_RE.search(ins.attrs)
            if m:
                total += _flops_of_computation(comps, m.group(1), cache)
            for cm in re.finditer(r"(?:to_apply|branch_computations)=\{?%?([\w.\-]+)", ins.attrs):
                total += _flops_of_computation(comps, cm.group(1), cache)
    cache[name] = total
    return total


def _root_instrs(comp: Computation) -> List[Instr]:
    root = next((i for i in comp.instrs if i.is_root), None)
    if root is None and comp.instrs:
        root = comp.instrs[-1]
    if root is None:
        return []
    if root.op == "tuple":
        return [comp.by_name[o] for o in root.operands if o in comp.by_name]
    return [root]


def _dus_alias_correction(comps: Dict[str, Computation], called: str) -> float:
    """For in-place-update fusions: bytes to SUBTRACT from the naive
    (operands + output) count. Each dynamic-update-slice root element aliases
    a full buffer that appears both as operand and output but only touches
    update-slice bytes (read + write). Roots reached through elementwise
    unary wrappers (convert/copy/bitcast) count too — on TPU those fuse into
    the slice update."""
    comp = comps.get(called)
    if comp is None:
        return 0.0
    corr = 0.0
    for r in _root_instrs(comp):
        # peel unary wrappers to find a dus
        seen = 0
        while r.op in ("convert", "copy", "bitcast") and r.operands and seen < 4:
            nxt = comp.by_name.get(r.operands[0])
            if nxt is None:
                break
            r = nxt
            seen += 1
        if r.op != "dynamic-update-slice" or len(r.operands) < 2:
            continue
        buf = type_bytes(r.out_type)
        upd = type_bytes(_operand_type(comp, r.operands[1]))
        # naive charged: buf as output + buf as aliased operand + upd read.
        # actual traffic: upd read + upd write  =>  subtract 2*buf - upd.
        corr += 2.0 * buf - upd
    return corr


_MIRROR_OPS = {"parameter", "convert", "bitcast", "constant"}


def _is_dtype_mirror(comps: Dict[str, Computation], called: str) -> bool:
    comp = comps.get(called)
    if comp is None:
        return False
    return all(i.op in _MIRROR_OPS for i in comp.instrs)


_LAYOUT_RE = re.compile(r"\{([\d,]*)\}")


def _is_alias_copy(comp: Computation, ins: Instr) -> bool:
    """Same-shape same-layout copy: a loop-carry aliasing artifact that
    in-place buffer donation elides on TPU."""
    if ins.op != "copy" or not ins.operands:
        return False
    src = _operand_type(comp, ins.operands[0])
    if not src:
        return False
    norm = lambda t: re.sub(r"\s", "", t)
    return norm(src) == norm(ins.out_type)


def _fusion_bytes(comps: Dict[str, Computation], called: str) -> Optional[float]:
    """Precise fusion-boundary traffic: parameters consumed only by internal
    dynamic-slice ops are charged at slice size; dynamic-update-slice roots
    (possibly behind convert/copy/bitcast) charge update size; everything
    else at full size."""
    comp = comps.get(called)
    if comp is None:
        return None
    total = 0.0
    params = [i for i in comp.instrs if i.op == "parameter"]
    dus_alias_params = set()
    # writes (root side)
    for r in _root_instrs(comp):
        seen = 0
        while r.op in ("convert", "copy", "bitcast") and r.operands and seen < 4:
            nxt = comp.by_name.get(r.operands[0])
            if nxt is None:
                break
            r = nxt
            seen += 1
        if r.op == "dynamic-update-slice" and len(r.operands) >= 2:
            total += type_bytes(_operand_type(comp, r.operands[1]))
            buf = comp.by_name.get(r.operands[0])
            # the aliased buffer operand (possibly behind a bitcast/convert)
            seen = 0
            while buf is not None and buf.op in ("convert", "copy", "bitcast") and buf.operands and seen < 4:
                buf = comp.by_name.get(buf.operands[0])
                seen += 1
            if buf is not None and buf.op == "parameter":
                dus_alias_params.add(buf.name)
        else:
            total += type_bytes(r.out_type)
    # reads (parameter side): kLoop fusions are output-driven, so a param
    # reaching the root only through (elementwise-unary)* -> dynamic-slice
    # is read at slice granularity, not full size.
    uses_of: Dict[str, List[Instr]] = {}
    for i in comp.instrs:
        for o in i.operands:
            uses_of.setdefault(o, []).append(i)
    for p in params:
        if p.name in dus_alias_params:
            continue  # in-place buffer: not read beyond the slice
        frontier = [p.name]
        sliced_bytes = 0.0
        full = False
        seen = set()
        while frontier:
            n = frontier.pop()
            if n in seen:
                continue
            seen.add(n)
            for u in uses_of.get(n, []):
                if u.op in ("convert", "bitcast", "copy"):
                    frontier.append(u.name)
                elif u.op == "dynamic-slice" and u.operands and u.operands[0] == n:
                    sliced_bytes += type_bytes(u.out_type)
                else:
                    full = True
        if full or not uses_of.get(p.name):
            total += type_bytes(p.out_type)
        else:
            total += sliced_bytes
    return total


def _walk_bytes(
    comps: Dict[str, Computation],
    name: str,
    mult: float,
    stats: HloStats,
    flop_cache: Dict[str, float],
) -> None:
    comp = comps.get(name)
    if comp is None:
        return
    for ins in comp.instrs:
        if ins.op in _FREE_OPS:
            continue
        if ins.op == "while":
            trip = 1
            m = _TRIP_RE.search(ins.attrs)
            if m:
                trip = int(m.group(1))
            stats.while_trips.append(trip)
            b = _BODY_RE.search(ins.attrs)
            c = _COND_RE.search(ins.attrs)
            if b:
                _walk_bytes(comps, b.group(1), mult * trip, stats, flop_cache)
            if c:
                _walk_bytes(comps, c.group(1), mult * trip, stats, flop_cache)
            continue
        if ins.op == "call":
            m = _CALLS_RE.search(ins.attrs)
            if m:
                _walk_bytes(comps, m.group(1), mult, stats, flop_cache)
            continue
        # opaque op (incl. fusion): operands read + output written, with
        # slice-touching ops charged at slice granularity
        if ins.op == "dynamic-slice":
            op_bytes = 2.0 * type_bytes(ins.out_type)
        elif ins.op == "dynamic-update-slice":
            upd = type_bytes(_operand_type(comp, ins.operands[1])) if len(ins.operands) > 1 else 0
            op_bytes = 2.0 * upd
        elif ins.op in ("gather", "slice"):
            idx = (
                type_bytes(_operand_type(comp, ins.operands[1]))
                if ins.op == "gather" and len(ins.operands) > 1
                else 0
            )
            op_bytes = 2.0 * type_bytes(ins.out_type) + idx
        else:
            op_bytes = type_bytes(ins.out_type)
            for o in ins.operands:
                op_bytes += type_bytes(_operand_type(comp, o))
            if ins.op == "fusion":
                m = _CALLS_RE.search(ins.attrs)
                if m:
                    if _is_dtype_mirror(comps, m.group(1)):
                        stats.mirror_bytes += mult * op_bytes
                        continue
                    fb = _fusion_bytes(comps, m.group(1))
                    if fb is not None:
                        op_bytes = fb
        if ins.op == "convert":  # bare dtype mirror (CPU bf16-dot artifact)
            stats.mirror_bytes += mult * op_bytes
            continue
        if _is_alias_copy(comp, ins):  # loop-carry copy (elided on TPU)
            stats.mirror_bytes += mult * op_bytes
            continue
        stats.hbm_bytes += mult * op_bytes
        stats.bytes_by_op[ins.op] = stats.bytes_by_op.get(ins.op, 0.0) + mult * op_bytes

        if ins.op == "dot":
            stats.flops += mult * _dot_flops(comp, ins)
            stats.dot_count += 1
        elif ins.op == "convolution":
            stats.flops += mult * 2.0 * math.prod(type_dims(ins.out_type) or [0])
        elif ins.op == "fusion":
            m = _CALLS_RE.search(ins.attrs)
            if m:
                stats.flops += mult * _flops_of_computation(comps, m.group(1), flop_cache)
        elif ins.op in COLLECTIVES or any(ins.op.startswith(k) for k in COLLECTIVES):
            kind = next((k for k in COLLECTIVES if ins.op.startswith(k)), ins.op)
            in_bytes = sum(type_bytes(_operand_type(comp, o)) for o in ins.operands)
            stats.coll_bytes[kind] = stats.coll_bytes.get(kind, 0.0) + mult * in_bytes
            stats.coll_count[kind] = stats.coll_count.get(kind, 0) + int(mult)


def analyze_hlo(text: str) -> HloStats:
    comps, entry = parse_module(text)
    stats = HloStats()
    if entry is None:
        return stats
    _walk_bytes(comps, entry, 1.0, stats, {})
    return stats
