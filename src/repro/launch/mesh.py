"""Production mesh builders.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; everything else sees the real (single) device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, ep: int = 0):
    """16x16 single-pod (256 chips) or 2x16x16 multi-pod (512 chips) mesh.

    ``ep`` re-factorizes the 16-way model dimension into an explicit expert
    axis (MoE expert parallelism): (data, expert=ep, model=16//ep). The
    logical-rule tables route `expert` dims to the new axis when present.
    """
    if ep:
        assert 16 % ep == 0, ep
        shape = (2, 16, ep, 16 // ep) if multi_pod else (16, ep, 16 // ep)
        axes = (("pod",) if multi_pod else ()) + ("data", "expert", "model")
        return jax.make_mesh(shape, axes)
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Mesh over whatever devices actually exist (tests / examples)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
