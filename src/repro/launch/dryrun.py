import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count on first init). Everything else in the repo sees the real device.

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
from typing import Any, Dict, Optional  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import clock
from repro.configs import SHAPES, get_config, get_shape, list_archs, shape_applicable  # noqa: E402
from repro.launch import sharding as sh  # noqa: E402
from repro.launch.hlo_analysis import analyze_hlo, normalize_cost_analysis  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.roofline import model_flops_for, roofline  # noqa: E402
from repro.models import module as mod  # noqa: E402
from repro.models.model_zoo import build_model  # noqa: E402
from repro.train.train_step import TrainConfig, make_train_step, state_specs  # noqa: E402

SERVE_DTYPE = jnp.bfloat16


def _bf16_params(spec_tree):
    """Serving stores parameters in bf16."""
    return mod.tree_map_specs(
        lambda s: mod.ParamSpec(s.shape, s.axes, SERVE_DTYPE if s.dtype == jnp.float32 else s.dtype, s.init, s.scale),
        spec_tree,
    )


def _shardings_and_shapes(spec_tree, mesh, rules):
    return (
        sh.tree_shardings(spec_tree, mesh, rules),
        mod.to_shape_dtype(spec_tree),
    )


def _out_shardings_like(fn, in_shapes, out_tree_shardings):
    """Build out_shardings matching fn's output structure via eval_shape."""
    out_shape = jax.eval_shape(fn, *in_shapes)
    return out_shape


def _replicated(mesh):
    return jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())


@dataclasses.dataclass
class CellResult:
    arch: str
    shape: str
    mesh: str
    status: str  # ok | skip | error
    note: str = ""
    compile_s: float = 0.0
    memory: Optional[dict] = None
    cost: Optional[dict] = None
    hlo: Optional[dict] = None
    roofline: Optional[dict] = None

    def to_dict(self):
        return dataclasses.asdict(self)


def _useful_bytes_per_dev(cfg, shape, model, n_dev) -> float:
    """Minimum HBM traffic per device: active params once + cache R/W."""
    act_param_bytes = cfg.active_param_count() * (
        2 if shape.kind != "train" else 4
    )
    cache_bytes = 0
    if shape.kind == "decode":
        cache = model.cache_specs(shape)
        cache_bytes = 2 * mod.tree_bytes(cache)  # read + write
    if shape.kind == "train":
        # params + grads + m/v read&write (fp32) dominates weight traffic
        act_param_bytes = cfg.param_count() * (4 + 4 + 4 * 4)
    return (act_param_bytes + cache_bytes) / n_dev


ACT_STACK_BUDGET = 4 * 2**30  # target saved-residual stack per device


def auto_microbatches(cfg, shape, dp_size: int) -> int:
    """Grad-accumulation factor keeping the per-device saved-residual stack
    (n_layers x b_dev x seq x d_model x 2B, the scan-carry checkpoint cost)
    under ~4 GiB. Constrained so each microbatch still divides the DP axis."""
    if shape.kind != "train":
        return 1
    stack = cfg.n_layers * (shape.global_batch / dp_size) * shape.seq_len * cfg.d_model * 2
    n = 1
    while (
        stack / n > ACT_STACK_BUDGET
        and shape.global_batch % (2 * n) == 0
        and (shape.global_batch // (2 * n)) % dp_size == 0
    ):
        n *= 2
    return n


def run_cell(
    arch: str,
    shape_name: str,
    multi_pod: bool = False,
    remat: str = "full",
    microbatch: int = 0,
    rule_overrides: Optional[Dict[str, tuple]] = None,
    bf16_params: bool = False,
    moe_dispatch: str = "scatter",
    ep: int = 0,
    verbose: bool = True,
) -> CellResult:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh_name = ("2x16x16" if multi_pod else "16x16") + (f"+ep{ep}" if ep else "")
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return CellResult(arch, shape_name, mesh_name, "skip", why)

    mesh = make_production_mesh(multi_pod=multi_pod, ep=ep)
    n_dev = mesh.size
    act_rules = dict(sh.ACT_RULES)
    param_rules = dict(sh.PARAM_RULES)
    if rule_overrides:
        for k, v in rule_overrides.items():
            act_rules[k] = tuple(v)
            if k in param_rules:
                param_rules[k] = tuple(v)
    sh.set_sharding_context(mesh, act_rules)

    model = build_model(cfg, remat_policy=remat if shape.kind == "train" else "none")
    if hasattr(model, "moe_dispatch"):
        model.moe_dispatch = moe_dispatch
    dp_size = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    n_micro = microbatch if microbatch > 0 else auto_microbatches(cfg, shape, dp_size)
    t0 = clock.now()
    try:
        if shape.kind == "train":
            tc = TrainConfig(n_microbatches=n_micro, bf16_params=bf16_params)
            sspecs = state_specs(model, tc)
            s_shard, s_shapes = _shardings_and_shapes(sspecs, mesh, param_rules)
            in_specs = model.input_specs(shape)
            b_shard, b_shapes = _shardings_and_shapes(in_specs, mesh, act_rules)
            step = make_train_step(model, tc)
            out_shape = jax.eval_shape(step, s_shapes, b_shapes)
            out_shard = (s_shard, jax.tree.map(lambda _: _replicated(mesh), out_shape[1]))
            jitted = jax.jit(
                step,
                in_shardings=(s_shard, b_shard),
                out_shardings=out_shard,
                donate_argnums=(0,),
            )
            with mesh:
                lowered = jitted.lower(s_shapes, b_shapes)
                compiled = lowered.compile()
        elif shape.kind == "prefill":
            pspecs = _bf16_params(model.param_specs())
            p_shard, p_shapes = _shardings_and_shapes(pspecs, mesh, param_rules)
            in_specs = model.input_specs(shape)
            b_shard, b_shapes = _shardings_and_shapes(in_specs, mesh, act_rules)
            cspecs = model.cache_specs(shape)
            c_shard = sh.tree_shardings(cspecs, mesh, act_rules)
            fn = lambda p, b: model.prefill(p, b)
            out_shape = jax.eval_shape(fn, p_shapes, b_shapes)
            out_shard = (c_shard, _replicated(mesh))
            jitted = jax.jit(fn, in_shardings=(p_shard, b_shard), out_shardings=out_shard)
            with mesh:
                lowered = jitted.lower(p_shapes, b_shapes)
                compiled = lowered.compile()
        else:  # decode
            pspecs = _bf16_params(model.param_specs())
            p_shard, p_shapes = _shardings_and_shapes(pspecs, mesh, param_rules)
            cspecs = model.cache_specs(shape)
            c_shard, c_shapes = _shardings_and_shapes(cspecs, mesh, act_rules)
            in_specs = model.input_specs(shape)
            b_shard, b_shapes = _shardings_and_shapes(in_specs, mesh, act_rules)
            fn = model.decode_step
            out_shard = (c_shard, _replicated(mesh))
            jitted = jax.jit(
                fn,
                in_shardings=(p_shard, c_shard, b_shard),
                out_shardings=out_shard,
                donate_argnums=(1,),
            )
            with mesh:
                lowered = jitted.lower(p_shapes, c_shapes, b_shapes)
                compiled = lowered.compile()
    except Exception as e:  # compile failures are bugs; surface them
        return CellResult(
            arch, shape_name, mesh_name, "error", f"{type(e).__name__}: {e}",
            compile_s=clock.now() - t0,
        )
    finally:
        sh.set_sharding_context(None)

    compile_s = clock.now() - t0
    mem = compiled.memory_analysis()
    mem_d = {
        "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
    }
    mem_d["total_per_device"] = (
        mem_d["argument_bytes"] + mem_d["output_bytes"] + mem_d["temp_bytes"]
        - mem_d["alias_bytes"]
    )
    cost = normalize_cost_analysis(compiled.cost_analysis())
    cost_d = {k: float(v) for k, v in cost.items()
              if k in ("flops", "bytes accessed", "transcendentals")}

    stats = analyze_hlo(compiled.as_text())
    rep = roofline(
        arch, shape_name, stats, n_dev,
        model_flops_for(cfg, shape),
        _useful_bytes_per_dev(cfg, shape, model, n_dev),
    )
    res = CellResult(
        arch, shape_name, mesh_name, "ok",
        compile_s=compile_s, memory=mem_d, cost=cost_d,
        hlo=stats.to_dict(), roofline=rep.to_dict(),
    )
    if verbose:
        gb = mem_d["total_per_device"] / 2**30
        print(
            f"[{arch} x {shape_name} x {mesh_name}] OK compile={compile_s:.1f}s "
            f"mem/dev={gb:.2f}GiB flops/dev={stats.flops:.3e} "
            f"hbm/dev={stats.hbm_bytes:.3e} coll/dev={stats.total_coll_bytes:.3e} "
            f"dominant={rep.dominant} bound={rep.bound_s*1e3:.1f}ms frac={rep.fraction:.3f}"
        )
        sys.stdout.flush()
    return res


def main():
    ap = argparse.ArgumentParser(description="Multi-pod dry-run driver")
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--remat", default="full", choices=["none", "full", "dots"])
    ap.add_argument("--microbatch", type=int, default=0, help="0 = auto")
    ap.add_argument("--out", default="")
    ap.add_argument("--rule", action="append", default=[],
                    help="logical=axis1+axis2 overrides (hillclimbing)")
    ap.add_argument("--bf16-params", action="store_true")
    ap.add_argument("--moe-dispatch", default="scatter", choices=["scatter", "einsum"])
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    overrides = {}
    for r in args.rule:
        k, v = r.split("=", 1)
        overrides[k] = tuple(x for x in v.split("+") if x)

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                res = run_cell(
                    arch, shape, multi_pod=mp, remat=args.remat,
                    microbatch=args.microbatch, rule_overrides=overrides or None,
                    bf16_params=args.bf16_params, moe_dispatch=args.moe_dispatch,
                )
                if res.status == "skip":
                    print(f"[{arch} x {shape} x {'2x16x16' if mp else '16x16'}] SKIP: {res.note}")
                elif res.status == "error":
                    print(f"[{arch} x {shape} x {'2x16x16' if mp else '16x16'}] ERROR: {res.note}")
                results.append(res.to_dict())

    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")
    n_err = sum(1 for r in results if r["status"] == "error")
    sys.exit(1 if n_err else 0)


if __name__ == "__main__":
    main()
