"""Three-term roofline model for TPU v5e-class chips.

  compute   = per-device HLO flops / peak bf16 FLOP/s
  memory    = per-device HBM traffic / HBM bandwidth
  collective= per-device collective operand bytes / ICI link bandwidth

(The spec's ``X_total / (chips * BW)`` equals our per-device form since the
HLO analysed is the per-device SPMD program.)

``fraction_of_roofline`` compares useful work against the binding term:
  * compute-bound cells: useful = MODEL_FLOPS time (an MFU-style number)
  * memory-bound cells:  useful = minimum required bytes (params read once +
    cache/batch traffic) — an MBU-style number.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.launch.hlo_analysis import HloStats

PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    n_devices: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    bound_s: float
    model_flops: float  # total useful flops (6ND / 2ND)
    hlo_flops_total: float
    useful_ratio: float  # MODEL_FLOPS / HLO_FLOPs(total)
    useful_bytes: float  # minimum per-device traffic (memory-bound cells)
    fraction: float  # useful time on dominant resource / bound_s
    note: str = ""

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def roofline(
    arch: str,
    shape: str,
    stats: HloStats,
    n_devices: int,
    model_flops: float,
    useful_bytes_per_dev: float = 0.0,
    note: str = "",
) -> RooflineReport:
    compute_s = stats.flops / PEAK_FLOPS_BF16
    memory_s = stats.hbm_bytes / HBM_BW
    coll_s = stats.total_coll_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    bound = terms[dominant]

    # useful time = the larger of (ideal compute time for MODEL_FLOPS,
    # ideal HBM time for the minimum traffic). fraction = useful / bound —
    # an MFU-style number for compute-bound cells, MBU-style for
    # memory-bound ones.
    useful_compute_s = (model_flops / n_devices) / PEAK_FLOPS_BF16
    useful_mem_s = useful_bytes_per_dev / HBM_BW
    frac = max(useful_compute_s, useful_mem_s) / bound if bound else 0.0

    hlo_total = stats.flops * n_devices
    return RooflineReport(
        arch=arch,
        shape=shape,
        n_devices=n_devices,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=coll_s,
        dominant=dominant,
        bound_s=bound,
        model_flops=model_flops,
        hlo_flops_total=hlo_total,
        useful_ratio=(model_flops / hlo_total) if hlo_total else 0.0,
        useful_bytes=useful_bytes_per_dev,
        fraction=frac,
        note=note,
    )


def model_flops_for(cfg, shape) -> float:
    """6*N*D for training, 2*N*D for inference (N = active params)."""
    n = cfg.active_param_count()
    d = shape.tokens_per_step
    return (6.0 if shape.kind == "train" else 2.0) * n * d
