"""repro — cloud-scale adaptive data processing (paper reproduction).

Top-level conveniences are lazy (PEP 562) so ``import repro`` stays free of
the API layer until first use::

    import repro
    repro.sql("SELECT text FROM 'data.jsonl' WHERE words > 50").execute()
"""
from __future__ import annotations

__all__ = ["sql", "SQLError"]


def __getattr__(name):
    if name in ("sql", "SQLError"):
        # importlib (not attribute traversal): ``repro.api``'s from-import
        # rebinds its ``sql`` attribute from the submodule to the function
        import importlib

        return getattr(importlib.import_module("repro.api.sql"), name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
