"""Pallas TPU kernel: Mamba2 SSD chunked scan (forward).

Grid (B, H, NC) with the chunk axis innermost — TPU executes the grid
sequentially, so the inter-chunk state lives in a VMEM scratch (P, N) f32
carried across chunk steps (reset at chunk 0). Each program computes the
quadratic intra-chunk term on the MXU ((Q,N)x(N,Q) and (Q,Q)x(Q,P) dots)
plus the inter-chunk contribution from the carried state; the chunk length
Q and head dim P are the MXU-aligned tile sizes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref, y_ref, state_ref):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)  # (Q, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)  # (Q,)
    a = -jnp.exp(alog_ref[0].astype(jnp.float32))  # scalar
    bm = b_ref[0, 0].astype(jnp.float32)  # (Q, N)
    cm = c_ref[0, 0].astype(jnp.float32)  # (Q, N)
    q = x.shape[0]

    da = dt * a  # (Q,)
    cum = jnp.cumsum(da)  # (Q,)
    # decay matrix L[i, j] = exp(cum_i - cum_j) for j <= i
    diff = cum[:, None] - cum[None, :]
    tri = jnp.tril(jnp.ones((q, q), jnp.float32))
    lmat = jnp.exp(jnp.where(tri > 0, diff, -jnp.inf)) * tri

    scores = (cm @ bm.T) * lmat * dt[None, :]  # (Q, Q)
    y_intra = scores @ x  # (Q, P)

    state = state_ref[...]  # (P, N)
    y_inter = jnp.exp(cum)[:, None] * (cm @ state.T)  # (Q, N)@(N, P) -> (Q, P)

    y_ref[0, 0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    total = cum[q - 1]
    decay_to_end = jnp.exp(total - cum)  # (Q,)
    s_chunk = x.T @ (bm * (dt * decay_to_end)[:, None])  # (P, Q)@(Q, N) -> (P, N)
    state_ref[...] = state * jnp.exp(total) + s_chunk


def ssd_pallas(
    x: jnp.ndarray,  # (B, S, H, P)
    dt: jnp.ndarray,  # (B, S, H)
    a_log: jnp.ndarray,  # (H,)
    b_mat: jnp.ndarray,  # (B, S, 1, N)  (single group)
    c_mat: jnp.ndarray,  # (B, S, 1, N)
    chunk: int,
    interpret: bool = True,
) -> jnp.ndarray:
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    xb = x.transpose(0, 2, 1, 3).reshape(bsz, h, nc, chunk, p)
    dtb = dt.transpose(0, 2, 1).reshape(bsz, h, nc, chunk)
    bb = b_mat[:, :, 0].reshape(bsz, nc, chunk, n)
    cb = c_mat[:, :, 0].reshape(bsz, nc, chunk, n)

    grid = (bsz, h, nc)
    from jax.experimental.pallas import tpu as pltpu

    y = pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, p), lambda b, hh, c: (b, hh, c, 0, 0)),
            pl.BlockSpec((1, 1, 1, chunk), lambda b, hh, c: (b, hh, c, 0)),
            pl.BlockSpec((1,), lambda b, hh, c: (hh,)),
            pl.BlockSpec((1, 1, chunk, n), lambda b, hh, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, n), lambda b, hh, c: (b, c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, chunk, p), lambda b, hh, c: (b, hh, c, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, h, nc, chunk, p), jnp.float32),
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xb, dtb, a_log, bb, cb)
    return y.reshape(bsz, h, s, p).transpose(0, 2, 1, 3)  # (B, S, H, P)
