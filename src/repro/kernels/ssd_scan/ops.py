"""Jit'd public wrapper for the SSD Pallas kernel."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.ssd_scan.ssd_scan import ssd_pallas


def ssd_forward(x, dt, a_log, b_mat, c_mat, chunk: int, interpret: bool = True):
    """Matches repro.models.mamba2.ssd_chunked's y output (g=1)."""
    return ssd_pallas(x, dt, a_log, b_mat, c_mat, chunk, interpret=interpret)
