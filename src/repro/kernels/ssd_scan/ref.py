"""Pure-jnp oracle for the SSD chunk-scan kernel: re-exports the model's
chunked SSD implementation (single-group case g=1, as in mamba2-1.3b)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.mamba2 import ssd_chunked


def ssd_ref(x, dt, a_log, b_mat, c_mat, chunk):
    """x (B, S, H, P), dt (B, S, H), a_log (H,), b/c (B, S, 1, N) ->
    y (B, S, H, P), final_state (B, H, P, N)."""
    return ssd_chunked(x, dt, a_log, b_mat, c_mat, chunk)
