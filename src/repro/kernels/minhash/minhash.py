"""Pallas TPU kernel: MinHash signatures.

Tiling: grid (D/BD, P/BP); each program loads a (BD, S) tile of shingle
hashes + a (BP,) slice of permutation params into VMEM and computes the
running min over the shingle axis in chunks, so the (BD, BP, CHUNK)
intermediate stays VMEM-resident (default 64x64x256 u32 = 4 MiB).
Pure integer VPU work — no MXU — which is why dedup's signature stage maps
cleanly onto TPU even though the paper ran it on CPU clusters.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SENTINEL = 0xFFFFFFFF  # plain int: jnp constants may not be captured by kernels

BLOCK_D = 64
BLOCK_P = 64
CHUNK_S = 256


def _minhash_kernel(h_ref, mask_ref, a_ref, b_ref, out_ref, *, chunk_s: int):
    h = h_ref[...]  # (BD, S) uint32
    mask = mask_ref[...]  # (BD, S) bool
    a = a_ref[...]  # (BP,)
    b = b_ref[...]
    bd, s = h.shape
    bp = a.shape[0]
    acc = jnp.full((bd, bp), SENTINEL, jnp.uint32)
    n_chunks = (s + chunk_s - 1) // chunk_s
    for c in range(n_chunks):  # static unroll: S is a compile-time shape
        lo = c * chunk_s
        hc = jax.lax.dynamic_slice_in_dim(h, lo, min(chunk_s, s - lo), axis=1)
        mc = jax.lax.dynamic_slice_in_dim(mask, lo, min(chunk_s, s - lo), axis=1)
        vals = a[None, :, None] * hc[:, None, :] + b[None, :, None]  # u32 wrap
        vals = jnp.where(mc[:, None, :], vals, jnp.uint32(SENTINEL))
        acc = jnp.minimum(acc, vals.min(axis=2).astype(jnp.uint32))
    out_ref[...] = acc


def minhash_pallas(h: jnp.ndarray, mask: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
                   block_d: int = BLOCK_D, block_p: int = BLOCK_P,
                   chunk_s: int = CHUNK_S, interpret: bool = True) -> jnp.ndarray:
    """h (D, S) uint32, mask (D, S) bool, a/b (P,) uint32 -> (D, P) uint32.

    D and P must be multiples of the block sizes (ops.py pads).
    """
    d, s = h.shape
    p = a.shape[0]
    assert d % block_d == 0 and p % block_p == 0, (d, p, block_d, block_p)
    grid = (d // block_d, p // block_p)
    return pl.pallas_call(
        functools.partial(_minhash_kernel, chunk_s=chunk_s),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_d, s), lambda i, j: (i, 0)),
            pl.BlockSpec((block_d, s), lambda i, j: (i, 0)),
            pl.BlockSpec((block_p,), lambda i, j: (j,)),
            pl.BlockSpec((block_p,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((block_d, block_p), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d, p), jnp.uint32),
        interpret=interpret,
    )(h, mask, a, b)
