"""Jit'd public wrapper: padding + dispatch to the Pallas kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.minhash.minhash import BLOCK_D, BLOCK_P, minhash_pallas

# smallest shingle-axis bucket; buckets grow by powers of two (64, 128, ...)
_MIN_BUCKET_S = 64


def _bucket_up(n: int, base: int) -> int:
    """Next power-of-two multiple of ``base`` that is >= n."""
    m = base
    while m < n:
        m *= 2
    return m


def minhash_signatures(
    hashes: np.ndarray, mask: np.ndarray, a: np.ndarray, b: np.ndarray,
    interpret: bool = True, bucket: bool = True,
) -> jnp.ndarray:
    """hashes (D, S) uint64/uint32, mask (D, S) bool, a/b (P,) any int ->
    (D, P) uint32 signatures. Inputs are folded to uint32 and padded to
    kernel block multiples.

    With ``bucket`` (default), D and S pad up to power-of-two buckets
    instead of exact block multiples: the S axis is a compile-time shape
    (the kernel statically unrolls its chunk loop), so the streaming
    ``SignatureBatcher`` — which dispatches super-batch after super-batch
    with varying doc counts and shingle widths — would otherwise compile a
    fresh kernel per distinct shape. Bucketing bounds the compile cache to
    O(log) shapes; padded shingles carry ``mask=False`` (min-ignored) and
    padded doc rows are sliced off, so values never change.
    """
    h32 = (np.asarray(hashes, np.uint64) & 0xFFFFFFFF).astype(np.uint32) ^ (
        np.asarray(hashes, np.uint64) >> np.uint64(32)
    ).astype(np.uint32)
    a32 = (np.asarray(a, np.uint64).astype(np.uint32) | np.uint32(1))  # odd multipliers
    b32 = np.asarray(b, np.uint64).astype(np.uint32)
    d, s = h32.shape
    p = a32.shape[0]
    pd = (_bucket_up(d, BLOCK_D) if bucket else d + ((-d) % BLOCK_D)) - d
    ps = (_bucket_up(s, _MIN_BUCKET_S) - s) if bucket else 0
    pp = (-p) % BLOCK_P
    if pd or ps:
        h32 = np.pad(h32, ((0, pd), (0, ps)))
        mask = np.pad(mask, ((0, pd), (0, ps)))
    if pp:
        a32 = np.pad(a32, (0, pp), constant_values=1)
        b32 = np.pad(b32, (0, pp))
    out = minhash_pallas(
        jnp.asarray(h32), jnp.asarray(mask), jnp.asarray(a32), jnp.asarray(b32),
        interpret=interpret,
    )
    return out[:d, :p]


def minhash_signatures_packed(
    values: np.ndarray, offsets: np.ndarray, a: np.ndarray, b: np.ndarray,
    interpret: bool = True, bucket: bool = True,
) -> jnp.ndarray:
    """Packed-ragged entry point: ``values`` is the concatenation of every
    doc's shingle hashes in doc order, ``offsets`` (n_docs + 1,) delimits
    docs — the same offsets-plus-buffer layout ``repro.core.columnar`` uses
    for string columns. The dense (D, S_max) matrix + mask are built with a
    single vectorized scatter instead of a per-doc Python loop, then
    dispatched through :func:`minhash_signatures` — identical values."""
    offsets = np.asarray(offsets, np.int64)
    values = np.asarray(values, np.uint64)
    n = offsets.size - 1
    if n <= 0:
        return minhash_signatures(np.zeros((0, 1), np.uint64),
                                  np.zeros((0, 1), bool), a, b,
                                  interpret=interpret, bucket=bucket)
    if offsets[0] != 0 or offsets[-1] != values.size or np.any(np.diff(offsets) < 0):
        raise ValueError("offsets must be monotonic, start at 0 and span values")
    lens = np.diff(offsets)
    s_max = max(int(lens.max()), 1)
    mask = np.arange(s_max, dtype=np.int64)[None, :] < lens[:, None]
    padded = np.zeros((n, s_max), dtype=np.uint64)
    # row-major True positions of mask enumerate docs in order == values order
    padded[mask] = values
    return minhash_signatures(padded, mask, a, b,
                              interpret=interpret, bucket=bucket)
