"""Jit'd public wrapper: padding + dispatch to the Pallas kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.minhash.minhash import BLOCK_D, BLOCK_P, minhash_pallas


def minhash_signatures(
    hashes: np.ndarray, mask: np.ndarray, a: np.ndarray, b: np.ndarray,
    interpret: bool = True,
) -> jnp.ndarray:
    """hashes (D, S) uint64/uint32, mask (D, S) bool, a/b (P,) any int ->
    (D, P) uint32 signatures. Inputs are folded to uint32 and padded to
    kernel block multiples."""
    h32 = (np.asarray(hashes, np.uint64) & 0xFFFFFFFF).astype(np.uint32) ^ (
        np.asarray(hashes, np.uint64) >> np.uint64(32)
    ).astype(np.uint32)
    a32 = (np.asarray(a, np.uint64).astype(np.uint32) | np.uint32(1))  # odd multipliers
    b32 = np.asarray(b, np.uint64).astype(np.uint32)
    d, s = h32.shape
    p = a32.shape[0]
    pd = (-d) % BLOCK_D
    pp = (-p) % BLOCK_P
    if pd:
        h32 = np.pad(h32, ((0, pd), (0, 0)))
        mask = np.pad(mask, ((0, pd), (0, 0)))
    if pp:
        a32 = np.pad(a32, (0, pp), constant_values=1)
        b32 = np.pad(b32, (0, pp))
    out = minhash_pallas(
        jnp.asarray(h32), jnp.asarray(mask), jnp.asarray(a32), jnp.asarray(b32),
        interpret=interpret,
    )
    return out[:d, :p]
