"""Pure-jnp oracle for the MinHash signature kernel.

Permutation family: sig[d, p] = min over valid shingles s of
(a[p] * h[d, s] + b[p]) with uint32 wraparound — TPU-native 32-bit
arithmetic (the M61 family used on the host path needs 64-bit mults that
TPU VREGs lack; the uint32 multiply-add family has the same min-wise
uniformity properties for LSH purposes).
"""
from __future__ import annotations

import jax.numpy as jnp

SENTINEL = jnp.uint32(0xFFFFFFFF)


def minhash_ref(h: jnp.ndarray, mask: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray):
    """h (D, S) uint32, mask (D, S) bool, a/b (P,) uint32 -> (D, P) uint32."""
    vals = a[None, :, None] * h[:, None, :] + b[None, :, None]  # (D, P, S) u32 wrap
    vals = jnp.where(mask[:, None, :], vals, SENTINEL)
    return vals.min(axis=2).astype(jnp.uint32)
