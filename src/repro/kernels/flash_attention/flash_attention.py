"""Pallas TPU kernel: flash attention forward (causal / sliding-window, GQA).

Grid (B*Hq, n_q_blocks, n_kv_blocks), kv innermost (sequential on TPU) so
the online-softmax running stats (m, l) and the output accumulator live in
VMEM scratch across kv steps. GQA is handled in the index map: query head
``h`` reads kv head ``h // group``, so KV is never materialised at Hq.
Causal skipping: kv blocks strictly above the diagonal are masked out
entirely (the dominant-term reduction the XLA fallback cannot do — see
EXPERIMENTS.md §Perf).

VMEM per program (defaults BQ=BK=256, hd<=256, f32 scratch):
q 256xhd + k/v 256xhd + scores 256x256 + acc 256xhd  ~= 1.3 MiB.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 256
DEFAULT_BLOCK_K = 256
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, window: int, scale: float, seq_len: int,
                  block_q: int, block_k: int):
    iq = pl.program_id(1)
    jk = pl.program_id(2)
    n_k = pl.num_programs(2)

    @pl.when(jk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = jk * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    # block-level skip: fully-masked kv blocks do no work
    run = jnp.bool_(True)
    if causal:
        run = jnp.logical_and(run, (jk * block_k) <= (iq * block_q + block_q - 1))
    if window > 0:
        run = jnp.logical_and(run, (jk * block_k + block_k - 1) > (iq * block_q - window))

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale  # (BQ, hd)
        k = k_ref[0].astype(jnp.float32)  # (BK, hd)
        v = v_ref[0].astype(jnp.float32)
        s = q @ k.T  # (BQ, BK)
        mask = k_pos < seq_len
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window > 0:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + p @ v
        m_scr[...] = m_new

    @pl.when(jk == n_k - 1)
    def _finish():
        l = jnp.maximum(l_scr[...], 1e-20)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jnp.ndarray,  # (B, Sq, Hq, hd)
    k: jnp.ndarray,  # (B, Skv, Hkv, hd)
    v: jnp.ndarray,
    causal: bool = True,
    window: int = 0,  # 0 = full
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jnp.ndarray:
    b, sq, hq, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = 1.0 / math.sqrt(hd)
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)

    pad_q = (-sq) % block_q
    pad_k = (-skv) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0))) if pad_q else q
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else k
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0))) if pad_k else v

    # (B*H, S, hd) layouts
    qh = qp.transpose(0, 2, 1, 3).reshape(b * hq, sq + pad_q, hd)
    kh = kp.transpose(0, 2, 1, 3).reshape(b * hkv, skv + pad_k, hd)
    vh = vp.transpose(0, 2, 1, 3).reshape(b * hkv, skv + pad_k, hd)

    grid = (b * hq, (sq + pad_q) // block_q, (skv + pad_k) // block_k)

    out = pl.pallas_call(
        functools.partial(
            _flash_kernel, causal=causal, window=window or 0, scale=scale,
            seq_len=skv, block_q=block_q, block_k=block_k,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, iq, jk: (bh, iq, 0)),
            # GQA: query head bh -> kv head (bh % hq) // g within the batch
            pl.BlockSpec(
                (1, block_k, hd),
                lambda bh, iq, jk, g=g, hq=hq, hkv=hkv: ((bh // hq) * hkv + (bh % hq) // g, jk, 0),
            ),
            pl.BlockSpec(
                (1, block_k, hd),
                lambda bh, iq, jk, g=g, hq=hq, hkv=hkv: ((bh // hq) * hkv + (bh % hq) // g, jk, 0),
            ),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda bh, iq, jk: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq + pad_q, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    out = out[:, :sq].reshape(b, hq, sq, hd).transpose(0, 2, 1, 3)
    return out
