"""Jit'd public wrapper for the flash-attention Pallas kernel."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels.flash_attention.flash_attention import flash_attention_fwd


def flash_attention(q, k, v, causal: bool = True, window: Optional[int] = None,
                    block_q: int = 256, block_k: int = 256, interpret: bool = True):
    """(b, sq, hq, hd) x (b, skv, hkv, hd) -> (b, sq, hq, hd)."""
    return flash_attention_fwd(
        q, k, v, causal=causal, window=window or 0,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
