"""Oracle for the flash-attention kernel: the materialized-softmax
reference from the model layers (GQA/causal/window aware)."""
from repro.models.layers import attention_reference  # noqa: F401
