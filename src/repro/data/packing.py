"""Sequence packing: token lists -> fixed (N, seq_len) training blocks."""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np


def pack_documents(
    docs: Sequence[Sequence[int]], seq_len: int, pad_id: int = 0
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Concatenate docs and cut into seq_len+1 windows.

    Returns (tokens (N, S), labels (N, S), loss_mask (N, S)) with next-token
    labels; the trailing partial window is padded and masked.
    """
    stream: List[int] = []
    for d in docs:
        stream.extend(int(t) for t in d)
    if not stream:
        z = np.zeros((0, seq_len), np.int32)
        return z, z.copy(), np.zeros((0, seq_len), np.float32)
    step = seq_len
    n_full = max(0, (len(stream) - 1) // step)
    rows_t, rows_l, rows_m = [], [], []
    for i in range(n_full):
        w = stream[i * step : i * step + seq_len + 1]
        rows_t.append(w[:-1])
        rows_l.append(w[1:])
        rows_m.append([1.0] * seq_len)
    rem = stream[n_full * step :]
    if len(rem) > 1:
        t = rem[:-1][:seq_len]
        l = rem[1:][: len(t)]
        m = [1.0] * len(t)
        pad = seq_len - len(t)
        rows_t.append(t + [pad_id] * pad)
        rows_l.append(l + [pad_id] * pad)
        rows_m.append(m + [0.0] * pad)
    return (
        np.asarray(rows_t, np.int32),
        np.asarray(rows_l, np.int32),
        np.asarray(rows_m, np.float32),
    )
