"""Synthetic corpus generator: scalable, seeded, with controllable fractions
of noise / duplicates / near-duplicates / multimodal samples — the offline
stand-in for the paper's LLaVA-based scaling corpus (§H.1)."""
from __future__ import annotations

import math
from typing import Dict, Iterator, List, Optional

import numpy as np

from repro.core import schema as S

_VOCAB = (
    "data juicer cloud scale adaptive processing foundation model multimodal operator "
    "pipeline filter mapper dedup ray tpu mesh shard batch token image video audio "
    "quality score train sample system efficient runtime engine recipe insight "
    "probability gradient neural network language vision speech alignment semantic".split()
)
_NOISE = list("!@#$%^&*<>{}[]|\\~`")


def _sentence(rng: np.random.Generator, n: int) -> str:
    return " ".join(rng.choice(_VOCAB, size=n)) + "."


def make_corpus(
    n: int,
    seed: int = 0,
    noise_frac: float = 0.15,
    dup_frac: float = 0.2,
    near_dup_frac: float = 0.1,
    multimodal_frac: float = 0.2,
    min_sents: int = 2,
    max_sents: int = 12,
) -> List[Dict]:
    """Returns n schema samples; ``dup_frac`` are exact copies of earlier
    samples and ``near_dup_frac`` are word-dropped near-copies."""
    rng = np.random.default_rng(seed)
    out: List[Dict] = []
    originals: List[str] = []
    for i in range(n):
        r = rng.random()
        if out and r < dup_frac:
            text = originals[int(rng.integers(0, len(originals)))]
            kind = "dup"
        elif out and r < dup_frac + near_dup_frac:
            base = originals[int(rng.integers(0, len(originals)))].split()
            keep = rng.random(len(base)) > 0.08
            text = " ".join(w for w, k in zip(base, keep) if k)
            kind = "near_dup"
        else:
            n_s = int(rng.integers(min_sents, max_sents + 1))
            text = " ".join(_sentence(rng, int(rng.integers(5, 18))) for _ in range(n_s))
            if rng.random() < noise_frac:
                junk = "".join(rng.choice(_NOISE, size=int(rng.integers(20, 80))))
                text = junk + " " + text if rng.random() < 0.5 else text + " " + junk
                kind = "noisy"
            else:
                kind = "clean"
            originals.append(text)
        s = S.new_sample(text)
        s["meta"] = {
            "id": i, "kind": kind,
            "domain": str(rng.choice(["web", "code", "news", "dialog"])),
        }
        if rng.random() < multimodal_frac:
            n_img = int(rng.integers(1, 3))
            tags_pool = ["cat", "dog", "tree", "car", "person", "house", "sky"]
            s["images"] = [f"img://{i}/{j}" for j in range(n_img)]
            s["image_meta"] = [
                {
                    "width": int(rng.integers(16, 4096)),
                    "height": int(rng.integers(16, 4096)),
                    "bytes": int(rng.integers(1_000, 5_000_000)),
                    "nsfw_score": float(rng.beta(1, 20)),
                    "tags": list(rng.choice(tags_pool, size=2, replace=False)),
                }
                for _ in range(n_img)
            ]
            s["text"] = (S.IMAGE_TOKEN + " ") * n_img + s["text"]
        if rng.random() < multimodal_frac / 2:
            s["videos"] = [f"vid://{i}"]
            energy = np.abs(rng.standard_normal(24) * rng.random() * 4).tolist()
            s["video_meta"] = [{
                "duration": float(rng.uniform(0.5, 600)),
                "fps": 24, "frame_energy": [round(e, 4) for e in energy],
            }]
            s["text"] = S.VIDEO_TOKEN + " " + s["text"]
        if rng.random() < multimodal_frac / 2:
            s["audios"] = [f"aud://{i}"]
            s["audio_meta"] = [{
                "duration": float(rng.uniform(0.2, 120)),
                "rms_signal": float(rng.uniform(0.05, 1.0)),
                "rms_noise": float(rng.uniform(0.001, 0.3)),
            }]
            s["text"] = S.AUDIO_TOKEN + " " + s["text"]
        out.append(s)
    return out
