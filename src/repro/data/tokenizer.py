"""Deterministic offline tokenizers (no external vocab files).

``ByteTokenizer`` — raw UTF-8 bytes + BOS/EOS/PAD; exact round-trip.
``HashWordTokenizer`` — whitespace words hashed into a fixed vocab
(stable blake2); fast, any vocab size, used to feed the assigned-arch
models whose configs fix large vocab sizes.
"""
from __future__ import annotations

import hashlib
from typing import List, Sequence

import numpy as np


class ByteTokenizer:
    PAD, BOS, EOS = 256, 257, 258
    vocab_size = 259

    def encode(self, text: str, add_bos: bool = True, add_eos: bool = True) -> List[int]:
        ids = list(text.encode("utf-8"))
        if add_bos:
            ids = [self.BOS] + ids
        if add_eos:
            ids = ids + [self.EOS]
        return ids

    def decode(self, ids: Sequence[int]) -> str:
        return bytes(i for i in ids if i < 256).decode("utf-8", errors="replace")


class HashWordTokenizer:
    """word -> 4 + blake2(word) % (vocab-4); ids 0..3 reserved (pad/bos/eos/unk)."""

    PAD, BOS, EOS, UNK = 0, 1, 2, 3

    def __init__(self, vocab_size: int = 32000):
        assert vocab_size > 8
        self.vocab_size = vocab_size

    def _wid(self, w: str) -> int:
        h = hashlib.blake2b(w.encode("utf-8"), digest_size=8).digest()
        return 4 + int.from_bytes(h, "little") % (self.vocab_size - 4)

    def encode(self, text: str, add_bos: bool = True, add_eos: bool = True) -> List[int]:
        ids = [self._wid(w) for w in text.split()]
        if add_bos:
            ids = [self.BOS] + ids
        if add_eos:
            ids = ids + [self.EOS]
        return ids

    def encode_batch(self, texts: Sequence[str]) -> List[List[int]]:
        return [self.encode(t) for t in texts]
