"""Training feed: DJDataset -> tokenized, packed, mesh-sharded batches.

This is where the paper's data pipeline meets the training stack: the
processed dataset is tokenized (HashWordTokenizer to match any assigned
arch vocab), packed to fixed sequences, and yielded as device arrays placed
with the same logical-axis rules the train step uses.
"""
from __future__ import annotations

from typing import Iterator, Optional

import jax
import numpy as np

from repro.data.packing import pack_documents
from repro.data.tokenizer import HashWordTokenizer
from repro.launch import sharding as sh


class PackedDataLoader:
    def __init__(
        self,
        dataset,
        seq_len: int,
        global_batch: int,
        vocab_size: int = 32000,
        mesh=None,
        seed: int = 0,
        drop_remainder: bool = True,
    ):
        self.seq_len = seq_len
        self.global_batch = global_batch
        self.mesh = mesh
        tok = HashWordTokenizer(vocab_size)
        docs = [tok.encode(s.get("text", "")) for s in dataset]
        self.tokens, self.labels, self.mask = pack_documents(docs, seq_len)
        rng = np.random.default_rng(seed)
        self.order = rng.permutation(len(self.tokens))
        self.drop_remainder = drop_remainder

    def __len__(self):
        return len(self.tokens) // self.global_batch

    def batches(self, epochs: int = 1) -> Iterator[dict]:
        for _ in range(epochs):
            for i in range(0, len(self.order) - self.global_batch + 1, self.global_batch):
                idx = self.order[i : i + self.global_batch]
                batch = {
                    "tokens": self.tokens[idx],
                    "labels": self.labels[idx],
                    "loss_mask": self.mask[idx],
                }
                if self.mesh is not None:
                    batch = {
                        k: jax.device_put(
                            v,
                            sh.named_sharding(v.shape, ("batch", "seq"), self.mesh, sh.ACT_RULES),
                        )
                        for k, v in batch.items()
                    }
                yield batch
