"""Intra-job scale-out: shard one streaming job across many runners.

``Recipe.shards > 1`` turns a cluster job into a small task DAG published
into the SAME queue the job came from (``repro.api.cluster``): at first
claim the lead runner pins the plan, splits the input into contiguous row
ranges, and submits one **map** task per range plus (for dedup plans)
**reduce** tasks per band owner and one **finalize** task that splices the
partial results back in input order. Shard tasks are first-class queue jobs
— O_EXCL attempt-numbered claims, heartbeat TTLs, per-task checkpoints —
so a SIGKILL'd shard runner fails over exactly like a whole job does
today, with ``resumed_at > 0`` on the re-claimed attempt.

Task naming: ``<job>~s<k>`` (map shard k), ``<job>~r<o>`` (reduce owner o),
``<job>~fin`` (finalize). ``~`` never appears in user job ids (uuid hex /
caller-chosen names), and shard tasks are hidden from job listings; they
surface through ``status(parent)["shards"]`` and the cluster overview.

Plan split (``split_plan``): the pinned plan's longest pipelineable chain
prefix runs inside every map task (over that shard's row range). What
follows decides the mode:

* ``dedup`` — the first stateful op is a streaming MinHash dedup: maps run
  prefix + ``shard_minhash_map`` (local presign, spill, band-key routing);
  reduces rebuild each owned band's bucket heads over the global doc order
  and verify candidate pairs; finalize merges components (the
  StreamingUnionFind reconciliation barrier), replays the spills keep-first
  per component, and streams the post-dedup suffix into the parent export —
  byte-identical to the single-runner run in ``exact`` mode.
* ``chain`` — no barrier at all: maps run the whole plan over their range
  and finalize concatenates the partial exports in shard order.
* ``barrier`` — a non-dedup barrier/stateful op: maps run the chain prefix,
  finalize concatenates the parts and runs the remaining plan single-runner
  (graceful degradation — prefix compute still scales out).

The lead runner supervises: it claims ready shard tasks INLINE when no
other runner takes them (single-runner liveness), while any other
ClusterRunner picks them up through the normal ``next_job`` path (shard
specs carry ``after`` dependency lists the queue enforces). If the lead
dies, the parent job fails over and the new lead re-enters supervision —
completed shard tasks are terminal results it simply observes.
"""
from __future__ import annotations

import os
import time
from typing import Any, Dict, List, Optional, Tuple

from repro.core import clock, obs
from repro.api.cluster import (
    CANCELLED, FAILED, QUEUED, SHARD_SEP, SUCCEEDED, TERMINAL, ClusterQueue,
    Lease, _read_json, _write_json_atomic, is_shard_task, parent_of,
)
from repro.core.recipes import Recipe

__all__ = [
    "SHARD_SEP", "is_shard_task", "parent_of", "map_task_id",
    "reduce_task_id", "finalize_task_id", "task_sort_key",
]

# shards="auto" sizing targets (env-tunable): aim for shards of roughly
# this many rows / bytes, capped by 2x the live runner fleet's capacity
AUTO_TARGET_ROWS_ENV = "REPRO_SHARD_TARGET_ROWS"
AUTO_TARGET_BYTES_ENV = "REPRO_SHARD_TARGET_BYTES"
DEFAULT_AUTO_TARGET_ROWS = 50_000
DEFAULT_AUTO_TARGET_BYTES = 64 << 20

# streaming MinHash ops whose stateful stage shards.py knows how to partition
MINHASH_STREAMING_OPS = (
    "document_minhash_deduplicator",
    "streaming_minhash_deduplicator",
    "distributed_minhash_deduplicator",
)

# SHARD_SEP / is_shard_task / parent_of live in api.cluster (which cannot
# import this module) and are re-exported here: one strict predicate —
# ONLY the reserved `~s<k>/~r<o>/~fin` suffixes — shared by the queue,
# the SLO view and this module. A user job named "nightly~v2" is a plain
# job everywhere.


def map_task_id(job_id: str, k: int) -> str:
    return f"{job_id}{SHARD_SEP}s{k}"


def reduce_task_id(job_id: str, o: int) -> str:
    return f"{job_id}{SHARD_SEP}r{o}"


def finalize_task_id(job_id: str) -> str:
    return f"{job_id}{SHARD_SEP}fin"


def task_sort_key(task_id: str) -> Tuple[int, int]:
    """maps -> reduces -> finalize, numerically within a kind (lexicographic
    listing order would interleave: 'fin' < 'r1' < 's0')."""
    suffix = task_id.rsplit(SHARD_SEP, 1)[-1]
    if suffix.startswith("s"):
        kind, idx = 0, suffix[1:]
    elif suffix.startswith("r") and suffix != "r":
        kind, idx = 1, suffix[1:]
    else:
        return (2, 0)
    try:
        return (kind, int(idx))
    except ValueError:
        return (2, 1)


def shard_dir_for(queue: ClusterQueue, job_id: str) -> str:
    return os.path.join(queue.checkpoint_dir(job_id), "shards")


# ---------------------------------------------------------------------------
# plan splitting
# ---------------------------------------------------------------------------


def split_plan(plan_cfgs: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Locate the first non-pipelineable segment in a pinned plan.

    Returns ``{"mode": "dedup"|"barrier"|"chain", "n_prefix": N}`` where N
    is the number of chain ops that precede it (the part every map task
    runs). The pinned configs are lifted into the logical-plan IR and its
    segment partition walked; ``plan_segments`` keeps op order and makes
    each barrier/stateful op its own single-op segment, so slicing the
    CONFIG list by op counts is exact."""
    from repro.core.plan import LogicalPlan

    plan = LogicalPlan.from_op_configs(plan_cfgs)
    n = 0
    for seg in plan.segments():
        if getattr(seg, "stateful", False):
            cfg = plan_cfgs[n]
            if cfg.get("name") in MINHASH_STREAMING_OPS:
                return {"mode": "dedup", "n_prefix": n}
            return {"mode": "barrier", "n_prefix": n}
        if getattr(seg, "barrier", False):
            return {"mode": "barrier", "n_prefix": n}
        n += len(seg.ops)
    return {"mode": "chain", "n_prefix": n}


def wants_sharding(shards: Any) -> bool:
    """Whether a recipe's ``shards`` value requests the sharded path —
    accepts ints, numeric strings, and ``"auto"``."""
    if isinstance(shards, str):
        s = shards.strip().lower()
        if s == "auto":
            return True
        try:
            return int(s) > 1
        except ValueError:
            return False
    try:
        return int(shards or 0) > 1
    except (TypeError, ValueError):
        return False


def _env_int(name: str, default: int) -> int:
    try:
        return int(os.environ.get(name, "") or default)
    except ValueError:
        return default


def resolve_shard_count(recipe: Recipe, n_rows: int,
                        queue: Optional[ClusterQueue] = None
                        ) -> Tuple[int, Optional[Dict[str, Any]]]:
    """``(n_shards, decision)``. For explicit integer ``shards`` the decision
    is None. For ``shards="auto"`` the count is picked from input row/byte
    estimates and the live runner fleet, and the decision dict (inputs +
    chosen value) is persisted in shardmeta and recorded as a span attribute
    in the job trace (ISSUE 8 / ROADMAP carry-over). Accepts a Recipe or a
    raw spec dict."""
    if isinstance(recipe, dict):
        shards, dataset_path = recipe.get("shards"), recipe.get("dataset_path")
    else:
        shards, dataset_path = recipe.shards, recipe.dataset_path
    if not (isinstance(shards, str) and shards.strip().lower() == "auto"):
        try:
            return int(shards or 0), None
        except (TypeError, ValueError):
            return 0, None
    target_rows = max(1, _env_int(AUTO_TARGET_ROWS_ENV,
                                  DEFAULT_AUTO_TARGET_ROWS))
    target_bytes = max(1, _env_int(AUTO_TARGET_BYTES_ENV,
                                   DEFAULT_AUTO_TARGET_BYTES))
    try:
        est_bytes = os.path.getsize(dataset_path) if dataset_path else 0
    except OSError:
        est_bytes = 0
    by_rows = -(-n_rows // target_rows) if n_rows else 1
    by_bytes = -(-est_bytes // target_bytes) if est_bytes else 1
    want = max(1, by_rows, by_bytes)
    # cap by the fleet: ~2 shard tasks per live capacity slot keeps every
    # runner busy through stragglers without flooding the queue
    capacity = 0
    if queue is not None:
        for card in queue.runner_cards(live_only=True):
            capacity += max(1, int(card.get("capacity", 1)))
    cap = max(2, 2 * capacity) if capacity else want
    chosen = max(1, min(want, cap))
    decision = {
        "requested": "auto", "n_rows": n_rows, "est_bytes": est_bytes,
        "target_rows": target_rows, "target_bytes": target_bytes,
        "by_rows": by_rows, "by_bytes": by_bytes,
        "live_capacity": capacity, "cap": cap, "chosen": chosen,
    }
    return chosen, decision


def count_rows(path: str) -> int:
    """Non-empty input lines == the row indices ``row_range`` slices over."""
    from repro.core.storage import _open_read_binary

    n = 0
    with _open_read_binary(path) as f:
        for line in f:
            if line.strip():
                n += 1
    return n


def shard_ranges(n_rows: int, n_shards: int) -> List[List[int]]:
    """Contiguous near-equal [lo, hi) ranges covering [0, n_rows) in order —
    contiguity is what preserves the global doc order the dedup merge (and
    the chain-mode concat) rely on."""
    base, rem = divmod(n_rows, n_shards)
    ranges: List[List[int]] = []
    lo = 0
    for k in range(n_shards):
        size = base + (1 if k < rem else 0)
        ranges.append([lo, lo + size])
        lo += size
    return ranges


# ---------------------------------------------------------------------------
# shard-set construction (lead runner, first claim)
# ---------------------------------------------------------------------------


def _ensure_meta(queue: ClusterQueue, job_id: str, recipe: Recipe,
                 split: Dict[str, Any]) -> Optional[Dict[str, Any]]:
    """Compute-once shard metadata under the shared store. A re-claimed lead
    REUSES the persisted ranges (never recounts — the split must be stable
    across failover); a zombie lead rewriting it writes identical content."""
    sdir = shard_dir_for(queue, job_id)
    os.makedirs(sdir, exist_ok=True)
    path = os.path.join(sdir, "shardmeta.json")
    meta = _read_json(path)
    if meta is not None:
        return meta
    n_rows = count_rows(recipe.dataset_path)
    resolved, auto_decision = resolve_shard_count(recipe, n_rows, queue)
    n_shards = max(1, min(resolved, n_rows or 1))
    if n_shards < 2:
        return None  # degenerate input: run unsharded
    dedup_cfg = None
    n_reducers = 0
    if split["mode"] == "dedup":
        dedup_cfg = dict(recipe.fixed_plan[split["n_prefix"]])
        n_reducers = min(n_shards, int(dedup_cfg.get("num_bands", 16)))
    meta = {
        "job_id": job_id, "n_rows": n_rows, "n_shards": n_shards,
        "ranges": shard_ranges(n_rows, n_shards), "mode": split["mode"],
        "n_prefix": split["n_prefix"], "n_reducers": n_reducers,
        "dedup": dedup_cfg,
    }
    if auto_decision is not None:
        # the auto-tuning decision is part of the stable shard metadata:
        # a failover lead reuses it rather than re-deriving a different
        # count from a changed fleet
        meta["auto"] = auto_decision
    _write_json_atomic(path, meta)
    return meta


def _map_recipe(recipe: Recipe, meta: Dict[str, Any], k: int) -> Dict[str, Any]:
    sdir_name = meta["shard_dir"]
    mode = meta["mode"]
    n_prefix = meta["n_prefix"]
    plan = [dict(c) for c in recipe.fixed_plan]
    if mode == "dedup":
        d = meta["dedup"]
        shard_plan = plan[:n_prefix] + [{
            "name": "shard_minhash_map", "shard_index": k,
            "n_shards": meta["n_shards"], "n_reducers": meta["n_reducers"],
            "shard_dir": sdir_name,
            "num_permutations": d.get("num_permutations", 128),
            "num_bands": d.get("num_bands", 16), "ngram": d.get("ngram", 5),
            "use_kernel": bool(d.get("use_kernel", False)),
            "super_batch": d.get("super_batch", 2048),
        }]
        export = os.path.join(sdir_name, f"out-{k}.jsonl")  # always empty
    elif mode == "chain":
        shard_plan = plan
        export = os.path.join(sdir_name, f"part-{k}.jsonl")
    else:  # barrier: maps run only the chain prefix
        shard_plan = plan[:n_prefix]
        export = os.path.join(sdir_name, f"part-{k}.jsonl")
    rd = recipe.to_dict()
    rd.update(
        name=f"{recipe.name}{SHARD_SEP}s{k}", shards=0,
        row_range=list(meta["ranges"][k]), export_path=export,
        process=shard_plan, fixed_plan=shard_plan,
        # per-task checkpoints (runner assigns queue.checkpoint_dir(task_id))
        # make shard failover resume mid-plan, exactly like jobs do
        checkpoint_dir=None, insight=False,
        # the task's own spec-level trace (not the parent recipe's) is what
        # the executing runner threads into the run
        trace=None,
    )
    return rd


def _submit_quiet(queue: ClusterQueue, spec: Dict[str, Any]) -> None:
    """Idempotent shard-spec publication: the parent lease is exclusive, so
    an existing spec means a previous (or zombie) lead already published
    identical content."""
    task_id = spec["job_id"]
    if os.path.exists(queue.spec_path(task_id)):
        return
    try:
        queue.submit(spec["recipe"], job_id=task_id, extra={
            k: v for k, v in spec.items() if k not in ("job_id", "recipe")})
    except ValueError:
        pass


def publish_shard_tasks(queue: ClusterQueue, job_id: str, recipe: Recipe,
                        meta: Dict[str, Any],
                        trace: Optional[Dict[str, Any]] = None,
                        tenant: Optional[str] = None) -> List[str]:
    """Submit the shard-task DAG; returns every task id in execution order.

    ``trace`` is the PARENT job's trace context: every shard task inherits
    the parent's trace_id and roots its own span under the parent's root
    span, so the whole DAG — including failed-over attempts — merges into
    one trace (core.obs). ``tenant`` is likewise the parent's: shard tasks
    run under the parent's identity (fair-share service and per-tenant SLOs
    attribute them to it) but bypass quota admission — the parent already
    holds the slot."""
    n_shards, n_reducers = meta["n_shards"], meta["n_reducers"]
    mode = meta["mode"]
    base = recipe.to_dict()
    base.update(shards=0, trace=None)
    owner = {"tenant": tenant} if tenant else {}

    def task_trace() -> Dict[str, Any]:
        if not trace or not trace.get("trace_id"):
            return {}
        return {"trace": {"trace_id": trace["trace_id"],
                          "root_span": obs.new_id(),
                          "parent_span": trace.get("root_span")}}

    map_ids = [map_task_id(job_id, k) for k in range(n_shards)]
    for k in range(n_shards):
        _submit_quiet(queue, {
            "job_id": map_ids[k], "recipe": _map_recipe(recipe, meta, k),
            "shard": {"parent": job_id, "kind": "map", "index": k,
                      "n_shards": n_shards, "mode": mode},
            **owner, **task_trace(),
        })
    reduce_ids: List[str] = []
    if mode == "dedup":
        for o in range(n_reducers):
            tid = reduce_task_id(job_id, o)
            reduce_ids.append(tid)
            _submit_quiet(queue, {
                "job_id": tid, "recipe": dict(base),
                "shard": {"parent": job_id, "kind": "reduce", "index": o,
                          "n_shards": n_shards, "n_reducers": n_reducers,
                          "dedup": meta["dedup"]},
                "after": list(map_ids),
                **owner, **task_trace(),
            })
    fin_id = finalize_task_id(job_id)
    _submit_quiet(queue, {
        "job_id": fin_id, "recipe": dict(base),
        "shard": {"parent": job_id, "kind": "finalize", "index": 0,
                  "mode": mode, "n_shards": n_shards,
                  "n_reducers": n_reducers, "n_prefix": meta["n_prefix"],
                  "n_rows": meta["n_rows"], "dedup": meta.get("dedup")},
        "after": list(map_ids) + list(reduce_ids),
        **owner, **task_trace(),
    })
    return map_ids + reduce_ids + [fin_id]


# ---------------------------------------------------------------------------
# lead-runner supervision
# ---------------------------------------------------------------------------


def run_sharded(runner, lease: Lease, spec: Dict[str, Any], recipe: Recipe,
                monitor: List[dict], cancel_event, lease_lost
                ) -> Optional[Dict[str, Any]]:
    """Supervise one sharded job from its (parent) lease. Returns the parent
    report, or None when sharding degenerates (caller runs unsharded).

    Liveness: the supervisor claims + executes ready shard tasks INLINE, so
    one lone runner still finishes the whole DAG; extra runners shorten the
    critical path by claiming map tasks concurrently through ``next_job``.
    On parent-lease loss it aborts WITHOUT touching shard tasks — the
    failover lead resumes supervision over the surviving task states.
    """
    from repro.core.dataset import ExecutionCancelled

    queue: ClusterQueue = runner.queue
    job_id = lease.job_id
    if not recipe.dataset_path or not recipe.export_path:
        return None
    t0 = clock.now()
    recipe.fixed_plan = runner._pin_plan(job_id, recipe)
    split = split_plan(recipe.fixed_plan)
    if split["mode"] == "barrier" and split["n_prefix"] == 0:
        return None  # nothing parallelizable before the barrier
    meta = _ensure_meta(queue, job_id, recipe, split)
    if meta is None:
        return None
    meta = {**meta, "shard_dir": shard_dir_for(queue, job_id)}
    parent_trace = spec.get("trace") or {}
    tasks = publish_shard_tasks(queue, job_id, recipe, meta,
                                trace=parent_trace,
                                tenant=spec.get("tenant"))
    specs = {t: queue.read_spec(t) for t in tasks}
    fin_id = tasks[-1]
    queue.log_event("sharded", job_id=job_id, n_shards=meta["n_shards"],
                    mode=meta["mode"], n_reducers=meta["n_reducers"],
                    auto=meta.get("auto"))
    # the shard-plan span records HOW the job was split — including the
    # full shards="auto" decision (inputs + chosen count) when auto-tuned
    plan_span = obs.start_span(parent_trace.get("trace_id"), "shards:plan",
                               kind="shards",
                               parent_id=parent_trace.get("root_span"), t0=t0)
    if plan_span is not None:
        plan_span.set(n_shards=meta["n_shards"], mode=meta["mode"],
                      n_reducers=meta["n_reducers"], n_rows=meta["n_rows"])
        if meta.get("auto"):
            plan_span.set(auto=meta["auto"])
        # per-rule optimizer rewrite diffs, persisted by _pin_plan alongside
        # the pinned plan — the shards:plan span shows WHAT the rules did to
        # the plan this DAG was split from (docs/observability.md)
        plan_rec = _read_json(os.path.join(
            queue.checkpoint_dir(job_id), "plan.json")) or {}
        if plan_rec.get("rewrites"):
            plan_span.set(rewrites=plan_rec["rewrites"])
        plan_span.end()

    poll = min(0.2, max(0.05, getattr(runner, "poll", 0.2)))
    while True:
        if lease_lost.is_set():
            # failover: the next lead takes over the surviving shard tasks
            raise ExecutionCancelled(f"parent lease lost: {job_id}")
        if cancel_event.is_set() and queue.is_cancelled(job_id):
            for t in tasks:
                if queue.state_of(t) not in TERMINAL:
                    try:
                        queue.cancel(t)
                    except KeyError:
                        pass
            raise ExecutionCancelled(f"sharded job cancelled: {job_id}")
        states = {t: queue.state_of(t) for t in tasks}
        if states[fin_id] == SUCCEEDED:
            break
        failed = [t for t in tasks if states[t] in (FAILED, CANCELLED)]
        if failed:
            rec = _read_json(queue.result_path(failed[0])) or {}
            for t in tasks:
                if states[t] not in TERMINAL:
                    try:
                        queue.cancel(t)
                    except KeyError:
                        pass
            raise RuntimeError(
                f"shard task {failed[0]} {states[failed[0]]}: "
                f"{rec.get('error') or 'no error recorded'}")
        claimed = False
        for t in tasks:
            if states[t] != QUEUED:
                continue
            deps = specs[t].get("after") or ()
            if any(states.get(d) != SUCCEEDED for d in deps):
                continue
            shard_lease = queue.try_claim(t, runner.runner_id,
                                          ttl=runner.lease_ttl)
            if shard_lease is not None:
                runner._execute(shard_lease)  # inline, synchronous
                claimed = True
                break
        if not claimed:
            time.sleep(poll)

    fin_rec = _read_json(queue.result_path(fin_id)) or {}
    fin_rep = fin_rec.get("report") or {}
    task_summary: Dict[str, Any] = {}
    for t in tasks:
        rec = _read_json(queue.result_path(t)) or {}
        rep = rec.get("report") or {}
        task_summary[t] = {
            "state": rec.get("state"), "attempt": rec.get("attempt"),
            "runner_id": rec.get("runner_id"),
            "resumed_at": rep.get("resumed_at", 0),
        }
    return {
        "recipe": recipe.name, "n_in": meta["n_rows"],
        "n_out": fin_rep.get("n_out", 0), "seconds": clock.now() - t0,
        "plan": [c.get("name") for c in recipe.fixed_plan],
        "errors": 0, "streaming": True, "resumed_at": 0, "dispatch": [],
        "sharded": {"n_shards": meta["n_shards"], "mode": meta["mode"],
                    "n_reducers": meta["n_reducers"], "tasks": task_summary},
    }


# ---------------------------------------------------------------------------
# reduce / finalize task bodies (dispatched by ClusterRunner._execute)
# ---------------------------------------------------------------------------


def run_reduce_task(runner, spec: Dict[str, Any]) -> Dict[str, Any]:
    from repro.core.dedup.sharded import run_reduce

    sh = spec["shard"]
    d = sh["dedup"] or {}
    thr = float(d.get("jaccard_threshold", 0.7))
    rep = run_reduce(
        shard_dir_for(runner.queue, sh["parent"]), sh["index"],
        sh["n_shards"], sh["n_reducers"],
        int(d.get("num_bands", 16)), thr, verify=thr > 0)
    return {"n_in": rep["n_docs"], "n_out": rep["n_pairs"], "seconds": 0.0,
            "reduce": rep}


def _concat_parts(queue: ClusterQueue, parent: str, n_shards: int,
                  export_path: str) -> int:
    """Splice partial exports in shard (== input) order into the parent
    export. Plain targets get a raw byte concat; encoded targets re-stream
    rows through BlockWriter so the export codec stays in charge."""
    from repro.core.storage import BlockWriter, SampleBlock, read_jsonl

    sdir = shard_dir_for(queue, parent)
    parts = [os.path.join(sdir, f"part-{k}.jsonl") for k in range(n_shards)]
    n_out = 0
    if not export_path.endswith(".zst"):
        tmp = f"{export_path}.{os.getpid()}.tmp"
        with open(tmp, "wb") as out:
            for p in parts:
                with open(p, "rb") as f:
                    for line in f:
                        if line.strip():
                            out.write(line)
                            n_out += 1
        os.replace(tmp, export_path)
        return n_out
    writer = BlockWriter(export_path)
    ok = False
    try:
        for p in parts:
            rows = list(read_jsonl(p))
            if rows:
                n_out += writer.write_block(SampleBlock(rows, nbytes=0)) or len(rows)
        ok = True
    finally:
        writer.close(success=ok)
    return n_out


def run_finalize_task(runner, spec: Dict[str, Any], monitor: List[dict],
                      cancel) -> Dict[str, Any]:
    """The merge/reconciliation step, running as its own fault-tolerant
    queue task once every upstream shard task has succeeded."""
    from repro.core.dataset import ExecutionCancelled, stream_segments
    from repro.core.executor import Executor
    from repro.core.plan import LogicalPlan
    from repro.core.storage import BlockWriter

    queue: ClusterQueue = runner.queue
    sh = spec["shard"]
    parent = sh["parent"]
    mode = sh["mode"]
    task_id = spec["job_id"]
    recipe = Recipe.from_dict(spec.get("recipe") or {})
    t0 = clock.now()

    if mode == "chain":
        n_out = _concat_parts(queue, parent, sh["n_shards"], recipe.export_path)
        return {"n_in": sh.get("n_rows", n_out), "n_out": n_out,
                "seconds": clock.now() - t0, "mode": mode, "resumed_at": 0}

    plan_rec = _read_json(os.path.join(queue.checkpoint_dir(parent),
                                       "plan.json")) or {}
    plan_cfgs = plan_rec.get("plan") or list(recipe.process)
    n_prefix = int(sh["n_prefix"])

    if mode == "barrier":
        # concat the prefix parts, then run the remaining plan single-runner
        sdir = shard_dir_for(queue, parent)
        merged = os.path.join(sdir, "merged.jsonl")
        _concat_parts(queue, parent, sh["n_shards"], merged)
        sub = Recipe.from_dict(recipe.to_dict())
        sub.name = f"{recipe.name}{SHARD_SEP}fin"
        sub.dataset_path = merged
        sub.row_range = None
        sub.shards = 0
        sub.insight = False
        sub.process = [dict(c) for c in plan_cfgs[n_prefix:]]
        sub.fixed_plan = [dict(c) for c in plan_cfgs[n_prefix:]]
        sub.checkpoint_dir = queue.checkpoint_dir(task_id)
        _, rep = Executor(sub).run_streaming(
            materialize=False, monitor=monitor, cancel=cancel)
        return {"n_in": rep.n_in, "n_out": rep.n_out,
                "seconds": clock.now() - t0, "mode": mode,
                "resumed_at": rep.resumed_at}

    # dedup: reconciliation barrier + keep-first spill replay + suffix chain
    from repro.core.dedup.sharded import iter_final_blocks

    d = sh["dedup"] or {}
    counters: Dict[str, int] = {}
    blocks = iter_final_blocks(
        shard_dir_for(queue, parent), n_shards=sh["n_shards"],
        n_bands=int(d.get("num_bands", 16)), n_reducers=sh["n_reducers"],
        mode=d.get("streaming", "exact"), backend=d.get("backend", "balanced"),
        n_partitions=int(d.get("n_partitions", 8)),
        super_batch=int(d.get("super_batch", 2048)), counters=counters)
    suffix_plan = LogicalPlan.from_op_configs(plan_cfgs[n_prefix + 1:])
    suffix_ops = suffix_plan.ops()
    sub = Recipe.from_dict(recipe.to_dict())
    sub.shards = 0
    sub.row_range = None
    engine = Executor(sub)._make_engine()
    sink = BlockWriter(recipe.export_path)
    ok = False
    try:
        if suffix_ops:
            segments = suffix_plan.segments()
            _, _, n_out = stream_segments(
                blocks, segments, engine, sink=sink, collect=False,
                n_workers_hint=getattr(engine, "n_workers", 1) or 1,
                monitor=monitor, cancel=cancel)
        else:
            n_out = 0
            for blk in blocks:
                if cancel is not None and cancel():
                    raise ExecutionCancelled("finalize cancelled")
                sink.write_block(blk)
                n_out += len(blk)
        ok = True
    finally:
        sink.close(success=ok)
        close = getattr(engine, "close", None)
        if close is not None:
            close()
    return {"n_in": counters.get("n_docs", 0), "n_out": n_out,
            "n_kept": counters.get("n_kept", 0),
            "n_pairs": counters.get("n_pairs", 0),
            "seconds": clock.now() - t0, "mode": mode, "resumed_at": 0}
