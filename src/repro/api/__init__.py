"""Python-first entry point (paper §4): ``import repro.api as dj``.

One lazy Pipeline API behind every front-end — CLI recipes, REST async
jobs and the NL agent all lower to the same deferred plan and dispatch
through the adaptive Executor (fusion / reordering / streaming segments).
"""
from repro.api.analysis import DEFAULT_ANALYZE_OPS, analyze, discover_stat_ops
from repro.api.cluster import (
    ClusterQueue, ClusterRunner, Lease, PlacementPolicy,
)
from repro.api.jobs import (
    ClusterJobHandle, Job, JobManager, JobState, JobStoreFull,
)
from repro.api.pipeline import (
    LazyDataset, Pipeline, from_dataset, from_recipe, from_samples, read_jsonl,
)
from repro.api.sql import SQLError, sql

__all__ = [
    "DEFAULT_ANALYZE_OPS", "analyze", "discover_stat_ops",
    "ClusterQueue", "ClusterRunner", "Lease", "PlacementPolicy",
    "ClusterJobHandle", "Job", "JobManager", "JobState", "JobStoreFull",
    "LazyDataset", "Pipeline",
    "read_jsonl", "from_samples", "from_dataset", "from_recipe",
    "sql", "SQLError",
]
