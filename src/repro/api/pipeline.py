"""Lazy, chainable Pipeline API — the one Python-first entry point every
front-end (CLI / REST / NL agent / SQL) compiles down to (paper §4,
Appendix C.2).

A ``Pipeline`` is an immutable, deferred plan (Ray-Data-style fluent
chaining): each ``.map()/.filter()/.dedup()`` call validates the op name and
kwargs against the registry's typed signatures and returns a NEW pipeline.
Internally a pipeline IS a logical plan (``repro.core.plan.LogicalPlan``):
the fluent verbs append typed IR nodes, and ``to_recipe()`` — the single
Recipe<->IR serialization boundary — lowers the plan for the ``Executor``,
so fusion, workload-aware reordering, streaming-segment auto-selection,
checkpoints and insight mining all apply for free, and a fluent pipeline is
*byte-identical* to the equivalent recipe run.

    import repro.api as dj
    (dj.read_jsonl("in.jsonl")
       .map("clean_links_mapper")
       .filter("text_length_filter", min_val=80)
       .dedup(jaccard_threshold=0.7)
       .write_jsonl("out.jsonl")
       .execute())
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, Iterator, List, Optional, Tuple

from repro.core.plan import OPTION_FIELDS as _OPTION_FIELDS
from repro.core.plan import LogicalPlan
from repro.core.recipes import Recipe
from repro.core.registry import op_info

# method -> op taxonomy types it accepts (op_info()["type"])
_KIND_FOR_METHOD = {
    "map": ("Mapper", "Formatter"),
    "filter": ("Filter",),
    "dedup": ("Deduplicator",),
    "select": ("Selector",),
    "group": ("Grouper",),
    "aggregate": ("Aggregator",),
}


def _check_kind(method: str, name: str) -> None:
    kinds = _KIND_FOR_METHOD[method]
    actual = op_info(name)["type"]
    if actual not in kinds:
        hint = {"Mapper": "map", "Formatter": "map", "Filter": "filter",
                "Deduplicator": "dedup", "Selector": "select",
                "Grouper": "group", "Aggregator": "aggregate"}.get(actual, "op")
        raise TypeError(
            f"{name} is a {actual}, not a {'/'.join(kinds)}; "
            f"use .{hint}({name!r}, ...) or the generic .op()")


class Pipeline:
    """Immutable lazy plan — a fluent view over a ``LogicalPlan``."""

    def __init__(self, source: Optional[Dict[str, Any]] = None,
                 steps: Tuple[Dict[str, Any], ...] = (),
                 options: Optional[Dict[str, Any]] = None,
                 plan: Optional[LogicalPlan] = None):
        if plan is None:
            plan = LogicalPlan.from_op_configs(steps, source=source,
                                               options=options)
        self._plan = plan

    # ------------------------------------------------------------------
    # the underlying IR (and compatibility views over it)
    # ------------------------------------------------------------------
    @property
    def plan(self) -> LogicalPlan:
        """The logical-plan IR this pipeline wraps."""
        return self._plan

    @property
    def _source(self) -> Optional[Dict[str, Any]]:
        return self._plan.source

    @property
    def _steps(self) -> Tuple[Dict[str, Any], ...]:
        return tuple(self._plan.op_configs())

    @property
    def _options(self) -> Dict[str, Any]:
        return dict(self._plan.options)

    # ------------------------------------------------------------------
    # sources
    # ------------------------------------------------------------------
    @classmethod
    def read_jsonl(cls, path: str) -> "Pipeline":
        """Lazy JSONL/zst source — never decoded until execution."""
        return cls(plan=LogicalPlan({"kind": "jsonl", "path": path}))

    @classmethod
    def from_samples(cls, samples: Iterable[Dict[str, Any]]) -> "Pipeline":
        return cls(plan=LogicalPlan({"kind": "samples",
                                     "samples": list(samples)}))

    @classmethod
    def from_dataset(cls, dataset) -> "Pipeline":
        """Wrap an in-memory DJDataset, carrying its engine into the lowered
        recipe (a parallel/sharded dataset keeps running parallel/sharded;
        a later ``.with_engine()`` overrides)."""
        opts: Dict[str, Any] = {}
        engine_cls = type(getattr(dataset, "engine", None)).__name__
        if engine_cls == "ParallelEngine":
            opts = {"engine": "parallel",
                    "np": getattr(dataset.engine, "n_workers", 1) or 1}
        elif engine_cls == "ShardedEngine":
            opts = {"engine": "sharded"}
        return cls(plan=LogicalPlan({"kind": "dataset", "dataset": dataset},
                                    options=opts))

    @classmethod
    def from_recipe(cls, recipe: Recipe) -> "Pipeline":
        """Lift a declarative Recipe into the fluent representation
        (``LogicalPlan.from_recipe`` — the Recipe<->IR boundary)."""
        return cls(plan=LogicalPlan.from_recipe(recipe))

    # ------------------------------------------------------------------
    # chainable ops (validated, deferred)
    # ------------------------------------------------------------------
    def op(self, name: str, **kwargs) -> "Pipeline":
        """Generic chain step: any registered OP by name. Unknown names /
        bad kwargs fail HERE (LogicalPlan.with_op validates against the
        registry's typed signatures)."""
        return Pipeline(plan=self._plan.with_op({"name": name, **kwargs}))

    def map(self, name: str, **kwargs) -> "Pipeline":
        _check_kind("map", name)
        return self.op(name, **kwargs)

    def filter(self, name: str, **kwargs) -> "Pipeline":
        _check_kind("filter", name)
        return self.op(name, **kwargs)

    def dedup(self, name: str = "document_minhash_deduplicator",
              streaming: Optional[str] = None, **kwargs) -> "Pipeline":
        """Deduplicate. ``streaming`` picks the execution protocol under the
        streaming executor: ``"off"`` (dataset barrier, exact),
        ``"keep_first"`` (incremental stage, bounded memory, keeps a
        documented superset of the exact result), ``"windowed"``
        (keep_first with a bounded retroactive-merge horizon — pass
        ``window=`` rows; sits between keep_first and exact:
        exact ⊆ windowed ⊆ keep_first) or ``"exact"`` (two-pass
        incremental stage, byte-identical to the barrier). ``None`` defers
        to the op's own default."""
        _check_kind("dedup", name)
        if streaming is not None:
            kwargs["streaming"] = streaming
        return self.op(name, **kwargs)

    def select(self, name: str, **kwargs) -> "Pipeline":
        _check_kind("select", name)
        return self.op(name, **kwargs)

    def group(self, name: str, **kwargs) -> "Pipeline":
        _check_kind("group", name)
        return self.op(name, **kwargs)

    def aggregate(self, name: str, **kwargs) -> "Pipeline":
        _check_kind("aggregate", name)
        return self.op(name, **kwargs)

    # ------------------------------------------------------------------
    # run options (also chainable)
    # ------------------------------------------------------------------
    def options(self, **kwargs) -> "Pipeline":
        """Set Recipe-level run options (engine, np, use_fusion, ...)."""
        return Pipeline(plan=self._plan.with_options(**kwargs))

    def write_jsonl(self, path: str) -> "Pipeline":
        """Deferred export target (block-streamed, not materialized)."""
        return self.options(export_path=path)

    def with_engine(self, engine: str, np: Optional[int] = None) -> "Pipeline":
        opts: Dict[str, Any] = {"engine": engine}
        if np is not None:
            opts["np"] = np
        return self.options(**opts)

    def checkpoint(self, checkpoint_dir: str) -> "Pipeline":
        return self.options(checkpoint_dir=checkpoint_dir)

    def tenant(self, name: str) -> "Pipeline":
        """Owning tenant for cluster submission (``repro.api.cluster``):
        quota admission, fair-share claiming and per-tenant SLOs key on it.
        Local ``.execute()`` ignores it; omitted means the default tenant."""
        from repro.api.cluster import validate_tenant

        return self.options(tenant=validate_tenant(name))

    def shards(self, n) -> "Pipeline":
        """Intra-job scale-out: when this pipeline is submitted to a
        ``ClusterQueue``, split the input into ``n`` row-range shards that
        many runners execute cooperatively (``repro.api.shards``). Pass
        ``"auto"`` to let the lead runner pick the count from input size
        and the live runner fleet at claim time (the decision is recorded
        in the job trace). Local ``.execute()`` ignores it — sharding is a
        cluster-level protocol."""
        if isinstance(n, str):
            if n.strip().lower() != "auto":
                raise ValueError(f"shards must be an int or 'auto', got {n!r}")
            return self.options(shards="auto")
        return self.options(shards=int(n))

    def insight(self, on: bool = True) -> "Pipeline":
        return self.options(insight=on)

    # ------------------------------------------------------------------
    # lowering + execution
    # ------------------------------------------------------------------
    def to_recipe(self, name: str = "pipeline") -> Recipe:
        """Lower the plan into the declarative Recipe the Executor runs.
        This is the equivalence guarantee: executing the pipeline IS
        executing this recipe."""
        return self._plan.to_recipe(name)

    def save_recipe(self, path: str, name: str = "pipeline") -> None:
        self.to_recipe(name).save(path)

    def _source_dataset(self):
        from repro.core.dataset import DJDataset

        src = self._plan.source
        if src is None:
            return None
        if src["kind"] == "dataset":
            return src["dataset"]
        if src["kind"] == "samples":
            # protected copies: ops write into sample['stats']/['meta'], and
            # the caller's list must survive execute() unmutated (and be
            # reusable across runs of differently-configured pipelines)
            return DJDataset.from_samples(
                [{**s, "stats": dict(s.get("stats") or {}),
                  "meta": dict(s.get("meta") or {})}
                 for s in src["samples"]])
        return None  # jsonl: the Executor streams it from disk

    def _executor(self):
        from repro.core.executor import Executor

        return Executor(self.to_recipe())

    def execute(self, monitor: Optional[List[dict]] = None, cancel=None):
        """Lower and run through the Executor (streaming path auto-selected).
        Returns ``(DJDataset, RunReport)``. ``monitor``/``cancel`` are wired
        through for async job progress and cancellation."""
        return self._executor().run(dataset=self._source_dataset(),
                                    monitor=monitor, cancel=cancel)

    def iter_blocks(self, prefetch: int = 4, cancel=None) -> Iterator[Any]:
        """Stream output SampleBlocks lazily — the full dataset is never
        materialized (except at genuine barrier ops). Ignores export_path."""
        return self._executor().stream_blocks(
            dataset=self._source_dataset(), prefetch=prefetch, cancel=cancel)

    def iter_samples(self, prefetch: int = 4) -> Iterator[Dict[str, Any]]:
        for blk in self.iter_blocks(prefetch=prefetch):
            yield from blk.samples

    def explain(self) -> Dict[str, Any]:
        """Optimized plan + streaming segments, without running: probes a
        small head sample, applies the optimizer rules, partitions into
        pipelineable/barrier segments. Includes the typed IR node list
        (``"nodes"``) and the per-rule rewrite diffs (``"rewrites"``)."""
        return self._executor().explain(dataset=self._source_dataset())

    # ------------------------------------------------------------------
    def __repr__(self):
        src = self._plan.source["kind"] if self._plan.source else "none"
        chain = " -> ".join(n.name for n in self._plan.nodes) or "<empty>"
        return (f"Pipeline(source={src}, steps=[{chain}], "
                f"options={self._plan.options})")


# Ray-Data-style alias: a Pipeline IS a lazy dataset handle.
LazyDataset = Pipeline

read_jsonl = Pipeline.read_jsonl
from_samples = Pipeline.from_samples
from_dataset = Pipeline.from_dataset
from_recipe = Pipeline.from_recipe
