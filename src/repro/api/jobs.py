"""Async job subsystem behind the REST layer (paper §4, Appendix C.2).

``JobManager`` runs Pipelines on a bounded pool of daemon worker threads
with a bounded in-memory job store: ``submit()`` returns immediately (a
TB-scale run must not block a synchronous HTTP handler), status polling
reads the live per-op monitor rows the streaming executor mutates in
place, and ``cancel()`` flips an event the executor polls once per block.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
import uuid
from collections import OrderedDict
from typing import Any, Dict, List, Optional

from repro.core.dataset import ExecutionCancelled


class JobState:
    QUEUED = "queued"
    RUNNING = "running"
    SUCCEEDED = "succeeded"
    FAILED = "failed"
    CANCELLED = "cancelled"

    TERMINAL = (SUCCEEDED, FAILED, CANCELLED)


class JobStoreFull(RuntimeError):
    """The bounded job store has no evictable (finished) slot left."""


def _json_num(v: float) -> float:
    # monitor rows use inf for not-yet-run speeds; orjson rejects inf
    return v if v == v and abs(v) != float("inf") else 0.0


@dataclasses.dataclass
class Job:
    id: str
    pipeline: Any  # repro.api.pipeline.Pipeline
    state: str = JobState.QUEUED
    monitor: List[dict] = dataclasses.field(default_factory=list)
    error: Optional[str] = None
    report: Any = None  # core.executor.RunReport on success
    created_at: float = dataclasses.field(default_factory=time.time)
    started_at: Optional[float] = None
    finished_at: Optional[float] = None
    cancel_event: threading.Event = dataclasses.field(default_factory=threading.Event)

    def cancel(self) -> None:
        self.cancel_event.set()

    def done(self) -> bool:
        return self.state in JobState.TERMINAL

    def status(self, verbose: bool = True) -> Dict[str, Any]:
        """JSON-safe snapshot. The monitor rows are mutated concurrently by
        the worker thread; dict copies under the GIL give a consistent-enough
        view for progress display."""
        out: Dict[str, Any] = {
            "job_id": self.id,
            "state": self.state,
            "created_at": self.created_at,
            "started_at": self.started_at,
            "finished_at": self.finished_at,
            "error": self.error,
        }
        if verbose:
            rows = [dict(r) for r in list(self.monitor)]
            for r in rows:
                r["speed"] = _json_num(r.get("speed", 0.0))
            out["progress"] = {
                "per_op": rows,
                "ops_started": sum(1 for r in rows if r["in"] > 0),
                "ops_total": len(rows),
            }
            if self.report is not None:
                rep = self.report
                out["report"] = {
                    "recipe": rep.recipe, "n_in": rep.n_in, "n_out": rep.n_out,
                    "seconds": rep.seconds, "plan": rep.plan,
                    "errors": rep.errors, "streaming": rep.streaming,
                }
        return out


class JobManager:
    """Bounded thread-pool runner + bounded in-memory job store.

    Workers are daemon threads fed from a queue, so an interpreter exit never
    blocks on a stuck job; ``max_jobs`` bounds the store — submitting past it
    evicts the oldest *finished* jobs, and fails with JobStoreFull when all
    retained jobs are still live.
    """

    def __init__(self, max_workers: int = 2, max_jobs: int = 64):
        self.max_workers = max(1, max_workers)
        self.max_jobs = max(1, max_jobs)
        self._jobs: "OrderedDict[str, Job]" = OrderedDict()
        self._queue: "queue.Queue[Optional[str]]" = queue.Queue()
        self._lock = threading.Lock()
        self._workers: List[threading.Thread] = []
        self._shutdown = False

    # ------------------------------------------------------------------
    def submit(self, pipeline, job_id: Optional[str] = None) -> Job:
        """Enqueue a pipeline; returns the (queued) Job immediately."""
        job = Job(id=job_id or uuid.uuid4().hex[:12], pipeline=pipeline)
        with self._lock:
            if self._shutdown:
                raise RuntimeError("JobManager is shut down")
            while len(self._jobs) >= self.max_jobs:
                victim = next((j for j in self._jobs.values() if j.done()), None)
                if victim is None:
                    raise JobStoreFull(
                        f"job store full ({self.max_jobs} live jobs)")
                del self._jobs[victim.id]
            self._jobs[job.id] = job
            self._ensure_workers()
        self._queue.put(job.id)
        return job

    def get(self, job_id: str) -> Job:
        with self._lock:
            return self._jobs[job_id]  # KeyError -> caller maps to 404

    def list(self) -> List[Dict[str, Any]]:
        with self._lock:
            jobs = list(self._jobs.values())
        return [j.status(verbose=False) for j in jobs]

    def cancel(self, job_id: str) -> Job:
        """Request cancellation. Queued jobs flip to cancelled immediately;
        running jobs stop at the next block boundary."""
        job = self.get(job_id)
        job.cancel()
        with self._lock:
            if job.state == JobState.QUEUED:
                job.state = JobState.CANCELLED
                job.finished_at = time.time()
        return job

    def shutdown(self, wait: bool = False) -> None:
        with self._lock:
            self._shutdown = True
            workers = list(self._workers)
        for _ in workers:
            self._queue.put(None)
        if wait:
            for t in workers:
                t.join(timeout=5)

    # ------------------------------------------------------------------
    def _ensure_workers(self) -> None:
        # grow the pool by one per submit, up to max_workers (called under
        # self._lock); idle daemon workers blocked on the queue are cheap
        if len(self._workers) < self.max_workers:
            t = threading.Thread(target=self._worker_loop, daemon=True,
                                 name=f"dj-job-worker-{len(self._workers)}")
            self._workers.append(t)
            t.start()

    def _worker_loop(self) -> None:
        while True:
            job_id = self._queue.get()
            if job_id is None:
                return
            # claim atomically: cancel() takes the same lock for its
            # QUEUED -> CANCELLED transition, so a job cancelled while
            # queued can never also start running
            with self._lock:
                job = self._jobs.get(job_id)
                if job is None or job.done():
                    continue
                if job.cancel_event.is_set():
                    job.state = JobState.CANCELLED
                    job.finished_at = time.time()
                    continue
                job.state = JobState.RUNNING
                job.started_at = time.time()
            try:
                _, report = job.pipeline.execute(
                    monitor=job.monitor, cancel=job.cancel_event.is_set)
                job.report = report
                job.state = JobState.SUCCEEDED
            except ExecutionCancelled:
                job.state = JobState.CANCELLED
            except Exception as e:  # noqa: BLE001 — job isolation boundary
                job.error = f"{type(e).__name__}: {e}"
                job.state = JobState.FAILED
            finally:
                job.finished_at = time.time()
