"""Async job subsystem behind the REST layer (paper §4, Appendix C.2).

``JobManager`` runs Pipelines on a bounded pool of daemon worker threads
with a bounded in-memory job store: ``submit()`` returns immediately (a
TB-scale run must not block a synchronous HTTP handler), status polling
reads the live per-op monitor rows the streaming executor mutates in
place, and ``cancel()`` flips an event the executor polls once per block.

With a ``job_dir``, every state transition snapshots the store to
``<job_dir>/jobs.jsonl`` (one JSON record per job, atomic replace), and a
restarted manager restores prior jobs from it: finished jobs report their
final state, progress rows and report unchanged; jobs that were queued or
running when the process died surface as ``failed`` with an
"interrupted by restart" error (their threads are gone — honesty over
optimism). Restored jobs are status-only (``restored: true``). Restore
respects ``max_jobs``: a snapshot larger than the bound keeps only the
newest records, evicting oldest-first like the live store.

With a ``cluster_dir``, the manager becomes a **thin client of the
distributed queue** (``repro.api.cluster``): ``submit`` durably enqueues
the lowered recipe, status/list/cancel read and write the shared store, and
execution is done by whatever runners lease from the queue — including the
manager's own in-process runner (one ``ClusterRunner`` of ``max_workers``
capacity), so single-node deployments keep working with zero extra
processes while multi-node ones just point more ``dj runner`` processes at
the same dir. The REST contract is unchanged either way.
"""
from __future__ import annotations

import dataclasses
import os
import queue
import threading
import time
import uuid
from collections import OrderedDict
from typing import Any, Dict, List, Optional

from repro.core import clock, obs
from repro.core.dataset import ExecutionCancelled
from repro.core.dispatch import aggregate_dispatch
from repro.core.storage import json_dumps, json_loads


class JobState:
    QUEUED = "queued"
    RUNNING = "running"
    SUCCEEDED = "succeeded"
    FAILED = "failed"
    CANCELLED = "cancelled"

    TERMINAL = (SUCCEEDED, FAILED, CANCELLED)


class JobStoreFull(RuntimeError):
    """The bounded job store has no evictable (finished) slot left."""


def _json_num(v: float) -> float:
    # monitor rows use inf for not-yet-run speeds; orjson rejects inf
    return v if v == v and abs(v) != float("inf") else 0.0


@dataclasses.dataclass
class Job:
    id: str
    pipeline: Any  # repro.api.pipeline.Pipeline
    state: str = JobState.QUEUED
    monitor: List[dict] = dataclasses.field(default_factory=list)
    error: Optional[str] = None
    report: Any = None  # core.executor.RunReport on success
    created_at: float = dataclasses.field(default_factory=time.time)
    started_at: Optional[float] = None
    finished_at: Optional[float] = None
    cancel_event: threading.Event = dataclasses.field(default_factory=threading.Event)
    restored: bool = False  # loaded from a snapshot — status-only, no pipeline

    def cancel(self) -> None:
        self.cancel_event.set()

    def done(self) -> bool:
        return self.state in JobState.TERMINAL

    def status(self, verbose: bool = True) -> Dict[str, Any]:
        """JSON-safe snapshot. The monitor rows are mutated concurrently by
        the worker thread; dict copies under the GIL give a consistent-enough
        view for progress display."""
        out: Dict[str, Any] = {
            "job_id": self.id,
            "state": self.state,
            "created_at": self.created_at,
            "started_at": self.started_at,
            "finished_at": self.finished_at,
            "error": self.error,
        }
        if self.restored:
            out["restored"] = True
        if verbose:
            rows = [dict(r) for r in list(self.monitor)]
            for r in rows:
                r["speed"] = _json_num(r.get("speed", 0.0))
            rep = self.report
            disp = (rep.get("dispatch") if isinstance(rep, dict)
                    else getattr(rep, "dispatch", None)) if rep is not None else None
            out["progress"] = {
                "per_op": rows,
                "ops_started": sum(1 for r in rows if r["in"] > 0),
                "ops_total": len(rows),
                # same shape as cluster-mode status(): final report counters
                # when done, live per-op redispatches while running
                "dispatch": aggregate_dispatch(
                    disp or [{"redispatches": sum(
                        int(r.get("redispatches", 0) or 0) for r in rows)}]),
            }
            if self.report is not None:
                rep = self.report
                if isinstance(rep, dict):
                    out["report"] = rep
                else:
                    tr = getattr(rep, "trace", None) or {}
                    out["report"] = {
                        "recipe": rep.recipe, "n_in": rep.n_in, "n_out": rep.n_out,
                        "seconds": rep.seconds, "plan": rep.plan,
                        "errors": rep.errors, "streaming": rep.streaming,
                        # per-segment adaptive-dispatch summaries (redispatches,
                        # quarantined workers, window) — docs/runtime.md
                        "dispatch": list(getattr(rep, "dispatch", ()) or ()),
                        # trace ids only — the spans themselves live in the
                        # RunReport / obs spill, not the status payload
                        "trace": {"trace_id": tr.get("trace_id"),
                                  "root_span": tr.get("root_span"),
                                  "n_spans": len(tr.get("spans") or ())}
                                 if tr else None,
                    }
        return out


class ClusterJobHandle:
    """Job-shaped view over a cluster-queue job: quacks like :class:`Job`
    (``id``/``state``/``status()``/``done()``/``cancel()``) so the REST
    handlers serve single-node and cluster jobs through one code path, but
    every read goes to the shared store — the handle holds no job state."""

    def __init__(self, cluster, job_id: str):
        self._cluster = cluster
        self.id = job_id

    @property
    def state(self) -> str:
        return self._cluster.state_of(self.id)

    def done(self) -> bool:
        return self.state in JobState.TERMINAL

    def cancel(self) -> None:
        self._cluster.cancel(self.id)

    def status(self, verbose: bool = True) -> Dict[str, Any]:
        return self._cluster.status(self.id, verbose=verbose)


class JobManager:
    """Bounded thread-pool runner + bounded in-memory job store — or, with a
    ``cluster_dir``, a thin client of the distributed cluster queue.

    Workers are daemon threads fed from a queue, so an interpreter exit never
    blocks on a stuck job; ``max_jobs`` bounds the store — submitting past it
    evicts the oldest *finished* jobs, and fails with JobStoreFull when all
    retained jobs are still live.
    """

    def __init__(self, max_workers: int = 2, max_jobs: int = 64,
                 job_dir: Optional[str] = None,
                 cluster_dir: Optional[str] = None,
                 start_runner: bool = True):
        self.max_workers = max(1, max_workers)
        self.max_jobs = max(1, max_jobs)
        self.job_dir = job_dir
        self.cluster = None
        self._runner = None
        self._runner_stop = threading.Event()
        self._runner_thread: Optional[threading.Thread] = None
        self._jobs: "OrderedDict[str, Job]" = OrderedDict()
        self._queue: "queue.Queue[Optional[str]]" = queue.Queue()
        self._lock = threading.Lock()
        self._persist_lock = threading.Lock()  # serializes snapshot writes
        self._workers: List[threading.Thread] = []
        self._shutdown = False
        if cluster_dir:
            from repro.api.cluster import ClusterQueue, ClusterRunner

            self.cluster = ClusterQueue(cluster_dir)
            if start_runner:
                # single-node mode IS cluster mode with one in-process
                # runner: same queue, same leases, same failover semantics
                self._runner = ClusterRunner(
                    self.cluster, capacity=self.max_workers,
                    runner_id=f"inproc-{os.getpid():x}")
                self._runner_thread = threading.Thread(
                    target=self._runner.run_forever,
                    args=(self._runner_stop.is_set,),
                    daemon=True, name="dj-inproc-runner")
                self._runner_thread.start()
            return
        if job_dir:
            os.makedirs(job_dir, exist_ok=True)
            self._restore()

    # ------------------------------------------------------------------
    # JSONL snapshot persistence
    # ------------------------------------------------------------------
    def _snapshot_path(self) -> Optional[str]:
        return os.path.join(self.job_dir, "jobs.jsonl") if self.job_dir else None

    def _persist(self) -> None:
        """Atomically rewrite the snapshot (one status record per job).
        Cheap at the store's bounded size; called on every transition."""
        path = self._snapshot_path()
        if path is None:
            return
        tmp = path + ".tmp"
        with self._persist_lock:
            # serialize INSIDE the write lock: a snapshot built before the
            # lock could capture pre-transition state yet win the write
            # race, persisting a stale (e.g. still-running) record over the
            # newer one
            with self._lock:
                jobs = list(self._jobs.values())
            lines = [json_dumps(j.status(verbose=True)) for j in jobs]
            with open(tmp, "wb") as f:
                for ln in lines:
                    f.write(ln + b"\n")
            os.replace(tmp, path)

    def _restore(self) -> None:
        """Load prior jobs from the snapshot. Jobs that were live when the
        previous process died cannot be resumed (their threads are gone) —
        they restore as FAILED with an explicit interruption error."""
        path = self._snapshot_path()
        if path is None or not os.path.exists(path):
            return
        with open(path, "rb") as f:
            for raw in f:
                raw = raw.strip()
                if not raw:
                    continue
                try:
                    rec = json_loads(raw)
                except ValueError:
                    continue  # torn line from a mid-write crash
                job = Job(id=rec.get("job_id") or uuid.uuid4().hex[:12],
                          pipeline=None, restored=True)
                job.state = rec.get("state", JobState.FAILED)
                job.error = rec.get("error")
                job.created_at = rec.get("created_at") or job.created_at
                job.started_at = rec.get("started_at")
                job.finished_at = rec.get("finished_at")
                job.monitor = list(rec.get("progress", {}).get("per_op") or [])
                job.report = rec.get("report")
                if job.state not in JobState.TERMINAL:
                    job.state = JobState.FAILED
                    job.error = "interrupted by server restart"
                    job.finished_at = job.finished_at or clock.now()
                self._jobs[job.id] = job
        # the restored store must honour the bound a smaller max_jobs imposes
        # (a restarted server may be configured tighter than the one that
        # wrote the snapshot): evict oldest-first, like the live store — all
        # restored jobs are terminal by construction, so eviction never fails
        while len(self._jobs) > self.max_jobs:
            self._jobs.popitem(last=False)

    # ------------------------------------------------------------------
    def submit(self, pipeline, job_id: Optional[str] = None,
               tenant: Optional[str] = None):
        """Enqueue a pipeline; returns the (queued) Job immediately. In
        cluster mode the pipeline is lowered to its recipe and durably
        enqueued in the shared store (so it needs a file-backed source),
        owned by ``tenant`` (or the recipe's own tenant, or the default
        tenant)."""
        if self.cluster is not None:
            if self._shutdown:
                raise RuntimeError("JobManager is shut down")
            recipe = pipeline.to_recipe().to_dict()
            if not recipe.get("dataset_path"):
                raise ValueError(
                    "cluster jobs need a file-backed source (dataset_path): "
                    "in-memory samples cannot be leased by remote runners")
            from repro.api.cluster import AdmissionDenied

            # same bound, same 503: max_jobs caps the LIVE backlog (terminal
            # results don't count). The bound is enforced INSIDE submit via
            # O_EXCL admission slots — the old live_count()-then-submit
            # check let two managers race past it together
            try:
                jid = self.cluster.submit(recipe, job_id=job_id,
                                          tenant=tenant,
                                          max_live=self.max_jobs)
            except AdmissionDenied as e:
                raise JobStoreFull(str(e)) from e
            return ClusterJobHandle(self.cluster, jid)
        job = Job(id=job_id or uuid.uuid4().hex[:12], pipeline=pipeline)
        with self._lock:
            if self._shutdown:
                raise RuntimeError("JobManager is shut down")
            while len(self._jobs) >= self.max_jobs:
                victim = next((j for j in self._jobs.values() if j.done()), None)
                if victim is None:
                    raise JobStoreFull(
                        f"job store full ({self.max_jobs} live jobs)")
                del self._jobs[victim.id]
            self._jobs[job.id] = job
            self._ensure_workers()
        self._queue.put(job.id)
        self._persist()
        return job

    def get(self, job_id: str):
        if self.cluster is not None:
            self.cluster.read_spec(job_id)  # KeyError -> caller maps to 404
            return ClusterJobHandle(self.cluster, job_id)
        with self._lock:
            return self._jobs[job_id]  # KeyError -> caller maps to 404

    def list(self) -> List[Dict[str, Any]]:
        if self.cluster is not None:
            return self.cluster.jobs()
        with self._lock:
            jobs = list(self._jobs.values())
        return [j.status(verbose=False) for j in jobs]

    def cancel(self, job_id: str):
        """Request cancellation. Queued jobs flip to cancelled immediately;
        running jobs stop at the next block boundary."""
        job = self.get(job_id)
        job.cancel()
        if self.cluster is not None:
            return job
        with self._lock:
            if job.state == JobState.QUEUED:
                job.state = JobState.CANCELLED
                job.finished_at = clock.now()
        self._persist()
        return job

    def cluster_status(self) -> Dict[str, Any]:
        """GET /cluster payload: runner cards + scores, live/expired leases,
        queue depth. ``enabled: False`` outside cluster mode."""
        if self.cluster is None:
            return {"enabled": False}
        return self.cluster.overview()

    def cluster_slo(self, tenant: Optional[str] = None) -> Dict[str, Any]:
        """GET /cluster/slo payload: queue-wait percentiles, per-runner
        throughput, failover/preemption counts from the cluster event log;
        with ``tenant`` (the ``?tenant=`` query) just that tenant's
        breakdown. ``enabled: False`` outside cluster mode."""
        if self.cluster is None:
            return {"enabled": False}
        from repro.api.slo import cluster_slo

        return cluster_slo(self.cluster.dir, tenant=tenant)

    def tenants(self) -> Dict[str, Any]:
        """GET /tenants payload: per-tenant weight/quota/live-jobs/service
        rollup. ``enabled: False`` outside cluster mode."""
        if self.cluster is None:
            return {"enabled": False}
        return {"enabled": True, "tenants": self.cluster.tenant_overview()}

    def metrics_snapshot(self) -> Dict[str, Any]:
        """GET /metrics payload: this process's live registry, plus the
        merged cross-process spills when running against a cluster dir."""
        out: Dict[str, Any] = {"process": obs.metrics().snapshot()}
        if self.cluster is not None:
            out["cluster"] = obs.merged_metrics(self.cluster.obs_dir())
        return out

    def shutdown(self, wait: bool = False) -> None:
        with self._lock:
            self._shutdown = True
            workers = list(self._workers)
        if self.cluster is not None:
            self._runner_stop.set()
            if wait and self._runner is not None:
                self._runner.drain(timeout=10.0)
            if wait and self._runner_thread is not None:
                self._runner_thread.join(timeout=5)
            return
        for _ in workers:
            self._queue.put(None)
        if wait:
            for t in workers:
                t.join(timeout=5)

    # ------------------------------------------------------------------
    def _ensure_workers(self) -> None:
        # grow the pool by one per submit, up to max_workers (called under
        # self._lock); idle daemon workers blocked on the queue are cheap
        if len(self._workers) < self.max_workers:
            t = threading.Thread(target=self._worker_loop, daemon=True,
                                 name=f"dj-job-worker-{len(self._workers)}")
            self._workers.append(t)
            t.start()

    def _worker_loop(self) -> None:
        while True:
            job_id = self._queue.get()
            if job_id is None:
                return
            # claim atomically: cancel() takes the same lock for its
            # QUEUED -> CANCELLED transition, so a job cancelled while
            # queued can never also start running
            with self._lock:
                job = self._jobs.get(job_id)
                if job is None or job.done():
                    continue
                if job.cancel_event.is_set():
                    job.state = JobState.CANCELLED
                    job.finished_at = clock.now()
                    continue
                job.state = JobState.RUNNING
                job.started_at = clock.now()
            self._persist()
            try:
                _, report = job.pipeline.execute(
                    monitor=job.monitor, cancel=job.cancel_event.is_set)
                job.report = report
                job.state = JobState.SUCCEEDED
            except ExecutionCancelled:
                job.state = JobState.CANCELLED
            except Exception as e:  # noqa: BLE001 — job isolation boundary
                job.error = f"{type(e).__name__}: {e}"
                job.state = JobState.FAILED
            finally:
                job.finished_at = clock.now()
                self._persist()
