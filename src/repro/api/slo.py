"""Cluster SLO views computed from the fsync'd event log (ISSUE 8; the
on-ramp to ROADMAP item 3's multi-tenant SLOs).

``log.jsonl`` is the single source of truth the queue already fsyncs on
every transition, so the SLO math needs no extra bookkeeping and works on
any cluster dir, live or post-mortem:

* **queue-wait** — first ``claimed.ts`` minus ``submitted.ts`` per job
  (p50/p95/mean/max). The latency a submitter actually experiences before
  any runner starts working.
* **per-runner throughput** — rows/s and jobs finished per runner, from
  the ``finished`` events' enriched ``n_out``/``seconds`` fields.
* **failover / preemption counts** — ``requeued_after_expiry`` events
  (lease failovers) and the dispatcher's preemption/redispatch counters
  carried on ``finished`` events.

Shard tasks (``~``-suffixed ids) are folded into their parent's runner
stats but excluded from queue-wait percentiles — a shard task's "wait"
is DAG scheduling, not submitter-visible latency.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional

from repro.core import obs


def percentile(xs: List[float], q: float) -> float:
    """Nearest-rank percentile (q in [0, 1]); 0.0 on empty input."""
    if not xs:
        return 0.0
    s = sorted(xs)
    k = max(0, min(len(s) - 1, int(round(q * (len(s) - 1)))))
    return s[k]


def _is_shard_task(job_id: Optional[str]) -> bool:
    return bool(job_id) and "~" in job_id


def compute_slo(events: Iterable[Dict[str, Any]]) -> Dict[str, Any]:
    """Fold an event stream (``ClusterQueue.read_log()``) into the SLO
    summary. Pure function of the events — hermetic under a fake clock."""
    submitted: Dict[str, float] = {}
    first_claim: Dict[str, float] = {}
    failovers = 0
    preempted = 0
    redispatches = 0
    finished_jobs = 0
    failed_jobs = 0
    runners: Dict[str, Dict[str, float]] = {}
    for ev in events:
        kind = ev.get("event")
        jid = ev.get("job_id")
        ts = float(ev.get("ts") or 0.0)
        if kind == "submitted":
            submitted.setdefault(jid, ts)
        elif kind == "claimed":
            first_claim.setdefault(jid, ts)
        elif kind == "requeued_after_expiry":
            failovers += 1
        elif kind == "finished":
            if not _is_shard_task(jid):
                finished_jobs += 1
                if ev.get("state") == "failed":
                    failed_jobs += 1
            preempted += int(ev.get("preempted") or 0)
            redispatches += int(ev.get("redispatches") or 0)
            rid = ev.get("runner_id")
            if rid:
                r = runners.setdefault(rid, {"jobs": 0, "rows": 0.0,
                                             "busy_seconds": 0.0})
                r["jobs"] += 1
                r["rows"] += float(ev.get("n_out") or 0.0)
                r["busy_seconds"] += float(ev.get("seconds") or 0.0)
    waits = [first_claim[j] - submitted[j]
             for j in first_claim
             if j in submitted and not _is_shard_task(j)]
    per_runner = {
        rid: {
            "jobs": int(r["jobs"]),
            "rows": int(r["rows"]),
            "busy_seconds": round(r["busy_seconds"], 6),
            "rows_per_second": (r["rows"] / r["busy_seconds"]
                                if r["busy_seconds"] > 0 else 0.0),
        }
        for rid, r in sorted(runners.items())
    }
    return {
        "queue_wait": {
            "n": len(waits),
            "p50": percentile(waits, 0.50),
            "p95": percentile(waits, 0.95),
            "mean": (sum(waits) / len(waits)) if waits else 0.0,
            "max": max(waits) if waits else 0.0,
        },
        "throughput": per_runner,
        "failovers": failovers,
        "preempted": preempted,
        "redispatches": redispatches,
        "jobs_finished": finished_jobs,
        "jobs_failed": failed_jobs,
    }


def cluster_slo(cluster_dir: str) -> Dict[str, Any]:
    """GET /cluster/slo payload: event-log SLOs + the merged per-process
    metrics spills from the cluster obs dir."""
    from repro.api.cluster import ClusterQueue

    queue = ClusterQueue(cluster_dir)
    out = compute_slo(queue.read_log())
    out["enabled"] = True
    out["metrics"] = obs.merged_metrics(queue.obs_dir())
    return out
