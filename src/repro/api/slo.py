"""Cluster SLO views computed from the fsync'd event log (ISSUE 8; the
on-ramp to ROADMAP item 3's multi-tenant SLOs).

``log.jsonl`` is the single source of truth the queue already fsyncs on
every transition, so the SLO math needs no extra bookkeeping and works on
any cluster dir, live or post-mortem:

* **queue-wait** — first ``claimed.ts`` minus ``submitted.ts`` per job
  (p50/p95/mean/max). The latency a submitter actually experiences before
  any runner starts working.
* **per-runner throughput** — rows/s and jobs finished per runner, from
  the ``finished`` events' enriched ``n_out``/``seconds`` fields.
* **failover / preemption counts** — ``requeued_after_expiry`` events
  (lease failovers) and the dispatcher's preemption/redispatch counters
  carried on ``finished`` events.
* **per-tenant breakdowns** — every ``submitted`` event carries the
  owning tenant, so queue-wait and throughput fold per tenant too (the
  noisy-neighbor view: is the light tenant's p95 bounded while a heavy
  tenant floods the queue?). Shard-task rows/busy-seconds fold into the
  PARENT's tenant.

Shard tasks (the reserved ``~s<k>/~r<o>/~fin`` id grammar —
``cluster.is_shard_task``, shared with api.shards) are folded into their
parent's runner stats but excluded from queue-wait percentiles — a shard
task's "wait" is DAG scheduling, not submitter-visible latency. A user
job that merely contains ``~`` (e.g. ``nightly~v2``) is a plain job and
counts normally.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Iterable, List, Optional

from repro.core import obs
from repro.api.cluster import DEFAULT_TENANT, is_shard_task, parent_of


def percentile(xs: List[float], q: float) -> float:
    """True nearest-rank percentile (q in [0, 1]); 0.0 on empty input.

    Nearest-rank is ``ceil(q * n)``-th of the sorted values (1-based).
    The previous ``int(round(q * (n - 1)))`` variant inherited Python's
    banker's rounding, picking the wrong element on even-length inputs
    (p50 of [1,2,3,4] came out 3.0, not 2.0)."""
    if not xs:
        return 0.0
    s = sorted(xs)
    k = min(len(s) - 1, max(0, math.ceil(q * len(s)) - 1))
    return s[k]


def _wait_stats(waits: List[float]) -> Dict[str, Any]:
    return {
        "n": len(waits),
        "p50": percentile(waits, 0.50),
        "p95": percentile(waits, 0.95),
        "mean": (sum(waits) / len(waits)) if waits else 0.0,
        "max": max(waits) if waits else 0.0,
    }


def compute_slo(events: Iterable[Dict[str, Any]]) -> Dict[str, Any]:
    """Fold an event stream (``ClusterQueue.read_log()``) into the SLO
    summary. Pure function of the events — hermetic under a fake clock.

    Requeued/failed-over jobs count exactly one queue wait (submit to
    FIRST claim — later re-claims are failover latency, surfaced by the
    ``failovers`` counter, not submitter wait)."""
    submitted: Dict[str, float] = {}
    first_claim: Dict[str, float] = {}
    tenant_of: Dict[str, str] = {}
    failovers = 0
    preempted = 0
    redispatches = 0
    finished_jobs = 0
    failed_jobs = 0
    runners: Dict[str, Dict[str, float]] = {}
    tenants: Dict[str, Dict[str, float]] = {}

    def tstats(tenant: str) -> Dict[str, float]:
        return tenants.setdefault(tenant, {
            "jobs_finished": 0, "jobs_failed": 0,
            "rows": 0.0, "busy_seconds": 0.0})

    for ev in events:
        kind = ev.get("event")
        jid = ev.get("job_id")
        ts = float(ev.get("ts") or 0.0)
        if kind == "submitted":
            submitted.setdefault(jid, ts)
            tenant_of.setdefault(jid, ev.get("tenant") or DEFAULT_TENANT)
        elif kind == "claimed":
            first_claim.setdefault(jid, ts)
        elif kind == "requeued_after_expiry":
            failovers += 1
        elif kind == "finished":
            tenant = (tenant_of.get(jid) or tenant_of.get(parent_of(jid))
                      or DEFAULT_TENANT)
            t = tstats(tenant)
            if not is_shard_task(jid):
                finished_jobs += 1
                t["jobs_finished"] += 1
                if ev.get("state") == "failed":
                    failed_jobs += 1
                    t["jobs_failed"] += 1
            preempted += int(ev.get("preempted") or 0)
            redispatches += int(ev.get("redispatches") or 0)
            t["rows"] += float(ev.get("n_out") or 0.0)
            t["busy_seconds"] += float(ev.get("seconds") or 0.0)
            rid = ev.get("runner_id")
            if rid:
                r = runners.setdefault(rid, {"jobs": 0, "rows": 0.0,
                                             "busy_seconds": 0.0})
                r["jobs"] += 1
                r["rows"] += float(ev.get("n_out") or 0.0)
                r["busy_seconds"] += float(ev.get("seconds") or 0.0)
    waits: List[float] = []
    tenant_waits: Dict[str, List[float]] = {}
    for j, t0 in first_claim.items():
        if j not in submitted or is_shard_task(j):
            continue
        w = t0 - submitted[j]
        waits.append(w)
        tenant_waits.setdefault(
            tenant_of.get(j) or DEFAULT_TENANT, []).append(w)
    per_runner = {
        rid: {
            "jobs": int(r["jobs"]),
            "rows": int(r["rows"]),
            "busy_seconds": round(r["busy_seconds"], 6),
            "rows_per_second": (r["rows"] / r["busy_seconds"]
                                if r["busy_seconds"] > 0 else 0.0),
        }
        for rid, r in sorted(runners.items())
    }
    per_tenant: Dict[str, Dict[str, Any]] = {}
    for tenant in sorted(set(tenants) | set(tenant_waits)):
        t = tstats(tenant)
        per_tenant[tenant] = {
            "queue_wait": _wait_stats(tenant_waits.get(tenant, [])),
            "jobs_finished": int(t["jobs_finished"]),
            "jobs_failed": int(t["jobs_failed"]),
            "rows": int(t["rows"]),
            "busy_seconds": round(t["busy_seconds"], 6),
            "rows_per_second": (t["rows"] / t["busy_seconds"]
                                if t["busy_seconds"] > 0 else 0.0),
        }
    return {
        "queue_wait": _wait_stats(waits),
        "throughput": per_runner,
        "tenants": per_tenant,
        "failovers": failovers,
        "preempted": preempted,
        "redispatches": redispatches,
        "jobs_finished": finished_jobs,
        "jobs_failed": failed_jobs,
    }


def empty_tenant_slo() -> Dict[str, Any]:
    """The zeroed per-tenant breakdown ``cluster_slo(tenant=...)`` returns
    for a tenant with no logged activity yet (a 200, not a 404 — an idle
    tenant is a healthy tenant)."""
    return {
        "queue_wait": _wait_stats([]),
        "jobs_finished": 0, "jobs_failed": 0,
        "rows": 0, "busy_seconds": 0.0, "rows_per_second": 0.0,
    }


def cluster_slo(cluster_dir: str,
                tenant: Optional[str] = None) -> Dict[str, Any]:
    """GET /cluster/slo payload: event-log SLOs + the merged per-process
    metrics spills from the cluster obs dir. With ``tenant`` set
    (``?tenant=`` query), the cluster-wide summary is replaced by that
    tenant's breakdown (zeroed for a tenant with no activity)."""
    from repro.api.cluster import ClusterQueue

    queue = ClusterQueue(cluster_dir)
    out = compute_slo(queue.read_log())
    out["enabled"] = True
    if tenant is not None:
        breakdown = out["tenants"].get(tenant) or empty_tenant_slo()
        out = {"enabled": True, "tenant": tenant, **breakdown}
        return out
    out["metrics"] = obs.merged_metrics(queue.obs_dir())
    return out
