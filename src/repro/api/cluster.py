"""Distributed job queue + multi-node runner placement (paper §5.2: the
cloud-scale half of "adaptive execution").

The PR-3 JobManager was a single-process daemon pool: one server node ran
every job and a crashed run was merely *reported* as failed. This module is
the multi-node substrate beneath it — a durable, filesystem-coordinated job
queue from which N independent **runner processes** (threads, local
processes, or nodes sharing a filesystem) lease jobs with heartbeats and
TTLs, plus lease-expiry failover that resumes a dead runner's job from its
last segment-boundary checkpoint on a surviving runner.

Every coordination primitive is a plain POSIX file operation that behaves on
a shared filesystem (NFS-style): atomic claim via ``O_CREAT | O_EXCL``,
atomic publish via ``os.replace``, and an append-only fsync'd JSONL event
log. No sockets, no third-party broker — a runner is just a process pointed
at the same ``cluster_dir``.

Layout under ``cluster_dir/``::

  queue/<job_id>.json        job spec: recipe dict + submit metadata
  claims/<job_id>.a<N>.json  lease for attempt N: runner, deadline, renewals
  results/<job_id>.json      terminal record: state, report | error, attempt
  progress/<job_id>.json     live per-op monitor rows (heartbeat rewrites)
  cancel/<job_id>            cancellation marker (existence = cancelled)
  runners/<runner_id>.json   runner card: alive_at, capacity, active,
                             throughput EWMA, quarantine history
  health/<runner_id>.json    dispatch.HealthRegistry file (worker slots)
  checkpoints/<job_id>/      segment-boundary checkpoints (failover resume)
  log.jsonl                  append-only fsync'd event log

Lease protocol (attempt-numbered claims):

* a claim is ``claims/<job_id>.a<N>.json`` created with ``O_EXCL`` — exactly
  one runner wins attempt N;
* the **current** lease is the highest-numbered claim; a lease whose
  ``deadline`` (renewed by heartbeat to ``now + ttl``) has passed is
  *expired* and the job becomes claimable again at attempt N+1;
* a zombie runner (alive but past its deadline — GC pause, network hiccup)
  discovers the loss at its next heartbeat: ``renew`` fails once a newer
  attempt exists, the zombie aborts its run and discards its output, so the
  re-claimed attempt's export is the only one published;
* a re-claimed job resumes from the deepest segment-boundary checkpoint the
  dead attempt persisted (``checkpoints/<job_id>``) instead of restarting.

Placement is demand-side (Ray-lease-style): runners *pull*, but a runner
only claims when :class:`PlacementPolicy` ranks it best among live runner
cards — scored by observed throughput, resident (free) capacity, and
persisted WindowedDispatcher quarantine history — unless the job has waited
past the deference window (so a lone slow runner still makes progress).
"""
from __future__ import annotations

import dataclasses
import os
import re
import socket
import threading
import time
import uuid
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core import clock, obs
from repro.core.dispatch import aggregate_dispatch
from repro.core.storage import json_dumps, json_loads

# job states mirrored from repro.api.jobs.JobState (no import cycle)
QUEUED = "queued"
RUNNING = "running"
SUCCEEDED = "succeeded"
FAILED = "failed"
CANCELLED = "cancelled"
TERMINAL = (SUCCEEDED, FAILED, CANCELLED)

DEFAULT_LEASE_TTL = 15.0   # seconds a lease lives between heartbeats
DEFAULT_RUNNER_TTL = 30.0  # seconds before a runner card is considered dead
DEFAULT_DEFER = 2.0        # seconds a worse-placed runner defers to a better one

# ---------------------------------------------------------------------------
# multi-tenant identities
# ---------------------------------------------------------------------------

DEFAULT_TENANT = "default"
TENANTS_FILE = "tenants.json"      # <cluster_dir>/tenants.json (weights/quotas)
GLOBAL_SCOPE = "__all__"           # admission-slot scope for the backlog bound
                                   # (leading "_" is invalid as a tenant id, so
                                   # it can never collide with a real tenant)
FAIR_SHARE_ENV = "DJ_FAIR_SHARE"   # "0" falls back to pure FIFO claiming
SLOT_ORPHAN_GRACE = 10.0           # seconds a slot may exist without its spec
                                   # (a submit crashed between the two writes)
                                   # before a racing admission reclaims it

_TENANT_RE = re.compile(r"^[A-Za-z0-9][A-Za-z0-9._-]{0,63}$")

# shard-task id grammar (api.shards publishes `<job>~s<k>` map shards,
# `<job>~r<o>` reduce owners and `<job>~fin` finalize tasks). The predicate
# lives here — shards.py and slo.py import it — because shards.py already
# imports this module. ONLY the reserved suffixes count: a user job named
# "nightly~v2" is a plain job, not a shard task.
SHARD_SEP = "~"
_TASK_SUFFIX_RE = re.compile(r"^(?:s\d+|r\d+|fin)$")


def is_shard_task(job_id: Optional[str]) -> bool:
    """True only for the reserved ``~s<k>`` / ``~r<o>`` / ``~fin`` grammar."""
    if not job_id or SHARD_SEP not in job_id:
        return False
    return bool(_TASK_SUFFIX_RE.match(job_id.rsplit(SHARD_SEP, 1)[-1]))


def parent_of(task_id: str) -> str:
    """The parent job id of a shard task; identity for plain jobs (including
    user jobs whose names happen to contain ``~``)."""
    if not is_shard_task(task_id):
        return task_id
    return task_id.rsplit(SHARD_SEP, 1)[0]


def validate_tenant(tenant: str) -> str:
    """Tenant ids become directory names and log fields — restrict to a safe
    charset (letters/digits first, then ``._-``), max 64 chars."""
    if not isinstance(tenant, str) or not _TENANT_RE.match(tenant):
        raise ValueError(
            f"invalid tenant id {tenant!r}: must match "
            "[A-Za-z0-9][A-Za-z0-9._-]{0,63}")
    return tenant


class AdmissionDenied(RuntimeError):
    """A per-tenant quota or the cluster backlog bound rejected the
    submission (the REST layer maps this to the 503 contract)."""

    def __init__(self, msg: str, tenant: str = DEFAULT_TENANT,
                 scope: str = "cluster"):
        super().__init__(msg)
        self.tenant = tenant
        self.scope = scope  # "tenant" (quota) | "cluster" (backlog bound)


def _json_num(v: Any) -> Any:
    # monitor rows use inf for not-yet-run speeds; the serializer rejects inf
    if isinstance(v, float) and (v != v or abs(v) == float("inf")):
        return 0.0
    return v


def _sanitize_rows(rows: List[dict]) -> List[dict]:
    return [{k: _json_num(v) for k, v in dict(r).items()} for r in rows]


def _read_json(path: str) -> Optional[Dict[str, Any]]:
    """Best-effort JSON file read: None on missing/torn/mid-write files —
    readers race writers by design on a shared filesystem."""
    try:
        with open(path, "rb") as f:
            raw = f.read()
    except (FileNotFoundError, OSError):
        return None
    if not raw:
        return None
    try:
        data = json_loads(raw)
    except ValueError:
        return None
    return data if isinstance(data, dict) else None


def _write_json_atomic(path: str, payload: Dict[str, Any]) -> None:
    tmp = f"{path}.{os.getpid()}.{threading.get_ident()}.tmp"
    with open(tmp, "wb") as f:
        f.write(json_dumps(payload))
    os.replace(tmp, path)


def _mem_headroom_frac() -> Optional[float]:
    """MemAvailable / MemTotal from /proc/meminfo — the runner card's
    memory-headroom signal. None where /proc is unavailable (macOS)."""
    try:
        with open("/proc/meminfo", "r", encoding="ascii") as f:
            fields = {}
            for line in f:
                k, _, v = line.partition(":")
                fields[k.strip()] = v
                if "MemTotal" in fields and "MemAvailable" in fields:
                    break
        total = float(fields["MemTotal"].split()[0])
        avail = float(fields["MemAvailable"].split()[0])
        return avail / total if total > 0 else None
    except (OSError, KeyError, ValueError, IndexError):
        return None


@dataclasses.dataclass
class Lease:
    """One runner's exclusive hold on one job attempt."""

    job_id: str
    runner_id: str
    attempt: int
    deadline: float
    ttl: float

    def expired(self, now: Optional[float] = None) -> bool:
        return (now if now is not None else clock.now()) > self.deadline


class PlacementPolicy:
    """Scores runner cards for demand-side placement.

    ``score`` favours runners with (a) higher observed throughput (EWMA of
    samples/sec over completed jobs), (b) more resident free capacity, and
    (c) fewer persisted worker quarantines (a runner whose WindowedDispatcher
    kept quarantining workers is a machine the scheduler should trust less —
    the ROADMAP's cross-run health item). A runner with no free slot scores
    0 and never claims.
    """

    def __init__(self, defer_seconds: float = DEFAULT_DEFER):
        self.defer_seconds = defer_seconds

    @staticmethod
    def score(card: Dict[str, Any]) -> float:
        capacity = max(1, int(card.get("capacity", 1)))
        free = capacity - int(card.get("active", 0))
        if free <= 0:
            return 0.0
        throughput = float(card.get("throughput", 0.0)) or 1.0
        quarantines = int(card.get("quarantines", 0))
        base = throughput * (free / capacity) / (1.0 + quarantines)
        # memory headroom (block-pipeline working sets are RAM-bound): a
        # runner near OOM scores down to 25% of its base; cards from older
        # runners without the field are unaffected
        mem_frac = card.get("mem_frac")
        if mem_frac is not None:
            base *= 0.25 + 0.75 * min(1.0, max(0.0, float(mem_frac)))
        return base

    def should_claim(self, runner_id: str, cards: List[Dict[str, Any]],
                     waited: float) -> bool:
        """Claim when this runner is the best-placed live candidate, or the
        job has already waited out the deference window (starvation guard:
        a lone or uniformly-bad pool still drains the queue)."""
        if waited >= self.defer_seconds:
            return True
        mine = next((c for c in cards if c.get("runner_id") == runner_id), None)
        if mine is None:
            return True  # no card yet — claiming beats stalling
        my_score = self.score(mine)
        if my_score <= 0.0:
            return False
        for c in cards:
            if c.get("runner_id") == runner_id:
                continue
            s = self.score(c)
            # deterministic tie-break so two equal runners don't both defer
            if s > my_score or (s == my_score
                                and str(c.get("runner_id")) < runner_id):
                return False
        return True


class ClusterQueue:
    """Durable shared-store job queue (see module docstring for protocol)."""

    SUBDIRS = ("queue", "claims", "results", "progress", "cancel",
               "runners", "health", "checkpoints", "obs")

    def __init__(self, cluster_dir: str, lease_ttl: float = DEFAULT_LEASE_TTL,
                 runner_ttl: float = DEFAULT_RUNNER_TTL,
                 fair_share: Optional[bool] = None):
        self.dir = os.path.abspath(cluster_dir)
        self.lease_ttl = lease_ttl
        self.runner_ttl = runner_ttl
        # fair_share=False claims in pure submit order (pre-tenant FIFO);
        # default on, env-overridable so subprocess runners can be switched
        # per-fleet (the bench's FIFO baseline)
        if fair_share is None:
            fair_share = os.environ.get(FAIR_SHARE_ENV, "1") != "0"
        self.fair_share = fair_share
        # scheduler state derived from log.jsonl (never persisted — failover
        # re-derives it by folding the log): per-tenant claim counts plus the
        # byte offset already folded, guarded for the in-process runner +
        # submitter threads sharing one queue object
        self._sched_lock = threading.Lock()
        self._log_offset = 0
        self._service: Dict[str, float] = {}   # tenant -> claims granted
        self._tenant_of: Dict[str, str] = {}   # job_id -> tenant (from log)
        self._spec_meta: Dict[str, Tuple[str, Tuple[str, ...], float]] = {}
        self._tenants_cfg: Tuple[Dict[str, Any], Any] = ({}, None)
        for sub in self.SUBDIRS:
            os.makedirs(os.path.join(self.dir, sub), exist_ok=True)

    # ------------------------------------------------------------------
    # paths
    # ------------------------------------------------------------------
    def _p(self, *parts: str) -> str:
        return os.path.join(self.dir, *parts)

    def spec_path(self, job_id: str) -> str:
        return self._p("queue", f"{job_id}.json")

    def claim_path(self, job_id: str, attempt: int) -> str:
        return self._p("claims", f"{job_id}.a{attempt}.json")

    def result_path(self, job_id: str) -> str:
        return self._p("results", f"{job_id}.json")

    def progress_path(self, job_id: str) -> str:
        return self._p("progress", f"{job_id}.json")

    def cancel_path(self, job_id: str) -> str:
        return self._p("cancel", job_id)

    def checkpoint_dir(self, job_id: str) -> str:
        return self._p("checkpoints", job_id)

    def health_path(self, runner_id: str) -> str:
        return self._p("health", f"{runner_id}.json")

    def obs_dir(self) -> str:
        """Per-process span/metrics spill files land here (core.obs);
        ``merge_trace(obs_dir, trace_id)`` is the driver-side merge."""
        return self._p("obs")

    def slot_dir(self, scope: str) -> str:
        """Admission-slot directory for one tenant (or ``GLOBAL_SCOPE``).
        Lives under ``queue/`` but ``job_ids`` never sees it — its scandir
        keeps only ``*.json`` entries."""
        return self._p("queue", "tenants", scope)

    # ------------------------------------------------------------------
    # tenant config (tenants.json: weights, quotas, API keys)
    # ------------------------------------------------------------------
    def tenants_config(self) -> Dict[str, Any]:
        """Parsed ``<cluster_dir>/tenants.json``, cached by (mtime, size)::

            {"tenants": {"alice": {"weight": 4, "max_live_jobs": 8,
                                   "api_keys": ["sk-alice-1"]}},
             "default_weight": 1, "default_max_live_jobs": null}

        Absent file -> every tenant gets weight 1 and no quota — the
        single-tenant deployment needs no config at all."""
        path = self._p(TENANTS_FILE)
        try:
            st = os.stat(path)
            key = (st.st_mtime_ns, st.st_size)
        except OSError:
            self._tenants_cfg = ({}, None)
            return {}
        cfg, cached_key = self._tenants_cfg
        if cached_key == key:
            return cfg
        cfg = _read_json(path) or {}
        self._tenants_cfg = (cfg, key)
        return cfg

    def tenant_entry(self, tenant: str) -> Dict[str, Any]:
        entry = (self.tenants_config().get("tenants") or {}).get(tenant)
        return entry if isinstance(entry, dict) else {}

    def tenant_weight(self, tenant: str) -> float:
        """Fair-share weight (claims granted proportional to it). Clamped
        positive so a zero/negative config never divides by zero — it just
        makes the tenant lowest-priority."""
        w = self.tenant_entry(tenant).get(
            "weight", self.tenants_config().get("default_weight", 1.0))
        try:
            return max(float(w), 1e-9)
        except (TypeError, ValueError):
            return 1.0

    def tenant_quota(self, tenant: str) -> Optional[int]:
        """Max live (queued+running) jobs for the tenant; None = unlimited."""
        q = self.tenant_entry(tenant).get(
            "max_live_jobs", self.tenants_config().get("default_max_live_jobs"))
        if q is None:
            return None
        try:
            return max(0, int(q))
        except (TypeError, ValueError):
            return None

    def tenant_for_key(self, api_key: str) -> Optional[str]:
        """Tenant owning ``api_key`` per tenants.json, or None when unknown
        (the REST layer maps None to 403)."""
        if not api_key:
            return None
        for tenant, entry in (self.tenants_config().get("tenants")
                              or {}).items():
            if isinstance(entry, dict) and api_key in (
                    entry.get("api_keys") or ()):
                return tenant
        return None

    # ------------------------------------------------------------------
    # atomic admission (per-tenant quotas + the backlog bound)
    # ------------------------------------------------------------------
    def _slot_stale(self, rec: Optional[Dict[str, Any]]) -> bool:
        """A slot is reclaimable when its holder reached a terminal state, or
        its spec never appeared (a submit crashed between slot-acquire and
        spec publish) past the grace window. An unreadable slot is LIVE — a
        torn read means the writing submitter is mid-create right now."""
        if rec is None:
            return False
        holder = rec.get("job_id")
        if not holder:
            return False
        if os.path.exists(self.spec_path(holder)):
            return self.state_of(holder) in TERMINAL
        return clock.now() - float(rec.get("ts") or 0.0) > SLOT_ORPHAN_GRACE

    def _acquire_slot(self, scope: str, limit: int,
                      job_id: str) -> Optional[str]:
        """Claim one of ``limit`` O_EXCL slot files under the scope's slot
        dir. O_EXCL is the admission atom: two submitters racing past the
        bound collide on the same slot file and exactly one wins — unlike
        the old count-then-submit check, which both could pass. Slots held
        by terminal jobs are reclaimed lazily (unlink, then O_EXCL re-race:
        only one reclaimer can win the recreate). Returns the held slot
        path, or None when every slot belongs to a live job."""
        d = self.slot_dir(scope)
        os.makedirs(d, exist_ok=True)
        payload = json_dumps({"job_id": job_id, "ts": clock.now()})
        for k in range(limit):
            path = os.path.join(d, f"slot{k}.json")
            fd = None
            try:
                fd = os.open(path, os.O_WRONLY | os.O_CREAT | os.O_EXCL, 0o644)
            except FileExistsError:
                if not self._slot_stale(_read_json(path)):
                    continue
                try:
                    os.unlink(path)
                except FileNotFoundError:
                    pass
                try:
                    fd = os.open(path,
                                 os.O_WRONLY | os.O_CREAT | os.O_EXCL, 0o644)
                except FileExistsError:
                    continue  # another reclaimer won the re-race
            try:
                os.write(fd, payload)
                os.fsync(fd)
            finally:
                os.close(fd)
            return path
        return None

    def _admit(self, job_id: str, tenant: str,
               max_live: Optional[int]) -> None:
        """The atomic admission check ``submit`` runs for every non-shard
        job: a tenant-quota slot (when tenants.json sets one), then a
        cluster-backlog slot (when the caller bounds the live backlog).
        Raises :class:`AdmissionDenied` — slots already acquired for a
        denied submission are released immediately."""
        held: List[str] = []
        quota = self.tenant_quota(tenant)
        if quota is not None:
            slot = (self._acquire_slot(tenant, quota, job_id)
                    if quota > 0 else None)
            if slot is None:
                raise AdmissionDenied(
                    f"tenant {tenant!r} live-job quota reached ({quota})",
                    tenant=tenant, scope="tenant")
            held.append(slot)
        if max_live is not None:
            slot = (self._acquire_slot(GLOBAL_SCOPE, max_live, job_id)
                    if max_live > 0 else None)
            if slot is None:
                for p in held:
                    try:
                        os.unlink(p)
                    except OSError:
                        pass
                raise AdmissionDenied(
                    f"cluster backlog full ({max_live} live jobs)",
                    tenant=tenant, scope="cluster")

    # ------------------------------------------------------------------
    # event log
    # ------------------------------------------------------------------
    def log_event(self, event: str, **fields: Any) -> None:
        """Append one event to the fsync'd JSONL log. O_APPEND keeps
        concurrent single-line appends from interleaving; fsync makes the
        record durable before the caller proceeds (a claim that is not on
        disk is a claim a failover reader never saw)."""
        rec = json_dumps({"ts": clock.now(), "event": event, **fields})
        fd = os.open(self._p("log.jsonl"),
                     os.O_WRONLY | os.O_APPEND | os.O_CREAT, 0o644)
        try:
            os.write(fd, rec + b"\n")
            os.fsync(fd)
        finally:
            os.close(fd)

    def read_log(self) -> List[Dict[str, Any]]:
        out: List[Dict[str, Any]] = []
        try:
            with open(self._p("log.jsonl"), "rb") as f:
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        out.append(json_loads(line))
                    except ValueError:
                        continue  # torn tail from a crashed writer
        except FileNotFoundError:
            pass
        return out

    # ------------------------------------------------------------------
    # submission / inspection
    # ------------------------------------------------------------------
    def submit(self, recipe: Dict[str, Any],
               job_id: Optional[str] = None,
               extra: Optional[Dict[str, Any]] = None,
               tenant: Optional[str] = None,
               max_live: Optional[int] = None) -> str:
        """Enqueue a job spec (a Recipe dict). Returns the job id. The spec
        is the unit of durability: any runner that can read the shared dir
        can execute it. ``extra`` merges additional spec fields — how
        api.shards attaches shard descriptors and ``after`` dependency
        lists to the shard tasks it publishes.

        Every submission is owned by a ``tenant`` (explicit arg > extra >
        the recipe's own ``tenant`` field > :data:`DEFAULT_TENANT`) and runs
        the atomic admission check: per-tenant live-job quota from
        tenants.json, plus the cluster backlog bound when the caller passes
        ``max_live``. Raises :class:`AdmissionDenied` over-quota. Shard
        tasks bypass admission — their parent already holds the slots."""
        job_id = job_id or uuid.uuid4().hex[:12]
        if os.path.exists(self.spec_path(job_id)):
            raise ValueError(f"job id {job_id!r} already exists")
        extra = dict(extra or {})
        if tenant is None:
            tenant = extra.get("tenant") or (recipe or {}).get("tenant") \
                or DEFAULT_TENANT
        tenant = validate_tenant(tenant)
        shard = "shard" in extra or is_shard_task(job_id)
        if not shard:
            self._admit(job_id, tenant, max_live)
        spec = {
            "job_id": job_id,
            "recipe": dict(recipe),
            "submitted_at": clock.now(),
            **extra,
            "tenant": tenant,
        }
        if "trace" not in spec:
            # trace minted at submit: every runner/shard span of this job's
            # lifetime roots at root_span (core.obs). Shard tasks pass their
            # own trace via extra so the parent's trace_id is preserved.
            spec["trace"] = {"trace_id": obs.new_id(), "root_span": obs.new_id()}
        _write_json_atomic(self.spec_path(job_id), spec)
        self.log_event("submitted", job_id=job_id, tenant=tenant)
        return job_id

    def job_ids(self, include_shards: bool = False) -> List[str]:
        """All job ids, oldest-first. Sorted by spec-file mtime (one scandir,
        no JSON decodes — this runs on every runner poll) with the id as the
        tie-break; the atomic-replace publish makes mtime ≈ submit time.
        Shard tasks (``<job>~s0`` etc., api.shards) are internal and hidden
        unless ``include_shards`` — job listings/counts stay parent-level."""
        try:
            entries = list(os.scandir(self._p("queue")))
        except FileNotFoundError:
            return []
        keyed = []
        for e in entries:
            if not e.name.endswith(".json"):
                continue
            if not include_shards and is_shard_task(e.name[:-5]):
                continue
            try:
                mtime = e.stat().st_mtime
            except OSError:
                continue  # submitted/removed under our feet
            keyed.append((mtime, e.name[:-5]))
        return [jid for _, jid in sorted(keyed)]

    def _result_ids(self) -> set:
        try:
            return {n[:-5] for n in os.listdir(self._p("results"))
                    if n.endswith(".json")}
        except FileNotFoundError:
            return set()

    def _cancel_ids(self) -> set:
        try:
            return set(os.listdir(self._p("cancel")))
        except FileNotFoundError:
            return set()

    def _claims_by_job(self) -> Dict[str, Lease]:
        """Current (highest-attempt) lease per job from ONE claims listdir —
        the per-job ``current_lease`` scan is O(claims) each, which made the
        runner poll O(jobs x claims)."""
        best_name: Dict[str, Tuple[int, str]] = {}
        try:
            names = os.listdir(self._p("claims"))
        except FileNotFoundError:
            return {}
        for n in names:
            if not n.endswith(".json"):
                continue
            jid, _, attempt_s = n[:-5].rpartition(".a")
            try:
                attempt = int(attempt_s)
            except ValueError:
                continue
            if not jid:
                continue
            if jid not in best_name or attempt > best_name[jid][0]:
                best_name[jid] = (attempt, n)
        out: Dict[str, Lease] = {}
        for jid, (attempt, name) in best_name.items():
            rec = _read_json(self._p("claims", name))
            if rec is None:
                continue
            out[jid] = Lease(job_id=jid, runner_id=rec.get("runner_id", "?"),
                             attempt=int(rec.get("attempt", attempt)),
                             deadline=float(rec.get("deadline", 0.0)),
                             ttl=float(rec.get("ttl", self.lease_ttl)))
        return out

    def read_spec(self, job_id: str) -> Dict[str, Any]:
        spec = _read_json(self.spec_path(job_id))
        if spec is None:
            raise KeyError(job_id)
        return spec

    def current_lease(self, job_id: str) -> Optional[Lease]:
        """Highest-attempt claim on the job, expired or not."""
        best: Optional[Dict[str, Any]] = None
        try:
            names = os.listdir(self._p("claims"))
        except FileNotFoundError:
            return None
        prefix = f"{job_id}.a"
        for n in names:
            if not (n.startswith(prefix) and n.endswith(".json")):
                continue
            rec = _read_json(self._p("claims", n))
            if rec and (best is None or rec.get("attempt", 0) > best.get("attempt", 0)):
                best = rec
        if best is None:
            return None
        return Lease(job_id=job_id, runner_id=best.get("runner_id", "?"),
                     attempt=int(best.get("attempt", 1)),
                     deadline=float(best.get("deadline", 0.0)),
                     ttl=float(best.get("ttl", self.lease_ttl)))

    def is_cancelled(self, job_id: str) -> bool:
        return os.path.exists(self.cancel_path(job_id))

    def state_of(self, job_id: str) -> str:
        result = _read_json(self.result_path(job_id))
        if result is not None:
            return result.get("state", FAILED)
        if self.is_cancelled(job_id):
            return CANCELLED
        lease = self.current_lease(job_id)
        if lease is not None and not lease.expired():
            return RUNNING
        return QUEUED

    def status(self, job_id: str, verbose: bool = True) -> Dict[str, Any]:
        """REST-shaped merged view of one job (same keys as Job.status so
        the /jobs contract is identical in single-node and cluster mode)."""
        spec = self.read_spec(job_id)  # KeyError -> caller maps to 404
        result = _read_json(self.result_path(job_id)) or {}
        lease = self.current_lease(job_id)
        out: Dict[str, Any] = {
            "job_id": job_id,
            "state": self.state_of(job_id),
            "created_at": spec.get("submitted_at"),
            "started_at": result.get("started_at"),
            "finished_at": result.get("finished_at"),
            "error": result.get("error"),
            "cluster": True,
            "tenant": spec.get("tenant", DEFAULT_TENANT),
        }
        if lease is not None:
            out["runner_id"] = lease.runner_id
            out["attempt"] = lease.attempt
            if out["started_at"] is None and out["state"] == RUNNING:
                out["started_at"] = lease.deadline - lease.ttl
        if verbose:
            rows = list((result.get("progress") or {}).get("per_op") or [])
            if not rows:
                prog = _read_json(self.progress_path(job_id)) or {}
                rows = list(prog.get("per_op") or [])
            out["progress"] = {
                "per_op": rows,
                "ops_started": sum(1 for r in rows if r.get("in", 0) > 0),
                "ops_total": len(rows),
                # dispatcher counters (parity with single-node Job.status):
                # from the final report when terminal, else the live per-op
                # redispatch column is all that has crossed the heartbeat
                "dispatch": aggregate_dispatch(
                    (result.get("report") or {}).get("dispatch")
                    or [{"redispatches": sum(
                        int(r.get("redispatches", 0) or 0) for r in rows)}]),
            }
            if result.get("report") is not None:
                out["report"] = result["report"]
            srows = self.shard_rows(job_id)
            if srows:
                out["shards"] = srows
        return out

    # ------------------------------------------------------------------
    # shard-task observability (api.shards)
    # ------------------------------------------------------------------
    def shard_tasks(self, parent_id: str) -> List[str]:
        """Shard-task ids for one parent, maps -> reduces -> finalize."""
        from repro.api.shards import task_sort_key

        ids = [jid for jid in self.job_ids(include_shards=True)
               if is_shard_task(jid) and parent_of(jid) == parent_id]
        return sorted(ids, key=task_sort_key)

    def shard_rows(self, parent_id: str,
                   claims: Optional[Dict[str, Lease]] = None
                   ) -> List[Dict[str, Any]]:
        """Per-shard progress + lease-attempt rows for GET /cluster and the
        cluster-status CLI — the shard-level view job-level state hides."""
        tasks = self.shard_tasks(parent_id)
        if not tasks:
            return []
        if claims is None:
            claims = self._claims_by_job()
        rows: List[Dict[str, Any]] = []
        for tid in tasks:
            spec = _read_json(self.spec_path(tid)) or {}
            sh = spec.get("shard") or {}
            row: Dict[str, Any] = {
                "task_id": tid, "kind": sh.get("kind"),
                "index": sh.get("index"), "state": self.state_of(tid),
            }
            result = _read_json(self.result_path(tid))
            lease = claims.get(tid)
            if result is not None:
                row["attempt"] = result.get("attempt")
                row["runner_id"] = result.get("runner_id")
                rep = result.get("report") or {}
                row["resumed_at"] = rep.get("resumed_at", 0)
                if rep.get("n_out") is not None:
                    row["n_out"] = rep.get("n_out")
            elif lease is not None:
                row["attempt"] = lease.attempt
                row["runner_id"] = lease.runner_id
                row["lease_expired"] = lease.expired()
                prog = _read_json(self.progress_path(tid)) or {}
                per_op = prog.get("per_op") or []
                row["ops_started"] = sum(
                    1 for r in per_op if r.get("in", 0) > 0)
            rows.append(row)
        return rows

    def jobs(self) -> List[Dict[str, Any]]:
        return [self.status(jid, verbose=False) for jid in self.job_ids()]

    def depth(self) -> int:
        """Jobs with no terminal result and no live lease — the claimable
        backlog (the /cluster "queue depth")."""
        n = 0
        for jid in self.job_ids():
            if self.state_of(jid) == QUEUED:
                n += 1
        return n

    def live_count(self) -> int:
        """Queued + running jobs — the bound JobManager.max_jobs applies to
        in cluster mode (terminal results are durable and don't count)."""
        results = self._result_ids()
        cancelled = self._cancel_ids()
        return sum(1 for jid in self.job_ids()
                   if jid not in results and jid not in cancelled)

    def cancel(self, job_id: str) -> None:
        self.read_spec(job_id)  # KeyError for unknown ids
        fd = os.open(self.cancel_path(job_id),
                     os.O_WRONLY | os.O_CREAT, 0o644)
        os.close(fd)
        self.log_event("cancel_requested", job_id=job_id)

    # ------------------------------------------------------------------
    # runner cards
    # ------------------------------------------------------------------
    def write_card(self, card: Dict[str, Any]) -> None:
        _write_json_atomic(
            self._p("runners", f"{card['runner_id']}.json"),
            {**card, "alive_at": clock.now()})

    def runner_cards(self, live_only: bool = True) -> List[Dict[str, Any]]:
        cards: List[Dict[str, Any]] = []
        try:
            names = os.listdir(self._p("runners"))
        except FileNotFoundError:
            return cards
        now = clock.now()
        for n in names:
            if not n.endswith(".json"):
                continue
            card = _read_json(self._p("runners", n))
            if card is None:
                continue
            card["alive"] = (now - card.get("alive_at", 0.0)) <= self.runner_ttl
            if card["alive"] or not live_only:
                cards.append(card)
        return sorted(cards, key=lambda c: str(c.get("runner_id")))

    # ------------------------------------------------------------------
    # leasing
    # ------------------------------------------------------------------
    def try_claim(self, job_id: str, runner_id: str,
                  ttl: Optional[float] = None) -> Optional[Lease]:
        """Attempt-numbered exclusive claim. Returns the Lease, or None when
        another runner holds (or just won) the job."""
        if os.path.exists(self.result_path(job_id)) or self.is_cancelled(job_id):
            return None
        prev = self.current_lease(job_id)
        if prev is not None and not prev.expired():
            return None
        attempt = 1 if prev is None else prev.attempt + 1
        ttl = ttl or self.lease_ttl
        lease = Lease(job_id=job_id, runner_id=runner_id, attempt=attempt,
                      deadline=clock.now() + ttl, ttl=ttl)
        path = self.claim_path(job_id, attempt)
        try:
            # O_EXCL: the one coordination primitive a shared POSIX
            # filesystem gives us that is atomic across nodes
            fd = os.open(path, os.O_WRONLY | os.O_CREAT | os.O_EXCL, 0o644)
        except FileExistsError:
            return None  # lost the race for this attempt
        try:
            os.write(fd, json_dumps(dataclasses.asdict(lease)))
            os.fsync(fd)
        finally:
            os.close(fd)
        self.log_event("claimed", job_id=job_id, runner_id=runner_id,
                       attempt=attempt)
        if prev is not None:
            self.log_event("requeued_after_expiry", job_id=job_id,
                           dead_runner=prev.runner_id, attempt=attempt)
        return lease

    def _spec_info(self, jid: str) -> Optional[Tuple[str, Tuple[str, ...],
                                                     float]]:
        """(tenant, after-deps, submitted_at) for one spec. Write-once
        cached — specs are immutable after the atomic publish — so the
        runner poll decodes each spec JSON once ever, not once per poll.
        None for a torn/mid-write spec (skip it this poll, don't cache)."""
        info = self._spec_meta.get(jid)
        if info is None:
            spec = _read_json(self.spec_path(jid))
            if not spec:
                return None
            info = (spec.get("tenant") or DEFAULT_TENANT,
                    tuple(spec.get("after") or ()),
                    float(spec.get("submitted_at") or 0.0))
            self._spec_meta[jid] = info
        return info

    def _refresh_service(self) -> None:
        """Incrementally fold ``log.jsonl`` into the per-tenant claim counts
        the deficit round-robin orders by. Deriving service from the fsync'd
        log — not an in-memory counter — means a restarted or brand-new
        runner re-derives exactly the service history every other runner
        sees: failover needs no extra bookkeeping files. Caller holds
        ``_sched_lock``. Only complete lines are folded; a torn tail waits
        for the writer's newline."""
        try:
            with open(self._p("log.jsonl"), "rb") as f:
                f.seek(self._log_offset)
                chunk = f.read()
        except (FileNotFoundError, OSError):
            return
        end = chunk.rfind(b"\n")
        if end < 0:
            return
        for line in chunk[:end].split(b"\n"):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json_loads(line)
            except ValueError:
                continue
            jid = rec.get("job_id")
            if not jid:
                continue
            ev = rec.get("event")
            if ev == "submitted":
                self._tenant_of[jid] = rec.get("tenant") or DEFAULT_TENANT
            elif ev == "claimed":
                t = (self._tenant_of.get(jid)
                     or self._tenant_of.get(parent_of(jid))
                     or DEFAULT_TENANT)
                self._service[t] = self._service.get(t, 0.0) + 1.0
        self._log_offset += end + 1

    def next_job(self, runner_id: str,
                 policy: Optional[PlacementPolicy] = None,
                 ttl: Optional[float] = None) -> Optional[Lease]:
        """Claim the next claimable job this runner is well-placed for.
        This is the hot path (every runner, every poll): terminal/leased
        jobs are filtered through three one-listdir indexes and spec
        metadata comes from a write-once cache.

        Candidate order is weighted deficit round-robin across tenants:
        the tenant with the least service-per-weight (claims granted /
        tenants.json weight, folded from the event log) goes first, FIFO
        within a tenant. A heavy tenant's 50-deep backlog therefore cannot
        starve a light tenant's next job — each claim the heavy tenant
        wins raises its deficit rank until the light tenant is due. With
        ``fair_share`` off (or one tenant), order degenerates to the
        pre-tenant pure-FIFO mtime scan."""
        policy = policy or PlacementPolicy()
        cards = self.runner_cards()
        now = clock.now()
        results = self._result_ids()
        cancelled = self._cancel_ids()
        claims = self._claims_by_job()
        candidates: List[Tuple[str, str]] = []  # (job_id, tenant) mtime-order
        for jid in self.job_ids(include_shards=True):
            if jid in results or jid in cancelled:
                continue
            held = claims.get(jid)
            if held is not None and not held.expired(now):
                continue
            info = self._spec_info(jid)
            if info is None:
                continue
            tenant, deps, submitted_at = info
            # shard-task dependency gate (api.shards): claimable only once
            # every upstream task has a SUCCEEDED result
            if deps and any(
                    (_read_json(self.result_path(d)) or {}).get("state")
                    != SUCCEEDED for d in deps):
                continue
            waited = now - (submitted_at or now)
            if not policy.should_claim(runner_id, cards, waited):
                continue
            candidates.append((jid, tenant))
        if not candidates:
            return None
        if self.fair_share and len({t for _, t in candidates}) > 1:
            with self._sched_lock:
                self._refresh_service()
                service = dict(self._service)
            # stable sort: tenants ordered by deficit rank, mtime order
            # preserved within each tenant
            candidates.sort(key=lambda c: (
                service.get(c[1], 0.0) / self.tenant_weight(c[1]), c[1]))
        for jid, _tenant in candidates:
            lease = self.try_claim(jid, runner_id, ttl=ttl)
            if lease is not None:
                return lease
        return None

    def renew(self, lease: Lease, ttl: Optional[float] = None) -> bool:
        """Heartbeat: push the deadline out. Returns False when the lease
        was lost — a newer attempt exists (we expired and someone re-claimed)
        or the job finished/was cancelled elsewhere. A False return obliges
        the runner to abort and discard its output."""
        cur = self.current_lease(lease.job_id)
        if cur is None or cur.attempt != lease.attempt \
                or cur.runner_id != lease.runner_id:
            return False
        if os.path.exists(self.result_path(lease.job_id)):
            return False
        lease.ttl = ttl or lease.ttl
        lease.deadline = clock.now() + lease.ttl
        _write_json_atomic(self.claim_path(lease.job_id, lease.attempt),
                           dataclasses.asdict(lease))
        return True

    def expired_leases(self) -> List[Lease]:
        """Current leases past their deadline on unfinished jobs — the
        failover backlog surfaced by /cluster (claiming them is implicit in
        ``next_job``; this is observability, not a state change)."""
        out: List[Lease] = []
        for jid in self.job_ids(include_shards=True):
            if os.path.exists(self.result_path(jid)):
                continue
            lease = self.current_lease(jid)
            if lease is not None and lease.expired():
                out.append(lease)
        return out

    # ------------------------------------------------------------------
    # completion
    # ------------------------------------------------------------------
    def complete(self, lease: Lease, state: str,
                 report: Optional[Dict[str, Any]] = None,
                 error: Optional[str] = None,
                 started_at: Optional[float] = None,
                 progress: Optional[List[dict]] = None) -> bool:
        """Publish the terminal record. Attempt-monotonic: a stale attempt
        (a zombie that never noticed its lease loss) can never overwrite a
        newer attempt's result. Returns whether the record was published."""
        existing = _read_json(self.result_path(lease.job_id))
        if existing is not None and int(existing.get("attempt", 0)) > lease.attempt:
            self.log_event("stale_result_discarded", job_id=lease.job_id,
                           runner_id=lease.runner_id, attempt=lease.attempt,
                           kept_attempt=existing.get("attempt"))
            return False
        payload: Dict[str, Any] = {
            "job_id": lease.job_id, "state": state,
            "runner_id": lease.runner_id, "attempt": lease.attempt,
            "started_at": started_at, "finished_at": clock.now(),
            "error": error, "report": report,
        }
        if progress is not None:
            payload["progress"] = {"per_op": _sanitize_rows(progress)}
        _write_json_atomic(self.result_path(lease.job_id), payload)
        # enrich the finished event with throughput + dispatch counters so
        # the SLO view (api.slo) computes per-runner rows/s and preemption
        # counts straight from log.jsonl, no result-file scans
        rep = report or {}
        disp = aggregate_dispatch(rep.get("dispatch") or ())
        self.log_event("finished", job_id=lease.job_id, state=state,
                       runner_id=lease.runner_id, attempt=lease.attempt,
                       n_out=rep.get("n_out"), seconds=rep.get("seconds"),
                       redispatches=disp["redispatches"],
                       preempted=disp["preempted"])
        self._emit_root_span(lease, state, rep)
        return True

    def _emit_root_span(self, lease: Lease, state: str,
                        report: Dict[str, Any]) -> None:
        """Write the job's root span to the cluster obs spill. Only the
        ACCEPTED complete() emits it (stale attempts return before reaching
        here), so failover yields exactly one root per job — every lease /
        run / shard span parents into it by id."""
        if not obs.enabled():
            return
        try:
            spec = self.read_spec(lease.job_id)
        except KeyError:
            return
        tr = spec.get("trace") or {}
        if not tr.get("trace_id") or not tr.get("root_span"):
            return
        t0 = spec.get("submitted_at") or clock.now()
        root = {
            "trace_id": tr["trace_id"], "span_id": tr["root_span"],
            "parent_id": tr.get("parent_span"), "name": f"job:{lease.job_id}",
            "kind": "job", "t0": t0, "dur": max(0.0, clock.now() - t0),
            "pid": os.getpid(), "tid": 0,
            "attrs": {"state": state, "runner_id": lease.runner_id,
                      "attempt": lease.attempt,
                      "n_out": report.get("n_out")},
        }
        obs.configure(self.obs_dir())
        obs.record_span_dict(root)
        obs.flush()

    # ------------------------------------------------------------------
    # overview (GET /cluster, cli cluster-status)
    # ------------------------------------------------------------------
    def overview(self) -> Dict[str, Any]:
        states: Dict[str, int] = {}
        leases: List[Dict[str, Any]] = []
        now = clock.now()
        for jid in self.job_ids():
            st = self.state_of(jid)
            states[st] = states.get(st, 0) + 1
            lease = self.current_lease(jid)
            if lease is not None and st in (RUNNING, QUEUED):
                leases.append({**dataclasses.asdict(lease),
                               "expired": lease.expired(now)})
        cards = self.runner_cards(live_only=False)
        for c in cards:
            c["score"] = PlacementPolicy.score(c)
        # per-shard progress for sharded jobs (api.shards): group the shard
        # tasks under their parents, one claims listdir for all of them
        parents = sorted({parent_of(jid)
                          for jid in self.job_ids(include_shards=True)
                          if is_shard_task(jid)})
        sharded: Dict[str, List[Dict[str, Any]]] = {}
        if parents:
            claims = self._claims_by_job()
            for pid in parents:
                sharded[pid] = self.shard_rows(pid, claims=claims)
        out = {
            "enabled": True,
            "cluster_dir": self.dir,
            "queue_depth": states.get(QUEUED, 0),
            "jobs": states,
            "runners": cards,
            "leases": leases,
        }
        if sharded:
            out["sharded"] = sharded
        return out

    def tenant_overview(self) -> List[Dict[str, Any]]:
        """Per-tenant rollup for ``GET /tenants`` and ``cluster-status
        --tenants``: configured weight/quota merged with live queue state
        and the granted-claims service counter the fair-share scheduler
        ranks by. Covers config'd tenants plus every tenant seen in queue
        specs or the log (at minimum the default tenant)."""
        with self._sched_lock:
            self._refresh_service()
            service = dict(self._service)
        states: Dict[str, Dict[str, int]] = {}
        live: Dict[str, int] = {}
        for jid in self.job_ids():
            info = self._spec_info(jid)
            if info is None:
                continue
            t = info[0]
            st = self.state_of(jid)
            per = states.setdefault(t, {})
            per[st] = per.get(st, 0) + 1
            if st not in TERMINAL:
                live[t] = live.get(t, 0) + 1
        names = (set(self.tenants_config().get("tenants") or ())
                 | set(states) | set(service)) or {DEFAULT_TENANT}
        rows: List[Dict[str, Any]] = []
        for t in sorted(names):
            rows.append({
                "tenant": t,
                "weight": self.tenant_weight(t),
                "max_live_jobs": self.tenant_quota(t),
                "live_jobs": live.get(t, 0),
                "jobs": states.get(t, {}),
                "claims_granted": service.get(t, 0.0),
                "api_keys": len(self.tenant_entry(t).get("api_keys") or ()),
            })
        return rows


class ClusterRunner:
    """One job-leasing worker process/thread.

    The runner loop: publish a runner card (heartbeat), reap-and-claim the
    oldest well-placed job, execute it with segment-boundary checkpoints
    under the cluster dir, renew the lease from a heartbeat thread while the
    run streams, and publish the terminal record. ``capacity`` > 1 executes
    that many leased jobs concurrently in threads (resident capacity — the
    placement score's denominator).
    """

    def __init__(self, cluster_dir: str, runner_id: Optional[str] = None,
                 capacity: int = 1, lease_ttl: Optional[float] = None,
                 poll: float = 0.2, policy: Optional[PlacementPolicy] = None,
                 use_cluster_health: bool = True):
        self.queue = ClusterQueue(cluster_dir) if isinstance(cluster_dir, str) \
            else cluster_dir
        self.runner_id = runner_id or f"{socket.gethostname()}-{os.getpid():x}-{uuid.uuid4().hex[:4]}"
        self.capacity = max(1, capacity)
        self.lease_ttl = lease_ttl or self.queue.lease_ttl
        self.poll = poll
        self.policy = policy or PlacementPolicy()
        self.use_cluster_health = use_cluster_health
        self.jobs_done = 0
        self.throughput = 0.0  # samples/sec EWMA over completed jobs
        self._active: Dict[str, threading.Thread] = {}
        self._lock = threading.Lock()

    # ------------------------------------------------------------------
    def _card(self) -> Dict[str, Any]:
        from repro.core.dispatch import HealthRegistry

        quarantines = 0
        if self.use_cluster_health:
            quarantines = HealthRegistry(
                self.queue.health_path(self.runner_id)).total_quarantines()
        with self._lock:
            active = len(self._active)
        card = {
            "runner_id": self.runner_id,
            "pid": os.getpid(),
            "host": socket.gethostname(),
            "capacity": self.capacity,
            "active": active,
            "throughput": round(self.throughput, 3),
            "jobs_done": self.jobs_done,
            "quarantines": quarantines,
        }
        mem = _mem_headroom_frac()
        if mem is not None:
            card["mem_frac"] = round(mem, 4)
        return card

    def publish_card(self) -> None:
        self.queue.write_card(self._card())

    # ------------------------------------------------------------------
    def _build_executor(self, job_id: str, spec: Dict[str, Any],
                        trace: Optional[Dict[str, Any]] = None):
        from repro.core.executor import Executor
        from repro.core.recipes import Recipe

        recipe = Recipe.from_dict(spec.get("recipe") or {})
        if trace is not None:
            # run span parents under this lease's span — failover attempts
            # re-parent under their own lease span, same trace id
            recipe.trace = trace
        # failover resume: checkpoints live in the SHARED dir, keyed by job,
        # so a surviving runner resumes the dead runner's segments
        recipe.checkpoint_dir = recipe.checkpoint_dir or self.queue.checkpoint_dir(job_id)
        if self.use_cluster_health and not recipe.health_path:
            # worker-slot quarantine history persists per runner and feeds
            # the placement score via the runner card
            recipe.health_path = self.queue.health_path(self.runner_id)
        if recipe.fixed_plan is None and (recipe.use_fusion or recipe.use_reordering):
            # pin the optimized plan at first claim: reordering is derived
            # from a sampled probe of the stream, so a failover attempt
            # could otherwise re-derive a DIFFERENT op order than the one
            # the checkpoints it resumes were produced under
            recipe.fixed_plan = self._pin_plan(job_id, recipe)
        return Executor(recipe)

    def _pin_plan(self, job_id: str, recipe) -> List[Dict[str, Any]]:
        """First claimer resolves the optimized plan and publishes it under
        the job's checkpoint dir; every later attempt replays the persisted
        plan verbatim (deterministic failover)."""
        from repro.core.executor import Executor

        ckpt = self.queue.checkpoint_dir(job_id)
        os.makedirs(ckpt, exist_ok=True)
        path = os.path.join(ckpt, "plan.json")
        rec = _read_json(path)
        if rec is not None and isinstance(rec.get("plan"), list):
            return rec["plan"]
        ex = Executor(recipe)
        plan = ex.resolve_plan()
        # persist the per-rule rewrite diffs with the pinned plan so the
        # shards:plan span (and post-mortems) can show how the plan was
        # derived, even on a failover lead that never re-optimizes
        _write_json_atomic(path, {"job_id": job_id, "plan": plan,
                                  "rewrites": ex.last_rewrites,
                                  "pinned_at": clock.now()})
        self.queue.log_event("plan_pinned", job_id=job_id,
                             runner_id=self.runner_id, n_ops=len(plan))
        return plan

    def _execute(self, lease: Lease) -> None:
        from repro.core.dataset import ExecutionCancelled

        queue = self.queue
        job_id = lease.job_id
        started_at = clock.now()
        monitor: List[dict] = []
        cancel_event = threading.Event()
        lease_lost = threading.Event()
        hb_stop = threading.Event()

        def heartbeat() -> None:
            # renew at ttl/3 so two missed beats still precede expiry;
            # publish live progress + honour cancel markers on the way.
            # Transient I/O errors (the NFS hiccups this design targets) and
            # monitor-row races must cost at most one beat — a dead
            # heartbeat thread means spurious expiry + double execution
            while not hb_stop.wait(max(0.05, lease.ttl / 3.0)):
                try:
                    if queue.is_cancelled(job_id):
                        cancel_event.set()
                    if not queue.renew(lease):
                        lease_lost.set()
                        cancel_event.set()
                        return
                except Exception:  # noqa: BLE001 — missed beat, not death
                    continue
                try:
                    _write_json_atomic(queue.progress_path(job_id),
                                       {"per_op": _sanitize_rows(monitor),
                                        "runner_id": self.runner_id,
                                        "attempt": lease.attempt})
                    self.publish_card()
                except Exception:  # noqa: BLE001 — progress is best-effort
                    pass

        hb = threading.Thread(target=heartbeat, daemon=True,
                              name=f"dj-lease-hb-{job_id}")
        hb.start()
        state, report, error = FAILED, None, None
        lease_span = None
        try:
            spec = queue.read_spec(job_id)
            tr = spec.get("trace") or {}
            if tr.get("trace_id"):
                # lease span: one per (job, attempt). The spill dir is the
                # shared cluster obs dir, so a SIGKILL'd attempt's flushed
                # spans and the failover attempt's spans merge driver-side.
                obs.configure(queue.obs_dir())
                lease_span = obs.start_span(
                    tr["trace_id"], f"lease:{job_id}", kind="lease",
                    parent_id=tr.get("root_span"))
                if lease_span is not None:
                    lease_span.set(runner_id=self.runner_id,
                                   attempt=lease.attempt)
            run_trace = ({"trace_id": tr["trace_id"],
                          "span_id": lease_span.span_id}
                         if lease_span is not None else None)
            shard = spec.get("shard") or {}
            kind = shard.get("kind")
            if kind == "reduce":
                from repro.api import shards as shards_mod

                report = shards_mod.run_reduce_task(self, spec)
            elif kind == "finalize":
                from repro.api import shards as shards_mod

                report = shards_mod.run_finalize_task(
                    self, spec, monitor=monitor, cancel=cancel_event.is_set)
            else:
                from repro.api import shards as shards_mod

                if not kind and shards_mod.wants_sharding(
                        (spec.get("recipe") or {}).get("shards")):
                    # sharded parent job: this lease supervises the shard
                    # DAG (api.shards); None means sharding degenerated —
                    # fall through to the ordinary single-runner path
                    from repro.core.recipes import Recipe

                    report = shards_mod.run_sharded(
                        self, lease, spec,
                        Recipe.from_dict(spec.get("recipe") or {}),
                        monitor, cancel_event, lease_lost)
                if report is None:
                    executor = self._build_executor(job_id, spec,
                                                    trace=run_trace)
                    # run_streaming (not run): segment-boundary checkpoints
                    # are the failover-resume unit; materialize=False keeps
                    # the runner's memory bounded — output streams to the
                    # spec's export_path
                    _, rep = executor.run_streaming(
                        materialize=False, monitor=monitor,
                        cancel=cancel_event.is_set)
                    # the run's spans go to the shared spill; the report
                    # keeps only the ids (result payloads stay small)
                    run_tr = rep.trace or {}
                    for s in run_tr.get("spans") or ():
                        obs.record_span_dict(s)
                    report = {
                        "recipe": rep.recipe, "n_in": rep.n_in,
                        "n_out": rep.n_out,
                        "seconds": rep.seconds, "plan": rep.plan,
                        "errors": rep.errors, "streaming": rep.streaming,
                        "resumed_at": rep.resumed_at,
                        "dispatch": list(rep.dispatch or ()),
                        "trace": {"trace_id": run_tr.get("trace_id"),
                                  "root_span": run_tr.get("root_span"),
                                  "n_spans": len(run_tr.get("spans") or ())}
                                 if run_tr else None,
                    }
            state = SUCCEEDED
            secs = float(report.get("seconds") or 0.0)
            n_in = int(report.get("n_in") or 0)
            if secs > 0 and n_in:
                inst = n_in / secs
                self.throughput = inst if self.throughput == 0.0 \
                    else 0.7 * self.throughput + 0.3 * inst
        except ExecutionCancelled:
            state = CANCELLED
        except Exception as e:  # noqa: BLE001 — job isolation boundary
            state, error = FAILED, f"{type(e).__name__}: {e}"
        finally:
            hb_stop.set()
            hb.join(timeout=max(1.0, lease.ttl))
            # final ownership check: a stall can outlive the TTL without the
            # heartbeat ever observing the loss (it stops with the run) —
            # re-verify before publishing so a zombie can't clobber the
            # failover attempt's result. complete() is attempt-monotonic as
            # the last line of defence against the remaining race window.
            owned = not lease_lost.is_set()
            if owned:
                try:
                    owned = queue.renew(lease)
                except Exception:  # noqa: BLE001 — can't prove ownership
                    owned = False
            if not owned:
                # we are the zombie of a failed-over job: the re-claimed
                # attempt owns the result now — discard ours, only log
                queue.log_event("lease_lost_abort", job_id=job_id,
                                runner_id=self.runner_id,
                                attempt=lease.attempt)
            else:
                self.jobs_done += 1
                queue.complete(lease, state, report=report, error=error,
                               started_at=started_at, progress=monitor)
            if lease_span is not None:
                lease_span.set(state=state, owned=owned).end()
                try:
                    obs.flush()
                    obs.flush_metrics(queue.obs_dir())
                except OSError:
                    pass  # telemetry must never fail a job
            with self._lock:
                self._active.pop(job_id, None)
            self.publish_card()

    # ------------------------------------------------------------------
    def run_once(self) -> bool:
        """Claim and execute at most one job synchronously. Returns whether
        a job ran (test/bench hook — the daemon path is ``run_forever``)."""
        self.publish_card()
        lease = self.queue.next_job(self.runner_id, policy=self.policy,
                                    ttl=self.lease_ttl)
        if lease is None:
            return False
        with self._lock:
            self._active[lease.job_id] = threading.current_thread()
        self._execute(lease)
        return True

    def run_forever(self, stop: Optional[Callable[[], bool]] = None) -> None:
        """Lease-execute loop until ``stop()`` goes True. With capacity > 1
        jobs execute on daemon threads and the loop keeps claiming while
        slots are free."""
        last_card = 0.0
        while not (stop and stop()):
            now = clock.now()
            if now - last_card >= max(0.5, self.queue.runner_ttl / 3.0):
                self.publish_card()
                last_card = now
            with self._lock:
                free = self.capacity - len(self._active)
            lease = None
            if free > 0:
                lease = self.queue.next_job(self.runner_id, policy=self.policy,
                                            ttl=self.lease_ttl)
            if lease is None:
                time.sleep(self.poll)
                continue
            t = threading.Thread(target=self._execute, args=(lease,),
                                 daemon=True,
                                 name=f"dj-runner-{lease.job_id}")
            with self._lock:
                self._active[lease.job_id] = t
            t.start()
            self.publish_card()

    def drain(self, timeout: float = 30.0) -> None:
        """Wait for in-flight jobs (shutdown path for in-process runners)."""
        deadline = clock.now() + timeout
        while clock.now() < deadline:
            with self._lock:
                threads = list(self._active.values())
            threads = [t for t in threads
                       if t is not threading.current_thread() and t.is_alive()]
            if not threads:
                return
            threads[0].join(timeout=0.2)
