"""Dataset analysis through the shared insight path (paper §5.2).

``analyze()`` computes per-stat distributions with ``insight.snapshot`` —
the same snapshot machinery the InsightMiner uses during recipe runs — by
running Filter OPs in stats-only mode over *protected copies*, so the
caller's samples are never mutated and nothing is filtered out. ``auto``
discovers every applicable stat-producing Filter in the registry by probing
one sample (the previously-ignored ``dj analyze --auto``).
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List, Optional, Union

from repro.core.insight import snapshot
from repro.core.registry import create_op, list_ops, op_info
from repro.core.storage import read_jsonl

DEFAULT_ANALYZE_OPS = [
    "text_length_filter",
    "words_num_filter",
    "alnum_ratio_filter",
    "quality_score_filter",
]


def _stat_copy(sample: Dict[str, Any]) -> Dict[str, Any]:
    """Shallow copy with fresh stats/meta dicts — compute_stats writes into
    sample['stats'], so sharing those dicts would mutate the caller's data."""
    return {**sample,
            "stats": dict(sample.get("stats") or {}),
            "meta": dict(sample.get("meta") or {})}


# ops named <modality>_* read this sample key; absent key -> the op would
# only emit default/zero stats, polluting the report
_MODALITY_KEYS = {"image": "image_meta", "video": "video_meta",
                  "audio": "audio_meta"}


def discover_stat_ops(probe: Dict[str, Any],
                      include_model_ops: bool = False) -> List[str]:
    """Registry sweep: every Filter whose default-constructed ``compute_stats``
    succeeds on the probe sample and produces stats it did not already have.
    Modality-specific filters are skipped when the sample lacks that modality;
    model-backed filters are skipped by default (slow to set up for a quick
    analysis pass)."""
    found: List[str] = []
    before = set(probe.get("stats") or {})
    for name in list_ops():
        info = op_info(name)
        if info["type"] != "Filter":
            continue
        if info["uses_model"] and not include_model_ops:
            continue
        if any(p["required"] for p in info["params"]):
            continue
        gate = _MODALITY_KEYS.get(name.split("_", 1)[0])
        if gate and not probe.get(gate):
            continue
        try:
            op = create_op({"name": name})
            op.setup()
            s = op.compute_stats(_stat_copy(probe))
            if set(s.get("stats") or {}) - before:  # NEW stats only
                found.append(name)
        except Exception:  # noqa: BLE001 — inapplicable to this modality
            continue
    return found


def analyze(
    source: Union[str, Iterable[Dict[str, Any]]],
    ops: Optional[List[str]] = None,
    auto: bool = False,
    include_model_ops: bool = False,
    limit: Optional[int] = None,
) -> Dict[str, Any]:
    """Stats-only analysis: no filtering, no mutation of the input.

    ``source`` is a JSONL path, a DJDataset, or an iterable of samples.
    Returns ``{"n", "numeric": {stat: StatSummary}, "tags", "ops"}``.
    """
    from repro.core.dataset import DJDataset

    if isinstance(source, str):
        samples: List[Dict[str, Any]] = list(read_jsonl(source, limit=limit))
    elif isinstance(source, DJDataset):
        samples = source.samples()
    else:
        samples = list(source)
    if limit:
        samples = samples[:limit]

    work = [_stat_copy(s) for s in samples]
    op_names = list(ops or DEFAULT_ANALYZE_OPS)
    if auto and work:
        op_names = sorted(set(op_names) | set(
            discover_stat_ops(work[0], include_model_ops=include_model_ops)))

    applied: List[str] = []
    for name in op_names:
        try:
            op = create_op({"name": name})
            op.setup()
            work = op.compute_stats_batch(work)  # stats only — keeps every sample
            applied.append(name)
        except Exception:  # noqa: BLE001 — op inapplicable to this corpus
            continue

    snap = snapshot(work)
    return {"n": snap["n"], "numeric": snap["numeric"],
            "tags": snap["tags"], "ops": applied}
