"""SQL front-end: compile a small SELECT dialect onto the logical-plan IR.

``repro.sql("SELECT text FROM ds WHERE lang = 'en' AND words > 50")`` returns
a :class:`~repro.api.pipeline.Pipeline`, i.e. the query lowers through the
exact same ``LogicalPlan`` + rule optimizer as the fluent API, recipes and the
NL interface — SQL is *only* a parser; execution bytes are identical to the
hand-built chain.

Grammar subset (one statement, no joins/subqueries)::

    SELECT <* | col[, col...] | AGG(text[, k])>
    FROM   <name | 'path.jsonl'>
    [WHERE  pred [AND pred]...]          -- conjunctions only
    [GROUP BY col]                       -- with optional AGG in SELECT
    [ORDER BY stat_col [ASC|DESC]]       -- lowers to topk_stat_selector
    [LIMIT n]

Predicates compare a known *stat column* (``words``, ``text_len``, ...) to a
number with ``= < <= > >=``, or ``lang`` to a string with ``=`` / ``IN``.
Each stat column maps to the registry Filter that computes it; strict bounds
use ``math.nextafter`` so ``words > 50`` keeps exactly the rows the inclusive
filter with ``min_val=nextafter(50, inf)`` keeps.
"""
from __future__ import annotations

import inspect
import math
import re
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.api.pipeline import Pipeline
from repro.core.registry import did_you_mean

__all__ = ["sql", "SQLError", "parse_sql", "compile_query", "STAT_COLUMNS"]

# SQL column -> (filter op computing it, stat key it writes)
STAT_COLUMNS: Dict[str, Tuple[str, str]] = {
    "words": ("words_num_filter", "num_words"),
    "num_words": ("words_num_filter", "num_words"),
    "text_len": ("text_length_filter", "text_len"),
    "length": ("text_length_filter", "text_len"),
    "avg_word_len": ("avg_word_length_filter", "avg_word_len"),
    "alnum_ratio": ("alnum_ratio_filter", "alnum_ratio"),
    "special_char_ratio": ("special_char_ratio_filter", "special_char_ratio"),
    "stopword_ratio": ("stopword_ratio_filter", "stopword_ratio"),
    "word_rep_ratio": ("word_repetition_filter", "word_rep_ratio"),
    "char_rep_ratio": ("char_repetition_filter", "char_rep_ratio"),
    "num_tokens": ("token_count_filter", "num_tokens"),
    "tokens": ("token_count_filter", "num_tokens"),
    "max_line_len": ("maximum_line_length_filter", "max_line_len"),
    "quality_score": ("quality_score_filter", "quality_score"),
}
LANG_COLUMN = "lang"  # special: string-valued, language_heuristic_filter
_KNOWN_LANGS = ("en", "zh", "other", "unknown")

AGG_FUNCTIONS = {
    "concat": "concat_text_aggregator",
    "keywords": "keyword_summary_aggregator",
}

_KEYWORDS = frozenset(
    "select from where and group order by asc desc limit in".split())


class SQLError(ValueError):
    """Query rejected. ``kind`` tags the failure class (``"syntax"``,
    ``"unknown_column"``, ``"unsupported"``, ``"unknown_source"``) and
    ``suggestions`` carries registry did-you-mean candidates — the same
    contract the REST ``/jobs`` 404 uses for unknown OPs."""

    def __init__(self, message: str, kind: str = "syntax",
                 suggestions: Optional[List[str]] = None):
        super().__init__(message)
        self.kind = kind
        self.suggestions = list(suggestions or [])


# --------------------------------------------------------------------------
# tokenizer


@dataclass
class Token:
    kind: str  # "ident" | "number" | "string" | "punct" | "star"
    value: Any
    pos: int

    @property
    def word(self) -> str:
        return str(self.value).lower() if self.kind == "ident" else ""


_TOKEN_RE = re.compile(
    r"""\s*(?:
        (?P<string>'(?:[^'\\]|\\.)*'|"(?:[^"\\]|\\.)*")
      | (?P<number>-?\d+(?:\.\d+)?(?:[eE][-+]?\d+)?)
      | (?P<ident>[A-Za-z_][\w./-]*)
      | (?P<punct><=|>=|!=|<>|[=<>(),])
      | (?P<star>\*)
    )""",
    re.VERBOSE,
)


def tokenize(query: str) -> List[Token]:
    toks: List[Token] = []
    pos = 0
    while pos < len(query):
        m = _TOKEN_RE.match(query, pos)
        if not m:
            if query[pos:].strip() == "":
                break
            raise SQLError(
                f"cannot tokenize {query[pos:pos + 20]!r} at offset {pos}")
        pos = m.end()
        if m.lastgroup == "string":
            raw = m.group("string")
            toks.append(Token("string", raw[1:-1].replace("\\'", "'")
                              .replace('\\"', '"'), m.start()))
        elif m.lastgroup == "number":
            txt = m.group("number")
            num = float(txt)
            toks.append(Token("number", int(num) if num.is_integer()
                              and "." not in txt and "e" not in txt.lower()
                              else num, m.start()))
        elif m.lastgroup == "ident":
            toks.append(Token("ident", m.group("ident"), m.start()))
        elif m.lastgroup == "star":
            toks.append(Token("star", "*", m.start()))
        else:
            toks.append(Token("punct", m.group("punct"), m.start()))
    return toks


# --------------------------------------------------------------------------
# parser -> Query AST


@dataclass
class Predicate:
    column: str
    op: str  # "=", "<", "<=", ">", ">=", "in"
    value: Any  # number, string, or tuple of strings (IN)


@dataclass
class SelectItem:
    column: str
    func: Optional[str] = None  # lowercase agg fn name
    arg: Optional[int] = None  # e.g. KEYWORDS(text, 5) -> 5


@dataclass
class Query:
    select: List[SelectItem]
    star: bool
    source: str
    source_is_path: bool
    where: List[Predicate] = field(default_factory=list)
    group_by: Optional[str] = None
    order_by: Optional[str] = None
    order_desc: bool = False
    limit: Optional[int] = None


class _Parser:
    def __init__(self, toks: List[Token], query: str):
        self.toks = toks
        self.i = 0
        self.query = query

    def peek(self) -> Optional[Token]:
        return self.toks[self.i] if self.i < len(self.toks) else None

    def next(self) -> Token:
        t = self.peek()
        if t is None:
            raise SQLError("unexpected end of query")
        self.i += 1
        return t

    def expect_kw(self, word: str) -> None:
        t = self.next()
        if t.word != word:
            raise SQLError(f"expected {word.upper()}, got {t.value!r}")

    def at_kw(self, *words: str) -> bool:
        t = self.peek()
        return t is not None and t.word in words

    # -- clauses -----------------------------------------------------------
    def parse(self) -> Query:
        self.expect_kw("select")
        star, items = self._select_list()
        self.expect_kw("from")
        src = self.next()
        if src.kind == "string":
            source, is_path = src.value, True
        elif src.kind == "ident":
            source, is_path = src.value, False
        else:
            raise SQLError(f"FROM expects a name or quoted path, "
                           f"got {src.value!r}")
        q = Query(select=items, star=star, source=source,
                  source_is_path=is_path)
        if self.at_kw("where"):
            self.next()
            q.where = self._where()
        if self.at_kw("group"):
            self.next()
            self.expect_kw("by")
            col = self.next()
            if col.kind != "ident":
                raise SQLError(f"GROUP BY expects a column, got {col.value!r}")
            q.group_by = col.value
        if self.at_kw("order"):
            self.next()
            self.expect_kw("by")
            col = self.next()
            if col.kind != "ident":
                raise SQLError(f"ORDER BY expects a column, got {col.value!r}")
            q.order_by = col.value
            if self.at_kw("asc", "desc"):
                q.order_desc = self.next().word == "desc"
        if self.at_kw("limit"):
            self.next()
            n = self.next()
            if n.kind != "number" or not isinstance(n.value, int) \
                    or n.value <= 0:
                raise SQLError(f"LIMIT expects a positive integer, "
                               f"got {n.value!r}")
            q.limit = n.value
        t = self.peek()
        if t is not None:
            raise SQLError(f"trailing input at {t.value!r}")
        return q

    def _select_list(self) -> Tuple[bool, List[SelectItem]]:
        if self.peek() is not None and self.peek().kind == "star":
            self.next()
            return True, []
        items: List[SelectItem] = []
        while True:
            t = self.next()
            if t.kind != "ident":
                raise SQLError(f"SELECT expects columns, got {t.value!r}")
            nxt = self.peek()
            if nxt is not None and nxt.kind == "punct" and nxt.value == "(":
                fn = t.word
                if fn not in AGG_FUNCTIONS:
                    raise SQLError(
                        f"unknown aggregate function {t.value!r}"
                        + _hint(fn, AGG_FUNCTIONS),
                        kind="unknown_column",
                        suggestions=did_you_mean(fn, AGG_FUNCTIONS))
                self.next()  # (
                col = self.next()
                if col.kind != "ident":
                    raise SQLError(
                        f"{t.value}() expects a column, got {col.value!r}")
                arg = None
                if self.peek() is not None and self.peek().value == ",":
                    self.next()
                    k = self.next()
                    if k.kind != "number" or not isinstance(k.value, int):
                        raise SQLError(f"{t.value}() expects an integer "
                                       f"argument, got {k.value!r}")
                    arg = k.value
                close = self.next()
                if close.value != ")":
                    raise SQLError(f"expected ), got {close.value!r}")
                items.append(SelectItem(column=col.value, func=fn, arg=arg))
            else:
                items.append(SelectItem(column=t.value))
            if self.peek() is not None and self.peek().value == ",":
                self.next()
                continue
            return False, items

    def _where(self) -> List[Predicate]:
        preds: List[Predicate] = []
        while True:
            col = self.next()
            if col.kind != "ident":
                raise SQLError(f"WHERE expects a column, got {col.value!r}")
            op_t = self.next()
            if op_t.word == "in":
                self.expect_punct("(")
                vals = []
                while True:
                    v = self.next()
                    if v.kind != "string":
                        raise SQLError(f"IN (...) expects quoted strings, "
                                       f"got {v.value!r}")
                    vals.append(v.value)
                    sep = self.next()
                    if sep.value == ")":
                        break
                    if sep.value != ",":
                        raise SQLError(f"expected , or ), got {sep.value!r}")
                preds.append(Predicate(col.value, "in", tuple(vals)))
            elif op_t.kind == "punct" and op_t.value in (
                    "=", "<", "<=", ">", ">="):
                v = self.next()
                if v.kind not in ("number", "string"):
                    raise SQLError(f"comparison expects a literal, "
                                   f"got {v.value!r}")
                preds.append(Predicate(col.value, op_t.value, v.value))
            elif op_t.kind == "punct" and op_t.value in ("!=", "<>"):
                raise SQLError(
                    f"{op_t.value} is not supported (only = < <= > >= IN)",
                    kind="unsupported")
            else:
                raise SQLError(f"expected a comparison operator, "
                               f"got {op_t.value!r}")
            if self.at_kw("and"):
                self.next()
                continue
            if self.at_kw("or"):
                raise SQLError("OR is not supported (conjunctions only)",
                               kind="unsupported")
            return preds

    def expect_punct(self, p: str) -> None:
        t = self.next()
        if t.value != p:
            raise SQLError(f"expected {p}, got {t.value!r}")


def parse_sql(query: str) -> Query:
    toks = tokenize(query)
    if not toks:
        raise SQLError("empty query")
    return _Parser(toks, query).parse()


# --------------------------------------------------------------------------
# compiler -> op configs


def _hint(name: str, candidates) -> str:
    close = did_you_mean(name, candidates)
    return f" (did you mean {', '.join(close)}?)" if close else ""


def _unknown_column(name: str) -> SQLError:
    cols = sorted(set(STAT_COLUMNS) | {LANG_COLUMN, "text"})
    return SQLError(
        f"unknown column {name!r}{_hint(name, cols)}; known: {cols}",
        kind="unknown_column", suggestions=did_you_mean(name, cols))


def _strict_above(v: float) -> float:
    return math.nextafter(float(v), math.inf)


def _strict_below(v: float) -> float:
    return math.nextafter(float(v), -math.inf)


def compile_query(q: Query) -> Tuple[List[Dict[str, Any]], Dict[str, Any]]:
    """Query AST -> (op config list, info). ``info`` carries side-channel
    facts the caller needs: which stat columns got auto-injected compute
    filters, the GROUP BY container, etc."""
    ops: List[Dict[str, Any]] = []
    info: Dict[str, Any] = {"injected": []}

    # -- WHERE: merge numeric predicates per column into one range filter ---
    ranges: Dict[str, Dict[str, float]] = {}
    range_order: List[str] = []  # preserve first-mention order
    lang_keep: Optional[Tuple[str, ...]] = None
    for p in q.where:
        col = p.column.lower()
        if col == LANG_COLUMN:
            if p.op == "=":
                vals: Tuple[str, ...] = (str(p.value),)
            elif p.op == "in":
                vals = tuple(str(v) for v in p.value)
            else:
                raise SQLError(f"lang supports only = and IN, got {p.op!r}",
                               kind="unsupported")
            if lang_keep is not None:
                # AND of two lang constraints -> intersection
                vals = tuple(v for v in lang_keep if v in vals)
            lang_keep = vals
            continue
        if col not in STAT_COLUMNS:
            raise _unknown_column(p.column)
        if not isinstance(p.value, (int, float)):
            raise SQLError(f"column {p.column!r} compares to a number, "
                           f"got {p.value!r}", kind="syntax")
        if col not in ranges:
            ranges[col] = {}
            range_order.append(col)
        r = ranges[col]
        v = float(p.value)
        if p.op == "=":
            r["min_val"] = max(r.get("min_val", -math.inf), v)
            r["max_val"] = min(r.get("max_val", math.inf), v)
        elif p.op == ">=":
            r["min_val"] = max(r.get("min_val", -math.inf), v)
        elif p.op == ">":
            r["min_val"] = max(r.get("min_val", -math.inf), _strict_above(v))
        elif p.op == "<=":
            r["max_val"] = min(r.get("max_val", math.inf), v)
        elif p.op == "<":
            r["max_val"] = min(r.get("max_val", math.inf), _strict_below(v))

    filtered_stats = set()  # stat keys already computed by a WHERE filter
    if lang_keep is not None:
        ops.append({"name": "language_heuristic_filter",
                    "keep_langs": list(lang_keep)})
        filtered_stats.add(LANG_COLUMN)
    for col in range_order:
        op_name, stat_key = STAT_COLUMNS[col]
        cfg: Dict[str, Any] = {"name": op_name}
        cfg.update(ranges[col])
        ops.append(cfg)
        filtered_stats.add(stat_key)

    def _ensure_stat(column: str) -> str:
        """Make sure ``column``'s stat is computed; inject an unbounded
        (keep-everything) filter when WHERE didn't already. Returns the
        stat key."""
        col = column.lower()
        if col == LANG_COLUMN:
            if LANG_COLUMN not in filtered_stats:
                ops.append({"name": "language_heuristic_filter",
                            "keep_langs": list(_KNOWN_LANGS)})
                filtered_stats.add(LANG_COLUMN)
                info["injected"].append(LANG_COLUMN)
            return LANG_COLUMN
        if col not in STAT_COLUMNS:
            raise _unknown_column(column)
        op_name, stat_key = STAT_COLUMNS[col]
        if stat_key not in filtered_stats:
            ops.append({"name": op_name})  # default bounds: (-inf, inf)
            filtered_stats.add(stat_key)
            info["injected"].append(stat_key)
        return stat_key

    # -- aggregates in SELECT ----------------------------------------------
    aggs = [it for it in q.select if it.func]
    if len(aggs) > 1:
        raise SQLError("at most one aggregate function per query",
                       kind="unsupported")
    if aggs and q.group_by is None:
        raise SQLError(f"{aggs[0].func.upper()}() requires GROUP BY",
                       kind="syntax")
    if aggs and aggs[0].column != "text":
        raise SQLError(f"{aggs[0].func.upper()}() aggregates the text "
                       f"column, got {aggs[0].column!r}", kind="unsupported")

    # -- GROUP BY -> grouper + aggregator barrier --------------------------
    if q.group_by is not None:
        if q.order_by is not None:
            raise SQLError("ORDER BY with GROUP BY is not supported",
                           kind="unsupported")
        col = q.group_by.lower()
        if col == LANG_COLUMN or col in STAT_COLUMNS:
            key = _ensure_stat(q.group_by)
            source = "stats"
        else:
            key, source = q.group_by, "meta"  # free-form meta key
        ops.append({"name": "key_value_grouper", "key": key,
                    "source": source})
        info["group_source"] = source
        if aggs and aggs[0].func == "keywords":
            agg_cfg: Dict[str, Any] = {"name": AGG_FUNCTIONS["keywords"]}
            if aggs[0].arg is not None:
                agg_cfg["top_k"] = aggs[0].arg
            ops.append(agg_cfg)
        else:
            ops.append({"name": AGG_FUNCTIONS["concat"]})

    # -- ORDER BY / LIMIT -> topk_stat_selector ----------------------------
    if q.order_by is not None:
        stat_key = _ensure_stat(q.order_by)
        if stat_key == LANG_COLUMN:
            raise SQLError("ORDER BY needs a numeric stat column",
                           kind="unsupported")
        sel: Dict[str, Any] = {"name": "topk_stat_selector",
                               "stat_key": stat_key,
                               "descending": bool(q.order_desc)}
        if q.limit is not None:
            sel["k"] = q.limit
        else:
            sel["fraction"] = 1.0  # full sort, keep everything
        ops.append(sel)
    elif q.limit is not None:
        raise SQLError("LIMIT requires ORDER BY (results are otherwise "
                       "unordered)", kind="unsupported")

    # -- SELECT projection -------------------------------------------------
    if not q.star and not aggs:
        cols = [it.column for it in q.select]
        for c in cols:
            lc = c.lower()
            if lc not in ("text", "meta", "stats", "id") \
                    and lc != LANG_COLUMN and lc not in STAT_COLUMNS:
                raise _unknown_column(c)
        if cols != ["text"]:
            fields = []
            for c in cols:
                lc = c.lower()
                if lc == LANG_COLUMN or lc in STAT_COLUMNS:
                    _ensure_stat(c)
                    f = "stats"
                else:
                    f = lc
                if f not in fields:
                    fields.append(f)
            ops.append({"name": "select_fields_mapper", "fields": fields})
    return ops, info


# --------------------------------------------------------------------------
# FROM resolution + public entry point


def _resolve_source(q: Query, source, dataset_path: Optional[str],
                    caller_frame) -> Pipeline:
    if source is not None:
        if isinstance(source, Pipeline):
            return source
        if isinstance(source, str):
            return Pipeline.read_jsonl(source)
        if isinstance(source, (list, tuple)):
            return Pipeline.from_samples(list(source))
        return Pipeline.from_dataset(source)
    if dataset_path is not None:
        return Pipeline.read_jsonl(dataset_path)
    if q.source_is_path:
        return Pipeline.read_jsonl(q.source)
    # FROM <name>: look the identifier up in the caller's scope
    if caller_frame is not None:
        ns = dict(caller_frame.f_globals)
        ns.update(caller_frame.f_locals)
        if q.source in ns:
            v = ns[q.source]
            if isinstance(v, Pipeline):
                return v
            if isinstance(v, str):
                return Pipeline.read_jsonl(v)
            if isinstance(v, (list, tuple)):
                return Pipeline.from_samples(list(v))
            return Pipeline.from_dataset(v)
    raise SQLError(
        f"cannot resolve FROM source {q.source!r}: pass source=/dataset_path="
        f" or use a quoted path ('data.jsonl')", kind="unknown_source")


def sql(query: str, source=None, *, dataset_path: Optional[str] = None,
        export_path: Optional[str] = None, **options) -> Pipeline:
    """Compile ``query`` to a :class:`Pipeline` over the shared logical-plan
    IR. ``source`` may be a Pipeline, a dataset, a samples list or a jsonl
    path; otherwise ``FROM`` resolves via ``dataset_path=``, a quoted path
    literal, or a same-named variable in the caller's scope. Extra keyword
    ``options`` pass through to :meth:`Pipeline.options`."""
    q = parse_sql(query)
    frame = inspect.currentframe()
    caller = frame.f_back if frame is not None else None
    try:
        pipe = _resolve_source(q, source, dataset_path, caller)
    finally:
        del frame, caller
    op_cfgs, _ = compile_query(q)
    for cfg in op_cfgs:
        cfg = dict(cfg)
        name = cfg.pop("name")
        pipe = pipe.op(name, **cfg)
    if export_path is not None:
        pipe = pipe.write_jsonl(export_path)
    if options:
        pipe = pipe.options(**options)
    return pipe
