"""Mamba2 (SSD — state-space duality) LM [arXiv:2405.21060].

Implements the chunked SSD algorithm: intra-chunk quadratic attention-like
term + inter-chunk linear state recurrence. ``ssd_chunked`` is the pure-jnp
formulation (also the oracle for the Pallas kernel in
``repro.kernels.ssd_scan``); decode keeps an O(1) recurrent state, which is
what makes the ``long_500k`` cell feasible for this family.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.sharding import logical_constraint
from repro.models import layers as L
from repro.models import module as mod
from repro.models.transformer import remat_wrap

STATE_DTYPE = jnp.float32


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x (b, s, ch), w (ch, k), b (ch,)."""
    k = w.shape[-1]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):
        out = out + pad[:, i : i + x.shape[1]].astype(jnp.float32) * w[:, i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def segsum(a: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum a[..., j+1..i], -inf for j > i.

    a: (..., q). returns (..., q, q) lower-triangular log-decay matrix.
    """
    q = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)  # (..., q)
    diff = cum[..., :, None] - cum[..., None, :]  # (..., i, j) = sum(j+1..i)
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(
    x: jax.Array,  # (b, s, nh, hd)
    dt: jax.Array,  # (b, s, nh)   (already softplus'ed, > 0)
    a_log: jax.Array,  # (nh,)     A = -exp(a_log)
    b_mat: jax.Array,  # (b, s, g, ds)
    c_mat: jax.Array,  # (b, s, g, ds)
    chunk: int,
    init_state: jax.Array | None = None,  # (b, nh, hd, ds)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (b, s, nh, hd), final_state (b, nh, hd, ds))."""
    bsz, s, nh, hd = x.shape
    g, ds = b_mat.shape[2], b_mat.shape[3]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    rep = nh // g

    A = -jnp.exp(a_log.astype(jnp.float32))  # (nh,)
    dt32 = dt.astype(jnp.float32)
    da = dt32 * A  # (b, s, nh) log-decay per step

    xr = x.reshape(bsz, nc, chunk, nh, hd).astype(jnp.float32)
    dtr = dt32.reshape(bsz, nc, chunk, nh)
    dar = da.reshape(bsz, nc, chunk, nh)
    br = jnp.repeat(b_mat.reshape(bsz, nc, chunk, g, ds), rep, axis=3).astype(jnp.float32)
    cr = jnp.repeat(c_mat.reshape(bsz, nc, chunk, g, ds), rep, axis=3).astype(jnp.float32)

    # --- intra-chunk (quadratic within chunk) ---
    lmat = jnp.exp(segsum(dar.transpose(0, 1, 3, 2)))  # (b, nc, nh, q, q)
    scores = jnp.einsum("bcqhn,bckhn->bchqk", cr, br) * lmat
    scores = scores * dtr.transpose(0, 1, 3, 2)[:, :, :, None, :]  # weight by dt_j
    y_intra = jnp.einsum("bchqk,bckhp->bcqhp", scores, xr)

    # --- chunk states ---
    cum = jnp.cumsum(dar, axis=2)  # (b, nc, q, nh)
    total = cum[:, :, -1]  # (b, nc, nh)
    decay_to_end = jnp.exp(total[:, :, None] - cum)  # (b, nc, q, nh)
    s_chunk = jnp.einsum(
        "bcqhn,bcqh,bcqhp->bchpn", br, dtr * decay_to_end, xr
    )  # (b, nc, nh, hd, ds)

    # --- inter-chunk recurrence over chunk states ---
    h0 = (
        jnp.zeros((bsz, nh, hd, ds), jnp.float32)
        if init_state is None
        else init_state.astype(jnp.float32)
    )

    def step(h, inp):
        s_c, tot = inp  # (b, nh, hd, ds), (b, nh)
        h_prev = h
        h = h * jnp.exp(tot)[:, :, None, None] + s_c
        return h, h_prev

    final, h_prevs = jax.lax.scan(
        step,
        h0,
        (s_chunk.transpose(1, 0, 2, 3, 4), total.transpose(1, 0, 2)),
    )
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)  # (b, nc, nh, hd, ds)

    # --- inter-chunk contribution ---
    in_decay = jnp.exp(cum)  # (b, nc, q, nh)
    y_inter = jnp.einsum("bcqhn,bcqh,bchpn->bcqhp", cr, in_decay, h_prevs)

    y = (y_intra + y_inter).reshape(bsz, s, nh, hd)
    return y, final


def ssd_decode_step(
    state: jax.Array,  # (b, nh, hd, ds) fp32
    x: jax.Array,  # (b, nh, hd)
    dt: jax.Array,  # (b, nh)
    a_log: jax.Array,  # (nh,)
    b_vec: jax.Array,  # (b, g, ds)
    c_vec: jax.Array,  # (b, g, ds)
) -> Tuple[jax.Array, jax.Array]:
    nh = x.shape[1]
    g = b_vec.shape[1]
    rep = nh // g
    A = -jnp.exp(a_log.astype(jnp.float32))
    da = jnp.exp(dt.astype(jnp.float32) * A)  # (b, nh)
    br = jnp.repeat(b_vec, rep, axis=1).astype(jnp.float32)  # (b, nh, ds)
    cr = jnp.repeat(c_vec, rep, axis=1).astype(jnp.float32)
    upd = jnp.einsum("bh,bhp,bhn->bhpn", dt.astype(jnp.float32), x.astype(jnp.float32), br)
    state = state * da[:, :, None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", state, cr)
    return state, y


class Mamba2LM:
    def __init__(self, cfg: ModelConfig, remat_policy: str = "full"):
        self.cfg = cfg
        self.remat_policy = remat_policy

    # ------------------------------------------------------------------
    @property
    def _dims(self):
        c = self.cfg
        di = c.d_inner
        nh = c.ssm_nheads
        g, ds = c.ssm_ngroups, c.ssm_state
        conv_dim = di + 2 * g * ds
        return di, nh, g, ds, conv_dim

    def _layer_specs(self) -> Dict[str, mod.ParamSpec]:
        c = self.cfg
        nl, d = c.n_layers, c.d_model
        di, nh, g, ds, conv_dim = self._dims
        proj_out = 2 * di + 2 * g * ds + nh
        return {
            "norm": mod.spec((nl, d), ("layers", "embed"), init="ones"),
            "w_in": mod.spec((nl, d, proj_out), ("layers", "embed", "ssm_inner"), init="scaled"),
            "conv_w": mod.spec((nl, conv_dim, c.ssm_conv), ("layers", "ssm_inner", "conv"), init="scaled"),
            "conv_b": mod.spec((nl, conv_dim), ("layers", "ssm_inner"), init="zeros"),
            "dt_bias": mod.spec((nl, nh), ("layers", "ssm_heads"), init="zeros"),
            "a_log": mod.spec((nl, nh), ("layers", "ssm_heads"), init="zeros"),
            "d_skip": mod.spec((nl, nh), ("layers", "ssm_heads"), init="ones"),
            "norm_g": mod.spec((nl, di), ("layers", "ssm_inner"), init="ones"),
            "w_out": mod.spec((nl, di, d), ("layers", "ssm_inner", "embed"), init="scaled"),
        }

    def param_specs(self):
        c = self.cfg
        p: Dict[str, Any] = {
            "embed": mod.spec((c.padded_vocab, c.d_model), ("vocab", "embed")),
            "layers": self._layer_specs(),
            "final_norm": mod.spec((c.d_model,), ("embed",), init="ones"),
        }
        if not c.tie_embeddings:
            p["head"] = mod.spec((c.d_model, c.padded_vocab), ("embed", "vocab"), init="scaled")
        return p

    def init_params(self, key):
        return mod.init_tree(self.param_specs(), key)

    # ------------------------------------------------------------------
    def _split_proj(self, zxbcdt):
        di, nh, g, ds, conv_dim = self._dims
        z = zxbcdt[..., :di]
        xbc = zxbcdt[..., di : di + conv_dim]
        dt = zxbcdt[..., di + conv_dim :]
        return z, xbc, dt

    def _block(self, p, x, mode: str, state=None):
        """mode: 'train' (full seq) or 'decode' (state = (conv_state, ssm_state))."""
        c = self.cfg
        di, nh, g, ds, conv_dim = self._dims
        h = L.rms_norm(x, p["norm"], c.norm_eps)
        zxbcdt = jnp.einsum("bsd,dp->bsp", h, p["w_in"].astype(h.dtype))
        z, xbc, dt = self._split_proj(zxbcdt)
        dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))

        if mode == "train":
            xbc = causal_conv1d(xbc, p["conv_w"], p["conv_b"])
            xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
            x_in = xbc[..., :di].reshape(*xbc.shape[:2], nh, c.ssm_headdim)
            b_mat = xbc[..., di : di + g * ds].reshape(*xbc.shape[:2], g, ds)
            c_mat = xbc[..., di + g * ds :].reshape(*xbc.shape[:2], g, ds)
            y, _ = ssd_chunked(x_in, dt, p["a_log"], b_mat, c_mat, c.ssm_chunk)
            y = y + p["d_skip"].astype(jnp.float32)[:, None] * x_in.astype(jnp.float32)
            y = y.reshape(*xbc.shape[:2], di)
            new_state = None
        else:
            conv_state, ssm_state = state  # (b, conv-1, conv_dim), (b, nh, hd, ds)
            seq = jnp.concatenate([conv_state.astype(xbc.dtype), xbc], axis=1)
            conv_out = causal_conv1d(seq, p["conv_w"], p["conv_b"])[:, -1:]
            new_conv = seq[:, 1:]
            xbc1 = jax.nn.silu(conv_out.astype(jnp.float32)).astype(x.dtype)[:, 0]
            x_in = xbc1[..., :di].reshape(-1, nh, c.ssm_headdim)
            b_vec = xbc1[..., di : di + g * ds].reshape(-1, g, ds)
            c_vec = xbc1[..., di + g * ds :].reshape(-1, g, ds)
            ssm_state, y = ssd_decode_step(
                ssm_state, x_in, dt[:, 0], p["a_log"], b_vec, c_vec
            )
            y = y + p["d_skip"].astype(jnp.float32) [:, None] * x_in.astype(jnp.float32)
            y = y.reshape(x.shape[0], 1, di)
            new_state = (new_conv.astype(conv_state.dtype), ssm_state)

        # gated RMSNorm then out-projection
        y = y * jax.nn.silu(z.astype(jnp.float32))
        y = L.rms_norm(y.astype(x.dtype), p["norm_g"], c.norm_eps)
        out = jnp.einsum("bsd,dp->bsp", y, p["w_out"].astype(x.dtype))
        x = x + out
        return logical_constraint(x, ("batch", "seq", "embed")), new_state

    # ------------------------------------------------------------------
    def loss_fn(self, params, batch):
        c = self.cfg
        x = L.embed(batch["tokens"], params["embed"])
        x = logical_constraint(x, ("batch", "seq", "embed"))
        block = remat_wrap(lambda xx, pp: self._block(pp, xx, "train")[0], self.remat_policy)
        x, _ = jax.lax.scan(lambda xx, pp: (block(xx, pp), None), x, params["layers"])
        x = L.rms_norm(x, params["final_norm"], c.norm_eps)
        head = params.get("head")
        if head is None:
            head = params["embed"].T
        logits = L.lm_logits(x, head)
        logits = logical_constraint(logits, ("batch", "seq", "vocab"))
        loss = L.softmax_xent(logits, batch["labels"], batch.get("loss_mask"), valid_vocab=c.vocab_size)
        return loss, {"xent": loss}

    # ------------------------------------------------------------------
    def _block_prefill(self, p, x):
        """Full-sequence pass that also returns the final recurrent state."""
        c = self.cfg
        di, nh, g, ds, conv_dim = self._dims
        h = L.rms_norm(x, p["norm"], c.norm_eps)
        zxbcdt = jnp.einsum("bsd,dp->bsp", h, p["w_in"].astype(h.dtype))
        z, xbc_raw, dt = self._split_proj(zxbcdt)
        dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
        xbc = causal_conv1d(xbc_raw, p["conv_w"], p["conv_b"])
        xbc = jax.nn.silu(xbc.astype(jnp.float32)).astype(x.dtype)
        x_in = xbc[..., :di].reshape(*xbc.shape[:2], nh, c.ssm_headdim)
        b_mat = xbc[..., di : di + g * ds].reshape(*xbc.shape[:2], g, ds)
        c_mat = xbc[..., di + g * ds :].reshape(*xbc.shape[:2], g, ds)
        y, final = ssd_chunked(x_in, dt, p["a_log"], b_mat, c_mat, c.ssm_chunk)
        y = y + p["d_skip"].astype(jnp.float32)[:, None] * x_in.astype(jnp.float32)
        y = y.reshape(*xbc.shape[:2], di)
        y = y * jax.nn.silu(z.astype(jnp.float32))
        y = L.rms_norm(y.astype(x.dtype), p["norm_g"], c.norm_eps)
        out = jnp.einsum("bsd,dp->bsp", y, p["w_out"].astype(x.dtype))
        x = x + out
        conv_state = xbc_raw[:, -(c.ssm_conv - 1):].astype(STATE_DTYPE)
        return x, (conv_state, final)

    def prefill(self, params, batch, cache_budget: int = 0):
        # recurrent state is O(1): no budget needed
        c = self.cfg
        x = L.embed(batch["tokens"], params["embed"])
        block = remat_wrap(lambda xx, pp: self._block_prefill(pp, xx), self.remat_policy)
        x, states = jax.lax.scan(lambda xx, pp: block(xx, pp), x, params["layers"])
        x = L.rms_norm(x, params["final_norm"], c.norm_eps)
        head = params.get("head")
        if head is None:
            head = params["embed"].T
        logits = L.lm_logits(x[:, -1:], head)[..., : c.vocab_size]
        conv_states, ssm_states = states
        return {"conv": conv_states, "ssm": ssm_states}, logits

    def decode_step(self, params, cache, batch):
        c = self.cfg
        x = L.embed(batch["token"], params["embed"])

        def scan_body(xx, per_layer):
            pp, conv_s, ssm_s = per_layer
            xx, (conv_s, ssm_s) = self._block(pp, xx, "decode", (conv_s, ssm_s))
            return xx, (conv_s, ssm_s)

        x, (conv_n, ssm_n) = jax.lax.scan(
            scan_body, x, (params["layers"], cache["conv"], cache["ssm"])
        )
        x = L.rms_norm(x, params["final_norm"], c.norm_eps)
        head = params.get("head")
        if head is None:
            head = params["embed"].T
        logits = L.lm_logits(x, head)[..., : c.vocab_size]
        return {"conv": conv_n, "ssm": ssm_n}, logits

    # ------------------------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind == "train":
            return {
                "tokens": mod.spec((b, s), ("batch", "seq"), i32, "zeros"),
                "labels": mod.spec((b, s), ("batch", "seq"), i32, "zeros"),
                "loss_mask": mod.spec((b, s), ("batch", "seq"), jnp.float32, "ones"),
            }
        if shape.kind == "prefill":
            return {"tokens": mod.spec((b, s), ("batch", "seq"), i32, "zeros")}
        return {
            "token": mod.spec((b, 1), ("batch", "seq"), i32, "zeros"),
            "pos": mod.spec((), (), i32, "zeros"),
        }

    def cache_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        c = self.cfg
        b = shape.global_batch
        di, nh, g, ds, conv_dim = self._dims
        return {
            "conv": mod.spec(
                (c.n_layers, b, c.ssm_conv - 1, conv_dim),
                ("layers", "cache_batch", None, "ssm_inner"),
                STATE_DTYPE, "zeros",
            ),
            "ssm": mod.spec(
                (c.n_layers, b, nh, c.ssm_headdim, ds),
                ("layers", "cache_batch", "ssm_heads", None, "state"),
                STATE_DTYPE, "zeros",
            ),
        }
