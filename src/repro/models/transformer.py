"""Decoder-only transformer LM covering the dense / moe / vlm families.

Uniform model API (shared by all families in ``repro.models``):
  * ``param_specs()``           -> ParamSpec tree (shapes + logical axes)
  * ``init_params(key)``        -> materialised params (reduced configs)
  * ``loss_fn(params, batch)``  -> (loss, metrics)         [train shapes]
  * ``prefill(params, batch)``  -> (cache, last_logits)    [prefill shapes]
  * ``decode_step(params, cache, batch)`` -> (cache, logits) [decode shapes]
  * ``input_specs(shape)`` / ``cache_specs(shape)`` -> ShapeDtypeStruct trees

Layers are stacked on a leading ``layers`` dim and executed with
``jax.lax.scan`` (+ selectable remat policy) so giant configs compile fast
and the dry-run HLO stays compact.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.sharding import logical_constraint
from repro.models import layers as L
from repro.models import module as mod
from repro.models.decode_attn import decode_attention
from repro.models.moe import moe_layer

CACHE_DTYPE = jnp.bfloat16
MOE_AUX_COEF = 0.01


def remat_wrap(fn, policy: str):
    if policy == "none":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        )
    return jax.checkpoint(fn)  # 'full': save nothing


class TransformerLM:
    def __init__(self, cfg: ModelConfig, remat_policy: str = "full",
                 moe_dispatch: str = "scatter"):
        self.cfg = cfg
        self.remat_policy = remat_policy
        self.moe_dispatch = moe_dispatch

    # ------------------------------------------------------------------
    # Parameters
    # ------------------------------------------------------------------
    def _layer_specs(self) -> Dict[str, mod.ParamSpec]:
        c = self.cfg
        nl, d, f = c.n_layers, c.d_model, c.d_ff
        qd, kvd = c.q_dim, c.kv_dim
        s: Dict[str, mod.ParamSpec] = {
            "norm1": mod.spec((nl, d), ("layers", "embed"), init="ones"),
            "wq": mod.spec((nl, d, qd), ("layers", "embed", "heads"), init="scaled"),
            "wk": mod.spec((nl, d, kvd), ("layers", "embed", "kv_heads"), init="scaled"),
            "wv": mod.spec((nl, d, kvd), ("layers", "embed", "kv_heads"), init="scaled"),
            "wo": mod.spec((nl, qd, d), ("layers", "heads", "embed"), init="scaled"),
            "norm2": mod.spec((nl, d), ("layers", "embed"), init="ones"),
        }
        if c.qkv_bias:
            s["bq"] = mod.spec((nl, qd), ("layers", "heads"), init="zeros")
            s["bk"] = mod.spec((nl, kvd), ("layers", "kv_heads"), init="zeros")
            s["bv"] = mod.spec((nl, kvd), ("layers", "kv_heads"), init="zeros")
        if c.family == "moe":
            e = c.n_experts
            s["router"] = mod.spec((nl, d, e), ("layers", "embed", "expert"), init="scaled")
            s["eg"] = mod.spec((nl, e, d, f), ("layers", "expert", "embed", "mlp"), init="scaled")
            s["eu"] = mod.spec((nl, e, d, f), ("layers", "expert", "embed", "mlp"), init="scaled")
            s["ed"] = mod.spec((nl, e, f, d), ("layers", "expert", "mlp", "embed"), init="scaled")
        elif c.mlp_type == "swiglu":
            s["wg"] = mod.spec((nl, d, f), ("layers", "embed", "mlp"), init="scaled")
            s["wu"] = mod.spec((nl, d, f), ("layers", "embed", "mlp"), init="scaled")
            s["wd"] = mod.spec((nl, f, d), ("layers", "mlp", "embed"), init="scaled")
        else:  # gelu
            s["wu"] = mod.spec((nl, d, f), ("layers", "embed", "mlp"), init="scaled")
            s["wd"] = mod.spec((nl, f, d), ("layers", "mlp", "embed"), init="scaled")
            s["bu"] = mod.spec((nl, f), ("layers", "mlp"), init="zeros")
            s["bd"] = mod.spec((nl, d), ("layers", "embed"), init="zeros")
        return s

    def param_specs(self):
        c = self.cfg
        p: Dict[str, Any] = {
            "embed": mod.spec((c.padded_vocab, c.d_model), ("vocab", "embed")),
            "layers": self._layer_specs(),
            "final_norm": mod.spec((c.d_model,), ("embed",), init="ones"),
        }
        if not c.tie_embeddings:
            p["head"] = mod.spec((c.d_model, c.padded_vocab), ("embed", "vocab"), init="scaled")
        if c.family == "vlm":
            p["patch_proj"] = mod.spec(
                (c.d_model, c.d_model), ("embed", "embed"), init="scaled"
            )
        return p

    def init_params(self, key):
        return mod.init_tree(self.param_specs(), key)

    # ------------------------------------------------------------------
    # One transformer block
    # ------------------------------------------------------------------
    def _qkv(self, p, h, positions):
        c = self.cfg
        hd = c.resolved_head_dim
        b, s, _ = h.shape
        q = jnp.einsum("bsd,dq->bsq", h, p["wq"].astype(h.dtype))
        k = jnp.einsum("bsd,dq->bsq", h, p["wk"].astype(h.dtype))
        v = jnp.einsum("bsd,dq->bsq", h, p["wv"].astype(h.dtype))
        if c.qkv_bias:
            q = q + p["bq"].astype(h.dtype)
            k = k + p["bk"].astype(h.dtype)
            v = v + p["bv"].astype(h.dtype)
        q = q.reshape(b, s, c.n_heads, hd)
        k = k.reshape(b, s, c.n_kv_heads, hd)
        v = v.reshape(b, s, c.n_kv_heads, hd)
        q = L.apply_rope(q, positions, c.rope_theta)
        k = L.apply_rope(k, positions, c.rope_theta)
        return q, k, v

    def _mlp(self, p, h):
        c = self.cfg
        if c.family == "moe":
            out, aux = moe_layer(
                h, p["router"], p["eg"], p["eu"], p["ed"], c.top_k,
                c.capacity_factor, dispatch=self.moe_dispatch,
            )
            return out, aux
        if c.mlp_type == "swiglu":
            return L.mlp_swiglu(h, p["wg"], p["wu"], p["wd"]), 0.0
        return L.mlp_gelu(h, p["wu"], p["wd"], p.get("bu"), p.get("bd")), 0.0

    def _block_train(self, p, x, positions):
        c = self.cfg
        h = L.rms_norm(x, p["norm1"], c.norm_eps)
        q, k, v = self._qkv(p, h, positions)
        attn = L.attention_chunked(q, k, v, causal=True, window=c.attn_window)
        attn = jnp.einsum(
            "bsq,qd->bsd",
            attn.reshape(attn.shape[0], attn.shape[1], -1),
            p["wo"].astype(x.dtype),
        )
        x = x + attn
        x = logical_constraint(x, ("batch", "seq", "embed"))
        h = L.rms_norm(x, p["norm2"], c.norm_eps)
        m, aux = self._mlp(p, h)
        x = x + m
        x = logical_constraint(x, ("batch", "seq", "embed"))
        return x, aux

    # ------------------------------------------------------------------
    # Train
    # ------------------------------------------------------------------
    def _backbone_inputs(self, params, batch):
        """Token (+patch) embedding. Returns x (b, s, d)."""
        c = self.cfg
        x = L.embed(batch["tokens"], params["embed"])
        if c.family == "vlm":
            pe = batch["patch_embeds"].astype(x.dtype)
            pe = jnp.einsum("bpd,de->bpe", pe, params["patch_proj"].astype(x.dtype))
            x = jnp.concatenate([pe, x], axis=1)
        return logical_constraint(x, ("batch", "seq", "embed"))

    def loss_fn(self, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
        c = self.cfg
        x = self._backbone_inputs(params, batch)
        positions = jnp.arange(x.shape[1])
        block = remat_wrap(
            lambda xx, pp: self._block_train(pp, xx, positions), self.remat_policy
        )

        def scan_body(xx, pp):
            xx, aux = block(xx, pp)
            return xx, aux

        x, auxs = jax.lax.scan(scan_body, x, params["layers"])
        x = L.rms_norm(x, params["final_norm"], c.norm_eps)
        head = params.get("head")
        if head is None:
            head = params["embed"].T
        if c.family == "vlm":  # drop patch positions before the LM head
            x = x[:, c.n_patches :]
        logits = L.lm_logits(x, head)
        logits = logical_constraint(logits, ("batch", "seq", "vocab"))
        loss = L.softmax_xent(logits, batch["labels"], batch.get("loss_mask"), valid_vocab=c.vocab_size)
        aux = jnp.sum(auxs) if c.family == "moe" else 0.0
        total = loss + MOE_AUX_COEF * aux
        return total, {"xent": loss, "moe_aux": jnp.asarray(aux, jnp.float32)}

    # ------------------------------------------------------------------
    # Serve: prefill + decode
    # ------------------------------------------------------------------
    def cache_len(self, seq_len: int) -> int:
        c = self.cfg
        return min(seq_len, c.attn_window) if c.attn_window else seq_len

    def _block_prefill(self, p, x, positions, a_alloc: int):
        """Like _block_train but also emits this layer's (k, v) cache."""
        c = self.cfg
        h = L.rms_norm(x, p["norm1"], c.norm_eps)
        q, k, v = self._qkv(p, h, positions)
        attn = L.attention_chunked(q, k, v, causal=True, window=c.attn_window)
        attn = jnp.einsum(
            "bsq,qd->bsd", attn.reshape(attn.shape[0], attn.shape[1], -1),
            p["wo"].astype(x.dtype),
        )
        x = x + attn
        h = L.rms_norm(x, p["norm2"], c.norm_eps)
        m, _ = self._mlp(p, h)
        x = x + m
        x = logical_constraint(x, ("batch", "seq", "embed"))
        s = x.shape[1]
        if a_alloc <= s:
            # ring layout: position p -> slot p % a; holds when s % a == 0
            # (asserted in input_specs for the assigned shapes)
            k_c, v_c = k[:, -a_alloc:], v[:, -a_alloc:]
        else:  # full-attention cache with decode budget appended
            pad = ((0, 0), (0, a_alloc - s), (0, 0), (0, 0))
            k_c, v_c = jnp.pad(k, pad), jnp.pad(v, pad)
        cache_axes = ("batch", "kv_heads", "kv_seq", None)
        k_c = logical_constraint(L.cache_store(k_c).astype(CACHE_DTYPE), cache_axes)
        v_c = logical_constraint(L.cache_store(v_c).astype(CACHE_DTYPE), cache_axes)
        return x, (k_c, v_c)

    def prefill(self, params, batch, cache_budget: int = 0):
        c = self.cfg
        x = self._backbone_inputs(params, batch)
        positions = jnp.arange(x.shape[1])
        s = x.shape[1]
        a_alloc = self.cache_len(s) if c.attn_window else s + cache_budget
        block = remat_wrap(
            lambda xx, pp: self._block_prefill(pp, xx, positions, a_alloc),
            self.remat_policy,
        )
        x, (k_all, v_all) = jax.lax.scan(lambda xx, pp: block(xx, pp), x, params["layers"])
        x = L.rms_norm(x, params["final_norm"], c.norm_eps)
        head = params.get("head")
        if head is None:
            head = params["embed"].T
        last = x[:, -1:]
        logits = L.lm_logits(last, head)[..., : c.vocab_size]
        cache = {"k": k_all, "v": v_all}  # (L, b, A, hkv, hd)
        return cache, logits

    def _block_decode(self, p, x, kst, vst, i, pos, slot):
        """x: (b, 1, d); kst/vst: full stacked cache (L, b, hkv, A, hd).

        The new token's K/V is written as a single-slot slice into the
        stacked cache (carried through the layer scan), so with donation the
        update is in-place — per-layer traffic is one cache READ plus a
        token-sized write, never a full-slice rewrite.
        """
        c = self.cfg
        h = L.rms_norm(x, p["norm1"], c.norm_eps)
        q, k, v = self._qkv(p, h, jnp.array([pos]) if not isinstance(pos, jax.Array) else pos[None])
        attn, kst, vst = decode_attention(q, k, v, kst, vst, i, pos)
        attn = jnp.einsum(
            "bsq,qd->bsd", attn.reshape(attn.shape[0], 1, -1), p["wo"].astype(x.dtype)
        )
        x = x + attn
        h = L.rms_norm(x, p["norm2"], c.norm_eps)
        m, _ = self._mlp(p, h)
        x = x + m
        return x, kst, vst

    def decode_step(self, params, cache, batch):
        """batch: {'token': (b, 1) int32, 'pos': scalar int32}."""
        c = self.cfg
        x = L.embed(batch["token"], params["embed"])
        x = logical_constraint(x, ("batch", "seq", "embed"))
        pos = jnp.asarray(batch["pos"])
        kst, vst = cache["k"], cache["v"]
        slot = pos % kst.shape[3]

        def scan_body(carry, per_layer):
            xx, kc, vc = carry
            pp, i = per_layer
            xx, kc, vc = self._block_decode(pp, xx, kc, vc, i, pos, slot)
            return (xx, kc, vc), None

        (x, kst, vst), _ = jax.lax.scan(
            scan_body, (x, kst, vst), (params["layers"], jnp.arange(c.n_layers))
        )
        x = L.rms_norm(x, params["final_norm"], c.norm_eps)
        head = params.get("head")
        if head is None:
            head = params["embed"].T
        logits = L.lm_logits(x, head)[..., : c.vocab_size]
        return {"k": kst, "v": vst}, logits

    # ------------------------------------------------------------------
    # Dry-run specs
    # ------------------------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        c = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind == "train":
            text = s - (c.n_patches if c.family == "vlm" else 0)
            d: Dict[str, Any] = {
                "tokens": mod.spec((b, text), ("batch", "seq"), i32, "zeros"),
                "labels": mod.spec((b, s if c.family != "vlm" else text), ("batch", "seq"), i32, "zeros"),
                "loss_mask": mod.spec((b, s if c.family != "vlm" else text), ("batch", "seq"), jnp.float32, "ones"),
            }
            if c.family == "vlm":
                d["patch_embeds"] = mod.spec(
                    (b, c.n_patches, c.d_model), ("batch", "seq", "embed"), jnp.bfloat16
                )
            return d
        if shape.kind == "prefill":
            text = s - (c.n_patches if c.family == "vlm" else 0)
            d = {"tokens": mod.spec((b, text), ("batch", "seq"), i32, "zeros")}
            if c.family == "vlm":
                d["patch_embeds"] = mod.spec(
                    (b, c.n_patches, c.d_model), ("batch", "seq", "embed"), jnp.bfloat16
                )
            return d
        # decode: one new token against a cache of seq_len
        if c.attn_window:
            assert s % c.attn_window == 0 or s < c.attn_window, (
                "ring-buffer prefill assumes seq %% window == 0"
            )
        return {
            "token": mod.spec((b, 1), ("batch", "seq"), i32, "zeros"),
            "pos": mod.spec((), (), i32, "zeros"),
        }

    def cache_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        c = self.cfg
        b = shape.global_batch
        a = self.cache_len(shape.seq_len)
        hd = c.resolved_head_dim
        kv = (c.n_layers, b, c.n_kv_heads, a, hd)
        axes = ("layers", "cache_batch", "kv_heads", "kv_seq", None)
        return {
            "k": mod.spec(kv, axes, CACHE_DTYPE, "zeros"),
            "v": mod.spec(kv, axes, CACHE_DTYPE, "zeros"),
        }
