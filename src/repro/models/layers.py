"""Shared model layers: norms, RoPE, GQA attention (chunked online-softmax),
MLPs, embeddings, losses.

Attention notes (TPU adaptation):
  * ``attention_chunked`` is a flash-attention-equivalent formulation in pure
    ``jax.lax`` (scan over KV chunks with online softmax). It never
    materialises the full (sq, skv) score matrix, so prefill_32k compiles and
    fits; on real TPUs the Pallas kernel in ``repro.kernels.flash_attention``
    is the fast path (selected via ``use_pallas``).
  * ``attention_decode`` is a single-token dense attention over the KV cache.
    When the cache is sharded over ``kv_seq`` (mesh axis ``model``), XLA's
    SPMD partitioner turns the softmax/contraction reductions into
    flash-decoding-style partial reductions + all-reduces.
  * GQA: caches store ``n_kv_heads`` heads; KV is repeated to ``n_heads``
    per chunk at compute time (chunk-local, negligible memory).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.launch.sharding import logical_constraint

COMPUTE_DTYPE = jnp.bfloat16

# (batch, kv_heads, group, query_seq[, head_dim]) — the flash-attention
# working layout. kv_heads never divides the 16-way model axis on the
# assigned archs, so the divisibility guard routes `model` to the query dim.
_QS_AXES = ("batch", "kv_heads", None, "attn_sq", None)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, gamma: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    out = x32 * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32)).astype(dt)


def layer_norm(x: jax.Array, gamma: jax.Array, beta: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (out * gamma.astype(jnp.float32) + beta.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # (head_dim//2,)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., : hd // 2], x32[..., hd // 2 :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def _repeat_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """(b, s, hkv, d) -> (b, s, n_heads, d) by group broadcast."""
    b, s, hkv, d = k.shape
    g = n_heads // hkv
    if g == 1:
        return k
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, hkv, g, d))
    return k.reshape(b, s, n_heads, d)


def _chunk_mask(sq: int, skv: int, chunk: int, c_idx, causal: bool,
                window: Optional[int], q_offset: int):
    """(sq, chunk) validity mask for kv chunk c_idx."""
    q_pos = q_offset + jnp.arange(sq)[:, None]
    k_pos = (c_idx * chunk + jnp.arange(chunk))[None, :]
    mask = k_pos < skv  # padded keys
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    return mask


def _flash_fwd_scan(q5, k, v, causal, window, chunk, q_offset):
    """Online-softmax forward. q5: (b, sq, hkv, g, hd); k/v: (b, skv, hkv, hd).

    Returns out5 (b, sq, hkv, g, hd) and lse (b, hkv, g, sq) fp32.
    """
    b, sq, hkv, g, hd = q5.shape
    skv = k.shape[1]
    chunk = min(chunk, skv)
    pad = (-skv) % chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = (skv + pad) // chunk
    scale = 1.0 / math.sqrt(hd)
    qs = (q5.astype(COMPUTE_DTYPE) * scale).transpose(0, 2, 3, 1, 4)  # (b,k,g,sq,hd)
    qs = logical_constraint(qs, _QS_AXES)
    k_sc = k.reshape(b, n_chunks, chunk, hkv, hd).transpose(1, 0, 2, 3, 4)
    v_sc = v.reshape(b, n_chunks, chunk, hkv, hd).transpose(1, 0, 2, 3, 4)

    def body(carry, inp):
        m, l, acc = carry  # (b,k,g,sq), (b,k,g,sq), (b,k,g,sq,hd)
        k_c, v_c, c_idx = inp
        s = jnp.einsum(
            "bkgqd,bckd->bkgqc", qs, k_c.astype(COMPUTE_DTYPE),
            preferred_element_type=jnp.float32,
        )
        mask = _chunk_mask(sq, skv, chunk, c_idx, causal, window, q_offset)
        s = jnp.where(mask[None, None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        p = jnp.where(mask[None, None, None], jnp.exp(s - m_safe[..., None]), 0.0)
        corr = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - m_safe))
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bkgqc,bckd->bkgqd", p.astype(COMPUTE_DTYPE), v_c.astype(COMPUTE_DTYPE),
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    m0 = logical_constraint(jnp.full((b, hkv, g, sq), -jnp.inf, jnp.float32), _QS_AXES[:4])
    l0 = logical_constraint(jnp.zeros((b, hkv, g, sq), jnp.float32), _QS_AXES[:4])
    acc0 = logical_constraint(jnp.zeros((b, hkv, g, sq, hd), jnp.float32), _QS_AXES)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (k_sc, v_sc, jnp.arange(n_chunks)))
    l_safe = jnp.maximum(l, 1e-20)
    out = (acc / l_safe[..., None]).transpose(0, 3, 1, 2, 4)  # (b,sq,k,g,hd)
    lse = m + jnp.log(l_safe)
    return out.astype(q5.dtype), lse


from functools import partial as _partial  # noqa: E402


@_partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q5, k, v, causal=True, window=None, chunk=1024, q_offset=0):
    """Flash attention (pure-jax custom_vjp): saves only (out, lse); the
    backward re-streams KV chunks — no O(sq*skv) tensor is ever saved.
    GQA-native: q5 (b, sq, hkv, g, hd) against k/v (b, skv, hkv, hd)."""
    out, _ = _flash_fwd_scan(q5, k, v, causal, window, chunk, q_offset)
    return out


def _flash_fwd(q5, k, v, causal, window, chunk, q_offset):
    out, lse = _flash_fwd_scan(q5, k, v, causal, window, chunk, q_offset)
    return out, (q5, k, v, out, lse)


def _flash_bwd(causal, window, chunk, q_offset, res, d_out):
    q5, k, v, out, lse = res
    b, sq, hkv, g, hd = q5.shape
    skv = k.shape[1]
    chunk = min(chunk, skv)
    pad = (-skv) % chunk
    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else k
    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))) if pad else v
    n_chunks = (skv + pad) // chunk
    scale = 1.0 / math.sqrt(hd)

    qs = logical_constraint(q5.astype(COMPUTE_DTYPE).transpose(0, 2, 3, 1, 4), _QS_AXES)
    do = logical_constraint(d_out.astype(COMPUTE_DTYPE).transpose(0, 2, 3, 1, 4), _QS_AXES)
    o5 = out.astype(COMPUTE_DTYPE).transpose(0, 2, 3, 1, 4)
    delta = jnp.sum(do.astype(jnp.float32) * o5.astype(jnp.float32), axis=-1)  # (b,k,g,sq)
    k_sc = kp.reshape(b, n_chunks, chunk, hkv, hd).transpose(1, 0, 2, 3, 4)
    v_sc = vp.reshape(b, n_chunks, chunk, hkv, hd).transpose(1, 0, 2, 3, 4)

    def body(dq_acc, inp):
        k_c, v_c, c_idx = inp
        s = jnp.einsum(
            "bkgqd,bckd->bkgqc", qs * scale, k_c.astype(COMPUTE_DTYPE),
            preferred_element_type=jnp.float32,
        )
        mask = _chunk_mask(sq, skv, chunk, c_idx, causal, window, q_offset)
        p = jnp.where(mask[None, None, None], jnp.exp(s - lse[..., None]), 0.0)
        pc = p.astype(COMPUTE_DTYPE)
        dv_c = jnp.einsum("bkgqc,bkgqd->bckd", pc, do, preferred_element_type=jnp.float32)
        dp = jnp.einsum(
            "bkgqd,bckd->bkgqc", do, v_c.astype(COMPUTE_DTYPE),
            preferred_element_type=jnp.float32,
        )
        dsc = (p * (dp - delta[..., None])).astype(COMPUTE_DTYPE)
        dq_c = jnp.einsum(
            "bkgqc,bckd->bkgqd", dsc, k_c.astype(COMPUTE_DTYPE),
            preferred_element_type=jnp.float32,
        )
        dk_c = jnp.einsum("bkgqc,bkgqd->bckd", dsc, qs, preferred_element_type=jnp.float32)
        return dq_acc + dq_c, (dk_c * scale, dv_c)

    dq0 = logical_constraint(jnp.zeros((b, hkv, g, sq, hd), jnp.float32), _QS_AXES)
    dq, (dk_st, dv_st) = jax.lax.scan(body, dq0, (k_sc, v_sc, jnp.arange(n_chunks)))
    dq5 = (dq * scale).transpose(0, 3, 1, 2, 4).astype(q5.dtype)
    dk = dk_st.transpose(1, 0, 2, 3, 4).reshape(b, skv + pad, hkv, hd)[:, :skv]
    dv = dv_st.transpose(1, 0, 2, 3, 4).reshape(b, skv + pad, hkv, hd)[:, :skv]
    return dq5, dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention.defvjp(_flash_fwd, _flash_bwd)


def attention_chunked(
    q: jax.Array,  # (b, sq, hq, hd)
    k: jax.Array,  # (b, skv, hkv, hd)
    v: jax.Array,  # (b, skv, hkv, hd)
    q_offset: int = 0,
    causal: bool = True,
    window: Optional[int] = None,
    chunk: int = 1024,
) -> jax.Array:
    """Flash-attention wrapper at (b, s, heads, hd) layout (GQA handled
    natively inside — KV is never repeated at full sequence length)."""
    b, sq, hq, hd = q.shape
    hkv = k.shape[2]
    q5 = q.reshape(b, sq, hkv, hq // hkv, hd)
    out5 = flash_attention(q5, k, v, causal, window, chunk, q_offset)
    return out5.reshape(b, sq, hq, hd)


def attention_reference(
    q: jax.Array, k: jax.Array, v: jax.Array, q_offset: int = 0,
    causal: bool = True, window: Optional[int] = None,
) -> jax.Array:
    """Materialized-softmax oracle for tests."""
    b, sq, hq, hd = q.shape
    skv = k.shape[1]
    k_r = _repeat_kv(k, hq).astype(jnp.float32)
    v_r = _repeat_kv(v, hq).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32), k_r) / math.sqrt(hd)
    q_pos = q_offset + jnp.arange(sq)[:, None]
    k_pos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v_r)
    return out.astype(q.dtype)


def attention_decode(
    q: jax.Array,  # (b, 1, hq, hd)
    k_cache: jax.Array,  # (b, hkv, skv, hd) — attention-native layout
    v_cache: jax.Array,
    cache_len: jax.Array,  # (b,) or scalar — number of valid cache entries
    window: Optional[int] = None,
) -> jax.Array:
    """Single-token attention over a pre-allocated cache.

    GQA-native (KV never repeated) and layout-native (the cache is stored
    (b, hkv, skv, hd) so the QK^T / PV contractions need no transposes).
    When the cache is sharded over ``kv_seq`` (mesh axis ``model``), XLA
    partitions the max/sum/PV reductions into flash-decoding-style partial
    reductions + small all-reduces.
    """
    b, _, hq, hd = q.shape
    hkv, skv = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    scale = 1.0 / math.sqrt(hd)
    # f32 at slice level: decode is HBM-bound, and explicit casts avoid the
    # CPU backend's whole-cache bf16->f32 operand mirror (see decode_attn.py)
    qc = (q.astype(jnp.float32) * scale)[:, 0].reshape(b, hkv, g, hd)
    s = jnp.einsum("bkgd,bksd->bkgs", qc, k_cache.astype(jnp.float32))
    pos = jnp.arange(skv)[None, :]  # (1, skv)
    cl = jnp.asarray(cache_len).reshape(-1, 1)  # (b or 1, 1)
    mask = pos < cl
    if window is not None:
        mask &= pos >= (cl - window)
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.where(mask[:, None, None, :], jnp.exp(s - m), 0.0)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum(
        "bkgs,bksd->bkgd", p / jnp.maximum(l, 1e-20), v_cache.astype(jnp.float32)
    )
    return out.reshape(b, 1, hq, hd).astype(q.dtype)


def cache_store(k: jax.Array) -> jax.Array:
    """(b, s, hkv, hd) -> cache layout (b, hkv, s, hd)."""
    return k.transpose(0, 2, 1, 3)


def cache_write(cache: jax.Array, new: jax.Array, slot) -> jax.Array:
    """Write ``new`` (b, 1, hkv, hd) into cache (b, hkv, A, hd) at ``slot``."""
    return jax.lax.dynamic_update_slice_in_dim(
        cache, cache_store(new).astype(cache.dtype), slot, axis=2
    )


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def mlp_swiglu(x, w_gate, w_up, w_down):
    h = jnp.einsum("bsd,df->bsf", x, w_gate.astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, w_up.astype(x.dtype))
    h = jax.nn.silu(h.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("bsf,fd->bsd", h, w_down.astype(x.dtype))


def mlp_gelu(x, w_up, w_down, b_up=None, b_down=None):
    h = jnp.einsum("bsd,df->bsf", x, w_up.astype(x.dtype))
    if b_up is not None:
        h = h + b_up.astype(h.dtype)
    h = jax.nn.gelu(h.astype(jnp.float32), approximate=True).astype(x.dtype)
    out = jnp.einsum("bsf,fd->bsd", h, w_down.astype(x.dtype))
    if b_down is not None:
        out = out + b_down.astype(out.dtype)
    return out


# ---------------------------------------------------------------------------
# Embedding / head / loss
# ---------------------------------------------------------------------------


def embed(tokens: jax.Array, table: jax.Array) -> jax.Array:
    """tokens (b, s) int32 -> (b, s, d). Gather; XLA partitions sharded vocab."""
    return jnp.take(table, tokens, axis=0).astype(COMPUTE_DTYPE)


def lm_logits(x: jax.Array, head: jax.Array) -> jax.Array:
    """x (b, s, d) @ head (d, vocab) -> (b, s, vocab)."""
    return jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype))


def softmax_xent(
    logits: jax.Array,
    labels: jax.Array,
    mask: Optional[jax.Array] = None,
    valid_vocab: Optional[int] = None,
):
    """Mean next-token cross entropy. logits (b, s, v) / labels (b, s).

    ``valid_vocab`` masks padded vocab columns (vocab padded for sharding).
    """
    logits32 = logits.astype(jnp.float32)
    if valid_vocab is not None and valid_vocab < logits.shape[-1]:
        col = jax.lax.broadcasted_iota(jnp.int32, logits32.shape, logits32.ndim - 1)
        logits32 = jnp.where(col < valid_vocab, logits32, -jnp.inf)
    lse = jax.nn.logsumexp(logits32, axis=-1)
    label_logit = jnp.take_along_axis(logits32, labels[..., None], axis=-1)[..., 0]
    nll = lse - label_logit
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def update_cache(cache: jax.Array, new: jax.Array, index: jax.Array) -> jax.Array:
    """Write ``new`` (b, 1, h, d) into ``cache`` (b, S, h, d) at ``index``."""
    return jax.lax.dynamic_update_slice_in_dim(cache, new.astype(cache.dtype), index, axis=1)
