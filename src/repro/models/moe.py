"""Capacity-based Mixture-of-Experts layer.

Two dispatch implementations:

  * ``dispatch="scatter"`` (default) — positions computed by cumsum over the
    routing one-hots, tokens moved with scatter-add / gather. O(tokens * d)
    data movement, no O(tokens^2) matmul. Gradients are the dual
    gather/scatter, equally cheap.
  * ``dispatch="einsum"`` — the classic mesh-TF / MaxText one-hot-matmul
    formulation. O(tokens * E*C * d) per group: measured ~8x the expert
    FFN compute itself on mixtral-8x22b train_4k (see EXPERIMENTS.md §Perf —
    kept as the measured baseline of that hillclimb step).

Expert FFN weights are sharded ``mlp -> model``; the expert dim is guarded
(8 / 40 experts do not divide the 16-way model axis — DESIGN.md
§Arch-applicability).
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.launch.sharding import logical_constraint


def moe_capacity(tokens_per_group: int, top_k: int, n_experts: int, cf: float) -> int:
    c = math.ceil(tokens_per_group * top_k * cf / n_experts)
    return max(4, min(c, tokens_per_group * top_k))


def _route(x, router_w, top_k):
    """Returns (gate (b,s,k) f32, idx (b,s,k) i32, aux scalar)."""
    e = router_w.shape[-1]
    logits = jnp.einsum("bsd,de->bse", x, router_w.astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, top_k)
    gate = gate / jnp.maximum(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)
    density = jnp.mean(probs, axis=(0, 1))
    frac = jnp.mean(jax.nn.one_hot(idx[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    aux = e * jnp.sum(density * frac)
    return gate, idx, aux


def _positions(idx, e, top_k, cap):
    """Capacity slots per (token, k): (pos (b,t), keep (b,t)) with t = s*k."""
    b, s, k = idx.shape
    oh = jax.nn.one_hot(idx, e, dtype=jnp.float32).reshape(b, s * k, e)
    pos_in_e = jnp.cumsum(oh, axis=1) - oh
    pos = jnp.sum(pos_in_e * oh, axis=-1)  # (b, t)
    keep = pos < cap
    return pos.astype(jnp.int32), keep


def _expert_ffn(dispatched, w_gate, w_up, w_down):
    # dispatched: batch over data, d replicated — the d-contraction is local
    # and becf comes out f-sharded from the weights (no per-matmul psum)
    dispatched = logical_constraint(dispatched, ("batch", "expert", None, "embed"))
    h = jnp.einsum("becd,edf->becf", dispatched, w_gate.astype(dispatched.dtype))
    u = jnp.einsum("becd,edf->becf", dispatched, w_up.astype(dispatched.dtype))
    h = logical_constraint(h, ("batch", "expert", None, "mlp"))
    h = jax.nn.silu(h.astype(jnp.float32)).astype(dispatched.dtype) * u
    out = jnp.einsum("becf,efd->becd", h, w_down.astype(dispatched.dtype))
    return logical_constraint(out, ("batch", "expert", None, "embed"))


def moe_layer(
    x: jax.Array,  # (b, s, d)
    router_w: jax.Array,  # (d, E)
    w_gate: jax.Array,  # (E, d, f)
    w_up: jax.Array,
    w_down: jax.Array,  # (E, f, d)
    top_k: int,
    capacity_factor: float = 1.25,
    dispatch: str = "scatter",
) -> Tuple[jax.Array, jax.Array]:
    """Returns (out (b, s, d), aux_loss scalar)."""
    b, s, d = x.shape
    e = router_w.shape[-1]
    cap = moe_capacity(s, top_k, e, capacity_factor)
    gate, idx, aux = _route(x, router_w, top_k)

    if dispatch == "einsum":
        return _moe_einsum(x, gate, idx, aux, w_gate, w_up, w_down, cap, e, top_k)

    pos, keep = _positions(idx, e, top_k, cap)  # (b, t)
    t = s * top_k
    idx_f = idx.reshape(b, t)
    gate_f = gate.reshape(b, t) * keep
    x_rep = jnp.broadcast_to(x[:, :, None, :], (b, s, top_k, d)).reshape(b, t, d)
    val = x_rep * keep[..., None].astype(x.dtype)

    # vmap over the (data-sharded) batch dim so the scatter/gather carry an
    # explicit batching dim — the SPMD partitioner keeps them batch-local
    # instead of replicating (global-index scatter forces all-gathers).
    def scatter_one(vv, ii, pp):
        return jnp.zeros((e, cap, d), x.dtype).at[ii, pp].add(vv, mode="drop")

    dispatched = jax.vmap(scatter_one)(val, idx_f, pos)

    expert_out = _expert_ffn(dispatched, w_gate, w_up, w_down)

    gathered = jax.vmap(lambda eo, ii, pp: eo[ii, pp])(expert_out, idx_f, pos)
    out = (gathered * gate_f[..., None].astype(x.dtype)).reshape(b, s, top_k, d).sum(axis=2)
    return out, aux.astype(jnp.float32)


def _moe_einsum(x, gate, idx, aux, w_gate, w_up, w_down, cap, e, top_k):
    b, s, d = x.shape
    t = s * top_k
    pos, keep = _positions(idx, e, top_k, cap)
    oh_f = jax.nn.one_hot(idx, e, dtype=jnp.float32).reshape(b, t, e)
    gate_f = gate.reshape(b, t) * keep
    cap_oh = jax.nn.one_hot(pos, cap, dtype=x.dtype)
    disp = (oh_f * keep[..., None]).astype(x.dtype)
    x_rep = jnp.broadcast_to(x[:, :, None, :], (b, s, top_k, d)).reshape(b, t, d)
    dispatched = jnp.einsum("bte,btc,btd->becd", disp, cap_oh, x_rep)
    expert_out = _expert_ffn(dispatched, w_gate, w_up, w_down)
    combined = jnp.einsum(
        "becd,bte,btc,bt->btd", expert_out, disp, cap_oh, gate_f.astype(x.dtype)
    )
    out = combined.reshape(b, s, top_k, d).sum(axis=2)
    return out, aux.astype(jnp.float32)
