"""RecurrentGemma / Griffin hybrid LM [arXiv:2402.19427].

Layer pattern per super-block: (rec, rec, attn) — two RG-LRU recurrent
blocks then one local-MQA-attention block, each followed by an MLP. 38
layers = 12 scanned super-blocks + a 2-layer recurrent tail. The RG-LRU is
a gated diagonal linear recurrence evaluated with
``jax.lax.associative_scan`` (log-depth, TPU-friendly); gates are diagonal
(per-channel) — documented simplification vs. the paper's block-diagonal
projections. Decode keeps O(1) recurrent state + a window-sized attention
ring, which makes ``long_500k`` feasible.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.sharding import logical_constraint
from repro.models import layers as L
from repro.models import module as mod
from repro.models.decode_attn import decode_attention
from repro.models.transformer import remat_wrap, CACHE_DTYPE

LRU_C = 8.0
STATE_DTYPE = jnp.float32


def rglru_scan(x: jax.Array, a: jax.Array, init: jax.Array | None = None):
    """h_t = a_t h_{t-1} + x_t via associative scan. x, a: (b, s, w)."""
    if init is not None:
        # fold the initial state into the first step
        x = x.at[:, 0].add(a[:, 0] * init)
    def op(l, r):
        al, xl = l
        ar, xr = r
        return al * ar, xl * ar + xr
    _, h = jax.lax.associative_scan(op, (a, x), axis=1)
    return h


def rglru(x: jax.Array, lam, gx_w, gx_b, ga_w, ga_b, init=None):
    """RG-LRU (diagonal gates). x: (b, s, w) -> (h, last_state)."""
    x32 = x.astype(jnp.float32)
    i_t = jax.nn.sigmoid(x32 * gx_w + gx_b)
    r_t = jax.nn.sigmoid(x32 * ga_w + ga_b)
    log_a = -LRU_C * jax.nn.softplus(lam.astype(jnp.float32)) * r_t
    a_t = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i_t * x32)
    h = rglru_scan(gated, a_t, init)
    return h.astype(x.dtype), h[:, -1]


def rglru_step(state, x, lam, gx_w, gx_b, ga_w, ga_b):
    """Single decode step. state, x: (b, w)."""
    x32 = x.astype(jnp.float32)
    i_t = jax.nn.sigmoid(x32 * gx_w + gx_b)
    r_t = jax.nn.sigmoid(x32 * ga_w + ga_b)
    log_a = -LRU_C * jax.nn.softplus(lam.astype(jnp.float32)) * r_t
    a_t = jnp.exp(log_a)
    h = a_t * state + jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i_t * x32)
    return h, h.astype(x.dtype)


class GriffinLM:
    def __init__(self, cfg: ModelConfig, remat_policy: str = "full"):
        self.cfg = cfg
        self.remat_policy = remat_policy
        pat = cfg.hybrid_pattern or ("rec", "rec", "attn")
        self.pattern = pat
        self.n_super = cfg.n_layers // len(pat)
        self.n_tail = cfg.n_layers - self.n_super * len(pat)
        # tail layers follow the pattern prefix (all 'rec' for 38 = 12*3 + 2)
        self.tail_kinds = pat[: self.n_tail]
        assert all(k == "rec" for k in self.tail_kinds), "tail must be recurrent"
        self.rec_per_super = sum(1 for k in pat if k == "rec")
        self.attn_per_super = sum(1 for k in pat if k == "attn")

    # ------------------------------------------------------------------
    def _rec_specs(self, n: int, prefix_axis="layers") -> Dict[str, mod.ParamSpec]:
        c = self.cfg
        d, w = c.d_model, (c.lru_width or c.d_model)
        sp = lambda shape, axes, **kw: mod.spec((n,) + shape, (prefix_axis,) + axes, **kw)
        return {
            "norm1": sp((d,), ("embed",), init="ones"),
            "w_x": sp((d, w), ("embed", "lru"), init="scaled"),
            "w_y": sp((d, w), ("embed", "lru"), init="scaled"),
            "conv_w": sp((w, 4), ("lru", "conv"), init="scaled"),
            "conv_b": sp((w,), ("lru",), init="zeros"),
            "lam": sp((w,), ("lru",), init="normal", scale=0.5),
            "gx_w": sp((w,), ("lru",), init="ones"),
            "gx_b": sp((w,), ("lru",), init="zeros"),
            "ga_w": sp((w,), ("lru",), init="ones"),
            "ga_b": sp((w,), ("lru",), init="zeros"),
            "w_out": sp((w, d), ("lru", "embed"), init="scaled"),
            "norm2": sp((d,), ("embed",), init="ones"),
            "wg": sp((d, c.d_ff), ("embed", "mlp"), init="scaled"),
            "wu": sp((d, c.d_ff), ("embed", "mlp"), init="scaled"),
            "wd": sp((c.d_ff, d), ("mlp", "embed"), init="scaled"),
        }

    def _attn_specs(self, n: int) -> Dict[str, mod.ParamSpec]:
        c = self.cfg
        d, hd = c.d_model, c.resolved_head_dim
        qd, kvd = c.n_heads * hd, c.n_kv_heads * hd
        sp = lambda shape, axes, **kw: mod.spec((n,) + shape, ("layers",) + axes, **kw)
        return {
            "norm1": sp((d,), ("embed",), init="ones"),
            "wq": sp((d, qd), ("embed", "heads"), init="scaled"),
            "wk": sp((d, kvd), ("embed", "kv_heads"), init="scaled"),
            "wv": sp((d, kvd), ("embed", "kv_heads"), init="scaled"),
            "wo": sp((qd, d), ("heads", "embed"), init="scaled"),
            "norm2": sp((d,), ("embed",), init="ones"),
            "wg": sp((d, c.d_ff), ("embed", "mlp"), init="scaled"),
            "wu": sp((d, c.d_ff), ("embed", "mlp"), init="scaled"),
            "wd": sp((c.d_ff, d), ("mlp", "embed"), init="scaled"),
        }

    def param_specs(self):
        c = self.cfg
        p: Dict[str, Any] = {
            "embed": mod.spec((c.padded_vocab, c.d_model), ("vocab", "embed")),
            "final_norm": mod.spec((c.d_model,), ("embed",), init="ones"),
            "head": mod.spec((c.d_model, c.padded_vocab), ("embed", "vocab"), init="scaled"),
            "super": {
                "rec0": self._rec_specs(self.n_super),
                "rec1": self._rec_specs(self.n_super),
                "attn": self._attn_specs(self.n_super),
            },
        }
        if self.n_tail:
            p["tail"] = self._rec_specs(self.n_tail)
        return p

    def init_params(self, key):
        return mod.init_tree(self.param_specs(), key)

    # ------------------------------------------------------------------
    def _mlp(self, p, x):
        h = L.rms_norm(x, p["norm2"], self.cfg.norm_eps)
        return x + L.mlp_swiglu(h, p["wg"], p["wu"], p["wd"])

    def _rec_block(self, p, x, mode, state=None):
        c = self.cfg
        h = L.rms_norm(x, p["norm1"], c.norm_eps)
        b1 = jnp.einsum("bsd,dw->bsw", h, p["w_x"].astype(h.dtype))
        b2 = jnp.einsum("bsd,dw->bsw", h, p["w_y"].astype(h.dtype))
        b2 = jax.nn.gelu(b2.astype(jnp.float32), approximate=True).astype(h.dtype)
        if mode == "decode":
            conv_state, lru_state = state  # (b, 3, w), (b, w)
            seq = jnp.concatenate([conv_state.astype(b1.dtype), b1], axis=1)
            from repro.models.mamba2 import causal_conv1d
            conv_out = causal_conv1d(seq, p["conv_w"], p["conv_b"])[:, -1]
            new_conv = seq[:, 1:].astype(conv_state.dtype)
            lru_state, y = rglru_step(
                lru_state, conv_out, p["lam"], p["gx_w"], p["gx_b"], p["ga_w"], p["ga_b"]
            )
            y = y[:, None]  # (b, 1, w)
            new_state = (new_conv, lru_state)
        else:
            from repro.models.mamba2 import causal_conv1d
            conv_out = causal_conv1d(b1, p["conv_w"], p["conv_b"])
            y, last = rglru(
                conv_out, p["lam"], p["gx_w"], p["gx_b"], p["ga_w"], p["ga_b"]
            )
            if mode == "train":
                new_state = None
            else:  # prefill: emit decode-ready state
                conv_tail = b1[:, -3:].astype(STATE_DTYPE)
                new_state = (conv_tail, last)
        merged = y * b2
        out = jnp.einsum("bsw,wd->bsd", merged, p["w_out"].astype(x.dtype))
        x = logical_constraint(x + out, ("batch", "seq", "embed"))
        return self._mlp(p, x), new_state

    def _attn_block(self, p, x, positions, mode, state=None, pos=None):
        c = self.cfg
        hd = c.resolved_head_dim
        h = L.rms_norm(x, p["norm1"], c.norm_eps)
        b, s, _ = h.shape
        q = jnp.einsum("bsd,dq->bsq", h, p["wq"].astype(h.dtype)).reshape(b, s, c.n_heads, hd)
        k = jnp.einsum("bsd,dq->bsq", h, p["wk"].astype(h.dtype)).reshape(b, s, c.n_kv_heads, hd)
        v = jnp.einsum("bsd,dq->bsq", h, p["wv"].astype(h.dtype)).reshape(b, s, c.n_kv_heads, hd)
        q = L.apply_rope(q, positions, c.rope_theta)
        k = L.apply_rope(k, positions, c.rope_theta)
        if mode == "decode":
            kst, vst, i = state  # stacked (n_super, b, hkv, A, hd)
            attn, kst, vst = decode_attention(q, k, v, kst, vst, i, pos)
            new_state = (kst, vst)
        else:
            attn = L.attention_chunked(q, k, v, causal=True, window=c.local_window)
            if mode == "train":
                new_state = None
            else:
                a = min(s, c.local_window)
                new_state = (
                    L.cache_store(k[:, -a:]).astype(CACHE_DTYPE),
                    L.cache_store(v[:, -a:]).astype(CACHE_DTYPE),
                )
        attn = jnp.einsum("bsq,qd->bsd", attn.reshape(b, s, -1), p["wo"].astype(x.dtype))
        x = logical_constraint(x + attn, ("batch", "seq", "embed"))
        return self._mlp(p, x), new_state

    # ------------------------------------------------------------------
    def _super_block(self, p, x, positions, mode, state=None, pos=None):
        """train/prefill super-block (decode is handled in _forward)."""
        st = state or {}
        x, s0 = self._rec_block(p["rec0"], x, mode, st.get("rec0"))
        x, s1 = self._rec_block(p["rec1"], x, mode, st.get("rec1"))
        x, sa = self._attn_block(p["attn"], x, positions, mode, st.get("attn"), pos)
        return x, {"rec0": s0, "rec1": s1, "attn": sa}

    def _forward(self, params, x, positions, mode, cache=None, pos=None):
        """Shared over train/prefill/decode. Returns (x, new_cache)."""
        if mode == "decode":
            kst, vst = cache["super"]["attn"]
            rec_st = {"rec0": cache["super"]["rec0"], "rec1": cache["super"]["rec1"]}

            def scan_dec(carry, per):
                xx, kc, vc = carry
                pp, rst, i = per
                xx, s0 = self._rec_block(pp["rec0"], xx, mode, rst["rec0"])
                xx, s1 = self._rec_block(pp["rec1"], xx, mode, rst["rec1"])
                xx, (kc, vc) = self._attn_block(
                    pp["attn"], xx, positions, mode, (kc, vc, i), pos
                )
                return (xx, kc, vc), {"rec0": s0, "rec1": s1}

            (x, kst, vst), new_rec = jax.lax.scan(
                scan_dec, (x, kst, vst),
                (params["super"], rec_st, jnp.arange(self.n_super)),
            )
            new_super = {**new_rec, "attn": (kst, vst)}
        else:
            blk = remat_wrap(
                lambda xx, pp: self._super_block(pp, xx, positions, mode, None, pos),
                self.remat_policy,
            )

            def scan_train(xx, pp):
                xx, new_st = blk(xx, pp)
                return xx, new_st

            x, new_super = jax.lax.scan(scan_train, x, params["super"])

        new_tail = None
        if self.n_tail:
            tails = []
            for i in range(self.n_tail):
                pp = jax.tree.map(lambda a: a[i], params["tail"])
                t_st = None
                if mode == "decode":
                    t_st = jax.tree.map(lambda a: a[i], cache["tail"])
                x, t_new = self._rec_block(pp, x, mode, t_st)
                tails.append(t_new)
            new_tail = jax.tree.map(lambda *xs: jnp.stack(xs), *tails)
        return x, {"super": new_super, "tail": new_tail}

    # ------------------------------------------------------------------
    def loss_fn(self, params, batch):
        c = self.cfg
        x = L.embed(batch["tokens"], params["embed"])
        x = logical_constraint(x, ("batch", "seq", "embed"))
        positions = jnp.arange(x.shape[1])
        x, _ = self._forward(params, x, positions, "train")
        x = L.rms_norm(x, params["final_norm"], c.norm_eps)
        logits = L.lm_logits(x, params["head"])
        logits = logical_constraint(logits, ("batch", "seq", "vocab"))
        loss = L.softmax_xent(logits, batch["labels"], batch.get("loss_mask"), valid_vocab=c.vocab_size)
        return loss, {"xent": loss}

    def prefill(self, params, batch, cache_budget: int = 0):
        # local-attention caches are windowed rings: no budget needed
        c = self.cfg
        x = L.embed(batch["tokens"], params["embed"])
        positions = jnp.arange(x.shape[1])
        x, cache = self._forward(params, x, positions, "prefill")
        x = L.rms_norm(x, params["final_norm"], c.norm_eps)
        logits = L.lm_logits(x[:, -1:], params["head"])[..., : c.vocab_size]
        return cache, logits

    def decode_step(self, params, cache, batch):
        c = self.cfg
        x = L.embed(batch["token"], params["embed"])
        pos = batch["pos"]
        positions = jnp.asarray(pos)[None]
        x, cache = self._forward(params, x, positions, "decode", cache, pos)
        x = L.rms_norm(x, params["final_norm"], c.norm_eps)
        logits = L.lm_logits(x, params["head"])[..., : c.vocab_size]
        return cache, logits

    # ------------------------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind == "train":
            return {
                "tokens": mod.spec((b, s), ("batch", "seq"), i32, "zeros"),
                "labels": mod.spec((b, s), ("batch", "seq"), i32, "zeros"),
                "loss_mask": mod.spec((b, s), ("batch", "seq"), jnp.float32, "ones"),
            }
        if shape.kind == "prefill":
            return {"tokens": mod.spec((b, s), ("batch", "seq"), i32, "zeros")}
        return {
            "token": mod.spec((b, 1), ("batch", "seq"), i32, "zeros"),
            "pos": mod.spec((), (), i32, "zeros"),
        }

    def cache_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        c = self.cfg
        b = shape.global_batch
        w = c.lru_width or c.d_model
        a = min(shape.seq_len, c.local_window)
        hd = c.resolved_head_dim
        n = self.n_super
        rec = lambda nn: (
            mod.spec((nn, b, 3, w), ("layers", "cache_batch", None, "lru"), STATE_DTYPE, "zeros"),
            mod.spec((nn, b, w), ("layers", "cache_batch", "lru"), STATE_DTYPE, "zeros"),
        )
        attn = (
            mod.spec((n, b, c.n_kv_heads, a, hd), ("layers", "cache_batch", "kv_heads", "kv_seq", None), CACHE_DTYPE, "zeros"),
            mod.spec((n, b, c.n_kv_heads, a, hd), ("layers", "cache_batch", "kv_heads", "kv_seq", None), CACHE_DTYPE, "zeros"),
        )
        out: Dict[str, Any] = {
            "super": {"rec0": rec(n), "rec1": rec(n), "attn": attn}
        }
        out["tail"] = rec(self.n_tail) if self.n_tail else None
        return out
