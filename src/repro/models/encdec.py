"""Whisper-style encoder-decoder [arXiv:2212.04356].

The conv/mel frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings (b, src_len, d_model). Encoder is
non-causal self-attention; decoder is causal self-attention + cross
attention with learned positional embeddings and GELU MLPs (biases on QKV
per the reference implementation).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch.sharding import logical_constraint
from repro.models import layers as L
from repro.models import module as mod
from repro.models.decode_attn import decode_attention
from repro.models.transformer import remat_wrap, CACHE_DTYPE


class EncDecLM:
    def __init__(self, cfg: ModelConfig, remat_policy: str = "full"):
        self.cfg = cfg
        self.remat_policy = remat_policy

    # ------------------------------------------------------------------
    def _attn_specs(self, n: int, prefix: str = "layers") -> Dict[str, mod.ParamSpec]:
        c = self.cfg
        d = c.d_model
        hd = c.resolved_head_dim
        qd, kvd = c.n_heads * hd, c.n_kv_heads * hd
        sp = lambda shape, axes, **kw: mod.spec((n,) + shape, (prefix,) + axes, **kw)
        out = {
            "wq": sp((d, qd), ("embed", "heads"), init="scaled"),
            "wk": sp((d, kvd), ("embed", "kv_heads"), init="scaled"),
            "wv": sp((d, kvd), ("embed", "kv_heads"), init="scaled"),
            "wo": sp((qd, d), ("heads", "embed"), init="scaled"),
            "bq": sp((qd,), ("heads",), init="zeros"),
            "bv": sp((kvd,), ("kv_heads",), init="zeros"),
        }
        return out

    def _mlp_specs(self, n: int) -> Dict[str, mod.ParamSpec]:
        c = self.cfg
        sp = lambda shape, axes, **kw: mod.spec((n,) + shape, ("layers",) + axes, **kw)
        return {
            "wu": sp((c.d_model, c.d_ff), ("embed", "mlp"), init="scaled"),
            "wd": sp((c.d_ff, c.d_model), ("mlp", "embed"), init="scaled"),
            "bu": sp((c.d_ff,), ("mlp",), init="zeros"),
            "bd": sp((c.d_model,), ("embed",), init="zeros"),
        }

    def _norm(self, n: int, name: str) -> Dict[str, mod.ParamSpec]:
        d = self.cfg.d_model
        return {
            f"{name}_g": mod.spec((n, d), ("layers", "embed"), init="ones"),
            f"{name}_b": mod.spec((n, d), ("layers", "embed"), init="zeros"),
        }

    def param_specs(self):
        c = self.cfg
        enc_layer = {**self._attn_specs(c.n_enc_layers), **self._mlp_specs(c.n_enc_layers)}
        enc_layer.update(self._norm(c.n_enc_layers, "ln1"))
        enc_layer.update(self._norm(c.n_enc_layers, "ln2"))
        dec_layer = {
            "self": self._attn_specs(c.n_layers),
            "cross": self._attn_specs(c.n_layers),
            **self._mlp_specs(c.n_layers),
        }
        dec_layer.update(self._norm(c.n_layers, "ln1"))
        dec_layer.update(self._norm(c.n_layers, "ln2"))
        dec_layer.update(self._norm(c.n_layers, "ln3"))
        return {
            "enc_pos": mod.spec((c.src_len, c.d_model), ("src", "embed")),
            "enc_layers": enc_layer,
            "enc_norm_g": mod.spec((c.d_model,), ("embed",), init="ones"),
            "enc_norm_b": mod.spec((c.d_model,), ("embed",), init="zeros"),
            "embed": mod.spec((c.padded_vocab, c.d_model), ("vocab", "embed")),
            "dec_pos": mod.spec((32768, c.d_model), (None, "embed")),
            "dec_layers": dec_layer,
            "dec_norm_g": mod.spec((c.d_model,), ("embed",), init="ones"),
            "dec_norm_b": mod.spec((c.d_model,), ("embed",), init="zeros"),
            "head": mod.spec((c.d_model, c.padded_vocab), ("embed", "vocab"), init="scaled"),
        }

    def init_params(self, key):
        return mod.init_tree(self.param_specs(), key)

    # ------------------------------------------------------------------
    def _proj_qkv(self, p, xq, xkv):
        c = self.cfg
        hd = c.resolved_head_dim
        b, sq, _ = xq.shape
        skv = xkv.shape[1]
        q = (jnp.einsum("bsd,dq->bsq", xq, p["wq"].astype(xq.dtype)) + p["bq"].astype(xq.dtype))
        k = jnp.einsum("bsd,dq->bsq", xkv, p["wk"].astype(xq.dtype))
        v = (jnp.einsum("bsd,dq->bsq", xkv, p["wv"].astype(xq.dtype)) + p["bv"].astype(xq.dtype))
        return (
            q.reshape(b, sq, c.n_heads, hd),
            k.reshape(b, skv, c.n_kv_heads, hd),
            v.reshape(b, skv, c.n_kv_heads, hd),
        )

    def _enc_layer(self, p, x):
        c = self.cfg
        h = L.layer_norm(x, p["ln1_g"], p["ln1_b"], c.norm_eps)
        q, k, v = self._proj_qkv(p, h, h)
        attn = L.attention_chunked(q, k, v, causal=False)
        x = x + jnp.einsum("bsq,qd->bsd", attn.reshape(*attn.shape[:2], -1), p["wo"].astype(x.dtype))
        h = L.layer_norm(x, p["ln2_g"], p["ln2_b"], c.norm_eps)
        x = x + L.mlp_gelu(h, p["wu"], p["wd"], p["bu"], p["bd"])
        return logical_constraint(x, ("batch", "seq", "embed"))

    def encode(self, params, frames):
        """frames: (b, src_len, d_model) precomputed embeddings (stub frontend)."""
        c = self.cfg
        x = (frames.astype(L.COMPUTE_DTYPE) + params["enc_pos"].astype(L.COMPUTE_DTYPE))
        enc = remat_wrap(lambda xx, pp: self._enc_layer(pp, xx), self.remat_policy)
        x, _ = jax.lax.scan(lambda xx, pp: (enc(xx, pp), None), x, params["enc_layers"])
        return L.layer_norm(x, params["enc_norm_g"], params["enc_norm_b"], c.norm_eps)

    def _dec_layer(self, p, x, enc_out, positions, mode, kv=None, pos=None, a_alloc=0):
        c = self.cfg
        h = L.layer_norm(x, p["ln1_g"], p["ln1_b"], c.norm_eps)
        q, k, v = self._proj_qkv(p["self"], h, h)
        if mode == "decode":
            kst, vst, i = kv  # stacked (L, b, hkv, A, hd) carried through scan
            attn, kst, vst = decode_attention(q, k, v, kst, vst, i, pos)
            new_kv = (kst, vst)
        else:
            attn = L.attention_chunked(q, k, v, causal=True)
            if mode == "prefill":
                pad = ((0, 0), (0, max(a_alloc - k.shape[1], 0)), (0, 0), (0, 0))
                new_kv = (
                    L.cache_store(jnp.pad(k, pad)).astype(CACHE_DTYPE),
                    L.cache_store(jnp.pad(v, pad)).astype(CACHE_DTYPE),
                )
            else:
                new_kv = None
        x = x + jnp.einsum("bsq,qd->bsd", attn.reshape(*attn.shape[:2], -1), p["self"]["wo"].astype(x.dtype))

        h = L.layer_norm(x, p["ln2_g"], p["ln2_b"], c.norm_eps)
        q2, k2, v2 = self._proj_qkv(p["cross"], h, enc_out)
        cross = L.attention_chunked(q2, k2, v2, causal=False)
        x = x + jnp.einsum("bsq,qd->bsd", cross.reshape(*cross.shape[:2], -1), p["cross"]["wo"].astype(x.dtype))

        h = L.layer_norm(x, p["ln3_g"], p["ln3_b"], c.norm_eps)
        x = x + L.mlp_gelu(h, p["wu"], p["wd"], p["bu"], p["bd"])
        return logical_constraint(x, ("batch", "seq", "embed")), new_kv

    def _decoder(self, params, tokens, enc_out, start_pos, mode, cache=None, pos=None, a_alloc=0):
        c = self.cfg
        x = L.embed(tokens, params["embed"])
        s = tokens.shape[1]
        positions = start_pos + jnp.arange(s)
        # learned positions, clamped at the table edge (decode beyond table
        # length only occurs for the out-of-spec decode_32k cell on whisper)
        pe = jnp.take(
            params["dec_pos"], jnp.minimum(positions, params["dec_pos"].shape[0] - 1), axis=0
        )
        x = x + pe.astype(x.dtype)
        x = logical_constraint(x, ("batch", "seq", "embed"))
        dec = remat_wrap(
            lambda xx, args: self._dec_layer(
                args[0], xx, enc_out, positions, mode, args[1], pos, a_alloc
            ),
            self.remat_policy if mode != "decode" else "none",
        )

        if mode == "decode":
            def body(carry, per):
                xx, kc, vc = carry
                pp, i = per
                xx, (kc, vc) = dec(xx, (pp, (kc, vc, i)))
                return (xx, kc, vc), None
            (x, kc, vc), _ = jax.lax.scan(
                body, (x, cache["k"], cache["v"]),
                (params["dec_layers"], jnp.arange(c.n_layers)),
            )
            kvs = (kc, vc)
        else:
            def body(xx, pp):
                xx, kv = dec(xx, (pp, None))
                return xx, kv
            x, kvs = jax.lax.scan(body, x, params["dec_layers"])
        x = L.layer_norm(x, params["dec_norm_g"], params["dec_norm_b"], c.norm_eps)
        return x, kvs

    # ------------------------------------------------------------------
    def loss_fn(self, params, batch):
        enc_out = self.encode(params, batch["frames"])
        x, _ = self._decoder(params, batch["tokens"], enc_out, 0, "train")
        logits = L.lm_logits(x, params["head"])
        logits = logical_constraint(logits, ("batch", "seq", "vocab"))
        loss = L.softmax_xent(logits, batch["labels"], batch.get("loss_mask"), valid_vocab=self.cfg.vocab_size)
        return loss, {"xent": loss}

    def prefill(self, params, batch, cache_budget: int = 0):
        enc_out = self.encode(params, batch["frames"])
        a_alloc = batch["tokens"].shape[1] + cache_budget
        x, kvs = self._decoder(
            params, batch["tokens"], enc_out, 0, "prefill", a_alloc=a_alloc
        )
        logits = L.lm_logits(x[:, -1:], params["head"])[..., : self.cfg.vocab_size]
        cache = {"k": kvs[0], "v": kvs[1], "enc_out": enc_out.astype(CACHE_DTYPE)}
        return cache, logits

    def decode_step(self, params, cache, batch):
        enc_out = cache["enc_out"].astype(L.COMPUTE_DTYPE)
        pos = batch["pos"]
        x, kvs = self._decoder(
            params, batch["token"], enc_out, jnp.asarray(pos), "decode", cache, pos
        )
        logits = L.lm_logits(x, params["head"])[..., : self.cfg.vocab_size]
        return {"k": kvs[0], "v": kvs[1], "enc_out": cache["enc_out"]}, logits

    # ------------------------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        c = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        frames = mod.spec((b, c.src_len, c.d_model), ("batch", "src", "embed"), jnp.bfloat16)
        if shape.kind == "train":
            return {
                "frames": frames,
                "tokens": mod.spec((b, s), ("batch", "seq"), i32, "zeros"),
                "labels": mod.spec((b, s), ("batch", "seq"), i32, "zeros"),
                "loss_mask": mod.spec((b, s), ("batch", "seq"), jnp.float32, "ones"),
            }
        if shape.kind == "prefill":
            return {
                "frames": frames,
                "tokens": mod.spec((b, s), ("batch", "seq"), i32, "zeros"),
            }
        return {
            "token": mod.spec((b, 1), ("batch", "seq"), i32, "zeros"),
            "pos": mod.spec((), (), i32, "zeros"),
        }

    def cache_specs(self, shape: ShapeConfig) -> Dict[str, Any]:
        c = self.cfg
        b = shape.global_batch
        hd = c.resolved_head_dim
        kv = (c.n_layers, b, c.n_kv_heads, shape.seq_len, hd)
        axes = ("layers", "cache_batch", "kv_heads", "kv_seq", None)
        return {
            "k": mod.spec(kv, axes, CACHE_DTYPE, "zeros"),
            "v": mod.spec(kv, axes, CACHE_DTYPE, "zeros"),
            "enc_out": mod.spec(
                (b, c.src_len, c.d_model), ("cache_batch", "src", "embed"), CACHE_DTYPE, "zeros"
            ),
        }
