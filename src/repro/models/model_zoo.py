"""Uniform model construction: ``build_model(cfg)`` -> family implementation.

All families expose the same API (see transformer.py docstring):
param_specs / init_params / loss_fn / prefill / decode_step /
input_specs / cache_specs.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.models.encdec import EncDecLM
from repro.models.mamba2 import Mamba2LM
from repro.models.rglru import GriffinLM
from repro.models.transformer import TransformerLM


def build_model(cfg: ModelConfig, remat_policy: str = "full"):
    if cfg.family in ("dense", "moe", "vlm"):
        return TransformerLM(cfg, remat_policy)
    if cfg.family == "ssm":
        return Mamba2LM(cfg, remat_policy)
    if cfg.family == "hybrid":
        return GriffinLM(cfg, remat_policy)
    if cfg.family == "encdec":
        return EncDecLM(cfg, remat_policy)
    raise ValueError(f"unknown family {cfg.family!r}")
