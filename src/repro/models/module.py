"""Minimal parameter-spec module system (no flax available offline).

A model describes its parameters as a pytree of :class:`ParamSpec` — shape,
dtype, *logical axis names* and an initializer tag. The same spec tree is
used to (a) materialise real params for smoke tests, (b) build
``jax.ShapeDtypeStruct`` stand-ins for the multi-pod dry-run, and (c) derive
``NamedSharding``s from the logical-axis rule table in ``repro.launch.sharding``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Tree = Any


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical axis name per dim (None = unsharded)
    dtype: Any = jnp.float32
    init: str = "normal"  # normal | zeros | ones | scaled
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def spec(shape, axes, dtype=jnp.float32, init="normal", scale=0.02) -> ParamSpec:
    return ParamSpec(tuple(shape), tuple(axes), dtype, init, scale)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn: Callable[[ParamSpec], Any], tree: Tree) -> Tree:
    return jax.tree.map(fn, tree, is_leaf=is_spec)


def to_shape_dtype(tree: Tree) -> Tree:
    """Spec tree -> ShapeDtypeStruct tree (no allocation; dry-run input)."""
    return tree_map_specs(lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), tree)


def _init_one(s: ParamSpec, key: jax.Array) -> jax.Array:
    if s.init == "zeros":
        return jnp.zeros(s.shape, s.dtype)
    if s.init == "ones":
        return jnp.ones(s.shape, s.dtype)
    if s.init == "scaled":  # fan-in scaled normal
        fan_in = s.shape[-2] if len(s.shape) >= 2 else max(s.shape[-1], 1)
        std = 1.0 / math.sqrt(fan_in)
        return (std * jax.random.normal(key, s.shape, jnp.float32)).astype(s.dtype)
    return (s.scale * jax.random.normal(key, s.shape, jnp.float32)).astype(s.dtype)


def init_tree(tree: Tree, key: jax.Array) -> Tree:
    """Materialise a spec tree into real parameters (reduced configs only)."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(s, k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def count_params(tree: Tree) -> int:
    leaves, _ = jax.tree.flatten(tree, is_leaf=is_spec)
    return int(sum(np.prod(s.shape) for s in leaves))


def tree_bytes(tree: Tree) -> int:
    leaves, _ = jax.tree.flatten(tree, is_leaf=is_spec)
    return int(sum(np.prod(s.shape) * np.dtype(s.dtype).itemsize for s in leaves))
