"""Sharded flash-decoding: cache write + single-token attention under
``shard_map``.

The decode KV cache is sharded along ``kv_seq`` on the ``model`` mesh axis.
XLA's automatic partitioner handles a dynamic-index update on a sharded dim
poorly (whole-stack selects / carry copies), so we hand-partition:

  * each model-rank owns a contiguous slice of cache positions;
  * the new token's K/V is written slice-locally (a masked slot update —
    no full-cache traffic anywhere);
  * each rank computes partial attention over its slice, and the partials
    are combined with the flash-decoding log-sum-exp correction in ONE
    psum over (acc, l, m).

Falls back to a single-device implementation when no mesh context is set
(CPU tests / examples).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.launch import sharding as sh
from repro.models import layers as L


def _partial_attention(q, k_l, v_l, valid_from, valid_to, base):
    """Partial (unnormalised) attention over a local cache slice.

    q: (b, hkv, g, hd) scaled; k_l/v_l: (b, hkv, a_loc, hd).
    valid positions are [valid_from, valid_to) in GLOBAL coordinates;
    ``base`` is this slice's global offset.
    Returns (acc (b,hkv,g,hd) f32, l (b,hkv,g) f32, m (b,hkv,g) f32).
    """
    # slice-level f32: decode attention is HBM-bound (cache reads dominate);
    # computing QK/PV in f32 costs nothing at the roofline and avoids the
    # CPU backend's whole-stack bf16->f32 operand mirroring.
    a_loc = k_l.shape[2]
    q = q.astype(jnp.float32)
    s = jnp.einsum("bkgd,bksd->bkgs", q, k_l.astype(jnp.float32))
    gpos = base + jnp.arange(a_loc)[None, None, None, :]
    mask = (gpos >= valid_from) & (gpos < valid_to)
    s = jnp.where(mask, s, -jnp.inf)
    m = jnp.max(s, axis=-1)
    m_safe = jnp.where(jnp.isneginf(m), 0.0, m)
    p = jnp.where(mask, jnp.exp(s - m_safe[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bkgs,bksd->bkgd", p, v_l.astype(jnp.float32))
    return acc, l, m


def _masked_slot_write(stack, new, i, lslot, in_range):
    """Write ``new`` (b, hkv, 1, hd) at [i, :, :, lslot] iff in_range —
    slice-sized ops only (reads the current slot to keep it when skipped)."""
    zero = jnp.zeros((), jnp.int32)
    idx = (jnp.asarray(i), zero, zero, jnp.asarray(lslot), zero)
    upd = new.astype(stack.dtype)[None]
    cur = jax.lax.dynamic_slice(stack, idx, upd.shape)
    upd = jnp.where(in_range, upd, cur)
    return jax.lax.dynamic_update_slice(stack, upd, idx)


def decode_attention(
    q: jax.Array,  # (b, 1, hq, hd)
    k_new: jax.Array,  # (b, 1, hkv, hd)
    v_new: jax.Array,
    kst: jax.Array,  # (L, b, hkv, A, hd)
    vst: jax.Array,
    i,  # layer index (traced scalar)
    pos,  # current position (traced scalar)
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (attn_out (b, 1, hq, hd), kst, vst)."""
    b, _, hq, hd = q.shape
    hkv, a = kst.shape[2], kst.shape[3]
    g = hq // hkv
    scale = 1.0 / math.sqrt(hd)
    pos = jnp.asarray(pos)
    slot = pos % a
    valid = jnp.minimum(pos + 1, a)
    mesh = sh._CTX["mesh"]

    k_t = L.cache_store(k_new)  # (b, hkv, 1, hd)
    v_t = L.cache_store(v_new)

    if mesh is None or "model" not in mesh.shape or mesh.shape["model"] == 1:
        # single-device / no-mesh fallback
        zero = jnp.zeros((), jnp.int32)
        idx = (jnp.asarray(i), zero, zero, slot, zero)
        kst = jax.lax.dynamic_update_slice(kst, k_t.astype(kst.dtype)[None], idx)
        vst = jax.lax.dynamic_update_slice(vst, v_t.astype(vst.dtype)[None], idx)
        k_l = jax.lax.dynamic_index_in_dim(kst, i, 0, keepdims=False)
        v_l = jax.lax.dynamic_index_in_dim(vst, i, 0, keepdims=False)
        out = L.attention_decode(q, k_l, v_l, valid)
        return out, kst, vst

    # derive specs through the same divisibility-guarded rule table used for
    # in_shardings (e.g. batch=1 on long_500k cannot shard over `data`)
    rules = sh.get_context_rules() or sh.ACT_RULES
    cache_spec = sh.partition_spec(
        kst.shape, ("layers", "cache_batch", "kv_heads", "kv_seq", None), mesh, rules
    )
    qspec = sh.partition_spec(q.shape, ("batch", None, None, None), mesh, rules)
    kv_new_spec = sh.partition_spec(k_t.shape, ("batch", None, None, None), mesh, rules)
    cb = cache_spec[1] if len(cache_spec) > 1 else None
    cache_b_axes = () if cb is None else ((cb,) if isinstance(cb, str) else tuple(cb))
    # attention output follows the CACHE's batch sharding (activations may be
    # batch-replicated under weight-stationary decode TP — see §Perf)
    out_spec = P(cb, None, None, None)

    seq_dim = cache_spec[3] if len(cache_spec) > 3 else None
    if not (seq_dim == "model" or (isinstance(seq_dim, tuple) and "model" in seq_dim)):
        # kv_seq not sharded (guarded out) -> single-rank math is wrong in
        # the manual body; use the local path under replication.
        zero = jnp.zeros((), jnp.int32)
        idx = (jnp.asarray(i), zero, zero, slot, zero)
        kst = jax.lax.dynamic_update_slice(kst, k_t.astype(kst.dtype)[None], idx)
        vst = jax.lax.dynamic_update_slice(vst, v_t.astype(vst.dtype)[None], idx)
        k_l = jax.lax.dynamic_index_in_dim(kst, i, 0, keepdims=False)
        v_l = jax.lax.dynamic_index_in_dim(vst, i, 0, keepdims=False)
        out = L.attention_decode(q, k_l, v_l, valid)
        return out, kst, vst

    def body(q_l, k_t_l, v_t_l, kst_l, vst_l, i_, pos_, slot_, valid_):
        b_loc = kst_l.shape[1]
        if q_l.shape[0] != b_loc:
            # activations batch-replicated (weight-stationary TP): slice the
            # local cache-batch rows by this rank's position on the cache axes
            rb = jnp.zeros((), jnp.int32)
            for ax in cache_b_axes:
                rb = rb * mesh.shape[ax] + jax.lax.axis_index(ax)
            q_l = jax.lax.dynamic_slice_in_dim(q_l, rb * b_loc, b_loc, 0)
            k_t_l = jax.lax.dynamic_slice_in_dim(k_t_l, rb * b_loc, b_loc, 0)
            v_t_l = jax.lax.dynamic_slice_in_dim(v_t_l, rb * b_loc, b_loc, 0)
        r = jax.lax.axis_index("model")
        a_loc = kst_l.shape[3]
        base = r * a_loc
        in_range = (slot_ >= base) & (slot_ < base + a_loc)
        lslot = jnp.clip(slot_ - base, 0, a_loc - 1)
        kst_l = _masked_slot_write(kst_l, k_t_l, i_, lslot, in_range)
        vst_l = _masked_slot_write(vst_l, v_t_l, i_, lslot, in_range)
        k_l = jax.lax.dynamic_index_in_dim(kst_l, i_, 0, keepdims=False)
        v_l = jax.lax.dynamic_index_in_dim(vst_l, i_, 0, keepdims=False)
        qc = (q_l.astype(L.COMPUTE_DTYPE) * scale)[:, 0].reshape(-1, hkv, g, hd)
        acc, l, m = _partial_attention(qc, k_l, v_l, 0, valid_, base)
        gm = jax.lax.pmax(m, "model")
        corr = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - gm))
        l_g, acc_g = jax.lax.psum((l * corr, acc * corr[..., None]), "model")
        out = acc_g / jnp.maximum(l_g, 1e-20)[..., None]
        return out.reshape(-1, 1, hq, hd).astype(q_l.dtype), kst_l, vst_l

    from jax.experimental.shard_map import shard_map

    out, kst, vst = shard_map(
        body,
        mesh=mesh,
        in_specs=(qspec, kv_new_spec, kv_new_spec,
                  cache_spec, cache_spec, P(), P(), P(), P()),
        out_specs=(out_spec, cache_spec, cache_spec),
        check_rep=False,
    )(q, k_t, v_t, kst, vst, jnp.asarray(i), pos, slot, valid)
    return out, kst, vst
