"""InternVL2-76B — InternViT + InternLM2 backbone [arXiv:2404.16821].

The assignment specifies the transformer BACKBONE only; the vision frontend
is a stub (``input_specs()`` supplies precomputed patch embeddings).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    mlp_type="swiglu",
    n_patches=256,
    source="arXiv:2404.16821; unverified",
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=512, n_patches=8,
    )
