"""Mamba2-1.3B — SSD (state-space duality), attention-free [arXiv:2405.21060]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_chunk=256,
    ssm_conv=4,
    ssm_ngroups=1,
    tie_embeddings=True,
    source="arXiv:2405.21060; unverified",
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, vocab_size=512, ssm_state=16,
        ssm_headdim=16, ssm_chunk=8,
    )
