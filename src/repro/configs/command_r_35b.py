"""Command-R 35B — GQA, no bias [hf:CohereForAI/c4ai-command-r-v01]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="command-r-35b",
    family="dense",
    n_layers=40,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22528,
    vocab_size=256000,
    mlp_type="swiglu",
    qkv_bias=False,
    source="hf:CohereForAI/c4ai-command-r-v01; unverified",
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=512,
    )
