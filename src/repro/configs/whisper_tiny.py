"""Whisper-tiny — encoder-decoder, conv frontend STUB [arXiv:2212.04356].

``input_specs()`` provides precomputed frame embeddings (batch, 1500, 384)
for the encoder; the decoder is a standard causal transformer with
cross-attention. No RoPE (learned/sinusoidal positions), GELU MLP.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-tiny",
    family="encdec",
    n_layers=4,       # decoder layers
    n_enc_layers=4,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    mlp_type="gelu",
    qkv_bias=True,
    src_len=1500,
    source="arXiv:2212.04356; unverified",
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, n_enc_layers=2, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab_size=512, src_len=32,
    )
