"""StarCoder2-15B — GQA, RoPE [arXiv:2402.19173].

Standard (non-gated) GELU MLP; d_ff = 4 * d_model. QKV uses bias in the HF
reference; the assignment line lists GQA+RoPE only, bias kept (hf card).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab_size=49152,
    mlp_type="gelu",
    qkv_bias=True,
    source="arXiv:2402.19173; hf",
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab_size=512,
    )
