"""Assigned-architecture registry: ``get_config(arch_id)`` / ``list_archs()``."""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401
    ModelConfig,
    ShapeConfig,
    SHAPES,
    get_shape,
    shape_applicable,
)

_ARCH_MODULES: Dict[str, str] = {
    "internvl2-76b": "repro.configs.internvl2_76b",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "granite-moe-3b-a800m": "repro.configs.granite_moe_3b_a800m",
    "command-r-35b": "repro.configs.command_r_35b",
    "starcoder2-15b": "repro.configs.starcoder2_15b",
    "phi3-medium-14b": "repro.configs.phi3_medium_14b",
    "qwen1.5-110b": "repro.configs.qwen15_110b",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "mamba2-1.3b": "repro.configs.mamba2_13b",
    "whisper-tiny": "repro.configs.whisper_tiny",
}


def list_archs() -> List[str]:
    return list(_ARCH_MODULES.keys())


def get_config(arch_id: str, reduced: bool = False) -> ModelConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {list_archs()}")
    mod = importlib.import_module(_ARCH_MODULES[arch_id])
    return mod.reduced() if reduced else mod.CONFIG
