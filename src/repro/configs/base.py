"""Model / shape configuration dataclasses shared by all assigned architectures.

Every architecture in ``repro.configs`` produces a :class:`ModelConfig`.
``ShapeConfig`` describes one of the assigned input-shape cells
(train_4k / prefill_32k / decode_32k / long_500k).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters (full, paper-spec values).

    ``family`` is one of: dense | moe | ssm | hybrid | encdec | vlm.
    """

    arch_id: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0  # 0 -> d_model // n_heads
    mlp_type: str = "swiglu"  # swiglu | gelu
    qkv_bias: bool = False
    attn_window: Optional[int] = None  # sliding-window size; None = full attention
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False

    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # --- SSM (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv: int = 4
    ssm_ngroups: int = 1

    # --- hybrid (recurrentgemma / Griffin) ---
    # pattern is applied per super-block; e.g. ("rec", "rec", "attn")
    hybrid_pattern: Tuple[str, ...] = ()
    lru_width: int = 0
    local_window: int = 2048

    # --- encoder-decoder (whisper) ---
    n_enc_layers: int = 0
    src_len: int = 0  # encoder source positions (precomputed frame embeds)

    # --- vlm ---
    n_patches: int = 0  # stub frontend: precomputed patch embeddings

    # pad embedding/head vocab so the `model` mesh axis divides it
    # (16 = divisibility-only baseline; 2048 = MXU-aligned, see §Perf)
    vocab_pad_multiple: int = 16

    # citation / provenance tag from the assignment table
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        m = max(self.vocab_pad_multiple, 1)
        return ((self.vocab_size + m - 1) // m) * m

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.resolved_head_dim

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def is_subquadratic(self) -> bool:
        """True when ``long_500k`` decode is feasible (bounded state)."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.attn_window is not None

    # ------------------------------------------------------------------
    # Parameter counting — used for MODEL_FLOPS = 6*N*D in the roofline.
    # ------------------------------------------------------------------
    def _attn_params(self) -> int:
        hd = self.resolved_head_dim
        p = self.d_model * (self.q_dim + 2 * self.kv_dim)  # qkv
        p += self.q_dim * self.d_model  # out proj
        if self.qkv_bias:
            p += self.q_dim + 2 * self.kv_dim
        return p

    def _mlp_params(self, d_ff: int) -> int:
        if self.mlp_type == "swiglu":
            return 3 * self.d_model * d_ff
        return 2 * self.d_model * d_ff

    def _moe_layer_params(self) -> Tuple[int, int]:
        """(total, active) params of one MoE FFN layer."""
        per_expert = self._mlp_params(self.d_ff)
        router = self.d_model * self.n_experts
        total = self.n_experts * per_expert + router
        active = self.top_k * per_expert + router
        return total, active

    def _ssm_layer_params(self) -> int:
        di, ds, nh = self.d_inner, self.ssm_state, self.ssm_nheads
        g = self.ssm_ngroups
        in_proj = self.d_model * (2 * di + 2 * g * ds + nh)
        conv = self.ssm_conv * (di + 2 * g * ds)
        out = di * self.d_model + di  # out proj + gate norm
        return in_proj + conv + out + 2 * nh  # + A_log, D

    def _rglru_layer_params(self) -> int:
        w = self.lru_width or self.d_model
        # in/out proj (gated, 2 branches) + conv + lru gates
        return self.d_model * 2 * w + 4 * w + w * self.d_model + 3 * w

    def param_count(self) -> int:
        """Total parameters (embedding included once when tied)."""
        emb = self.vocab_size * self.d_model
        head = 0 if self.tie_embeddings else self.vocab_size * self.d_model
        norms = 2 * self.d_model  # final norm (+ slack)

        if self.family == "ssm":
            body = self.n_layers * (self._ssm_layer_params() + self.d_model)
        elif self.family == "hybrid":
            pat = self.hybrid_pattern or ("rec", "rec", "attn")
            n_super = self.n_layers // len(pat)
            rem = self.n_layers - n_super * len(pat)
            per_super = 0
            for kind in pat:
                blk = self._attn_params() if kind == "attn" else self._rglru_layer_params()
                per_super += blk + self._mlp_params(self.d_ff) + 2 * self.d_model
            body = n_super * per_super
            for kind in (self.hybrid_pattern or ("rec", "rec", "attn"))[:rem]:
                blk = self._attn_params() if kind == "attn" else self._rglru_layer_params()
                body += blk + self._mlp_params(self.d_ff) + 2 * self.d_model
        elif self.family == "moe":
            moe_total, _ = self._moe_layer_params()
            body = self.n_layers * (self._attn_params() + moe_total + 2 * self.d_model)
        elif self.family == "encdec":
            enc = self.n_enc_layers * (
                self._attn_params() + self._mlp_params(self.d_ff) + 2 * self.d_model
            )
            dec = self.n_layers * (
                2 * self._attn_params() + self._mlp_params(self.d_ff) + 3 * self.d_model
            )
            body = enc + dec + self.src_len * self.d_model  # learned enc pos-emb
        else:  # dense / vlm
            body = self.n_layers * (
                self._attn_params() + self._mlp_params(self.d_ff) + 2 * self.d_model
            )
            if self.family == "vlm":
                body += self.d_model * self.d_model  # patch-embed projection stub
        return emb + head + norms + body

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k experts only)."""
        if self.family != "moe":
            return self.param_count()
        moe_total, moe_active = self._moe_layer_params()
        return self.param_count() - self.n_layers * (moe_total - moe_active)


# ---------------------------------------------------------------------------
# Input-shape cells
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens_per_step(self) -> int:
        if self.kind == "decode":
            return self.global_batch  # one new token per sequence
        return self.seq_len * self.global_batch


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch x shape) cell runs, and why not when skipped."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return False, "full quadratic attention: 500k decode infeasible (documented skip)"
    return True, ""
