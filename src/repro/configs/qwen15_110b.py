"""Qwen1.5-110B — QKV bias [hf:Qwen/Qwen1.5-0.5B scaled family]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen1.5-110b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=49152,
    vocab_size=152064,
    mlp_type="swiglu",
    qkv_bias=True,
    source="hf:Qwen/Qwen1.5-0.5B; hf",
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=192,
        vocab_size=512,
    )
