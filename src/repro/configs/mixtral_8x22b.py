"""Mixtral-8x22B — 8 experts top-2, sliding-window attention [arXiv:2401.04088]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    mlp_type="swiglu",
    n_experts=8,
    top_k=2,
    attn_window=4096,  # SWA per the assignment spec -> sub-quadratic
    source="arXiv:2401.04088; hf",
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
        vocab_size=512, n_experts=4, top_k=2, attn_window=16,
    )
