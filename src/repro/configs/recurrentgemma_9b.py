"""RecurrentGemma-9B (Griffin) — RG-LRU + local attention, 1 attn : 2 recurrent
[arXiv:2402.19427]. MQA (kv=1) local attention with a 2048 window.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    mlp_type="swiglu",
    hybrid_pattern=("rec", "rec", "attn"),
    lru_width=4096,
    local_window=2048,
    source="arXiv:2402.19427; unverified",
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=5, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab_size=512, lru_width=64, local_window=16,
    )
