"""Granite-MoE-3B-A800M — 40 experts top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base].

The structured assignment field says "MoE 40e top-8"; the prose note says
"32 experts". We follow the structured field (40 experts, top-8), which also
matches the HF card for granite-3.0-3b-a800m. Recorded in DESIGN.md.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    mlp_type="swiglu",
    n_experts=40,
    top_k=8,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; hf",
)


def reduced() -> ModelConfig:
    import dataclasses

    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=32,
        vocab_size=515, n_experts=8, top_k=4,
    )
