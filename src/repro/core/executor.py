"""End-to-end workflow orchestration (paper §5.3).

Executor: config -> load -> validate -> probe -> fuse/reorder ->
process (fault-tolerant, checkpointed, monitored) -> insight -> export.

Two execution paths through the runtime layer:

  * barriered — one dataset-wide pass per OP with full materialization
    between OPs. Required for per-OP insight mining and per-OP checkpoints.
  * streaming — the OP plan is partitioned into pipelineable segments
    (chains of batch-level Mappers/Filters) separated by barrier OPs
    (Selector / Grouper / Aggregator — and Deduplicator unless it opted into
    the incremental streaming protocol, which runs as a stateful stream
    STAGE instead); each block traverses a whole segment in ONE worker
    dispatch, fed by a bounded prefetch queue from the streaming JSONL
    reader and exported block-by-block, so the full dataset is only
    materialized at genuine barriers (paper §E.3, Fig. 4f). Insight mining
    rides the stream too (one snapshot per segment, SegmentInsightRecorder),
    and the optimizer probe is a uniform reservoir over the first scan
    window of the live block stream.

``run()`` selects the streaming path automatically unless the recipe
checkpoints (operator-level checkpoints persist whole stages);
``run_streaming()`` forces it (checkpointing then happens at segment
boundaries instead of per-op).
"""
from __future__ import annotations

import dataclasses
import itertools
import os
import time
from typing import Any, Dict, Iterable, Iterator, List, Optional, Tuple

from repro.core import clock, obs
from repro.core.adapter import Adapter
from repro.core.checkpoint import CheckpointManager, recipe_prefix_sigs
from repro.core.dataset import (
    DJDataset, ExecutionCancelled, iter_stream_blocks, seed_op_entries,
    seed_plan_entries, stream_segments,
)
from repro.core.engine import make_engine
from repro.core.fusion import plan_segments
from repro.core.insight import InsightMiner, SegmentInsightRecorder
from repro.core.ops_base import Operator
from repro.core.plan import LogicalPlan
from repro.core.recipes import Recipe
from repro.core.rules import annotate_plan, optimize_plan
from repro.core.registry import create_op
from repro.core.storage import (
    BlockPrefetcher, BlockWriter, SampleBlock, iter_sample_blocks,
    read_jsonl, reservoir_sample, split_blocks,
)

PROBE_LIMIT = 1000
# streaming probe: uniform reservoir over the first PROBE_SCAN_FACTOR x
# PROBE_LIMIT rows of the block stream (vs. the old head-biased first-1000)
PROBE_SCAN_FACTOR = 8
# explain() is a dry-run surface: probe far fewer samples than a real run
# so the command stays cheap even with slow/model-backed ops in the plan
EXPLAIN_PROBE_LIMIT = 128


@dataclasses.dataclass
class RunReport:
    recipe: str
    n_in: int
    n_out: int
    seconds: float
    per_op: List[dict]
    plan: List[str]
    resumed_at: int = 0
    insight: str = ""
    errors: int = 0
    streaming: bool = False
    # one summary per engine dispatch call (label = segment's op chain):
    # redispatches, speculation_wins, retries, quarantined workers, window
    dispatch: List[dict] = dataclasses.field(default_factory=list)
    # merged trace for this run: {"trace_id", "root_span", "spans": [...]}
    # (core.obs span dicts — run -> dispatch windows -> worker block spans
    # -> synthesized per-op spans). None when tracing is disabled.
    trace: Optional[Dict[str, Any]] = None


def _count_blocks(blocks: Iterable[SampleBlock], counter: Dict[str, int]) -> Iterator[SampleBlock]:
    for b in blocks:
        counter["n"] += len(b)
        yield b


class Executor:
    def __init__(self, recipe: Recipe, adapter: Optional[Adapter] = None):
        self.recipe = recipe
        self.adapter = adapter or Adapter()
        # set by _optimize_ops: the optimized LogicalPlan and the per-rule
        # rewrite diffs of the last optimization (explain / plan pinning /
        # the plan:optimize trace span all read these)
        self.last_plan: Optional[LogicalPlan] = None
        self.last_rewrites: List[dict] = []

    def _build_ops(self) -> List[Operator]:
        return [create_op(cfg) for cfg in self.recipe.process]

    def _plan_ops(self) -> Tuple[List[Operator], bool]:
        """(ops, fixed): a persisted ``fixed_plan`` (cluster failover replay)
        is rebuilt verbatim — the caller must then skip probe + optimize."""
        r = self.recipe
        if r.fixed_plan is not None:
            return [create_op(dict(c)) for c in r.fixed_plan], True
        return self._build_ops(), False

    def resolve_plan(self) -> List[Dict[str, Any]]:
        """Derive the optimized op plan WITHOUT running the recipe: the same
        probe + optimize a streaming run would perform, returned as op
        configs. Cluster runners persist this at first claim so a failover
        retry re-runs the identical plan (``Recipe.fixed_plan``)."""
        r = self.recipe
        if r.fixed_plan is not None:
            return [dict(c) for c in r.fixed_plan]
        ops = self._build_ops()
        if (r.use_fusion or r.use_reordering) and r.dataset_path:
            bb = {"block_bytes": r.block_bytes} if r.block_bytes else {}
            raw = iter_sample_blocks(r.dataset_path, n_workers=1, **bb)
            try:
                probe, _ = self._probe_blocks(raw)
            finally:
                raw.close()
            ops = self._optimize_ops(ops, probe)
        return [op.config() for op in ops]

    def _columnar_source(self) -> bool:
        return self.recipe.block_format != "row"

    def _make_engine(self):
        r = self.recipe
        kw: Dict[str, Any] = {}
        if r.engine == "parallel":
            kw["n_workers"] = r.np
        if r.health_path and r.engine in ("local", "parallel"):
            kw["health_path"] = r.health_path
        if r.engine in ("local", "parallel"):
            mb = r.mem_budget
            if mb is None:
                try:
                    mb = int(os.environ.get("DJ_BLOCK_MEM_BUDGET", "") or 0) or None
                except ValueError:
                    mb = None
            if mb:
                kw["mem_budget"] = mb
        return make_engine(r.engine, **kw)

    def streaming_eligible(self) -> bool:
        """Streaming drops the per-op dataset-wide barrier. Insight mining
        rides the block stream now (SegmentInsightRecorder: one timeline
        entry per segment instead of per op), so only operator-level
        checkpointing — which must persist whole stages — keeps the
        barriered path on auto-selection."""
        return not self.recipe.checkpoint_dir

    def run(self, dataset: Optional[DJDataset] = None,
            monitor: Optional[List[dict]] = None,
            cancel=None) -> tuple[DJDataset, RunReport]:
        """Execute the recipe. ``monitor`` (a caller-owned list) receives the
        live per-op progress rows; ``cancel`` is a callable polled during the
        run — returning True aborts with ExecutionCancelled. Both power the
        async job subsystem (repro.api.jobs)."""
        if self.streaming_eligible():
            return self.run_streaming(dataset, monitor=monitor, cancel=cancel)
        return self.run_barriered(dataset, monitor=monitor, cancel=cancel)

    # ------------------------------------------------------------------
    # tracing (core.obs): every run executes under a "run" span. The trace
    # id is inherited from recipe.trace (cluster submit / shard task) or
    # minted here for local runs; the span is pushed as the thread's ambient
    # parent so engine dispatch windows (and their worker block spans)
    # attach to it without any signature changes down the stack.
    # ------------------------------------------------------------------
    def _begin_run_span(self, path: str):
        tr = self.recipe.trace or {}
        trace_id = tr.get("trace_id") or (obs.new_id() if obs.enabled() else None)
        sp = obs.start_span(trace_id, f"run:{self.recipe.name}", kind="run",
                            parent_id=tr.get("span_id"))
        if sp is not None:
            sp.set(path=path, engine=self.recipe.engine, np=self.recipe.np)
            obs.tracer().stack().append(sp)
        return sp

    def _pop_run_span(self, sp) -> None:
        if sp is None:
            return
        stack = obs.tracer().stack()
        if sp in stack:
            stack.remove(sp)

    def _finish_run_span(self, sp, report: RunReport) -> None:
        """End the run span, synthesize per-op spans from the monitor rows
        (ops have no absolute timestamps — they are laid out sequentially
        from the run start, which is exact for barriered runs and a faithful
        plan-order approximation for pipelined segments), and attach the
        drained trace to the report."""
        if sp is None:
            return
        t_cursor = sp.t0
        for i, row in enumerate(report.per_op):
            secs = float(row.get("seconds", 0.0) or 0.0)
            op_sp = obs.start_span(sp.trace_id, f"op:{row.get('op')}",
                                   kind="op", parent_id=sp.span_id,
                                   t0=t_cursor, tid=1000 + i)
            if op_sp is not None:
                op_sp.set(n_in=row.get("in", 0), n_out=row.get("out", 0),
                          errors=row.get("errors", 0),
                          redispatches=row.get("redispatches", 0))
                op_sp.end(t_cursor + secs)
            t_cursor += secs
        sp.set(n_in=report.n_in, n_out=report.n_out, errors=report.errors,
               streaming=report.streaming, resumed_at=report.resumed_at)
        sp.end()
        m = obs.metrics()
        m.inc("run.rows_out_total", report.n_out)
        if report.seconds > 0:
            m.gauge("run.rows_per_second", report.n_out / report.seconds)
        report.trace = {"trace_id": sp.trace_id, "root_span": sp.span_id,
                        "spans": obs.drain(sp.trace_id)}

    # ------------------------------------------------------------------
    # streaming block-pipelined path
    # ------------------------------------------------------------------
    def _optimize_ops(self, ops: List[Operator], probe_samples: List[dict]) -> List[Operator]:
        """Probe + rule-based optimization over the logical-plan IR. The
        optimized plan and the per-rule rewrite diffs are kept on the
        executor (``last_plan`` / ``last_rewrites``) and emitted as a
        ``plan:optimize`` span under the ambient run span."""
        r = self.recipe
        if (r.use_fusion or r.use_reordering) and probe_samples:
            t0 = clock.now()
            self.adapter.probe_small_batch(probe_samples, ops)
            plan, rewrites = optimize_plan(
                LogicalPlan.from_ops(ops), self.adapter.probes,
                do_fuse=r.use_fusion, do_reorder=r.use_reordering)
            self.last_plan = plan
            self.last_rewrites = [rw.to_dict() for rw in rewrites]
            self._emit_plan_span(t0, self.last_rewrites)
            ops = plan.ops()
        return ops

    def _emit_plan_span(self, t0: float, rewrites: List[dict]) -> None:
        """Log the optimizer's per-rule before/after diffs onto the trace
        (kind="plan"), parented under the ambient run span when one is
        active, else under the recipe's submitted trace context."""
        if not obs.enabled():
            return
        tr = self.recipe.trace or {}
        stack = obs.tracer().stack()
        parent = stack[-1] if stack else None
        trace_id = parent.trace_id if parent is not None else tr.get("trace_id")
        sp = obs.start_span(
            trace_id, "plan:optimize", kind="plan",
            parent_id=parent.span_id if parent is not None else tr.get("span_id"),
            t0=t0)
        if sp is not None:
            sp.set(rules=rewrites,
                   n_rules_changed=sum(1 for rw in rewrites if rw["changed"]))
            sp.end()

    def _probe_samples(self, dataset: Optional[DJDataset]) -> List[dict]:
        if dataset is not None:
            # in-memory: hand the adapter the full pool — probe_small_batch
            # picks its own random subset, matching the barriered path
            return dataset.samples()
        if self.recipe.dataset_path:
            return list(read_jsonl(self.recipe.dataset_path, limit=PROBE_LIMIT))
        return []

    def _probe_blocks(self, src: Iterable[SampleBlock]
                      ) -> Tuple[List[dict], Iterable[SampleBlock]]:
        """Reservoir-sampled probe over the first block pass.

        Replaces the head-biased ``read_jsonl(limit=1000)`` probe for
        streamed file sources: scan blocks off the live stream until the
        reservoir window has seen PROBE_SCAN_FACTOR x PROBE_LIMIT rows (or
        the stream ends), draw a uniform PROBE_LIMIT-row sample from that
        window, and plan right then — the replan happens exactly once, when
        the reservoir fills. The scanned blocks are replayed ahead of the
        remaining stream, so nothing is decoded twice and resident memory
        stays O(scan window). Deterministic (fixed seed + first-seen order),
        so checkpoint resume re-derives the identical optimized plan."""
        scanned: List[SampleBlock] = []
        seen = 0
        for blk in src:
            scanned.append(blk)
            seen += len(blk)
            if seen >= PROBE_LIMIT * PROBE_SCAN_FACTOR:
                break
        # private decode for ColumnBlocks: .samples would cache row dicts and
        # mark the whole scan window materialized (losing its columnar path)
        probe = reservoir_sample(
            (s for b in scanned
             for s in (b.decode_rows() if hasattr(b, "decode_rows") else b.samples)),
            PROBE_LIMIT)
        return probe, itertools.chain(scanned, src)

    def explain(self, dataset: Optional[DJDataset] = None) -> Dict[str, Any]:
        """Optimized plan + streaming segments WITHOUT processing the
        dataset. Fusion/reordering need probed op speeds, so each op runs on
        a small head sample (EXPLAIN_PROBE_LIMIT rows — much smaller than a
        real run's probe, so the reordering can differ marginally); with no
        data source available, optimization falls back to declaration order."""
        r = self.recipe
        ops = self._optimize_ops(
            self._build_ops(), self._probe_samples(dataset)[:EXPLAIN_PROBE_LIMIT])
        segments = plan_segments(ops)
        src = {"kind": "jsonl", "path": r.dataset_path} if r.dataset_path else None
        opts = {"export_path": r.export_path} if r.export_path else {}
        plan_ir = self.last_plan or annotate_plan(LogicalPlan.from_ops(ops))
        plan_ir = LogicalPlan(src, plan_ir.nodes, opts)
        return {
            "recipe": r.name,
            "requested": [cfg.get("name") for cfg in r.process],
            "plan": [op.name for op in ops],
            # the optimized logical plan: typed Source/.../Sink nodes with
            # column deps + rule annotations, and the per-rule rewrite diffs
            "nodes": plan_ir.describe(),
            "rewrites": list(self.last_rewrites),
            "segments": [
                {"ops": [o.name for o in seg.ops], "barrier": seg.barrier,
                 "stateful": seg.stateful, "pushdown": seg.n_pushdown}
                for seg in segments
            ],
            "streaming": self.streaming_eligible(),
            "engine": r.engine,
            "np": r.np,
            # adaptive-dispatch policy the run will use (window sizing,
            # speculation, quarantine — docs/runtime.md "Adaptive dispatch")
            "dispatch": self._make_engine().dispatch_policy(),
            # whether the run will record a trace (docs/observability.md)
            "obs": {"tracing": obs.enabled()},
        }

    def stream_blocks(
        self, dataset: Optional[DJDataset] = None, prefetch: int = 4,
        monitor: Optional[List[dict]] = None, cancel=None,
    ) -> Iterator[Any]:
        """Lazy generator over output SampleBlocks: probe -> optimize ->
        stream, with no export and no full materialization (except at
        barrier ops). Powers ``Pipeline.iter_blocks``."""
        r = self.recipe
        if dataset is None and not r.dataset_path:
            raise ValueError("recipe has no dataset_path and no dataset given")
        engine = self._make_engine()
        ops, fixed = self._plan_ops()
        n_workers = getattr(engine, "n_workers", 1) or 1
        if dataset is not None:
            src: Iterable[SampleBlock] = iter(dataset.blocks)
            if not fixed:
                ops = self._optimize_ops(ops, self._probe_samples(dataset))
        else:
            bb = {"block_bytes": r.block_bytes} if r.block_bytes else {}
            if r.row_range:  # shard task: read only this slice of the input
                bb["row_range"] = tuple(r.row_range)
            src = iter_sample_blocks(r.dataset_path, n_workers=n_workers,
                                     columnar=self._columnar_source(), **bb)
            if (r.use_fusion or r.use_reordering) and not fixed:
                probe, src = self._probe_blocks(src)
                ops = self._optimize_ops(ops, probe)
        segments = plan_segments(ops)
        entries = seed_plan_entries(segments)
        if monitor is not None:
            monitor.extend(entries)
        prefetcher: Optional[BlockPrefetcher] = None
        if prefetch and dataset is None:
            src = prefetcher = BlockPrefetcher(src, depth=prefetch)
        try:
            yield from iter_stream_blocks(src, segments, engine, entries,
                                          n_workers, cancel)
        finally:
            if prefetcher is not None:
                prefetcher.close()

    def run_streaming(
        self, dataset: Optional[DJDataset] = None,
        materialize: bool = True, prefetch: int = 4,
        monitor: Optional[List[dict]] = None, cancel=None,
    ) -> tuple[DJDataset, RunReport]:
        """Streaming block-pipelined execution. With ``materialize=False``
        (and an ``export_path``) the output dataset is streamed to disk and
        the returned DJDataset is empty. A ``checkpoint_dir`` still forces
        per-segment materialization (stages are persisted whole), so peak
        memory is then one full dataset even with ``materialize=False``."""
        sp = self._begin_run_span("streaming")
        try:
            ds, report = self._run_streaming_impl(
                dataset, materialize=materialize, prefetch=prefetch,
                monitor=monitor, cancel=cancel)
        finally:
            self._pop_run_span(sp)  # never leak a stale ambient parent
        self._finish_run_span(sp, report)
        return ds, report

    def _run_streaming_impl(
        self, dataset: Optional[DJDataset] = None,
        materialize: bool = True, prefetch: int = 4,
        monitor: Optional[List[dict]] = None, cancel=None,
    ) -> tuple[DJDataset, RunReport]:
        r = self.recipe
        t0 = clock.now()
        engine = self._make_engine()
        if dataset is None and not r.dataset_path:
            raise ValueError("recipe has no dataset_path and no dataset given")

        ops, fixed = self._plan_ops()
        n_workers = getattr(engine, "n_workers", 1) or 1

        # source FIRST: with a file source the probe rides the live block
        # stream (uniform reservoir over the first scan window, replayed
        # ahead of the remaining stream) instead of a separate head-biased
        # read_jsonl(limit=...) pass
        counter = {"n": 0}
        counted = None
        if dataset is not None:
            counter["n"] = len(dataset)
            src: Iterable[SampleBlock] = iter(dataset.blocks)
            if not fixed:
                ops = self._optimize_ops(ops, self._probe_samples(dataset))
        else:
            bb = {"block_bytes": r.block_bytes} if r.block_bytes else {}
            if r.row_range:  # shard task: read only this slice of the input
                bb["row_range"] = tuple(r.row_range)
            counted = _count_blocks(
                iter_sample_blocks(r.dataset_path, n_workers=n_workers,
                                   columnar=self._columnar_source(), **bb), counter)
            src = counted
            if (r.use_fusion or r.use_reordering) and not fixed:
                # NOTE: on a checkpoint resume this scan is still required —
                # the resume point is keyed by the OPTIMIZED plan's prefix
                # sigs, and only the identical (deterministic) probe
                # re-derives the identical plan (a persisted fixed_plan
                # makes both the scan and the re-derivation unnecessary)
                probe, src = self._probe_blocks(src)
                ops = self._optimize_ops(ops, probe)
        plan = [op.name for op in ops]
        segments = plan_segments(ops)

        # segment-boundary checkpointing (only when forced via run_streaming
        # with a checkpoint_dir — run() routes checkpointed recipes here only
        # if the caller does so explicitly)
        op_cfgs = [op.config() for op in ops]
        sigs = recipe_prefix_sigs(op_cfgs)
        bounds: List[int] = []
        k = 0
        for seg in segments:
            k += len(seg.ops)
            bounds.append(k)
        ckpt = CheckpointManager(r.checkpoint_dir) if r.checkpoint_dir else None
        resumed_at, resumed_samples = 0, None
        if ckpt:
            resumed_at, resumed_samples = ckpt.resume_point(op_cfgs, allowed=set(bounds))

        if resumed_samples is not None:
            # original input size was persisted by the first (pre-crash) run;
            # fall back to the resumed-stage count if it predates that
            counter = {"n": ckpt.get_meta("n_in", len(resumed_samples))}
            if counted is not None:
                counted.close()  # release the probed file stream promptly
            src = iter(split_blocks(
                resumed_samples, n_workers=n_workers,
                total_hint_bytes=max(1, len(resumed_samples)) * 256))
        # sink first: a sink constructor failure must not strand a prefetch
        # thread that is already decoding blocks
        sink = BlockWriter(r.export_path) if r.export_path else None
        prefetcher: Optional[BlockPrefetcher] = None
        # prefetch only pays off over the lazy file-backed source — in-memory
        # blocks have no decode latency to overlap
        if prefetch and dataset is None and resumed_samples is None:
            src = prefetcher = BlockPrefetcher(src, depth=prefetch)
        # streaming insight: tap the source (the "load" snapshot) and every
        # segment's output stream — per-segment timeline, no barriers
        recorder = SegmentInsightRecorder() if r.insight else None
        if recorder is not None:
            src = recorder.tap("load", src)

        remaining = [(seg, end) for seg, end in zip(segments, bounds) if end > resumed_at]
        entries: List[dict] = []
        ok = False
        try:
            if ckpt and remaining:
                # checkpointing forces materialization at each segment
                # boundary (the stage must be persisted whole)
                blocks: List[SampleBlock] = []
                n_out = 0
                n_in_saved = resumed_samples is not None
                for seg, end in remaining:
                    is_last = end == bounds[-1]
                    blocks, ent, n_out = stream_segments(
                        src, [seg], engine, sink=sink if is_last else None,
                        collect=True, n_workers_hint=n_workers,
                        monitor=monitor, cancel=cancel, observer=recorder)
                    entries.extend(ent)
                    ckpt.save_stage(sigs[end - 1], end,
                                    [s for b in blocks for s in b.samples])
                    ckpt.gc()
                    if not n_in_saved:
                        # source fully drained by the first segment — persist
                        # the true input size for post-crash resumes
                        ckpt.set_meta("n_in", counter["n"])
                        n_in_saved = True
                    src = iter(blocks)
                if not materialize:
                    blocks = []
            else:
                blocks, entries, n_out = stream_segments(
                    src, [seg for seg, _ in remaining], engine, sink=sink,
                    collect=materialize, n_workers_hint=n_workers,
                    monitor=monitor, cancel=cancel, observer=recorder)
            ok = True
        finally:
            if sink is not None:
                sink.close(success=ok)  # failure keeps any previous export
            if prefetcher is not None:
                prefetcher.close()  # releases the fill thread on error paths

        errors = sum(len(op.errors) for op in ops)
        report = RunReport(
            recipe=r.name, n_in=counter["n"], n_out=n_out,
            seconds=clock.now() - t0, per_op=entries, plan=plan,
            resumed_at=resumed_at, errors=errors, streaming=True,
            insight=recorder.report() if recorder is not None else "",
            dispatch=list(getattr(engine, "dispatch_log", ())),
        )
        return DJDataset(blocks or [SampleBlock([])], engine), report

    # ------------------------------------------------------------------
    # barriered (per-op materializing) path
    # ------------------------------------------------------------------
    def run_barriered(self, dataset: Optional[DJDataset] = None,
                      monitor: Optional[List[dict]] = None,
                      cancel=None) -> tuple[DJDataset, RunReport]:
        sp = self._begin_run_span("barriered")
        try:
            ds, report = self._run_barriered_impl(dataset, monitor=monitor,
                                                  cancel=cancel)
        finally:
            self._pop_run_span(sp)
        self._finish_run_span(sp, report)
        return ds, report

    def _run_barriered_impl(self, dataset: Optional[DJDataset] = None,
                            monitor: Optional[List[dict]] = None,
                            cancel=None) -> tuple[DJDataset, RunReport]:
        r = self.recipe
        t0 = clock.now()
        engine = self._make_engine()
        if dataset is None:
            if not r.dataset_path:
                raise ValueError("recipe has no dataset_path and no dataset given")
            dataset = DJDataset.load(r.dataset_path, engine=engine,
                                     block_bytes=r.block_bytes)
        else:
            dataset = DJDataset(dataset.blocks, engine, dataset.lineage)
        n_in = len(dataset)

        ops, fixed = self._plan_ops()
        # probe + rule-based optimize (fusion & workload-aware reordering)
        if len(dataset) and not fixed:
            ops = self._optimize_ops(ops, dataset.samples())
        plan = [op.name for op in ops]

        # operator-level checkpoint resume
        resumed_at = 0
        ckpt = CheckpointManager(r.checkpoint_dir) if r.checkpoint_dir else None
        op_cfgs = [op.config() for op in ops]
        if ckpt:
            resumed_at, samples = ckpt.resume_point(op_cfgs)
            if samples is not None:
                dataset = DJDataset.from_samples(samples, engine)

        miner = InsightMiner() if r.insight else None
        if miner:
            miner.record("load", dataset.samples())

        monitor = monitor if monitor is not None else []
        # pre-seed one zero row per remaining op so async observers see the
        # full plan size (ops_total) up front, mirroring the streaming path
        rows = seed_op_entries(ops[resumed_at:])
        monitor.extend(rows)
        sigs = recipe_prefix_sigs(op_cfgs)
        errors = 0
        for i in range(resumed_at, len(ops)):
            if cancel is not None and cancel():
                raise ExecutionCancelled("barriered run cancelled")
            op = ops[i]
            step: List[dict] = []
            dataset = dataset.process(op, monitor=step)
            rows[i - resumed_at].update(step[0])
            errors += len(op.errors)
            if ckpt:
                ckpt.save_stage(sigs[i], i + 1, dataset.samples())
                ckpt.gc()
            if miner:
                miner.record(op.name, dataset.samples())

        if r.export_path:
            dataset.export(r.export_path)

        report = RunReport(
            recipe=r.name, n_in=n_in, n_out=len(dataset),
            seconds=clock.now() - t0, per_op=monitor, plan=plan,
            resumed_at=resumed_at,
            insight=miner.report() if miner else "", errors=errors,
            dispatch=list(getattr(engine, "dispatch_log", ())),
        )
        return dataset, report
