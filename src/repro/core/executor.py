"""End-to-end workflow orchestration (paper §5.3).

Executor: config -> load -> validate -> probe -> fuse/reorder ->
process (fault-tolerant, checkpointed, monitored) -> insight -> export.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

from repro.core.adapter import Adapter
from repro.core.checkpoint import CheckpointManager, recipe_prefix_sigs
from repro.core.dataset import DJDataset
from repro.core.engine import make_engine
from repro.core.fusion import optimize
from repro.core.insight import InsightMiner
from repro.core.ops_base import Operator
from repro.core.recipes import Recipe
from repro.core.registry import create_op


@dataclasses.dataclass
class RunReport:
    recipe: str
    n_in: int
    n_out: int
    seconds: float
    per_op: List[dict]
    plan: List[str]
    resumed_at: int = 0
    insight: str = ""
    errors: int = 0


class Executor:
    def __init__(self, recipe: Recipe, adapter: Optional[Adapter] = None):
        self.recipe = recipe
        self.adapter = adapter or Adapter()

    def _build_ops(self) -> List[Operator]:
        return [create_op(cfg) for cfg in self.recipe.process]

    def run(self, dataset: Optional[DJDataset] = None) -> tuple[DJDataset, RunReport]:
        r = self.recipe
        t0 = time.time()
        engine = make_engine(r.engine, **({"n_workers": r.np} if r.engine == "parallel" else {}))
        if dataset is None:
            if not r.dataset_path:
                raise ValueError("recipe has no dataset_path and no dataset given")
            dataset = DJDataset.load(r.dataset_path, engine=engine)
        else:
            dataset = DJDataset(dataset.blocks, engine, dataset.lineage)
        n_in = len(dataset)

        ops = self._build_ops()
        # probe + optimize (fusion & workload-aware reordering)
        if (r.use_fusion or r.use_reordering) and len(dataset):
            self.adapter.probe_small_batch(dataset.samples(), ops)
            ops = optimize(
                ops, self.adapter.probes,
                do_fuse=r.use_fusion, do_reorder=r.use_reordering,
            )
        plan = [op.name for op in ops]

        # operator-level checkpoint resume
        resumed_at = 0
        ckpt = CheckpointManager(r.checkpoint_dir) if r.checkpoint_dir else None
        op_cfgs = [op.config() for op in ops]
        if ckpt:
            resumed_at, samples = ckpt.resume_point(op_cfgs)
            if samples is not None:
                dataset = DJDataset.from_samples(samples, engine)

        miner = InsightMiner() if r.insight else None
        if miner:
            miner.record("load", dataset.samples())

        monitor: List[dict] = []
        sigs = recipe_prefix_sigs(op_cfgs)
        errors = 0
        for i in range(resumed_at, len(ops)):
            op = ops[i]
            dataset = dataset.process(op, monitor=monitor)
            errors += len(op.errors)
            if ckpt:
                ckpt.save_stage(sigs[i], i + 1, dataset.samples())
                ckpt.gc()
            if miner:
                miner.record(op.name, dataset.samples())

        if r.export_path:
            dataset.export(r.export_path)

        report = RunReport(
            recipe=r.name, n_in=n_in, n_out=len(dataset),
            seconds=time.time() - t0, per_op=monitor, plan=plan,
            resumed_at=resumed_at,
            insight=miner.report() if miner else "", errors=errors,
        )
        return dataset, report
