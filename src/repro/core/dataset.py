"""Data-Juicer-Dataset: the engine-agnostic facade (paper §5.1).

Chainable ``process()`` (single OP, chained calls, or a list), unified
across Local / Parallel / Sharded engines, with sample-level fault
tolerance, dataset-level OP handling (Deduplicator / Selector / Grouper /
Aggregator) and per-OP lineage stats for insight mining.
"""
from __future__ import annotations

import time
from typing import Any, Dict, Iterable, List, Optional, Sequence, Union

import numpy as np

from repro.core import clock
from repro.core import schema as S
from repro.core.engine import LocalEngine, make_engine
from repro.core.ops_base import (
    BARRIER_TYPES, Aggregator, Deduplicator, Filter, Grouper, Operator,
    Selector,
)
from repro.core.storage import (
    BlockPrefetcher, SampleBlock, read_jsonl, split_blocks, write_jsonl,
)

Sample = Dict[str, Any]
GROUP_KEY = "__group__"


class ExecutionCancelled(RuntimeError):
    """A cancel callback interrupted a run mid-stream (async job cancel)."""


def apply_dataset_op(op: Operator, samples: List[Sample]) -> List[Sample]:
    """Apply a dataset-level (barrier) OP to fully-materialized samples."""
    op.setup()
    if isinstance(op, Deduplicator):
        return op.dedup(samples)
    if isinstance(op, Selector):
        return op.select(samples)
    if isinstance(op, Grouper):
        return [{GROUP_KEY: g, "meta": {}, "stats": {}} for g in op.group(samples)]
    if isinstance(op, Aggregator):
        out = []
        for s in samples:
            if GROUP_KEY in s:
                out.append(op.run_batch_safe(s[GROUP_KEY])[0]
                           if s[GROUP_KEY] else S.empty_like({"text": ""}))
            else:
                out.append(s)
        # non-grouped input: aggregate everything into one sample
        if out and not any(GROUP_KEY in s for s in samples):
            out = op.run_batch_safe(samples)
        return out
    raise TypeError(f"{op.name} is not a dataset-level OP")


def seed_op_entries(ops: Sequence[Operator]) -> List[dict]:
    """One zero monitor entry per OP, in order — the shared row shape for
    both executor paths' live progress. ``redispatches`` counts speculative
    straggler re-submissions (charged to a segment's first op on the
    streaming path, per-call engine stats on the barriered path)."""
    return [{"op": op.name, "seconds": 0.0, "in": 0, "out": 0,
             "errors": 0, "speed": float("inf"), "redispatches": 0}
            for op in ops]


def seed_plan_entries(segments: Sequence) -> List[dict]:
    """One zero monitor entry per OP in plan order. Keyed by GLOBAL op index
    downstream, not op name — a recipe may legally contain two instances of
    the same OP class. Pre-seeding keeps per_op aligned with the plan even on
    empty input, and lets concurrent observers (async job polling) watch the
    rows fill in while the stream runs."""
    return seed_op_entries([op for seg in segments for op in seg.ops])


def iter_stream_blocks(
    blocks: Iterable[SampleBlock],
    segments: Sequence,  # List[fusion.Segment]
    engine,
    entries: Optional[List[dict]] = None,
    n_workers_hint: int = 1,
    cancel=None,
    observer=None,
):
    """Generator core of the streaming executor: drive a lazy block iterator
    through a planned sequence of segments, yielding output blocks.

    Pipelineable segments stream block-by-block through the engine's
    ``map_block_chain`` (one dispatch per block per segment); barrier segments
    drain the stream, run the dataset-level OP on the materialized samples,
    and re-split into blocks; *stateful* segments (streaming-capable dedup,
    ``Segment.stateful``) thread the op's incremental state through the block
    stream on the driver — blocks keep flowing, no materialization.
    ``entries`` (from :func:`seed_plan_entries`) is mutated in place as
    blocks complete — live per-op progress. A ``cancel`` callable returning
    True aborts the stream with ExecutionCancelled, checked once per block at
    the barrier drains, the stateful-stage ingests and the output drain.
    An ``observer`` (``observer.tap(label, stream)`` returning a wrapped
    stream) sees each segment's output blocks — the streaming insight hook.
    """
    if entries is None:
        entries = seed_plan_entries(segments)

    def record(op_idx: int, st: dict) -> None:
        e = entries[op_idx]
        for k in ("seconds", "in", "out", "errors"):
            e[k] += st[k]
        dt = e["seconds"]
        e["speed"] = e["in"] / dt if dt > 0 else float("inf")

    def check_cancel() -> None:
        if cancel is not None and cancel():
            raise ExecutionCancelled("streaming run cancelled")

    def charge(op_idx: int, st: dict) -> None:
        # presign-mapper work belongs to the dedup op's entry, but only its
        # time and errors — the stage itself owns the in/out counts (the
        # mapper is 1->1 and would double-count)
        entries[op_idx]["seconds"] += st["seconds"]
        entries[op_idx]["errors"] += st["errors"]

    charged_dispatch: set = set()  # summaries already attributed (by identity)

    def charge_dispatch(op_idx: int, label: str, n0: int) -> None:
        # attribute the engine's dispatch summaries (appended when a
        # map_block_chain call finishes) to the segment's first op row.
        # Label-matched because lazily chained segments interleave, and
        # identity-deduped because two segments may share an op-name label
        # (all segment generators run on the driver thread — no races)
        for s in (getattr(engine, "dispatch_log", None) or [])[n0:]:
            if s.get("label") == label and id(s) not in charged_dispatch:
                charged_dispatch.add(id(s))
                entries[op_idx]["redispatches"] += s.get("redispatches", 0)

    # Stateful (streaming-dedup) stages can push their embarrassingly-
    # parallel precompute (shingle + signature) into the engine's block
    # dispatch. When a pipelineable chain directly precedes the stage, the
    # sig mapper is APPENDED to that chain — no extra worker pool, the
    # signatures ride the dispatch that was happening anyway and overlap
    # with the driver-side band indexing. A stage with no preceding chain
    # gets its own dispatch over the raw source.
    segments = list(segments)
    states: Dict[int, Any] = {}
    attached: Dict[int, tuple] = {}  # chain seg idx -> (sig_ops, dedup op idx)
    off = 0
    prev_chain: Optional[int] = None
    for idx, seg in enumerate(segments):
        if getattr(seg, "stateful", False):
            op = seg.ops[0]
            op.setup()
            state = op.streaming_state()
            sig_ops = getattr(state, "presign_ops", lambda: None)()
            if sig_ops and prev_chain == idx - 1:
                attached[idx - 1] = (sig_ops, off)
                states[idx] = (state, True)  # upstream already pre-signs
            elif sig_ops:
                states[idx] = (state, sig_ops)
            else:
                states[idx] = (state, None)
            prev_chain = None
        elif seg.barrier:
            prev_chain = None
        else:
            prev_chain = idx
        off += len(seg.ops)

    stream: Iterable[SampleBlock] = blocks
    offset = 0
    for idx, seg in enumerate(segments):
        if getattr(seg, "stateful", False):
            state, presign = states[idx]

            def run_stateful(state=state, presign=presign, upstream=stream,
                             offset=offset):
                src = upstream
                if presign not in (True, None):  # dedicated presign dispatch
                    def presigned(upstream=src, sig_ops=presign):
                        label = "+".join(o.name for o in sig_ops)
                        n0 = len(getattr(engine, "dispatch_log", ()))
                        try:
                            for blk, sig_stats in engine.map_block_chain(sig_ops, upstream):
                                for st in sig_stats:
                                    charge(offset, st)
                                yield blk
                        finally:
                            charge_dispatch(offset, label, n0)
                    src = presigned()
                for blk, st in state.stream_blocks(src, check_cancel):
                    record(offset, st)
                    if len(blk):
                        yield blk

            stream = run_stateful()
        elif seg.barrier:
            op = seg.ops[0]
            # drain FIRST: the lazy upstream executes here, and its time
            # belongs to the upstream ops' entries, not the barrier's
            samples: List[Sample] = []
            for b in stream:
                check_cancel()
                samples.extend(b.samples)
            t0 = clock.now()
            n_in = len(samples)
            err0 = len(op.errors)
            out = [s for s in apply_dataset_op(op, samples) if not S.is_empty(s)]
            record(offset, {"op": op.name, "seconds": clock.now() - t0, "in": n_in,
                            "out": len(out), "errors": len(op.errors) - err0})
            stream = iter(split_blocks(out, n_workers=max(1, n_workers_hint),
                                       total_hint_bytes=max(1, len(out)) * 256))
        else:
            sig_ops, sig_owner = attached.get(idx, (None, None))
            n_push = getattr(seg, "n_pushdown", 0)
            if n_push:
                # predicate pushdown: the segment's leading vectorized
                # column-only filters run HERE, on the driver, right after
                # block decode — rows they drop are never pickled to a
                # worker. Blocks that can't take the columnar path (row
                # format, materialized, empties) fall back to run_chain
                # per block; stats land on the same per-op entries either way.
                def pushed(upstream=stream, push_ops=list(seg.ops[:n_push]),
                           offset=offset):
                    from repro.core.engine import _columnar_prefix, run_chain
                    for blk in upstream:
                        cur, cstats, k = _columnar_prefix(push_ops, blk)
                        for j, st in enumerate(cstats):
                            record(offset + j, st)
                        if k < len(push_ops):
                            rows, sub = run_chain(push_ops[k:], list(cur.samples))
                            for j, st in enumerate(sub):
                                record(offset + k + j, st)
                            cur = SampleBlock(rows, nbytes=0)
                        yield cur
                stream = pushed()
            def run(seg=seg, upstream=stream, offset=offset,
                    sig_ops=sig_ops, sig_owner=sig_owner, n_push=n_push):
                chain = seg.ops[n_push:] + (sig_ops or [])
                if not chain:  # whole segment pushed down, nothing to dispatch
                    yield from upstream
                    return
                n_own = len(seg.ops) - n_push
                # redispatch charges go to the first DISPATCHED op's row; a
                # fully-pushed segment dispatches only presign mappers, whose
                # summaries belong to the downstream dedup op
                owner = offset + n_push if n_own > 0 else sig_owner
                label = "+".join(o.name for o in chain)
                n0 = len(getattr(engine, "dispatch_log", ()))
                try:
                    for blk, stats in engine.map_block_chain(chain, upstream):
                        # run_chain emits one entry per op in chain order; any
                        # appended presign-mapper entries are charged to the
                        # downstream dedup op they belong to
                        for k, st in enumerate(stats):
                            if k < n_own:
                                record(offset + n_push + k, st)
                            else:
                                charge(sig_owner, st)
                        yield blk
                finally:
                    charge_dispatch(owner, label, n0)
            stream = run()
        if observer is not None:
            stream = observer.tap("+".join(o.name for o in seg.ops), stream)
        offset += len(seg.ops)

    for blk in stream:
        check_cancel()
        yield blk


def stream_segments(
    blocks: Iterable[SampleBlock],
    segments: Sequence,  # List[fusion.Segment]
    engine,
    sink=None,
    collect: bool = True,
    n_workers_hint: int = 1,
    monitor: Optional[List[dict]] = None,
    cancel=None,
    observer=None,
) -> tuple:
    """Drain :func:`iter_stream_blocks`, writing completed blocks to ``sink``
    as they arrive, so with ``collect=False`` the full dataset is never
    materialized (unless a barrier forces it). A ``monitor`` list receives
    the live per-op entries up front (async observers see them update).

    Returns ``(out_blocks, per_op_entries, n_out)`` where ``per_op_entries``
    is one monitor entry per OP (aggregated across blocks) in plan order.
    """
    entries = seed_plan_entries(segments)
    if monitor is not None:
        monitor.extend(entries)
    out_blocks: List[SampleBlock] = []
    n_out = 0
    for blk in iter_stream_blocks(blocks, segments, engine, entries,
                                  n_workers_hint, cancel, observer):
        n_out += len(blk)
        if sink is not None:
            sink.write_block(blk)
        if collect:
            out_blocks.append(blk)
    return out_blocks, entries, n_out


class DJDataset:
    def __init__(self, blocks: List[SampleBlock], engine=None, lineage: Optional[List[dict]] = None):
        self.blocks = blocks
        self.engine = engine or LocalEngine()
        self.lineage: List[dict] = lineage or []

    # ------------------------------------------------------------------
    # construction / export
    # ------------------------------------------------------------------
    @classmethod
    def from_samples(cls, samples: Iterable[Sample], engine=None, n_blocks_hint: int = 1,
                     block_bytes: Optional[int] = None):
        samples = list(samples)
        n_workers = getattr(engine, "n_workers", n_blocks_hint) or 1
        total = max(1, len(samples))
        kw = {"block_bytes": block_bytes} if block_bytes else {}
        blocks = split_blocks(samples, n_workers=max(n_workers, n_blocks_hint),
                              total_hint_bytes=total * 256, **kw)
        return cls(blocks, engine)

    @classmethod
    def load(cls, src: Union[str, Iterable[Sample]], engine=None,
             validator=None, limit: Optional[int] = None,
             block_bytes: Optional[int] = None):
        """DatasetBuilder entry: path (jsonl/.zst) or iterable of samples."""
        if isinstance(src, str):
            samples = list(read_jsonl(src, limit=limit))
        else:
            samples = list(src)
        if validator is not None:
            validator.validate(samples)
        return cls.from_samples(samples, engine, block_bytes=block_bytes)

    def export(self, path: str) -> int:
        return write_jsonl(path, self.samples())

    # ------------------------------------------------------------------
    def samples(self) -> List[Sample]:
        return [s for b in self.blocks for s in b.samples]

    def __len__(self):
        return sum(len(b) for b in self.blocks)

    def __iter__(self):
        for b in self.blocks:
            yield from b.samples

    def stats_column(self, key: str) -> np.ndarray:
        vals = [s.get("stats", {}).get(key) for s in self]
        return np.asarray([v for v in vals if v is not None])

    # ------------------------------------------------------------------
    # processing
    # ------------------------------------------------------------------
    def process(self, ops: Union[Operator, Sequence[Operator]],
                batch_size: Optional[int] = None, drop_empty: bool = True,
                monitor: Optional[list] = None) -> "DJDataset":
        if isinstance(ops, Operator):
            ops = [ops]
        ds = self
        for op in ops:
            ds = ds._process_one(op, batch_size, drop_empty, monitor)
        return ds

    def process_streaming(
        self, ops: Union[Operator, Sequence[Operator]],
        monitor: Optional[list] = None, prefetch: int = 0,
    ) -> "DJDataset":
        """Streaming block-pipelined processing (paper §E.3): the op plan is
        partitioned into pipelineable segments separated by barrier ops, and
        each block traverses a whole segment in one engine dispatch instead
        of one dataset-wide barrier per op. Results match ``process()``.

        ``prefetch`` defaults to 0 here: the blocks are already in memory,
        so a prefetch thread buys no decode overlap (the executor's lazy
        file-backed source is where it pays off)."""
        from repro.core.fusion import plan_segments

        if isinstance(ops, Operator):
            ops = [ops]
        segments = plan_segments(list(ops))
        src: Iterable[SampleBlock] = self.blocks
        prefetcher = None
        if prefetch:
            src = prefetcher = BlockPrefetcher(src, depth=prefetch)
        try:
            blocks, entries, _ = stream_segments(
                src, segments, self.engine, collect=True,
                n_workers_hint=max(1, len(self.blocks)), monitor=monitor,
            )
        finally:
            if prefetcher is not None:
                prefetcher.close()
        return DJDataset(blocks or [SampleBlock([])], self.engine,
                         self.lineage + entries)

    def _process_one(self, op: Operator, batch_size, drop_empty, monitor) -> "DJDataset":
        t0 = clock.now()
        n_before = len(self)
        bs = batch_size or op.default_batch_size

        redispatches = 0
        if isinstance(op, BARRIER_TYPES):
            out = apply_dataset_op(op, self.samples())
            new_blocks = split_blocks(out, n_workers=max(1, len(self.blocks)))
        else:
            new_blocks, es = self.engine.map_batches(op, self.blocks, bs)
            redispatches = int(es.get("redispatches", 0))

        if drop_empty:
            new_blocks = [
                SampleBlock([s for s in b.samples if not S.is_empty(s)]) for b in new_blocks
            ]
            new_blocks = [b for b in new_blocks if len(b)] or [SampleBlock([])]

        dt = clock.now() - t0
        n_after = sum(len(b) for b in new_blocks)
        entry = {
            "op": op.name, "seconds": dt, "in": n_before, "out": n_after,
            "errors": len(op.errors),
            "speed": n_before / dt if dt > 0 else float("inf"),
            "redispatches": redispatches,
        }
        if monitor is not None:
            monitor.append(entry)
        return DJDataset(new_blocks, self.engine, self.lineage + [entry])
