"""Data-Juicer-Dataset: the engine-agnostic facade (paper §5.1).

Chainable ``process()`` (single OP, chained calls, or a list), unified
across Local / Parallel / Sharded engines, with sample-level fault
tolerance, dataset-level OP handling (Deduplicator / Selector / Grouper /
Aggregator) and per-OP lineage stats for insight mining.
"""
from __future__ import annotations

import time
from typing import Any, Dict, Iterable, List, Optional, Sequence, Union

import numpy as np

from repro.core import schema as S
from repro.core.engine import LocalEngine, make_engine
from repro.core.ops_base import (
    Aggregator, Deduplicator, Filter, Grouper, Operator, Selector,
)
from repro.core.storage import SampleBlock, read_jsonl, split_blocks, write_jsonl

Sample = Dict[str, Any]
GROUP_KEY = "__group__"


class DJDataset:
    def __init__(self, blocks: List[SampleBlock], engine=None, lineage: Optional[List[dict]] = None):
        self.blocks = blocks
        self.engine = engine or LocalEngine()
        self.lineage: List[dict] = lineage or []

    # ------------------------------------------------------------------
    # construction / export
    # ------------------------------------------------------------------
    @classmethod
    def from_samples(cls, samples: Iterable[Sample], engine=None, n_blocks_hint: int = 1):
        samples = list(samples)
        n_workers = getattr(engine, "n_workers", n_blocks_hint) or 1
        total = max(1, len(samples))
        blocks = split_blocks(samples, n_workers=max(n_workers, n_blocks_hint),
                              total_hint_bytes=total * 256)
        return cls(blocks, engine)

    @classmethod
    def load(cls, src: Union[str, Iterable[Sample]], engine=None,
             validator=None, limit: Optional[int] = None):
        """DatasetBuilder entry: path (jsonl/.zst) or iterable of samples."""
        if isinstance(src, str):
            samples = list(read_jsonl(src, limit=limit))
        else:
            samples = list(src)
        if validator is not None:
            validator.validate(samples)
        return cls.from_samples(samples, engine)

    def export(self, path: str) -> int:
        return write_jsonl(path, self.samples())

    # ------------------------------------------------------------------
    def samples(self) -> List[Sample]:
        return [s for b in self.blocks for s in b.samples]

    def __len__(self):
        return sum(len(b) for b in self.blocks)

    def __iter__(self):
        for b in self.blocks:
            yield from b.samples

    def stats_column(self, key: str) -> np.ndarray:
        vals = [s.get("stats", {}).get(key) for s in self]
        return np.asarray([v for v in vals if v is not None])

    # ------------------------------------------------------------------
    # processing
    # ------------------------------------------------------------------
    def process(self, ops: Union[Operator, Sequence[Operator]],
                batch_size: Optional[int] = None, drop_empty: bool = True,
                monitor: Optional[list] = None) -> "DJDataset":
        if isinstance(ops, Operator):
            ops = [ops]
        ds = self
        for op in ops:
            ds = ds._process_one(op, batch_size, drop_empty, monitor)
        return ds

    def _process_one(self, op: Operator, batch_size, drop_empty, monitor) -> "DJDataset":
        t0 = time.time()
        n_before = len(self)
        bs = batch_size or op.default_batch_size

        if isinstance(op, (Deduplicator, Selector, Grouper)):
            op.setup()
            samples = self.samples()
            if isinstance(op, Deduplicator):
                out = op.dedup(samples)
            elif isinstance(op, Selector):
                out = op.select(samples)
            else:  # Grouper
                out = [{GROUP_KEY: g, "meta": {}, "stats": {}} for g in op.group(samples)]
            new_blocks = split_blocks(out, n_workers=max(1, len(self.blocks)))
        elif isinstance(op, Aggregator):
            op.setup()
            out = []
            for s in self.samples():
                if GROUP_KEY in s:
                    out.append(op.run_batch_safe(s[GROUP_KEY])[0]
                               if s[GROUP_KEY] else S.empty_like({"text": ""}))
                else:
                    out.append(s)
            # non-grouped input: aggregate everything into one sample
            if out and not any(GROUP_KEY in s for s in self.samples()):
                out = op.run_batch_safe(self.samples())
            new_blocks = split_blocks(out, n_workers=max(1, len(self.blocks)))
        else:
            new_blocks, _ = self.engine.map_batches(op, self.blocks, bs)

        if drop_empty:
            new_blocks = [
                SampleBlock([s for s in b.samples if not S.is_empty(s)]) for b in new_blocks
            ]
            new_blocks = [b for b in new_blocks if len(b)] or [SampleBlock([])]

        dt = time.time() - t0
        n_after = sum(len(b) for b in new_blocks)
        entry = {
            "op": op.name, "seconds": dt, "in": n_before, "out": n_after,
            "errors": len(op.errors),
            "speed": n_before / dt if dt > 0 else float("inf"),
        }
        if monitor is not None:
            monitor.append(entry)
        return DJDataset(new_blocks, self.engine, self.lineage + [entry])
