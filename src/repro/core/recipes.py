"""Data recipes: end-to-end pipeline configs (paper Fig. 6).

Recipes are dicts (JSON-native) with a minimal YAML-subset parser so the
paper's YAML-recipe workflow works offline (PyYAML is unavailable):
top-level scalars, one level of nesting, and `process:` lists of
`- op_name:` blocks with scalar args.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Union

from repro.core.storage import json_loads


@dataclasses.dataclass
class Recipe:
    name: str = "recipe"
    dataset_path: Optional[str] = None
    export_path: Optional[str] = None
    process: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    np: int = 1  # worker count
    engine: str = "local"
    use_fusion: bool = True
    use_reordering: bool = True
    checkpoint_dir: Optional[str] = None
    insight: bool = False
    block_bytes: Optional[int] = None  # None -> storage.DEFAULT_BLOCK_BYTES

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Recipe":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})

    @classmethod
    def load(cls, path: str) -> "Recipe":
        with open(path, "rb") as f:
            raw = f.read()
        if path.endswith(".json"):
            return cls.from_dict(json_loads(raw))
        return cls.from_dict(parse_simple_yaml(raw.decode("utf-8")))

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


def _scalar(tok: str) -> Any:
    t = tok.strip().strip('"').strip("'")
    if t.lower() in ("true", "false"):
        return t.lower() == "true"
    if t.lower() in ("null", "none", "~", ""):
        return None
    try:
        return int(t)
    except ValueError:
        pass
    try:
        return float(t)
    except ValueError:
        pass
    return t


def parse_simple_yaml(text: str) -> Dict[str, Any]:
    """Minimal YAML subset: `key: value`, `process:` with `- op:` blocks
    whose args are indented `key: value` lines."""
    root: Dict[str, Any] = {}
    cur_list: Optional[List[Dict[str, Any]]] = None
    cur_item: Optional[Dict[str, Any]] = None
    for raw in text.splitlines():
        if not raw.strip() or raw.strip().startswith("#"):
            continue
        indent = len(raw) - len(raw.lstrip())
        line = raw.strip()
        if indent == 0:
            cur_item = None
            if line.endswith(":"):
                cur_list = []
                root[line[:-1]] = cur_list
            else:
                k, _, v = line.partition(":")
                root[k.strip()] = _scalar(v)
                cur_list = None
        elif line.startswith("- "):
            if cur_list is None:
                raise ValueError(f"list item outside list: {raw!r}")
            body = line[2:]
            if body.endswith(":"):
                cur_item = {"name": body[:-1].strip()}
            elif ":" in body:
                k, _, v = body.partition(":")
                cur_item = {"name": k.strip()} if v.strip() == "" else {k.strip(): _scalar(v)}
                if "name" not in cur_item:
                    cur_item = {"name": k.strip(), **cur_item}
            else:
                cur_item = {"name": body.strip()}
            cur_list.append(cur_item)
        else:  # nested arg of the current list item
            if cur_item is None:
                k, _, v = line.partition(":")
                root[k.strip()] = _scalar(v)
            else:
                k, _, v = line.partition(":")
                cur_item[k.strip()] = _scalar(v)
    return root
