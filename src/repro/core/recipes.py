"""Data recipes: end-to-end pipeline configs (paper Fig. 6).

Recipes are dicts (JSON-native) with a minimal YAML-subset parser so the
paper's YAML-recipe workflow works offline (PyYAML is unavailable):
top-level scalars, one level of nesting, and `process:` lists of
`- op_name:` blocks with scalar args.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Union

from repro.core.storage import json_dumps, json_loads


@dataclasses.dataclass
class Recipe:
    name: str = "recipe"
    dataset_path: Optional[str] = None
    export_path: Optional[str] = None
    process: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    np: int = 1  # worker count
    engine: str = "local"
    use_fusion: bool = True
    use_reordering: bool = True
    checkpoint_dir: Optional[str] = None
    insight: bool = False
    block_bytes: Optional[int] = None  # None -> storage.DEFAULT_BLOCK_BYTES
    # cross-run worker-health file (dispatch.HealthRegistry): quarantines
    # persist here and previously-quarantined slots start on probation
    health_path: Optional[str] = None
    # block representation for streamed file sources: "columnar" decodes
    # JSONL straight into struct-of-arrays ColumnBlocks (repro.core.columnar)
    # — workers receive column buffers, vectorized filters skip row dicts,
    # pushdown-safe filters run at decode; "row" keeps list-of-dict blocks
    block_format: str = "columnar"
    # pre-optimized op plan (list of op configs). When set, the executor
    # skips probe + optimize and runs EXACTLY this plan — how cluster
    # failover replays a plan persisted at first claim (api.cluster)
    fixed_plan: Optional[List[Dict[str, Any]]] = None
    # resident in-flight block bytes budget for the engine dispatcher
    # (memory-pressure window shrink); None -> DJ_BLOCK_MEM_BUDGET env or off
    mem_budget: Optional[int] = None
    # intra-job scale-out (api.shards): >1 splits this job into that many
    # row-range shard tasks at first claim, executed by however many
    # ClusterRunners are around and spliced back in input order. Only
    # meaningful for cluster-submitted jobs; 0/1 runs single-runner.
    # "auto" picks the count from input size + live runner cards at claim
    # time (api.shards.resolve_shard_count) and records the decision in the
    # job trace.
    shards: Union[int, str] = 0
    # [lo, hi) row window of dataset_path this run reads — how a shard task
    # scopes itself to its range. Internal: set by api.shards, not by users.
    row_range: Optional[List[int]] = None
    # owning tenant for cluster submission (api.cluster): quota admission,
    # fair-share claiming and per-tenant SLOs key on it. None means the
    # default tenant — single-tenant recipes never need to set it.
    tenant: Optional[str] = None
    # trace context {"trace_id", "span_id"} linking this run's spans into an
    # enclosing trace (core.obs). Internal: minted at cluster submit /
    # Executor.run, threaded through shard tasks — not set by users.
    trace: Optional[Dict[str, Any]] = None

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Recipe":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})

    @classmethod
    def load(cls, path: str) -> "Recipe":
        with open(path, "rb") as f:
            raw = f.read()
        if path.endswith(".json"):
            return cls.from_dict(json_loads(raw))
        return cls.from_dict(parse_simple_yaml(raw.decode("utf-8")))

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    def save(self, path: str) -> None:
        """Persist in the format ``load`` will parse back: JSON for ``.json``
        paths, the simple-YAML subset otherwise — lets a fluent Pipeline be
        frozen into a shareable declarative recipe."""
        if path.endswith(".json"):
            with open(path, "wb") as f:
                f.write(json_dumps(self.to_dict()))
            return
        with open(path, "w", encoding="utf-8") as f:
            f.write(dump_simple_yaml(self.to_dict()))


def _scalar(tok: str) -> Any:
    t = tok.strip()
    if t.startswith("[") and t.endswith("]"):
        # inline flow list of scalars: [a, b] (row_range, keep_langs, ...).
        # naive comma split — the dumper's reparse check refuses values
        # (embedded commas, nesting) this can't round-trip
        inner = t[1:-1].strip()
        return [] if not inner else [_scalar(p) for p in inner.split(",")]
    t = t.strip('"').strip("'")
    if t.lower() in ("true", "false"):
        return t.lower() == "true"
    if t.lower() in ("null", "none", "~", ""):
        return None
    try:
        return int(t)
    except ValueError:
        pass
    try:
        return float(t)
    except ValueError:
        pass
    return t


def _yaml_scalar(v: Any) -> str:
    if isinstance(v, bool):
        return "true" if v else "false"
    if v is None:
        return "null"
    if isinstance(v, str):
        # the subset parser can't round-trip strings it would re-read as a
        # different type/value (numbers, booleans, padded text, newlines) —
        # refuse loudly rather than reload a silently different recipe
        # a trailing ':' would make the dumped line reparse as a list header
        if ("\n" in v or v.endswith(":") or _scalar(v) != v
                or not isinstance(_scalar(v), str)):
            raise ValueError(
                f"string {v!r} does not survive the simple-YAML subset; "
                f"save as .json")
        return v
    if isinstance(v, (list, tuple)):
        out = "[" + ", ".join(_yaml_scalar(x) for x in v) + "]"
        if _scalar(out) != list(v):  # validate by reparse
            raise ValueError(
                f"list {v!r} does not survive the simple-YAML subset; "
                f"save as .json")
        return out
    if not isinstance(v, (int, float)):
        raise ValueError(
            f"cannot express {v!r} in the simple-YAML subset; save as .json")
    return str(v)


def dump_simple_yaml(d: Dict[str, Any]) -> str:
    """Inverse of ``parse_simple_yaml`` for recipe dicts: top-level scalars
    plus a ``process:`` list of ``- op_name:`` blocks with scalar args."""
    lines: List[str] = []
    for k, v in d.items():
        # process/fixed_plan are op-config lists, dumped as blocks below.
        # trace is runtime-internal context, never part of a saved recipe
        if k in ("process", "fixed_plan", "trace") or v is None:
            continue
        lines.append(f"{k}: {_yaml_scalar(v)}")
    _dump_op_list(lines, "process", d.get("process", []))
    if d.get("fixed_plan") is not None:
        # a pinned plan is load-bearing (failover replays it verbatim) —
        # round-trip it like process; nested configs (fused_op) raise in
        # _yaml_scalar rather than being dropped silently
        _dump_op_list(lines, "fixed_plan", d["fixed_plan"])
    return "\n".join(lines) + "\n"


def _dump_op_list(lines: List[str], key: str, cfgs: List[Dict[str, Any]]) -> None:
    lines.append(f"{key}:")
    for cfg in cfgs:
        cfg = dict(cfg)
        name = cfg.pop("name")
        if not cfg:
            lines.append(f"  - {name}")
            continue
        lines.append(f"  - {name}:")
        for ak, av in cfg.items():
            lines.append(f"      {ak}: {_yaml_scalar(av)}")


def parse_simple_yaml(text: str) -> Dict[str, Any]:
    """Minimal YAML subset: `key: value`, `process:` with `- op:` blocks
    whose args are indented `key: value` lines."""
    root: Dict[str, Any] = {}
    cur_list: Optional[List[Dict[str, Any]]] = None
    cur_item: Optional[Dict[str, Any]] = None
    for raw in text.splitlines():
        if not raw.strip() or raw.strip().startswith("#"):
            continue
        indent = len(raw) - len(raw.lstrip())
        line = raw.strip()
        if indent == 0:
            cur_item = None
            if line.endswith(":"):
                cur_list = []
                root[line[:-1]] = cur_list
            else:
                k, _, v = line.partition(":")
                root[k.strip()] = _scalar(v)
                cur_list = None
        elif line.startswith("- "):
            if cur_list is None:
                raise ValueError(f"list item outside list: {raw!r}")
            body = line[2:]
            if body.endswith(":"):
                cur_item = {"name": body[:-1].strip()}
            elif ":" in body:
                k, _, v = body.partition(":")
                cur_item = {"name": k.strip()} if v.strip() == "" else {k.strip(): _scalar(v)}
                if "name" not in cur_item:
                    cur_item = {"name": k.strip(), **cur_item}
            else:
                cur_item = {"name": body.strip()}
            cur_list.append(cur_item)
        else:  # nested arg of the current list item
            if cur_item is None:
                k, _, v = line.partition(":")
                root[k.strip()] = _scalar(v)
            else:
                k, _, v = line.partition(":")
                cur_item[k.strip()] = _scalar(v)
    return root
