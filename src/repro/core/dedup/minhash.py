"""MinHash-LSH fuzzy deduplication (paper §E.1, Table 2; Broder [5,6]).

Pipeline: shingle -> 64-bit shingle hashes -> P permuted min-hashes
(signature) -> LSH banding -> candidate pairs via HASH-BASED AGGREGATION
(band-hash dict, not a sort/groupby shuffle — one of the two tricks behind
the paper's 3.3x) -> load-balanced union-find -> keep one doc per component.

Signature computation is vectorized numpy on the host and has a Pallas TPU
kernel (``repro.kernels.minhash``) for the accelerator path — it is the
embarrassingly-parallel 99% of dedup compute.
"""
from __future__ import annotations

import hashlib
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np

MERSENNE61 = (1 << 61) - 1
_MAXU32 = np.uint64(0xFFFFFFFF)


def shingle_hashes(text: str, n: int = 5, max_shingles: int = 512) -> np.ndarray:
    """Word-level n-gram shingles -> uint64 hashes (stable across runs)."""
    words = text.split()
    if len(words) < n:
        grams = [" ".join(words)] if words else [""]
    else:
        grams = [" ".join(words[i : i + n]) for i in range(len(words) - n + 1)]
    if len(grams) > max_shingles:
        step = len(grams) / max_shingles
        grams = [grams[int(i * step)] for i in range(max_shingles)]
    out = np.empty(len(grams), dtype=np.uint64)
    for i, g in enumerate(grams):
        out[i] = np.frombuffer(
            hashlib.blake2b(g.encode("utf-8"), digest_size=8).digest(), dtype=np.uint64
        )[0]
    return out


def make_permutations(n_perm: int, seed: int = 42) -> Tuple[np.ndarray, np.ndarray]:
    rng = np.random.default_rng(seed)
    a = rng.integers(1, MERSENNE61 - 1, size=n_perm, dtype=np.uint64)
    b = rng.integers(0, MERSENNE61 - 1, size=n_perm, dtype=np.uint64)
    return a, b


def signature_ref(hashes: np.ndarray, a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Oracle minhash signature: min over shingles of (a*h + b) mod M61,
    folded to 32 bits. hashes (S,), a/b (P,) -> (P,) uint32."""
    if hashes.size == 0:
        return np.full(a.shape, 0xFFFFFFFF, dtype=np.uint32)
    h = hashes[None, :].astype(np.uint64)
    vals = (a[:, None] * h + b[:, None]) % np.uint64(MERSENNE61)
    folded = (vals & _MAXU32) ^ (vals >> np.uint64(32))
    return folded.min(axis=1).astype(np.uint32)


def pad_docs(docs: Sequence[np.ndarray]) -> Tuple[np.ndarray, np.ndarray]:
    """Pad variable-length shingle arrays to a dense (N, S_max) uint64
    matrix plus validity mask — the shared super-batch layout of the Pallas
    kernel, the vectorized host path and the streaming SignatureBatcher."""
    max_s = max((d.size for d in docs), default=1) or 1
    padded = np.zeros((len(docs), max_s), dtype=np.uint64)
    mask = np.zeros((len(docs), max_s), dtype=bool)
    for i, d in enumerate(docs):
        padded[i, : d.size] = d
        mask[i, : d.size] = True
    return padded, mask


def _mod_m61(v: np.ndarray) -> np.ndarray:
    """Branch-free Mersenne reduction: ``v % (2^61 - 1)`` for uint64 ``v``
    without integer division (numpy's uint64 ``%`` is a scalar div per
    element — the hot-loop killer). ``(v & M61) + (v >> 61) < M61 + 8``, so
    one conditional subtract completes the reduction. Bit-exact with ``%``."""
    m61 = np.uint64(MERSENNE61)
    r = (v & m61) + (v >> np.uint64(61))
    return np.where(r >= m61, r - m61, r)


def signatures_batch_vectorized(
    docs: Sequence[np.ndarray], a: np.ndarray, b: np.ndarray,
    chunk_elems: int = 1 << 15,
) -> np.ndarray:
    """One vectorized dispatch for a whole super-batch of docs: pad shingle
    arrays to (rows, S_max) and compute signatures doc-chunk by doc-chunk so
    the (rows, n_perm, S_max) intermediate stays cache-sized. Identical
    arithmetic to :func:`signature_ref` (same uint64 wrap, same M61
    reduction via the division-free Mersenne fold, same 32-bit fold), so
    results are byte-identical to :func:`signature_ref`. NOTE: on hosts
    where numpy's scalar-divisor uint64 ``%`` is already optimized, the
    cache-resident per-doc reference loop measures as fast or faster — the
    streaming ``SignatureBatcher`` therefore keeps the per-doc loop for its
    host path and this entry serves straggler/fallback batches."""
    n = len(docs)
    n_perm = a.shape[0]
    if n == 0:
        return np.zeros((0, n_perm), dtype=np.uint32)
    padded, mask = pad_docs(docs)
    max_s = padded.shape[1]
    out = np.empty((n, n_perm), dtype=np.uint32)
    sentinel = np.uint32(0xFFFFFFFF)
    rows = max(1, chunk_elems // (n_perm * max_s))
    for i0 in range(0, n, rows):
        h = padded[i0 : i0 + rows]  # (R, S)
        m = mask[i0 : i0 + rows]
        vals = _mod_m61(a[None, :, None] * h[:, None, :] + b[None, :, None])
        folded = ((vals & _MAXU32) ^ (vals >> np.uint64(32))).astype(np.uint32)
        np.minimum.reduce(
            np.where(m[:, None, :], folded, sentinel), axis=2,
            out=out[i0 : i0 + h.shape[0]])
    return out


def signatures_batch(
    docs: Sequence[np.ndarray], n_perm: int = 128, seed: int = 42,
    use_kernel: bool = False,
) -> np.ndarray:
    """(n_docs, n_perm) uint32 signatures. ``use_kernel`` routes through the
    Pallas TPU kernel (interpret mode on CPU)."""
    a, b = make_permutations(n_perm, seed)
    if use_kernel:
        from repro.kernels.minhash.ops import minhash_signatures

        padded, mask = pad_docs(docs)
        return np.asarray(minhash_signatures(padded, mask, a, b))
    out = np.empty((len(docs), n_perm), dtype=np.uint32)
    for i, d in enumerate(docs):
        out[i] = signature_ref(d, a, b)
    return out


def lsh_bands(signatures: np.ndarray, n_bands: int) -> np.ndarray:
    """Hash each band of each signature -> (n_docs, n_bands) uint64 keys."""
    n_docs, n_perm = signatures.shape
    assert n_perm % n_bands == 0
    r = n_perm // n_bands
    bands = signatures.reshape(n_docs, n_bands, r).astype(np.uint64)
    # polynomial band hash (vectorized)
    key = np.zeros((n_docs, n_bands), dtype=np.uint64)
    mult = np.uint64(1099511628211)
    for i in range(r):
        key = key * mult + bands[:, :, i]
    return key


def candidate_pairs_hash_agg(band_keys: np.ndarray) -> List[Tuple[int, int]]:
    """Hash-based aggregation: bucket docs by (band, key) in a dict and emit
    star edges to the bucket head — avoids the expensive sort/groupby
    shuffle of LSH-on-big-data-engines (paper: 'hash-based aggregation')."""
    pairs: List[Tuple[int, int]] = []
    n_docs, n_bands = band_keys.shape
    for band in range(n_bands):
        buckets: Dict[int, int] = {}
        col = band_keys[:, band]
        for doc in range(n_docs):
            k = int(col[doc])
            head = buckets.get(k)
            if head is None:
                buckets[k] = doc
            else:
                pairs.append((head, doc))
    return pairs


def jaccard(a: np.ndarray, b: np.ndarray) -> float:
    sa, sb = set(a.tolist()), set(b.tolist())
    if not sa and not sb:
        return 1.0
    return len(sa & sb) / max(1, len(sa | sb))


def jaccard_unique(a: np.ndarray, b: np.ndarray) -> float:
    """:func:`jaccard` over arrays already deduplicated by ``np.unique`` —
    sorted-merge intersection instead of two Python set builds (the per-edge
    hot path of the streaming verifier). Equal to ``jaccard`` on the raw
    arrays, since set semantics ignore multiplicity."""
    if a.size == 0 and b.size == 0:
        return 1.0
    inter = np.intersect1d(a, b, assume_unique=True).size
    return inter / max(1, a.size + b.size - inter)


def minhash_dedup_indices(
    texts: Sequence[str],
    n_perm: int = 128,
    n_bands: int = 16,
    ngram: int = 5,
    jaccard_threshold: float = 0.7,
    verify_jaccard: bool = True,
    backend: str = "balanced",  # balanced | naive
    n_partitions: int = 8,
    use_kernel: bool = False,
    seed: int = 42,
) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (keep_mask (n,), component_id (n,))."""
    from repro.core.dedup.unionfind import (
        BalancedUnionFind, naive_components, partitioned_union,
    )

    n = len(texts)
    if n == 0:
        return np.zeros(0, bool), np.zeros(0, np.int64)
    docs = [shingle_hashes(t, n=ngram) for t in texts]
    sigs = signatures_batch(docs, n_perm=n_perm, seed=seed, use_kernel=use_kernel)
    keys = lsh_bands(sigs, n_bands)
    pairs = candidate_pairs_hash_agg(keys)
    if verify_jaccard and jaccard_threshold > 0:
        pairs = [
            (a, b) for a, b in pairs
            if jaccard(docs[a], docs[b]) >= jaccard_threshold
        ]
    if backend == "naive":
        comp = naive_components(n, pairs)
    else:
        uf = partitioned_union(n, pairs, n_partitions=n_partitions)
        comp = uf.components()
    keep = np.zeros(n, dtype=bool)
    seen: Dict[int, bool] = {}
    for i in range(n):
        c = int(comp[i])
        if c not in seen:
            seen[c] = True
            keep[i] = True
    return keep, comp
