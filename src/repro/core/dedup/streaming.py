"""Streaming incremental MinHash-LSH deduplication (paper §E.1 + §E.3).

Turns the MinHash ``Deduplicator`` from a pipeline *barrier* into a stateful
pipeline *stage*: blocks flow through, signatures are computed in
device-sized super-batches, candidate pairs are found by incremental
hash-based band aggregation (no sort/shuffle), and a growable union-find
decides keep/drop online — so a recipe containing dedup keeps the streaming
executor's block pipelining and bounded-memory guarantee.

Three components, composed by :class:`StreamingMinHashState`:

* :class:`SignatureBatcher` — accumulates shingled docs across blocks into
  super-batches and dispatches the existing ``repro.kernels.minhash`` Pallas
  kernel once per batch instead of once per block (bucketed pad shapes keep
  the compile cache bounded) — the ShardedEngine super-batching pattern
  applied to dedup; the host path keeps the cache-resident per-doc loop.
  When a pipelineable chain precedes the stage, signatures are instead
  precomputed worker-side (``presign_ops`` plants an internal
  ``minhash_signature_mapper`` on that chain's dispatch), overlapping the
  embarrassingly-parallel compute with driver-side indexing.
* :class:`LSHBandIndex` — incremental band-hash -> bucket-head registry
  (hash aggregation, paper §E.1). Shingle payloads for bucket heads — the
  dominant memory term, needed only for Jaccard verification — spill to an
  append-only disk file beyond a resident budget, so resident memory is
  O(band index), not O(dataset).
* :class:`StreamingUnionFind` — growable union-find with keep-first
  bookkeeping.

Semantics vs. the exact barriered result (``minhash_dedup_indices``):

* **keep-first** (single pass): doc *i* is kept iff no earlier doc is
  connected to it *at the time i arrives*. Candidate pairs always point
  backwards (bucket head index < doc index), so the exact keep set is a
  subset of the keep-first keep set: if *i* is the minimum of its final
  component it is also the minimum of its at-time component (which only
  contains docs <= i from the same final component). Keep-first may
  additionally keep docs whose components merge only *retroactively*
  (a later doc bridging two already-emitted components). This containment
  relation is property-tested in ``tests/test_streaming_dedup.py``.
* **windowed** (``windowed=True``): keep-first with a bounded
  retroactive-merge horizon — each doc's keep/drop decision is deferred
  until ``window`` newer docs have arrived, so merges bridged within the
  horizon are honored. Component minima only decrease over time, so the
  keep sets nest: ``exact ⊆ windowed ⊆ keep_first`` (``window=0``
  degenerates to keep_first; ``window=∞`` would be exact), memory stays
  O(index + window), and latency stays bounded. Property-tested against
  both oracles.
* **exact** (two passes, ``exact=True``): pass 1 streams blocks through,
  building the full verified candidate-pair registry in the barriered
  path's band-major order while spilling the samples to a disk file; the
  finalize pass replays the spill with the *final* components, reproducing
  ``minhash_dedup_indices`` (same union-find backend, same pair order, same
  component ids) — byte-identical output, still O(index + one block)
  resident memory, at the cost of one disk round-trip.
"""
from __future__ import annotations

import os
import tempfile
import time
from collections import OrderedDict, deque
from typing import Any, Dict, Iterable, Iterator, List, Optional, Tuple

import numpy as np

from repro.core import obs
from repro.core.dedup.minhash import (
    jaccard_unique, lsh_bands, make_permutations, shingle_hashes,
    signatures_batch_vectorized,
)

Sample = Dict[str, Any]

DEFAULT_SUPER_BATCH = 2048
DEFAULT_RESIDENT_SHINGLES = 50_000


# ---------------------------------------------------------------------------
# signature super-batching
# ---------------------------------------------------------------------------


class SignatureBatcher:
    """Accumulates shingled docs across blocks and computes MinHash
    signatures in one dispatch per super-batch.

    Per-doc signatures are independent, so batching composition never changes
    values — only how often the (vectorized numpy or Pallas) signature kernel
    is entered. ``add()`` buffers; ``ready`` flips once ``super_batch`` docs
    are pending; ``flush()`` returns ``(payloads, docs, sigs)`` for
    everything buffered.
    """

    def __init__(self, n_perm: int = 128, ngram: int = 5, seed: int = 42,
                 use_kernel: bool = False, super_batch: int = DEFAULT_SUPER_BATCH):
        self.n_perm = n_perm
        self.ngram = ngram
        self.use_kernel = use_kernel
        self.super_batch = max(1, super_batch)
        self._a, self._b = make_permutations(n_perm, seed)
        self._docs: List[np.ndarray] = []
        self._payloads: List[Any] = []
        self.docs_in = 0
        self.dispatches = 0

    def add(self, text: str, payload: Any = None) -> None:
        self._docs.append(shingle_hashes(text, n=self.ngram))
        self._payloads.append(payload)
        self.docs_in += 1

    @property
    def pending(self) -> int:
        return len(self._docs)

    @property
    def ready(self) -> bool:
        return len(self._docs) >= self.super_batch

    def flush(self) -> Tuple[List[Any], List[np.ndarray], np.ndarray]:
        """One signature dispatch for every buffered doc."""
        docs, payloads = self._docs, self._payloads
        self._docs, self._payloads = [], []
        if not docs:
            return [], [], np.zeros((0, self.n_perm), dtype=np.uint32)
        self.dispatches += 1
        # kernel-batch span: flush runs driver-side, so the ambient parent
        # is the enclosing run/segment span and timing comes straight from
        # the injectable clock (docs/observability.md)
        cur = obs.current_span()
        kb = obs.start_span(cur.trace_id if cur else None, "kernel:minhash",
                            kind="kernel_batch",
                            parent_id=cur.span_id if cur else None)
        m = obs.metrics()
        m.inc("dedup.signature_dispatches_total")
        m.inc("dedup.signature_docs_total", len(docs))
        if self.use_kernel:
            from repro.kernels.minhash.ops import minhash_signatures_packed

            # packed-ragged dispatch: one vectorized scatter builds the
            # dense layout instead of a per-doc pad loop (bit-exact)
            lens = np.fromiter((d.size for d in docs), np.int64, len(docs))
            offsets = np.zeros(len(docs) + 1, np.int64)
            np.cumsum(lens, out=offsets[1:])
            values = np.concatenate(docs) if len(docs) else np.zeros(0, np.uint64)
            sigs = np.asarray(minhash_signatures_packed(
                values, offsets, self._a, self._b))
        else:
            from repro.core.dedup.minhash import signature_ref

            # per-doc reference loop: cache-resident (128, S) intermediates
            # beat padded super-batch arrays on the host (numpy's scalar
            # uint64 % is fast; DRAM traffic is not) — the super-batch win
            # on the host path is dispatch amortization for the KERNEL
            # branch above and presign offload, not host vectorization
            sigs = np.empty((len(docs), self.n_perm), dtype=np.uint32)
            for i, d in enumerate(docs):
                sigs[i] = signature_ref(d, self._a, self._b)
        if kb is not None:
            kb.set(docs=len(docs), kernel=self.use_kernel).end()
        return payloads, docs, sigs


# ---------------------------------------------------------------------------
# spillable shingle store
# ---------------------------------------------------------------------------


class ShingleStore:
    """doc id -> uint64 shingle array with a bounded resident set.

    Entries past ``max_resident`` spill (LRU) to an append-only binary file;
    the in-memory side keeps only an ``id -> (offset, count)`` index. Arrays
    are immutable, so a re-loaded entry never has to be re-written.
    """

    def __init__(self, spill_dir: Optional[str] = None,
                 max_resident: int = DEFAULT_RESIDENT_SHINGLES):
        self.max_resident = max(1, max_resident)
        self._hot: "OrderedDict[int, np.ndarray]" = OrderedDict()
        self._offsets: Dict[int, Tuple[int, int]] = {}
        self._spill_dir = spill_dir
        self._path: Optional[str] = None
        self._write_fh = None
        self._read_fh = None
        self._write_pos = 0
        self.spilled = 0
        self.reloads = 0

    def _ensure_file(self) -> None:
        if self._write_fh is None:
            os.makedirs(self._spill_dir, exist_ok=True) if self._spill_dir else None
            fd, self._path = tempfile.mkstemp(
                prefix="dj-shingles-", suffix=".bin", dir=self._spill_dir)
            self._write_fh = os.fdopen(fd, "wb")

    def put(self, doc_id: int, arr: np.ndarray) -> None:
        self._hot[doc_id] = arr
        self._hot.move_to_end(doc_id)
        while len(self._hot) > self.max_resident:
            victim, varr = self._hot.popitem(last=False)
            if victim not in self._offsets:  # write-once
                self._ensure_file()
                raw = np.ascontiguousarray(varr, dtype=np.uint64).tobytes()
                self._write_fh.write(raw)
                self._offsets[victim] = (self._write_pos, varr.size)
                self._write_pos += len(raw)
                self.spilled += 1

    def get(self, doc_id: int) -> np.ndarray:
        arr = self._hot.get(doc_id)
        if arr is not None:
            self._hot.move_to_end(doc_id)
            return arr
        off, count = self._offsets[doc_id]  # KeyError = caller bug
        self._write_fh.flush()
        if self._read_fh is None:
            self._read_fh = open(self._path, "rb")
        self._read_fh.seek(off)
        arr = np.frombuffer(self._read_fh.read(count * 8), dtype=np.uint64)
        self.reloads += 1
        self.put(doc_id, arr)
        return arr

    def __contains__(self, doc_id: int) -> bool:
        return doc_id in self._hot or doc_id in self._offsets

    def __len__(self) -> int:
        return len(self._hot) + sum(1 for k in self._offsets if k not in self._hot)

    def close(self) -> None:
        for fh in (self._write_fh, self._read_fh):
            if fh is not None:
                try:
                    fh.close()
                except Exception:  # noqa: BLE001 — best-effort cleanup
                    pass
        self._write_fh = self._read_fh = None
        if self._path:
            try:
                os.remove(self._path)
            except OSError:
                pass
            self._path = None


# ---------------------------------------------------------------------------
# incremental LSH band index
# ---------------------------------------------------------------------------


class LSHBandIndex:
    """Incremental band-hash -> bucket-head registry (hash aggregation).

    ``insert`` reproduces ``candidate_pairs_hash_agg``'s star-edge structure
    exactly: the bucket head for a ``(band, key)`` is the first doc inserted
    with that key, so inserting docs in index order yields the identical
    candidate-pair *set* as the barriered batch pass. The resident core is
    the key->head int maps (O(index)); shingle payloads — needed only for
    Jaccard verification and only for bucket heads — live in a spillable
    :class:`ShingleStore`.
    """

    def __init__(self, n_bands: int, spill_dir: Optional[str] = None,
                 max_resident_shingles: int = DEFAULT_RESIDENT_SHINGLES):
        self.n_bands = n_bands
        self._buckets: List[Dict[int, int]] = [dict() for _ in range(n_bands)]
        self.shingles = ShingleStore(spill_dir, max_resident_shingles)
        self.n_docs = 0

    def insert(self, doc_id: int, band_keys: np.ndarray,
               doc_hashes: np.ndarray) -> List[Tuple[int, int, int]]:
        """Register one doc; returns ``(band, head, doc_id)`` candidate
        edges against existing bucket heads (may repeat a head across
        bands, matching the barriered pair stream)."""
        pairs: List[Tuple[int, int, int]] = []
        created = False
        for band in range(self.n_bands):
            bucket = self._buckets[band]
            key = int(band_keys[band])
            head = bucket.get(key)
            if head is None:
                bucket[key] = doc_id
                created = True
            else:
                pairs.append((band, head, doc_id))
        if created:
            # only bucket heads can appear as a future pair's left endpoint
            self.shingles.put(doc_id, doc_hashes)
        self.n_docs += 1
        return pairs

    @property
    def n_buckets(self) -> int:
        return sum(len(b) for b in self._buckets)

    def close(self) -> None:
        self.shingles.close()


# ---------------------------------------------------------------------------
# growable keep-first union-find
# ---------------------------------------------------------------------------


class StreamingUnionFind:
    """Union-by-rank + path-halving over a growable id space, tracking each
    component's minimum member — the keep-first representative."""

    def __init__(self):
        self._parent: Dict[int, int] = {}
        self._rank: Dict[int, int] = {}
        self._min: Dict[int, int] = {}

    def add(self, x: int) -> None:
        if x not in self._parent:
            self._parent[x] = x
            self._rank[x] = 0
            self._min[x] = x

    def find(self, x: int) -> int:
        p = self._parent
        while p[x] != x:
            p[x] = p[p[x]]  # path halving
            x = p[x]
        return x

    def union(self, a: int, b: int) -> bool:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return False
        if self._rank[ra] < self._rank[rb]:
            ra, rb = rb, ra
        self._parent[rb] = ra
        self._min[ra] = min(self._min[ra], self._min[rb])
        if self._rank[ra] == self._rank[rb]:
            self._rank[ra] += 1
        return True

    def connected(self, a: int, b: int) -> bool:
        return self.find(a) == self.find(b)

    def component_min(self, x: int) -> int:
        """First-arrived member of x's component (the kept representative)."""
        return self._min[self.find(x)]

    def __len__(self) -> int:
        return len(self._parent)


# ---------------------------------------------------------------------------
# the streaming dedup stage
# ---------------------------------------------------------------------------


class StreamingMinHashState:
    """Stateful stream stage: consumes upstream SampleBlocks, emits deduped
    SampleBlocks (see module docstring for keep-first vs exact semantics).

    Driven by ``dataset.iter_stream_blocks`` through :meth:`stream_blocks`;
    all heavyweight state (band index, union-find, spill files) lives for
    exactly one segment traversal and is released in ``close()``.
    """

    def __init__(self, *, n_perm: int = 128, n_bands: int = 16, ngram: int = 5,
                 jaccard_threshold: float = 0.7, verify_jaccard: bool = True,
                 backend: str = "balanced", n_partitions: int = 8,
                 use_kernel: bool = False, seed: int = 42, exact: bool = False,
                 windowed: bool = False, window: int = 4096,
                 super_batch: int = DEFAULT_SUPER_BATCH,
                 spill_dir: Optional[str] = None,
                 max_resident_shingles: int = DEFAULT_RESIDENT_SHINGLES):
        if n_perm % n_bands:
            raise ValueError(f"n_perm ({n_perm}) must divide into n_bands ({n_bands})")
        self.n_perm = n_perm
        self.n_bands = n_bands
        self.ngram = ngram
        self.seed = seed
        self.use_kernel = use_kernel
        self.jaccard_threshold = jaccard_threshold
        self.verify = verify_jaccard and jaccard_threshold > 0
        self.backend = backend
        self.n_partitions = n_partitions
        self.exact = exact
        self.windowed = bool(windowed) and not exact
        self.window = max(0, int(window))
        # (gid, sample) pairs whose keep/drop decision is still deferred
        self._window_q: "deque[Tuple[int, Sample]]" = deque()
        self.batcher = SignatureBatcher(n_perm=n_perm, ngram=ngram, seed=seed,
                                        use_kernel=use_kernel, super_batch=super_batch)
        self.index = LSHBandIndex(n_bands, spill_dir=spill_dir,
                                  max_resident_shingles=max_resident_shingles)
        self.uf = StreamingUnionFind()
        self.n_seen = 0
        self.n_kept = 0
        self.n_pairs = 0
        self.n_verified = 0
        # exact mode: verified pairs in the barriered band-major order + the
        # sample spill (disk, not memory)
        self._pairs_by_band: List[List[Tuple[int, int]]] = [[] for _ in range(n_bands)]
        self._spill_dir = spill_dir
        self._spill_path: Optional[str] = None
        self._spill_fh = None

    # -- exact-mode sample spill ------------------------------------------
    def _ensure_spill(self) -> None:
        if self._spill_fh is None:
            if self._spill_dir:
                os.makedirs(self._spill_dir, exist_ok=True)
            fd, self._spill_path = tempfile.mkstemp(
                prefix="dj-dedup-spill-", suffix=".jsonl", dir=self._spill_dir)
            self._spill_fh = os.fdopen(fd, "wb")

    def _spill_samples(self, samples: List[Sample]) -> None:
        from repro.core.storage import json_dumps

        self._ensure_spill()
        for s in samples:
            self._spill_fh.write(json_dumps(s) + b"\n")

    def _spill_lines(self, lines: Iterable[bytes]) -> None:
        """Spill pre-serialized JSONL lines (a ColumnBlock's export codec) —
        byte-identical to ``_spill_samples`` on the decoded rows, without
        ever building the row dicts."""
        self._ensure_spill()
        for raw in lines:
            self._spill_fh.write(raw + b"\n")

    def _replay_spill(self) -> Iterator[Sample]:
        from repro.core.storage import read_jsonl

        if self._spill_path is None:
            return iter(())
        self._spill_fh.flush()
        return read_jsonl(self._spill_path)

    # -- worker-side signature precompute ----------------------------------
    def presign_ops(self) -> Optional[List[Any]]:
        """Ops the engine should run over the upstream block stream BEFORE
        this stage (``dataset.iter_stream_blocks`` dispatches them through
        ``engine.map_block_chain``): shingle + signature per sample, i.e. the
        embarrassingly-parallel bulk of dedup compute, pipelined across
        worker processes and overlapped with driver-side band indexing.
        ``None`` on the kernel path — there the driver-side SignatureBatcher
        owns dispatch so super-batches hit the Pallas kernel with bucketed
        shapes."""
        if self.use_kernel:
            return None
        from repro.core.registry import create_op

        return [create_op({
            "name": "minhash_signature_mapper", "num_permutations": self.n_perm,
            "ngram": self.ngram, "seed": self.seed})]

    def _take_presigned(self, samples: List[Sample]
                        ) -> Tuple[List[Sample], List[np.ndarray], np.ndarray]:
        """Strip worker-computed signature carriers off a pre-signed block
        (computing any stragglers — e.g. fault-tolerance replacements —
        on the driver), preserving arrival order."""
        from repro.ops.dedup_ops import MH_DOC_KEY, MH_SIG_KEY

        docs: List[np.ndarray] = []
        sigs: List[np.ndarray] = []
        for s in samples:
            d = s.pop(MH_DOC_KEY, None)
            g = s.pop(MH_SIG_KEY, None)
            if d is None or g is None:
                d = shingle_hashes(s.get("text", ""), n=self.ngram)
                g = signatures_batch_vectorized([d], self.batcher._a,
                                                self.batcher._b)[0]
            docs.append(d)
            sigs.append(g)
        payloads: List[Sample] = [None] * len(samples) if self.exact \
            else list(samples)
        sig_arr = np.stack(sigs) if sigs else \
            np.zeros((0, self.n_perm), dtype=np.uint32)
        return payloads, docs, sig_arr

    def _take_presigned_columns(self, block
                                ) -> Tuple[List[Sample], List[np.ndarray], np.ndarray]:
        """Columnar counterpart of :meth:`_take_presigned`: read the
        signature carriers straight off a ColumnBlock's py columns — no row
        dicts. Exact mode only (payloads are all ``None``; emission happens
        from the spill replay, never from these samples)."""
        from repro.ops.dedup_ops import MH_DOC_KEY, MH_SIG_KEY

        docs_c = block.column_values(MH_DOC_KEY)
        sigs_c = block.column_values(MH_SIG_KEY)
        texts = None
        docs: List[np.ndarray] = []
        sigs: List[np.ndarray] = []
        for i in range(len(block)):
            d, g = docs_c[i], sigs_c[i]
            if d is None or g is None:
                # straggler (e.g. fault-tolerance replacement row): recompute
                if texts is None:
                    texts = block.string_values("text")
                d = shingle_hashes(texts[i], n=self.ngram)
                g = signatures_batch_vectorized([d], self.batcher._a,
                                                self.batcher._b)[0]
            docs.append(d)
            sigs.append(g)
        sig_arr = np.stack(sigs) if sigs else \
            np.zeros((0, self.n_perm), dtype=np.uint32)
        return [None] * len(block), docs, sig_arr

    # -- per-doc ingestion -------------------------------------------------
    def _ingest(self, payloads: List[Sample], docs: List[np.ndarray],
                sigs: np.ndarray) -> List[Sample]:
        """Insert a flushed super-batch into the index; returns keep-first
        survivors (empty in exact mode, which defers all emission)."""
        kept: List[Sample] = []
        if sigs.shape[0] == 0:
            return kept
        keys = lsh_bands(sigs, self.n_bands)
        for j, sample in enumerate(payloads):
            gid = self.n_seen
            self.n_seen += 1
            self.uf.add(gid)
            # uniqued shingles: lossless for Jaccard (set semantics), enables
            # the sorted-merge verifier, and halves spill/IPC bytes. The
            # signature was already computed from the raw array upstream.
            du = np.unique(docs[j])
            edges = self.index.insert(gid, keys[j], du)
            self.n_pairs += len(edges)
            for band, head, _ in edges:
                ok = True
                if self.verify:
                    ok = jaccard_unique(self.index.shingles.get(head), du) \
                        >= self.jaccard_threshold
                    self.n_verified += 1
                if not ok:
                    continue
                if self.exact:
                    self._pairs_by_band[band].append((head, gid))
                self.uf.union(head, gid)
            if self.exact:
                continue
            if self.windowed:
                # defer the decision until `window` newer docs have arrived
                self._window_q.append((gid, sample))
            elif self.uf.component_min(gid) == gid:
                # keep-first: gid is its component's first member right now
                sample.setdefault("stats", {})["dup_component"] = gid
                kept.append(sample)
                self.n_kept += 1
        if self.windowed:
            kept.extend(self._drain_window(self.window))
        return kept

    def _drain_window(self, target: int) -> List[Sample]:
        """Emit every deferred doc beyond ``target`` pending entries that is
        STILL its component's minimum — merges bridged while it waited in
        the horizon demote it, which plain keep-first would have missed."""
        out: List[Sample] = []
        while len(self._window_q) > target:
            gid, sample = self._window_q.popleft()
            if self.uf.component_min(gid) == gid:
                sample.setdefault("stats", {})["dup_component"] = gid
                out.append(sample)
                self.n_kept += 1
        return out

    # -- the stage driver --------------------------------------------------
    def stream_blocks(self, blocks: Iterable, check_cancel=None
                      ) -> Iterator[Tuple[Any, dict]]:
        """Drive the upstream block iterator through the dedup stage,
        yielding ``(SampleBlock, stats)`` as super-batches flush. Exact mode
        spills pass-1 samples to disk and emits everything from
        :meth:`_finalize_exact` once upstream is exhausted."""
        from repro.core.storage import SampleBlock

        from repro.ops.dedup_ops import MH_DOC_KEY, MH_SIG_KEY

        try:
            for blk in blocks:
                if check_cancel is not None:
                    check_cancel()
                t0 = time.perf_counter()
                n_in = len(blk)
                out: List[Sample] = []
                # non-materialized ColumnBlocks expose schema + columns
                # without decoding row dicts; anything else uses .samples
                cb = blk if (hasattr(blk, "has_column")
                             and not blk.materialized) else None
                presigned = (cb.has_column(MH_DOC_KEY) if cb is not None
                             else bool(blk.samples and MH_DOC_KEY in blk.samples[0]))
                if presigned:
                    # worker-pre-signed block: flush any batcher backlog
                    # first (doc ids must follow arrival order), then ingest
                    # directly — nothing left to super-batch
                    if self.batcher.pending:
                        out.extend(self._ingest(*self.batcher.flush()))
                    if cb is not None and self.exact:
                        # zero-materialization path: spill the export codec's
                        # lines minus the carrier keys, read the carriers
                        # straight off the py columns
                        self._spill_lines(cb.iter_json_lines(
                            exclude=(MH_DOC_KEY, MH_SIG_KEY)))
                        out.extend(self._ingest(*self._take_presigned_columns(cb)))
                    else:
                        # keep-first emission needs the row dicts as payloads
                        payloads, docs, sigs = self._take_presigned(blk.samples)
                        if self.exact:
                            self._spill_samples(blk.samples)
                        out.extend(self._ingest(payloads, docs, sigs))
                else:
                    texts = None
                    if cb is not None and self.exact and "py" not in cb.kinds:
                        # validate the text column BEFORE spilling so a
                        # fallback can never double-spill the block
                        try:
                            texts = cb.string_values("text")
                        except (TypeError, ValueError):
                            texts = None
                    if texts is not None:
                        self._spill_lines(cb.iter_json_lines())
                        for t in texts:
                            self.batcher.add(t, None)
                    else:
                        if self.exact:
                            self._spill_samples(blk.samples)
                        for s in blk.samples:
                            self.batcher.add(s.get("text", ""),
                                             None if self.exact else s)
                    while self.batcher.ready:
                        out.extend(self._ingest(*self.batcher.flush()))
                dt = time.perf_counter() - t0
                stats = {"op": "", "seconds": dt, "in": n_in,
                         "out": len(out), "errors": 0}
                if out or not self.exact:
                    yield SampleBlock(out, nbytes=0), stats
                elif n_in:  # exact pass 1: account ingestion, emit nothing
                    yield SampleBlock([], nbytes=0), stats

            # upstream exhausted: flush the tail, then finalize
            t0 = time.perf_counter()
            tail = self._ingest(*self.batcher.flush())
            if self.windowed:
                tail = tail + self._drain_window(0)
            if self.exact:
                if check_cancel is not None:
                    check_cancel()
                for out_blk in self._finalize_exact():
                    dt, t0 = time.perf_counter() - t0, time.perf_counter()
                    yield out_blk, {"op": "", "seconds": dt, "in": 0,
                                    "out": len(out_blk), "errors": 0}
                    if check_cancel is not None:
                        check_cancel()
            elif tail:
                yield SampleBlock(tail, nbytes=0), {
                    "op": "", "seconds": time.perf_counter() - t0, "in": 0,
                    "out": len(tail), "errors": 0}
        finally:
            self.close()

    def _finalize_exact(self) -> Iterator[Any]:
        """Replay the spill with the FINAL components, reproducing the
        barriered ``minhash_dedup_indices`` result exactly: same verified
        pairs in the same band-major order, same union-find backend, same
        component ids, keep = first member per component in index order."""
        from repro.core.dedup.unionfind import naive_components, partitioned_union
        from repro.core.storage import SampleBlock

        n = self.n_seen
        pairs = [p for band in self._pairs_by_band for p in band]
        if self.backend == "naive":
            comp = naive_components(n, pairs)
        else:
            comp = partitioned_union(n, pairs, n_partitions=self.n_partitions).components()
        seen: Dict[int, bool] = {}
        out: List[Sample] = []
        emit_every = max(1, self.batcher.super_batch)
        for i, s in enumerate(self._replay_spill()):
            c = int(comp[i])
            if c not in seen:
                seen[c] = True
                s.setdefault("stats", {})["dup_component"] = c
                out.append(s)
                self.n_kept += 1
                if len(out) >= emit_every:
                    yield SampleBlock(out, nbytes=0)
                    out = []
        if out:
            yield SampleBlock(out, nbytes=0)

    # -- bookkeeping -------------------------------------------------------
    def summary(self) -> Dict[str, Any]:
        return {
            "mode": ("exact" if self.exact
                     else "windowed" if self.windowed else "keep_first"),
            "n_seen": self.n_seen, "n_kept": self.n_kept,
            "n_pairs": self.n_pairs, "n_verified": self.n_verified,
            "n_buckets": self.index.n_buckets,
            "sig_dispatches": self.batcher.dispatches,
            "shingles_resident": len(self.index.shingles._hot),
            "shingles_spilled": self.index.shingles.spilled,
        }

    def close(self) -> None:
        self.index.close()
        for fh in (self._spill_fh,):
            if fh is not None:
                try:
                    fh.close()
                except Exception:  # noqa: BLE001 — best-effort cleanup
                    pass
        self._spill_fh = None
        if self._spill_path:
            try:
                os.remove(self._spill_path)
            except OSError:
                pass
            self._spill_path = None
