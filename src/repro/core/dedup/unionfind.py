"""Union-find backends for fuzzy deduplication (paper §E.1, Table 2).

``BalancedUnionFind`` — load-balanced union-find in the spirit of BTS [30]:
union-by-rank + path halving keeps trees balanced, and edges are processed
in hash-partitioned chunks with per-chunk local roots merged through a
compact boundary set — the structure that makes the distributed version
communication-efficient (3.3x over the vanilla path in the paper).

``naive_components`` — the 'vanilla' baseline: groupby-style pairwise
chaining without balancing (quadratic-ish trees under adversarial order),
kept for the speedup comparison benchmark.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Sequence, Tuple

import numpy as np


class BalancedUnionFind:
    def __init__(self, n: int):
        self.parent = np.arange(n, dtype=np.int64)
        self.rank = np.zeros(n, dtype=np.int8)

    def find(self, x: int) -> int:
        p = self.parent
        while p[x] != x:
            p[x] = p[p[x]]  # path halving
            x = p[x]
        return int(x)

    def union(self, a: int, b: int) -> bool:
        ra, rb = self.find(a), self.find(b)
        if ra == rb:
            return False
        if self.rank[ra] < self.rank[rb]:
            ra, rb = rb, ra
        self.parent[rb] = ra
        if self.rank[ra] == self.rank[rb]:
            self.rank[ra] += 1
        return True

    def add_edges(self, edges: Iterable[Tuple[int, int]]) -> int:
        n = 0
        for a, b in edges:
            n += self.union(a, b)
        return n

    def components(self) -> np.ndarray:
        """Root id per element (fully compressed)."""
        out = np.empty_like(self.parent)
        for i in range(len(self.parent)):
            out[i] = self.find(i)
        return out


def partitioned_union(
    n: int, edges: Sequence[Tuple[int, int]], n_partitions: int = 8
) -> BalancedUnionFind:
    """Load-balanced distributed union-find: hash-partition edges, build
    local forests, then merge only the (much smaller) cross-partition
    boundary pairs — the BTS-style scheme behind RayDeduplicator."""
    if n_partitions <= 1 or not edges:
        uf = BalancedUnionFind(n)
        uf.add_edges(edges)
        return uf
    parts: List[List[Tuple[int, int]]] = [[] for _ in range(n_partitions)]
    for a, b in edges:
        parts[hash((min(a, b), max(a, b))) % n_partitions].append((a, b))
    # local phase (parallelizable): each partition reduces its edges to a
    # spanning set of (local-root) boundary pairs
    boundary: List[Tuple[int, int]] = []
    for part in parts:
        if not part:
            continue
        local = BalancedUnionFind(n)
        local.add_edges(part)
        seen: Dict[int, int] = {}
        for a, b in part:
            ra = local.find(a)
            if ra not in seen:
                seen[ra] = a
            else:
                pass
        # spanning edges of each local component
        comp_rep: Dict[int, int] = {}
        for a, b in part:
            for x in (a, b):
                r = local.find(x)
                if r in comp_rep:
                    if comp_rep[r] != x:
                        pass
                else:
                    comp_rep[r] = x
        for a, b in part:
            r = local.find(a)
            rep = comp_rep[r]
            if a != rep:
                boundary.append((rep, a))
            if b != rep:
                boundary.append((rep, b))
    uf = BalancedUnionFind(n)
    uf.add_edges(boundary)
    return uf


def naive_components(n: int, edges: Sequence[Tuple[int, int]]) -> np.ndarray:
    """Vanilla baseline: chain-style union without rank/halving."""
    parent = list(range(n))

    def find(x):
        while parent[x] != x:
            x = parent[x]
        return x

    for a, b in edges:
        ra, rb = find(a), find(b)
        if ra != rb:
            parent[max(ra, rb)] = min(ra, rb)  # no balancing
    return np.asarray([find(i) for i in range(n)], dtype=np.int64)
