"""Band-partitioned sharded streaming MinHash dedup (ROADMAP item 1).

One streaming dedup job split across many runners, in three phases that
reproduce the single-runner :class:`~repro.core.dedup.streaming` result:

* **map** (one task per input shard) — :class:`ShardMapState` is a stateful
  stream stage (same protocol as ``StreamingMinHashState``) that runs over
  one contiguous row range of the input: it presigns locally (worker-side
  ``minhash_signature_mapper`` carriers or the driver-side SignatureBatcher),
  spills the post-prefix rows byte-identically to the single-runner exact
  spill, and **routes band keys to their owners** by writing one key file
  per reducer into the shared store. No band index is built map-side.
* **reduce** (one task per band owner, ``owner(band) = band % n_reducers``) —
  :func:`run_reduce` replays every owned band over the *global* doc order
  (shards in shard order, docs in local order == single-runner gid order),
  reproducing ``LSHBandIndex``'s bucket-head rule exactly (first doc with a
  key is the head), Jaccard-verifying each candidate edge against the
  uniqued shingles, and publishing the per-band verified pair lists.
* **finalize** (reconciliation barrier) — :func:`iter_final_blocks`
  assembles the global pair list in the barriered band-major order,
  recomputes components with the same union-find backend, and replays the
  concatenated spills keep-first-per-component — byte-identical to
  ``StreamingMinHashState._finalize_exact`` in ``exact`` mode. In
  ``keep_first``/``windowed`` mode the reconciliation merges per-owner
  components through a global :class:`StreamingUnionFind`, so the sharded
  keep set equals the *exact* keep set (a subset of what a single
  keep-first runner would emit — retroactive merges are visible here).

All intermediate files live under one shared ``shard_dir`` and are
published with pid-unique tmp files + ``os.replace``, so a zombie mapper
(SIGKILL survivor past its lease) can only republish identical bytes.
The per-shard ``meta-<k>.json`` is written LAST and acts as the publish
marker a reducer waits on (task "after" deps enforce it upstream too).
"""
from __future__ import annotations

import os
import time
from typing import Any, Dict, Iterable, Iterator, List, Optional, Tuple

import numpy as np

from repro.core.dedup.minhash import (
    jaccard_unique, lsh_bands, shingle_hashes, signatures_batch_vectorized,
)
from repro.core.dedup.streaming import (
    DEFAULT_SUPER_BATCH, SignatureBatcher, StreamingUnionFind,
)

Sample = Dict[str, Any]

# fault-injection hook (tests/bench): seconds to sleep per ingested block in
# the map stage — widens the SIGKILL window deterministically for the
# mid-dedup failover test without touching any production path
MAP_DELAY_ENV = "REPRO_SHARD_MAP_DELAY"


# ---------------------------------------------------------------------------
# shared-store file layout + atomic publishes
# ---------------------------------------------------------------------------


def spill_path(shard_dir: str, k: int) -> str:
    return os.path.join(shard_dir, f"spill-{k}.jsonl")


def shingle_path(shard_dir: str, k: int) -> str:
    return os.path.join(shard_dir, f"shingles-{k}.npz")


def route_path(shard_dir: str, k: int, owner: int) -> str:
    return os.path.join(shard_dir, f"route-{k}-{owner}.npy")


def meta_path(shard_dir: str, k: int) -> str:
    return os.path.join(shard_dir, f"meta-{k}.json")


def pairs_path(shard_dir: str, owner: int) -> str:
    return os.path.join(shard_dir, f"pairs-{owner}.npz")


def owned_bands(owner: int, n_bands: int, n_reducers: int) -> List[int]:
    return [b for b in range(n_bands) if b % n_reducers == owner]


def _np_save_atomic(path: str, arr: np.ndarray) -> None:
    tmp = f"{path}.{os.getpid()}.tmp"
    with open(tmp, "wb") as f:
        np.save(f, arr)
    os.replace(tmp, path)


def _np_savez_atomic(path: str, **arrays: np.ndarray) -> None:
    tmp = f"{path}.{os.getpid()}.tmp"
    with open(tmp, "wb") as f:
        np.savez(f, **arrays)
    os.replace(tmp, path)


def _json_write_atomic(path: str, payload: Dict[str, Any]) -> None:
    from repro.core.storage import json_dumps

    tmp = f"{path}.{os.getpid()}.tmp"
    with open(tmp, "wb") as f:
        f.write(json_dumps(payload))
    os.replace(tmp, path)


def read_shard_meta(shard_dir: str, k: int) -> Optional[Dict[str, Any]]:
    from repro.core.storage import json_loads

    try:
        with open(meta_path(shard_dir, k), "rb") as f:
            return json_loads(f.read())
    except (OSError, ValueError):
        return None


# ---------------------------------------------------------------------------
# map: local presign + spill + band-key routing
# ---------------------------------------------------------------------------


class ShardMapState:
    """Stateful stream stage for one map shard of a sharded dedup job.

    Mirrors ``StreamingMinHashState``'s ingestion paths (presigned rows,
    presigned columns, raw columnar, raw rows) so the spill file it writes is
    byte-identical to the slice of the single-runner exact spill covering
    this shard's rows. Emits no samples — its outputs are the shared-store
    files the reduce/finalize phases consume.
    """

    def __init__(self, *, shard_index: int, n_shards: int, n_reducers: int,
                 shard_dir: str, n_perm: int = 128, n_bands: int = 16,
                 ngram: int = 5, seed: int = 42, use_kernel: bool = False,
                 super_batch: int = DEFAULT_SUPER_BATCH):
        if n_perm % n_bands:
            raise ValueError(f"n_perm ({n_perm}) must divide into n_bands ({n_bands})")
        self.k = int(shard_index)
        self.n_shards = int(n_shards)
        self.n_reducers = max(1, int(n_reducers))
        self.dir = shard_dir
        self.n_perm = n_perm
        self.n_bands = n_bands
        self.ngram = ngram
        self.seed = seed
        self.use_kernel = use_kernel
        self.batcher = SignatureBatcher(n_perm=n_perm, ngram=ngram, seed=seed,
                                        use_kernel=use_kernel,
                                        super_batch=super_batch)
        os.makedirs(shard_dir, exist_ok=True)
        self.n_docs = 0
        self._keys: List[np.ndarray] = []      # (n, n_bands) uint64 per flush
        self._shingles: List[np.ndarray] = []  # uniqued uint64 per doc
        self._spill_fh = None
        self._spill_tmp = f"{spill_path(shard_dir, self.k)}.{os.getpid()}.tmp"
        self._published = False
        try:
            self._delay = float(os.environ.get(MAP_DELAY_ENV, "") or 0.0)
        except ValueError:
            self._delay = 0.0

    # -- spill (same bytes as the single-runner exact spill) ---------------
    def _ensure_spill(self) -> None:
        if self._spill_fh is None:
            self._spill_fh = open(self._spill_tmp, "wb")

    def _spill_samples(self, samples: List[Sample]) -> None:
        from repro.core.storage import json_dumps

        self._ensure_spill()
        for s in samples:
            self._spill_fh.write(json_dumps(s) + b"\n")

    def _spill_lines(self, lines: Iterable[bytes]) -> None:
        self._ensure_spill()
        for raw in lines:
            self._spill_fh.write(raw + b"\n")

    # -- presigned carriers ------------------------------------------------
    def presign_ops(self) -> Optional[List[Any]]:
        if self.use_kernel:
            return None
        from repro.core.registry import create_op

        return [create_op({
            "name": "minhash_signature_mapper", "num_permutations": self.n_perm,
            "ngram": self.ngram, "seed": self.seed})]

    def _take_presigned(self, samples: List[Sample]
                        ) -> Tuple[List[np.ndarray], np.ndarray]:
        from repro.ops.dedup_ops import MH_DOC_KEY, MH_SIG_KEY

        docs: List[np.ndarray] = []
        sigs: List[np.ndarray] = []
        for s in samples:
            d = s.pop(MH_DOC_KEY, None)
            g = s.pop(MH_SIG_KEY, None)
            if d is None or g is None:
                d = shingle_hashes(s.get("text", ""), n=self.ngram)
                g = signatures_batch_vectorized([d], self.batcher._a,
                                                self.batcher._b)[0]
            docs.append(d)
            sigs.append(g)
        sig_arr = np.stack(sigs) if sigs else \
            np.zeros((0, self.n_perm), dtype=np.uint32)
        return docs, sig_arr

    def _take_presigned_columns(self, block
                                ) -> Tuple[List[np.ndarray], np.ndarray]:
        from repro.ops.dedup_ops import MH_DOC_KEY, MH_SIG_KEY

        docs_c = block.column_values(MH_DOC_KEY)
        sigs_c = block.column_values(MH_SIG_KEY)
        texts = None
        docs: List[np.ndarray] = []
        sigs: List[np.ndarray] = []
        for i in range(len(block)):
            d, g = docs_c[i], sigs_c[i]
            if d is None or g is None:
                if texts is None:
                    texts = block.string_values("text")
                d = shingle_hashes(texts[i], n=self.ngram)
                g = signatures_batch_vectorized([d], self.batcher._a,
                                                self.batcher._b)[0]
            docs.append(d)
            sigs.append(g)
        sig_arr = np.stack(sigs) if sigs else \
            np.zeros((0, self.n_perm), dtype=np.uint32)
        return docs, sig_arr

    # -- ingestion ---------------------------------------------------------
    def _ingest(self, docs: List[np.ndarray], sigs: np.ndarray) -> None:
        if sigs.shape[0] == 0:
            return
        self._keys.append(lsh_bands(sigs, self.n_bands))
        for d in docs:
            # uniqued shingles: what the single-runner ShingleStore holds and
            # what jaccard_unique's assume_unique contract needs
            self._shingles.append(np.unique(d))
        self.n_docs += sigs.shape[0]

    def _ingest_flush(self) -> None:
        _, docs, sigs = self.batcher.flush()
        self._ingest(docs, sigs)

    def stream_blocks(self, blocks: Iterable, check_cancel=None
                      ) -> Iterator[Tuple[Any, dict]]:
        """Drive the upstream block stream through the map phase. Yields one
        empty accounting block per input block (the stage emits no samples);
        the shard's outputs are published to the shared store at stream end,
        never from :meth:`close` — a cancelled/zombie run publishes nothing
        it didn't finish."""
        from repro.core.storage import SampleBlock
        from repro.ops.dedup_ops import MH_DOC_KEY, MH_SIG_KEY

        try:
            for blk in blocks:
                if check_cancel is not None:
                    check_cancel()
                if self._delay:
                    time.sleep(self._delay)
                t0 = time.perf_counter()
                n_in = len(blk)
                cb = blk if (hasattr(blk, "has_column")
                             and not blk.materialized) else None
                presigned = (cb.has_column(MH_DOC_KEY) if cb is not None
                             else bool(blk.samples and MH_DOC_KEY in blk.samples[0]))
                if presigned:
                    if self.batcher.pending:
                        self._ingest_flush()
                    if cb is not None:
                        self._spill_lines(cb.iter_json_lines(
                            exclude=(MH_DOC_KEY, MH_SIG_KEY)))
                        self._ingest(*self._take_presigned_columns(cb))
                    else:
                        docs, sigs = self._take_presigned(blk.samples)
                        self._spill_samples(blk.samples)
                        self._ingest(docs, sigs)
                else:
                    texts = None
                    if cb is not None and "py" not in cb.kinds:
                        try:
                            texts = cb.string_values("text")
                        except (TypeError, ValueError):
                            texts = None
                    if texts is not None:
                        self._spill_lines(cb.iter_json_lines())
                        for t in texts:
                            self.batcher.add(t, None)
                    else:
                        self._spill_samples(blk.samples)
                        for s in blk.samples:
                            self.batcher.add(s.get("text", ""), None)
                    while self.batcher.ready:
                        self._ingest_flush()
                if n_in:
                    yield SampleBlock([], nbytes=0), {
                        "op": "", "seconds": time.perf_counter() - t0,
                        "in": n_in, "out": 0, "errors": 0}
            if check_cancel is not None:
                check_cancel()
            self._ingest_flush()
            self._publish()
        finally:
            self.close()

    # -- publication -------------------------------------------------------
    def _publish(self) -> None:
        if self._spill_fh is None:
            self._ensure_spill()  # zero-doc shard still publishes its files
        self._spill_fh.flush()
        self._spill_fh.close()
        self._spill_fh = None
        os.replace(self._spill_tmp, spill_path(self.dir, self.k))

        keys = (np.concatenate(self._keys) if self._keys
                else np.zeros((0, self.n_bands), dtype=np.uint64))
        lens = np.fromiter((a.size for a in self._shingles), np.int64,
                           len(self._shingles))
        offsets = np.zeros(len(self._shingles) + 1, np.int64)
        np.cumsum(lens, out=offsets[1:])
        values = (np.concatenate(self._shingles) if self._shingles
                  else np.zeros(0, np.uint64))
        _np_savez_atomic(shingle_path(self.dir, self.k),
                         offsets=offsets, values=values.astype(np.uint64))
        for o in range(self.n_reducers):
            cols = owned_bands(o, self.n_bands, self.n_reducers)
            _np_save_atomic(route_path(self.dir, self.k, o), keys[:, cols])
        # meta last: its existence marks every file above as complete
        _json_write_atomic(meta_path(self.dir, self.k),
                           {"shard": self.k, "n_docs": int(self.n_docs)})
        self._published = True

    def summary(self) -> Dict[str, Any]:
        return {"mode": "shard_map", "shard": self.k, "n_docs": self.n_docs,
                "sig_dispatches": self.batcher.dispatches}

    def close(self) -> None:
        if self._spill_fh is not None:
            try:
                self._spill_fh.close()
            except Exception:  # noqa: BLE001 — best-effort cleanup
                pass
            self._spill_fh = None
        if not self._published:
            try:
                os.remove(self._spill_tmp)
            except OSError:
                pass


# ---------------------------------------------------------------------------
# reduce: per-owner bucket heads + verified pairs
# ---------------------------------------------------------------------------


class _GlobalShingles:
    """gid -> uniqued shingle array across every shard's published file."""

    def __init__(self, shard_dir: str, counts: List[int]):
        self._base = np.zeros(len(counts) + 1, np.int64)
        np.cumsum(np.asarray(counts, np.int64), out=self._base[1:])
        self._data: List[Tuple[np.ndarray, np.ndarray]] = []
        for k in range(len(counts)):
            with np.load(shingle_path(shard_dir, k)) as z:
                self._data.append((z["offsets"], z["values"]))

    def get(self, gid: int) -> np.ndarray:
        k = int(np.searchsorted(self._base, gid, side="right")) - 1
        i = gid - int(self._base[k])
        off, val = self._data[k]
        return val[off[i]:off[i + 1]]


def shard_counts(shard_dir: str, n_shards: int) -> List[int]:
    counts: List[int] = []
    for k in range(n_shards):
        meta = read_shard_meta(shard_dir, k)
        if meta is None:
            raise FileNotFoundError(f"shard {k} meta missing in {shard_dir}")
        counts.append(int(meta["n_docs"]))
    return counts


def run_reduce(shard_dir: str, owner: int, n_shards: int, n_reducers: int,
               n_bands: int, jaccard_threshold: float,
               verify: bool = True) -> Dict[str, Any]:
    """Build the verified candidate-pair lists for every band this reducer
    owns, replaying docs in global gid order so bucket heads and pair order
    match the single-runner ``LSHBandIndex`` insertion exactly."""
    counts = shard_counts(shard_dir, n_shards)
    base = [0]
    for c in counts:
        base.append(base[-1] + c)
    shingles = _GlobalShingles(shard_dir, counts) if verify else None
    routes = [np.load(route_path(shard_dir, k, owner)) for k in range(n_shards)]
    bands = owned_bands(owner, n_bands, n_reducers)
    out: Dict[str, np.ndarray] = {}
    n_pairs = 0
    for j, band in enumerate(bands):
        bucket: Dict[int, int] = {}
        heads: List[int] = []
        docs: List[int] = []
        for k in range(n_shards):
            col = routes[k][:, j] if routes[k].size else routes[k].reshape(-1)
            for i in range(counts[k]):
                gid = base[k] + i
                key = int(col[i])
                head = bucket.get(key)
                if head is None:
                    bucket[key] = gid
                    continue
                if verify and jaccard_unique(
                        shingles.get(head), shingles.get(gid)) < jaccard_threshold:
                    continue
                heads.append(head)
                docs.append(gid)
        out[f"h{band}"] = np.asarray(heads, np.int64)
        out[f"d{band}"] = np.asarray(docs, np.int64)
        n_pairs += len(heads)
    _np_savez_atomic(pairs_path(shard_dir, owner), **out)
    return {"owner": owner, "bands": bands, "n_pairs": n_pairs,
            "n_docs": base[-1]}


# ---------------------------------------------------------------------------
# finalize: reconciliation barrier + keep-first replay
# ---------------------------------------------------------------------------


def load_global_pairs(shard_dir: str, n_bands: int,
                      n_reducers: int) -> List[Tuple[int, int]]:
    """All verified pairs in the barriered band-major order — band 0's pairs
    first, each band's pairs in gid order (exactly how the single-runner
    ``_pairs_by_band`` registry flattens)."""
    files: Dict[int, Any] = {}
    pairs: List[Tuple[int, int]] = []
    for band in range(n_bands):
        o = band % n_reducers
        if o not in files:
            files[o] = np.load(pairs_path(shard_dir, o))
        h = files[o][f"h{band}"]
        d = files[o][f"d{band}"]
        pairs.extend(zip(h.tolist(), d.tolist()))
    return pairs


def iter_spill_samples(shard_dir: str, n_shards: int) -> Iterator[Sample]:
    from repro.core.storage import read_jsonl

    for k in range(n_shards):
        yield from read_jsonl(spill_path(shard_dir, k))


def iter_final_blocks(shard_dir: str, *, n_shards: int, n_bands: int,
                      n_reducers: int, mode: str, backend: str = "balanced",
                      n_partitions: int = 8,
                      super_batch: int = DEFAULT_SUPER_BATCH,
                      counters: Optional[Dict[str, int]] = None
                      ) -> Iterator[Any]:
    """The reconciliation barrier: merge per-owner pairs into global
    components, then replay the concatenated spills keeping the first doc
    per component. ``exact`` reproduces ``_finalize_exact`` byte-for-byte
    (same backend, same band-major pair order, same ``dup_component`` ids);
    ``keep_first``/``windowed`` merge through a global StreamingUnionFind —
    the kept SET equals exact's, with each survivor stamped with its own gid
    (the id a streaming single-runner would have stamped)."""
    from repro.core.storage import SampleBlock

    counts = shard_counts(shard_dir, n_shards)
    n = sum(counts)
    pairs = load_global_pairs(shard_dir, n_bands, n_reducers)
    if counters is not None:
        counters["n_docs"] = n
        counters["n_pairs"] = len(pairs)

    emit_every = max(1, super_batch)
    out: List[Sample] = []
    n_kept = 0
    if mode == "exact":
        from repro.core.dedup.unionfind import naive_components, partitioned_union

        if backend == "naive":
            comp = naive_components(n, pairs)
        else:
            comp = partitioned_union(n, pairs,
                                     n_partitions=n_partitions).components()
        seen: Dict[int, bool] = {}
        for i, s in enumerate(iter_spill_samples(shard_dir, n_shards)):
            c = int(comp[i])
            if c not in seen:
                seen[c] = True
                s.setdefault("stats", {})["dup_component"] = c
                out.append(s)
                n_kept += 1
                if len(out) >= emit_every:
                    yield SampleBlock(out, nbytes=0)
                    out = []
    else:
        uf = StreamingUnionFind()
        for g in range(n):
            uf.add(g)
        for a, b in pairs:
            uf.union(a, b)
        for i, s in enumerate(iter_spill_samples(shard_dir, n_shards)):
            if uf.component_min(i) == i:
                s.setdefault("stats", {})["dup_component"] = i
                out.append(s)
                n_kept += 1
                if len(out) >= emit_every:
                    yield SampleBlock(out, nbytes=0)
                    out = []
    if out:
        yield SampleBlock(out, nbytes=0)
    if counters is not None:
        counters["n_kept"] = n_kept
