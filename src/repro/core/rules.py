"""Rule-based plan optimizer: ordered, inspectable rewrites over the IR.

The optimizer is a fixed, ordered list of rules applied to a
``LogicalPlan`` (repro.core.plan). Each rule returns a ``RuleRewrite`` —
the before/after op chain plus rule-specific detail — so every surface
that shows a plan (``Executor.explain``, the ``plan:optimize`` /
``shards:plan`` trace spans, ``dj explain``) can show exactly WHICH rule
changed WHAT:

  1. ``probe_cost_reorder``   — within each commutativity group, sort by
                                probed speed, fastest first (paper Fig. 9).
  2. ``filter_fusion``        — fuse adjacent fusible Filters into a
                                cascading FusedOP (harmonic speed, Eq. 1).
  3. ``probe_cost_reorder``   — second pass over the fused chain.
  4. ``predicate_pushdown``   — annotate the column-only filter prefix of
                                each chain segment (runs driver-side at
                                block decode; ``Segment.n_pushdown``).
  5. ``columnar_prefix``      — annotate the longest prefix of each chain
                                segment that can traverse the columnar
                                (struct-of-arrays) path.

Rules 1–3 rewrite node order/grouping; 4–5 are annotation rules — the
executor derives the same facts at runtime from the identical predicates
(``fusion.plan_segments`` / ``Operator.supports_columns``), so annotations
are documentation of what WILL happen, never a second source of truth.

The list-level kernels (``reorder``, ``fuse_filters``, ``op_speed``) live
in ``fusion.py``; ``fusion.optimize`` now delegates HERE, which makes this
module the single definition of optimizer ordering and keeps the rewritten
optimizer byte-identical to the historical reorder -> fuse -> reorder
sequence.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from repro.core.plan import LogicalPlan, PlanNode, kind_of_op

__all__ = ["RuleRewrite", "optimize_plan", "annotate_plan", "RULE_NAMES"]

RULE_NAMES = ("probe_cost_reorder", "filter_fusion", "predicate_pushdown",
              "columnar_prefix")


@dataclasses.dataclass
class RuleRewrite:
    """One rule application: inspectable before/after diff."""

    rule: str
    before: List[str]
    after: List[str]
    changed: bool
    detail: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return {"rule": self.rule, "before": self.before, "after": self.after,
                "changed": self.changed, "detail": self.detail}


def _names(nodes) -> List[str]:
    return [n.name for n in nodes]


def _rebuild_nodes(old_nodes, new_ops) -> List[PlanNode]:
    """Map a kernel's output instance list back onto plan nodes, reusing the
    node (and its annotations) wherever the instance survived, and minting
    nodes for optimizer-made instances (FusedOPs)."""
    by_id = {id(n.bind()): n for n in old_nodes}
    out: List[PlanNode] = []
    for op in new_ops:
        node = by_id.get(id(op))
        if node is None:
            node = PlanNode(kind_of_op(op), op.config(), op=op)
        out.append(node)
    return out


# ---------------------------------------------------------------------------
# rewrite rules
# ---------------------------------------------------------------------------


def _apply_reorder(plan: LogicalPlan, probes) -> Tuple[LogicalPlan, RuleRewrite]:
    from repro.core.fusion import op_speed, reorder

    ops = plan.ops()
    new_ops = reorder(ops, probes)
    nodes = _rebuild_nodes(plan.nodes, new_ops)
    before, after = _names(plan.nodes), _names(nodes)
    rw = RuleRewrite(
        "probe_cost_reorder", before, after, changed=before != after,
        detail={"speeds": {op.name: round(op_speed(op, probes), 1)
                           for op in new_ops}})
    return LogicalPlan(plan.source, nodes, plan.options), rw


def _apply_fusion(plan: LogicalPlan) -> Tuple[LogicalPlan, RuleRewrite]:
    from repro.core.fusion import fuse_filters

    ops = plan.ops()
    new_ops = fuse_filters(ops)
    nodes = _rebuild_nodes(plan.nodes, new_ops)
    before, after = _names(plan.nodes), _names(nodes)
    fused = [n.name for n in nodes if n.op_config().get("name") == "fused_op"]
    rw = RuleRewrite("filter_fusion", before, after,
                     changed=before != after, detail={"fused": fused})
    return LogicalPlan(plan.source, nodes, plan.options), rw


# ---------------------------------------------------------------------------
# annotation rules
# ---------------------------------------------------------------------------


def _chain_segments(plan: LogicalPlan) -> List[List[PlanNode]]:
    """Maximal runs of chain (non-barrier, non-stateful) nodes — the node
    view of ``fusion.plan_segments``'s chain segments."""
    from repro.core.fusion import is_barrier_op, is_stream_stage_op

    segs: List[List[PlanNode]] = []
    cur: List[PlanNode] = []
    for node in plan.nodes:
        op = node.bind()
        if is_barrier_op(op) or is_stream_stage_op(op):
            if cur:
                segs.append(cur)
                cur = []
        else:
            cur.append(node)
    if cur:
        segs.append(cur)
    return segs


def _apply_pushdown(plan: LogicalPlan) -> Tuple[LogicalPlan, RuleRewrite]:
    """Mark the leading run of column-only, pushdown-safe filters in every
    chain segment: the executor applies exactly these driver-side at block
    decode (``Segment.n_pushdown``), so dropped rows are never shipped to
    workers. Annotation mirrors ``plan_segments``'s predicate verbatim."""
    marked: List[str] = []
    for seg in _chain_segments(plan):
        for node in seg:
            op = node.bind()
            try:
                if not (op.pushdown_safe and op.supports_columns()):
                    break
            except Exception:  # noqa: BLE001 — opt-in probe must not fail planning
                break
            node.pushdown = True
            marked.append(node.name)
    names = _names(plan.nodes)
    rw = RuleRewrite("predicate_pushdown", names, names,
                     changed=bool(marked), detail={"pushdown": marked})
    return plan, rw


def _apply_columnar(plan: LogicalPlan) -> Tuple[LogicalPlan, RuleRewrite]:
    """Mark the longest prefix of each chain segment whose ops can traverse
    the struct-of-arrays column path (workers receive column buffers, not
    row dicts). The engine re-checks per block and falls back to the row
    path on any exception, so this marks eligibility, not obligation."""
    marked: List[str] = []
    for seg in _chain_segments(plan):
        for node in seg:
            try:
                if not node.bind().supports_columns():
                    break
            except Exception:  # noqa: BLE001
                break
            node.columnar = True
            marked.append(node.name)
    names = _names(plan.nodes)
    rw = RuleRewrite("columnar_prefix", names, names,
                     changed=bool(marked), detail={"columnar": marked})
    return plan, rw


# ---------------------------------------------------------------------------
# the ordered optimizer
# ---------------------------------------------------------------------------


def optimize_plan(plan: LogicalPlan, probes: Optional[Dict[str, Any]] = None,
                  do_fuse: bool = True, do_reorder: bool = True,
                  ) -> Tuple[LogicalPlan, List[RuleRewrite]]:
    """Apply the ordered rule list; returns the optimized plan plus one
    ``RuleRewrite`` per applied rule. Byte-compatibility contract: with the
    same probes, ``optimize_plan(LogicalPlan.from_ops(ops)).ops()`` is the
    exact op list the historical ``fusion.optimize(ops)`` produced."""
    rewrites: List[RuleRewrite] = []
    if do_reorder:
        plan, rw = _apply_reorder(plan, probes)
        rewrites.append(rw)
    if do_fuse:
        plan, rw = _apply_fusion(plan)
        rewrites.append(rw)
    if do_reorder:
        # second pass over the fused chain (a FusedOP joins its
        # commutativity group with the harmonic speed of its members)
        plan, rw = _apply_reorder(plan, probes)
        rw.detail["pass"] = 2
        rewrites.append(rw)
    plan, rw = _apply_pushdown(plan)
    rewrites.append(rw)
    plan, rw = _apply_columnar(plan)
    rewrites.append(rw)
    return plan, rewrites


def annotate_plan(plan: LogicalPlan) -> LogicalPlan:
    """Annotation rules only (pushdown + columnar) — for surfaces that show
    an unoptimized plan (explain with optimization disabled)."""
    plan, _ = _apply_pushdown(plan)
    plan, _ = _apply_columnar(plan)
    return plan
