"""Operator registry: name -> class, auto-discovery, config round-trip,
typed constructor signatures (powering fluent-API / REST kwarg validation)."""
from __future__ import annotations

import difflib
import inspect
import math
from typing import Any, Dict, List, Type

from repro.core.ops_base import FusedOP, Operator

OPS: Dict[str, Type[Operator]] = {}


def register(name: str):
    def deco(cls):
        cls._name = name
        OPS[name] = cls
        return cls

    return deco


def _ensure_builtin_ops_loaded() -> None:
    import repro.ops  # noqa: F401 — registers the builtin library


def did_you_mean(name: str, candidates) -> List[str]:
    """Close-match suggestions for a name against a candidate pool — the
    shared did-you-mean machinery behind unknown-op 404s, SQL unknown-column
    errors and fluent-API KeyErrors."""
    return difflib.get_close_matches(str(name), list(candidates), n=3,
                                     cutoff=0.6)


def suggestion_hint(name: str, candidates) -> str:
    close = did_you_mean(name, candidates)
    return f" (did you mean {', '.join(close)}?)" if close else ""


def unknown_op_message(name: str) -> str:
    """Error text for a missing OP name, with close-match suggestions."""
    return (f"unknown OP {name!r}{suggestion_hint(name, OPS)}; "
            f"known: {sorted(OPS)}")


def create_op(config: Dict[str, Any]) -> Operator:
    """{'name': ..., **params} -> Operator instance."""
    _ensure_builtin_ops_loaded()
    cfg = dict(config)
    name = cfg.pop("name")
    if name == "fused_op":
        ops = [create_op(c) for c in cfg.pop("ops")]
        return FusedOP(ops, **cfg)
    if name not in OPS:
        raise KeyError(unknown_op_message(name))
    return OPS[name](**cfg)


def _json_safe(v: Any) -> Any:
    if isinstance(v, bool) or v is None or isinstance(v, str):
        return v
    if isinstance(v, (int, float)):
        # orjson (the storage serializer) rejects inf/nan
        return v if math.isfinite(v) else repr(v)
    if isinstance(v, (list, tuple)):
        return [_json_safe(x) for x in v]
    return repr(v)


def op_signature(name: str) -> Dict[str, Any]:
    """Typed constructor signature of a registered OP: explicit parameter
    names, defaults and annotations, introspected from ``__init__``. OPs
    whose ``__init__`` is just ``**params`` report no explicit params and
    ``accepts_extra`` — kwarg validation is then a no-op for them."""
    _ensure_builtin_ops_loaded()
    if name not in OPS:
        raise KeyError(unknown_op_message(name))
    cls = OPS[name]
    params: List[Dict[str, Any]] = []
    accepts_extra = False
    for p in list(inspect.signature(cls.__init__).parameters.values())[1:]:
        if p.kind is p.VAR_KEYWORD:
            accepts_extra = True
            continue
        if p.kind is p.VAR_POSITIONAL:
            continue
        entry: Dict[str, Any] = {
            "name": p.name,
            "required": p.default is p.empty,
            "default": None if p.default is p.empty else _json_safe(p.default),
        }
        if p.annotation is not p.empty:
            ann = p.annotation
            entry["annotation"] = ann if isinstance(ann, str) else getattr(
                ann, "__name__", str(ann))
        params.append(entry)
    return {"name": name, "params": params, "accepts_extra": accepts_extra}


def validate_op_config(config: Dict[str, Any], strict: bool = True) -> None:
    """Fail fast on a bad op config: unknown name -> KeyError (with
    suggestions); with ``strict``, kwargs not in the OP's explicit signature
    -> TypeError (every OP takes ``**kw``, so typos like ``threshold`` vs
    ``jaccard_threshold`` would otherwise be silently absorbed)."""
    _ensure_builtin_ops_loaded()
    cfg = dict(config)
    name = cfg.pop("name", None)
    if not name:
        raise KeyError("op config is missing 'name'")
    if name == "fused_op":
        for sub in cfg.pop("ops", []):
            validate_op_config(sub, strict=strict)
        return
    sig = op_signature(name)  # raises KeyError on unknown name
    if not strict:
        return
    known = {p["name"] for p in sig["params"]}
    unknown = sorted(k for k in cfg if k not in known)
    if unknown and known:
        raise TypeError(
            f"{name} got unexpected parameter(s) {unknown}; "
            f"accepted: {sorted(known)}")
    missing = sorted(p["name"] for p in sig["params"]
                     if p["required"] and p["name"] not in cfg)
    if missing:
        raise TypeError(f"{name} missing required parameter(s) {missing}")


def list_ops() -> List[str]:
    _ensure_builtin_ops_loaded()
    return sorted(OPS)


def op_info(name: str) -> Dict[str, Any]:
    _ensure_builtin_ops_loaded()
    if name not in OPS:
        raise KeyError(unknown_op_message(name))
    cls = OPS[name]
    kind = next(
        (b.__name__ for b in cls.__mro__ if b.__name__ in (
            "Formatter", "Mapper", "Filter", "Deduplicator", "Selector",
            "Grouper", "Aggregator", "ScriptOP", "HumanOP")),
        "Operator",
    )
    return {
        "name": name,
        "type": kind,
        "doc": (cls.__doc__ or "").strip().split("\n")[0],
        "uses_model": cls.uses_model,
        "fusible": cls.fusible,
        "params": op_signature(name)["params"],
    }
