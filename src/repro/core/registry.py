"""Operator registry: name -> class, auto-discovery, config round-trip."""
from __future__ import annotations

from typing import Any, Dict, List, Type

from repro.core.ops_base import FusedOP, Operator

OPS: Dict[str, Type[Operator]] = {}


def register(name: str):
    def deco(cls):
        cls._name = name
        OPS[name] = cls
        return cls

    return deco


def _ensure_builtin_ops_loaded() -> None:
    import repro.ops  # noqa: F401 — registers the builtin library


def create_op(config: Dict[str, Any]) -> Operator:
    """{'name': ..., **params} -> Operator instance."""
    _ensure_builtin_ops_loaded()
    cfg = dict(config)
    name = cfg.pop("name")
    if name == "fused_op":
        ops = [create_op(c) for c in cfg.pop("ops")]
        return FusedOP(ops, **cfg)
    if name not in OPS:
        raise KeyError(f"unknown OP {name!r}; known: {sorted(OPS)}")
    return OPS[name](**cfg)


def list_ops() -> List[str]:
    _ensure_builtin_ops_loaded()
    return sorted(OPS)


def op_info(name: str) -> Dict[str, Any]:
    _ensure_builtin_ops_loaded()
    cls = OPS[name]
    kind = next(
        (b.__name__ for b in cls.__mro__ if b.__name__ in (
            "Formatter", "Mapper", "Filter", "Deduplicator", "Selector",
            "Grouper", "Aggregator", "ScriptOP", "HumanOP")),
        "Operator",
    )
    return {
        "name": name,
        "type": kind,
        "doc": (cls.__doc__ or "").strip().split("\n")[0],
        "uses_model": cls.uses_model,
        "fusible": cls.fusible,
    }
