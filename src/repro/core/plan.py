"""Logical-plan IR: the single plan representation every front-end lowers to.

A ``LogicalPlan`` is an immutable (source, op-node chain, run options)
triple. Typed nodes — Source / Map / Filter / Dedup / Select / GroupAgg /
Sink — wrap registry op configs and carry the registry's typed signature
plus column-dependency metadata (which sample columns an op reads and which
stat columns it writes). Every entry point builds one:

  * ``api.pipeline.Pipeline`` holds a LogicalPlan and its fluent verbs are
    thin wrappers over :meth:`LogicalPlan.with_op`;
  * ``api.sql`` compiles SELECT/WHERE/GROUP BY queries into plan nodes;
  * ``interface.nl`` emits a Pipeline, hence a plan;
  * declarative recipes round-trip through :meth:`from_recipe` /
    :meth:`to_recipe` — the Recipe is the single serialization boundary
    (``fixed_plan`` pinning, shard planning and REST submission all speak
    Recipe dicts produced here).

The optimizer (``repro.core.rules``) rewrites a plan with ordered,
inspectable rules; ``fusion.py`` keeps the list-level kernels the rules
call. Plans bind to live ``Operator`` instances lazily (``bind()``): the
executor probes and runs the SAME instances the rules reordered, which is
what keeps optimized output byte-identical to the pre-IR code path.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.ops_base import (
    Aggregator, Deduplicator, Filter, Formatter, FusedOP, Grouper, Mapper,
    Operator, Selector,
)
from repro.core.recipes import Recipe

# Recipe fields a plan may carry as run options — everything except the op
# chain itself (process) and the source (dataset_path), which the IR owns.
OPTION_FIELDS = {
    f.name for f in dataclasses.fields(Recipe)
} - {"process", "dataset_path"}

# registry taxonomy type -> IR node kind
_KIND_FOR_TYPE = {
    "Formatter": "map",
    "Mapper": "map",
    "Filter": "filter",
    "Deduplicator": "dedup",
    "Selector": "select",
    "Grouper": "group_agg",
    "Aggregator": "group_agg",
    "ScriptOP": "map",
    "HumanOP": "map",
}


def kind_of_config(cfg: Dict[str, Any]) -> str:
    from repro.core.registry import op_info

    name = cfg.get("name")
    if name == "fused_op":
        return "filter"  # fused groups are filter chains
    try:
        return _KIND_FOR_TYPE.get(op_info(name)["type"], "map")
    except KeyError:
        return "map"


def kind_of_op(op: Operator) -> str:
    if isinstance(op, FusedOP):
        return "filter"
    if isinstance(op, Filter):
        return "filter"
    if isinstance(op, Deduplicator):
        return "dedup"
    if isinstance(op, Selector):
        return "select"
    if isinstance(op, (Grouper, Aggregator)):
        return "group_agg"
    if isinstance(op, (Mapper, Formatter)):
        return "map"
    return "map"


def column_deps(op: Operator) -> Tuple[Tuple[str, ...], Tuple[str, ...]]:
    """(reads, writes): the sample columns an op consumes and the stat
    columns it produces — what the pushdown rule reasons over and what
    ``explain`` surfaces per node. Stat columns are dotted (``stats.lang``)."""
    if isinstance(op, FusedOP):
        reads: List[str] = []
        writes: List[str] = []
        for o in op.ops:
            r, w = column_deps(o)
            reads.extend(x for x in r if x not in reads)
            writes.extend(x for x in w if x not in writes)
        return tuple(reads), tuple(writes)
    if isinstance(op, Filter):
        keys = [getattr(op, "stat_key", None)] if getattr(op, "stat_key", None) \
            else list(getattr(op, "stats_keys", ()) or ())
        return ("text",), tuple(f"stats.{k}" for k in keys if k)
    if isinstance(op, Selector):
        sk = op.params.get("stat_key")
        return ((f"stats.{sk}",) if sk else ()), ()
    if isinstance(op, Grouper):
        key = op.params.get("key")
        src = op.params.get("source", "meta")
        return ((f"{src}.{key}",) if key else ()), ()
    if isinstance(op, Aggregator):
        return ("text",), ("text", "meta")
    if isinstance(op, Deduplicator):
        return ("text",), ()
    return ("text",), ("text",)  # mappers/formatters rewrite the payload


@dataclasses.dataclass
class PlanNode:
    """One typed IR node. ``config`` is the registry op config for op nodes,
    the source descriptor for ``source`` nodes, and ``{"path": ...}`` for
    ``sink`` nodes. Optimizer rules set the annotation flags (``pushdown``,
    ``columnar``) and swap/merge nodes; the bound instance (``op``) is
    created lazily and preserved across rule rewrites so probed speeds
    survive reordering."""

    kind: str
    config: Dict[str, Any] = dataclasses.field(default_factory=dict)
    op: Optional[Operator] = None
    pushdown: bool = False   # PredicatePushdownRule: runs driver-side at decode
    columnar: bool = False   # ColumnarPrefixRule: eligible for the column path

    @property
    def name(self) -> str:
        if self.op is not None:
            return self.op.name
        return self.config.get("name", self.kind)

    def bind(self) -> Operator:
        """The live Operator instance for this node (lazily constructed;
        stable across calls so probe results stick)."""
        if self.op is None:
            from repro.core.registry import create_op

            self.op = create_op(dict(self.config))
        return self.op

    def op_config(self) -> Dict[str, Any]:
        """Serializable op config. A bound node serializes its instance
        (covers optimizer-made FusedOPs, which never had a config)."""
        if self.op is not None:
            return self.op.config()
        return dict(self.config)

    def signature(self) -> Dict[str, Any]:
        """Registry typed signature(s) carried by this node."""
        from repro.core.registry import op_signature

        name = self.op_config().get("name")
        if name == "fused_op":
            return {"name": "fused_op",
                    "ops": [op_signature(c["name"])
                            for c in self.op_config().get("ops", [])]}
        try:
            return op_signature(name)
        except KeyError:
            return {"name": name, "params": [], "accepts_extra": True}

    def describe(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {"kind": self.kind, "name": self.name}
        if self.kind in ("source", "sink"):
            # source configs carry their own "kind" (jsonl/samples/...):
            # surface it as "format" so it can't clobber the node kind
            d.update({("format" if k == "kind" else k): v
                      for k, v in self.config.items()
                      if isinstance(v, (str, int, float, bool))})
            return d
        op = self.bind()
        reads, writes = column_deps(op)
        d["reads"] = list(reads)
        d["writes"] = list(writes)
        if self.pushdown:
            d["pushdown"] = True
        if self.columnar:
            d["columnar"] = True
        from repro.core.fusion import is_barrier_op, is_stream_stage_op

        if is_barrier_op(op):
            d["barrier"] = True
        if is_stream_stage_op(op):
            d["stateful"] = True
        return d


class LogicalPlan:
    """Immutable logical plan. All ``with_*`` builders return a NEW plan."""

    __slots__ = ("source", "nodes", "options")

    def __init__(self, source: Optional[Dict[str, Any]] = None,
                 nodes: Sequence[PlanNode] = (),
                 options: Optional[Dict[str, Any]] = None):
        self.source = source
        self.nodes: Tuple[PlanNode, ...] = tuple(nodes)
        self.options: Dict[str, Any] = dict(options or {})

    # ------------------------------------------------------------------
    # construction
    # ------------------------------------------------------------------
    @classmethod
    def from_op_configs(cls, cfgs: Iterable[Dict[str, Any]],
                        source: Optional[Dict[str, Any]] = None,
                        options: Optional[Dict[str, Any]] = None
                        ) -> "LogicalPlan":
        nodes = [PlanNode(kind_of_config(c), dict(c)) for c in cfgs]
        return cls(source, nodes, options)

    @classmethod
    def from_ops(cls, ops: Iterable[Operator],
                 source: Optional[Dict[str, Any]] = None,
                 options: Optional[Dict[str, Any]] = None) -> "LogicalPlan":
        """Wrap already-bound Operator instances (identity-preserving: the
        instances, including their probed speeds, ARE the plan)."""
        nodes = [PlanNode(kind_of_op(op), op.config(), op=op) for op in ops]
        return cls(source, nodes, options)

    @classmethod
    def from_recipe(cls, recipe: Recipe) -> "LogicalPlan":
        src = {"kind": "jsonl", "path": recipe.dataset_path} \
            if recipe.dataset_path else None
        opts = {k: v for k, v in recipe.to_dict().items()
                if k in OPTION_FIELDS}
        return cls.from_op_configs(recipe.process, source=src, options=opts)

    # ------------------------------------------------------------------
    # builders (validated, immutable)
    # ------------------------------------------------------------------
    def with_op(self, cfg: Dict[str, Any]) -> "LogicalPlan":
        from repro.core.registry import validate_op_config

        validate_op_config(cfg)  # unknown name / bad kwargs fail HERE
        node = PlanNode(kind_of_config(cfg), dict(cfg))
        return LogicalPlan(self.source, self.nodes + (node,), self.options)

    def with_options(self, **kwargs) -> "LogicalPlan":
        unknown = sorted(k for k in kwargs if k not in OPTION_FIELDS)
        if unknown:
            raise TypeError(f"unknown option(s) {unknown}; "
                            f"accepted: {sorted(OPTION_FIELDS)}")
        return LogicalPlan(self.source, self.nodes,
                           {**self.options, **kwargs})

    def with_source(self, source: Optional[Dict[str, Any]]) -> "LogicalPlan":
        return LogicalPlan(source, self.nodes, self.options)

    # ------------------------------------------------------------------
    # views
    # ------------------------------------------------------------------
    def ops(self) -> List[Operator]:
        return [n.bind() for n in self.nodes]

    def op_configs(self) -> List[Dict[str, Any]]:
        return [n.op_config() for n in self.nodes]

    def source_node(self) -> Optional[PlanNode]:
        if self.source is None:
            return None
        return PlanNode("source", dict(self.source))

    def sink_node(self) -> Optional[PlanNode]:
        path = self.options.get("export_path")
        if not path:
            return None
        return PlanNode("sink", {"path": path})

    def segments(self):
        """The streaming segment partition of this plan (fusion.Segment)."""
        from repro.core.fusion import plan_segments

        return plan_segments(self.ops())

    # ------------------------------------------------------------------
    # the single serialization boundary: Recipe <-> IR
    # ------------------------------------------------------------------
    def to_recipe(self, name: str = "plan") -> Recipe:
        """Lower this plan into the declarative Recipe the Executor runs.
        Executing the plan IS executing this recipe — the equivalence
        guarantee every front-end inherits."""
        d: Dict[str, Any] = {"name": self.options.get("name", name)}
        if self.source and self.source.get("kind") == "jsonl":
            d["dataset_path"] = self.source["path"]
        d.update({k: v for k, v in self.options.items() if k != "name"})
        d["process"] = self.op_configs()
        return Recipe.from_dict(d)

    def describe(self) -> List[Dict[str, Any]]:
        """Typed node list for explain/trace surfaces — Source and Sink
        included, column deps and rule annotations on every op node."""
        out: List[Dict[str, Any]] = []
        sn = self.source_node()
        if sn is not None:
            out.append(sn.describe())
        out.extend(n.describe() for n in self.nodes)
        kn = self.sink_node()
        if kn is not None:
            out.append(kn.describe())
        return out

    def __repr__(self):
        chain = " -> ".join(n.name for n in self.nodes) or "<empty>"
        src = (self.source or {}).get("kind", "none")
        return f"LogicalPlan(source={src}, nodes=[{chain}])"
