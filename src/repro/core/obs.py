"""Unified observability subsystem: structured trace spans + bounded
metrics registry (ISSUE 8, ROADMAP item 3 on-ramp).

**Spans.** A :class:`Span` is one timed unit of work — job, shard task,
run, segment, dispatch window, op, block, kernel batch — carrying a
``trace_id`` shared by every span of one logical job and a ``parent_id``
linking it into a tree. Ids are minted at `Executor.run` / cluster
``submit`` and *propagated*, not re-minted, across every boundary the
runtime crosses: worker IPC (the dispatcher ships a trace context into
``_guarded`` and the block span travels back in the result tuple),
cluster lease execution (the recipe carries ``trace``), and ``~s/~r/~fin``
shard tasks (the shard spec inherits the parent trace). A sharded job
killed mid-dedup and failed over therefore still yields ONE merged trace:
spans are deduped by ``span_id`` at merge time, and spans from the killed
attempt that never flushed are simply absent — no orphans, because every
emitted span's parent chain roots at the job span written by the accepted
``complete()``.

**Per-process spill.** Each process appends finished spans to
``<obs_dir>/spans-<pid>-<uniq>.jsonl`` (O_APPEND, line-atomic on local
and NFS-style shared filesystems — same trick as the cluster event log).
``merge_trace(obs_dir, trace_id)`` reads every spill, filters, dedupes
and sorts — that is the driver-side merge.

**Metrics.** :class:`MetricsRegistry` holds bounded counters / gauges /
fixed-bucket histograms (queue-wait, block compute, redispatches,
resident bytes, rows/s). ``snapshot()`` is JSON-safe; ``merge()`` folds
per-process snapshots into cluster totals for ``GET /metrics``.

Tracing defaults ON (cheap: in-memory append per span) but is fully
disabled with ``DJ_OBS=0`` or :func:`disable` — the bench asserts the
enabled-vs-disabled overhead stays ≤ 5%.

All timestamps come from :mod:`repro.core.clock` so tests can inject a
fake clock and span merging stays deterministic.
"""
from __future__ import annotations

import contextlib
import os
import threading
import uuid
from typing import Any, Dict, Iterable, List, Optional

from repro.core import clock
from repro.core.storage import json_dumps, json_loads

MAX_SPANS = 4096        # per-process in-memory bound; overflow -> dropped count
MAX_METRICS = 512       # distinct metric names per registry

# fixed histogram buckets (seconds) — chosen to straddle queue-waits of
# microseconds through multi-minute stragglers; fixed so per-process
# snapshots merge by simple elementwise addition
SECONDS_BUCKETS = (0.001, 0.005, 0.02, 0.1, 0.5, 2.0, 10.0, 60.0, float("inf"))


def new_id() -> str:
    return uuid.uuid4().hex[:16]


def enabled() -> bool:
    return _state.enabled


def disable() -> None:
    _state.enabled = False


def enable() -> None:
    _state.enabled = True


# ----------------------------------------------------------------------
# Spans
# ----------------------------------------------------------------------
class Span:
    """One timed unit of work. ``end()`` stamps the duration and hands the
    span to the tracer buffer; ``to_dict()`` is the persisted schema."""

    __slots__ = ("trace_id", "span_id", "parent_id", "name", "kind",
                 "t0", "dur", "attrs", "pid", "tid", "_done")

    def __init__(self, trace_id: str, name: str, kind: str = "span",
                 parent_id: Optional[str] = None,
                 span_id: Optional[str] = None,
                 t0: Optional[float] = None,
                 tid: Optional[Any] = None):
        self.trace_id = trace_id
        self.span_id = span_id or new_id()
        self.parent_id = parent_id
        self.name = name
        self.kind = kind
        self.t0 = clock.now() if t0 is None else t0
        self.dur = 0.0
        self.attrs: Dict[str, Any] = {}
        self.pid = os.getpid()
        self.tid = tid if tid is not None else threading.get_ident() % 100000
        self._done = False

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def end(self, t1: Optional[float] = None) -> "Span":
        if not self._done:
            self._done = True
            self.dur = max(0.0, (clock.now() if t1 is None else t1) - self.t0)
            _state.record(self)
        return self

    def to_dict(self) -> Dict[str, Any]:
        return {
            "trace_id": self.trace_id, "span_id": self.span_id,
            "parent_id": self.parent_id, "name": self.name,
            "kind": self.kind, "t0": self.t0, "dur": self.dur,
            "pid": self.pid, "tid": self.tid, "attrs": self.attrs,
        }


class _TracerState:
    """Process-global tracer: bounded span buffer + ambient parent stack
    (thread-local) + optional spill directory."""

    def __init__(self):
        self.enabled = os.environ.get("DJ_OBS", "1") not in ("0", "false", "")
        self.lock = threading.Lock()
        self.spans: List[Dict[str, Any]] = []
        self.dropped = 0
        self.spill_dir: Optional[str] = None
        self._spill_path: Optional[str] = None
        self._local = threading.local()

    # -- ambient context ------------------------------------------------
    def stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def current(self) -> Optional[Span]:
        st = self.stack()
        return st[-1] if st else None

    # -- recording ------------------------------------------------------
    def record(self, span: Span) -> None:
        if not self.enabled:
            return
        self.record_dict(span.to_dict())

    def record_dict(self, d: Dict[str, Any]) -> None:
        if not self.enabled:
            return
        with self.lock:
            if len(self.spans) >= MAX_SPANS:
                self.dropped += 1
                return
            self.spans.append(d)

    def configure(self, spill_dir: Optional[str]) -> None:
        with self.lock:
            self.spill_dir = spill_dir
            self._spill_path = None
            if spill_dir:
                os.makedirs(spill_dir, exist_ok=True)

    def flush(self) -> Optional[str]:
        """Append buffered spans to the per-process spill file and clear
        the buffer. No-op without a spill dir (in-process runs keep spans
        in memory for RunReport.trace)."""
        with self.lock:
            if not self.spill_dir or not self.spans:
                return self._spill_path
            if self._spill_path is None:
                self._spill_path = os.path.join(
                    self.spill_dir,
                    f"spans-{os.getpid()}-{uuid.uuid4().hex[:6]}.jsonl")
            batch, self.spans = self.spans, []
        buf = b"".join(json_dumps(d) + b"\n" for d in batch)
        fd = os.open(self._spill_path,
                     os.O_WRONLY | os.O_CREAT | os.O_APPEND, 0o644)
        try:
            os.write(fd, buf)
            os.fsync(fd)
        finally:
            os.close(fd)
        return self._spill_path

    def drain(self, trace_id: Optional[str] = None) -> List[Dict[str, Any]]:
        """Pop buffered spans (optionally one trace's) out of memory."""
        with self.lock:
            if trace_id is None:
                out, self.spans = self.spans, []
            else:
                out = [s for s in self.spans if s["trace_id"] == trace_id]
                self.spans = [s for s in self.spans
                              if s["trace_id"] != trace_id]
        return out

    def reset(self) -> None:
        with self.lock:
            self.spans = []
            self.dropped = 0
            self.spill_dir = None
            self._spill_path = None
        self.enabled = os.environ.get("DJ_OBS", "1") not in ("0", "false", "")


_state = _TracerState()


def tracer() -> _TracerState:
    return _state


def configure(spill_dir: Optional[str]) -> None:
    _state.configure(spill_dir)


def flush() -> Optional[str]:
    return _state.flush()


def drain(trace_id: Optional[str] = None) -> List[Dict[str, Any]]:
    return _state.drain(trace_id)


def reset() -> None:
    _state.reset()
    _metrics.reset()


def current_span() -> Optional[Span]:
    return _state.current()


def start_span(trace_id: Optional[str], name: str, kind: str = "span",
               parent_id: Optional[str] = None, **kw) -> Optional[Span]:
    """Create a span (NOT pushed on the ambient stack). Returns None when
    tracing is disabled or there is no trace context — callers guard with
    ``if span: span.end()`` and pay ~nothing on the disabled path."""
    if not _state.enabled or not trace_id:
        return None
    return Span(trace_id, name, kind=kind, parent_id=parent_id, **kw)


@contextlib.contextmanager
def span(trace_id: Optional[str], name: str, kind: str = "span",
         parent_id: Optional[str] = None, **kw):
    """Context manager: opens a span parented to the ambient span (unless
    ``parent_id`` given), pushes it as the ambient parent, ends on exit.
    Yields None when disabled."""
    if not _state.enabled or not trace_id:
        yield None
        return
    if parent_id is None:
        cur = _state.current()
        parent_id = cur.span_id if cur is not None else None
    sp = Span(trace_id, name, kind=kind, parent_id=parent_id, **kw)
    _state.stack().append(sp)
    try:
        yield sp
    finally:
        st = _state.stack()
        if st and st[-1] is sp:
            st.pop()
        sp.end()


def record_span_dict(d: Optional[Dict[str, Any]]) -> None:
    """Record a pre-built span dict (e.g. one shipped back over worker
    IPC)."""
    if d:
        _state.record_dict(d)


# ----------------------------------------------------------------------
# Trace merge + export
# ----------------------------------------------------------------------
def read_spills(obs_dir: str) -> List[Dict[str, Any]]:
    out: List[Dict[str, Any]] = []
    if not os.path.isdir(obs_dir):
        return out
    for fn in sorted(os.listdir(obs_dir)):
        if not (fn.startswith("spans-") and fn.endswith(".jsonl")):
            continue
        try:
            with open(os.path.join(obs_dir, fn), "rb") as f:
                for raw in f:
                    raw = raw.strip()
                    if not raw:
                        continue
                    try:
                        out.append(json_loads(raw))
                    except ValueError:
                        continue  # torn tail line from a killed process
        except OSError:
            continue
    return out


def merge_spans(spans: Iterable[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Dedupe by span_id (last-writer wins after a deterministic sort) and
    return spans ordered by (t0, span_id) — the merge that makes one trace
    out of failover re-executions."""
    best: Dict[str, Dict[str, Any]] = {}
    for s in sorted(spans, key=lambda s: (s.get("t0", 0.0), s.get("dur", 0.0))):
        sid = s.get("span_id")
        if sid:
            best[sid] = s
    return sorted(best.values(), key=lambda s: (s.get("t0", 0.0), s["span_id"]))


def merge_trace(obs_dir: str, trace_id: str,
                extra_spans: Optional[Iterable[Dict[str, Any]]] = None
                ) -> List[Dict[str, Any]]:
    spans = [s for s in read_spills(obs_dir) if s.get("trace_id") == trace_id]
    if extra_spans:
        spans.extend(s for s in extra_spans if s.get("trace_id") == trace_id)
    return merge_spans(spans)


def span_tree(spans: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Roots / children / orphans view (orphan = non-root span whose
    parent_id is absent from the set) — what the failover test asserts."""
    by_id = {s["span_id"]: s for s in spans}
    children: Dict[str, List[str]] = {}
    roots, orphans = [], []
    for s in spans:
        pid = s.get("parent_id")
        if pid is None:
            roots.append(s["span_id"])
        elif pid in by_id:
            children.setdefault(pid, []).append(s["span_id"])
        else:
            orphans.append(s["span_id"])
    return {"roots": roots, "children": children, "orphans": orphans,
            "by_id": by_id}


def chrome_trace(spans: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Catapult (chrome://tracing / Perfetto) JSON: complete "X" events
    with µs timestamps, plus process-name metadata."""
    events: List[Dict[str, Any]] = []
    pids = {}
    for s in spans:
        pid = s.get("pid", 0)
        if pid not in pids:
            pids[pid] = True
            events.append({
                "ph": "M", "name": "process_name", "pid": pid, "tid": 0,
                "args": {"name": f"dj-pid-{pid}"},
            })
        args = dict(s.get("attrs") or {})
        args["span_id"] = s["span_id"]
        if s.get("parent_id"):
            args["parent_id"] = s["parent_id"]
        args["trace_id"] = s.get("trace_id")
        events.append({
            "ph": "X",
            "name": s.get("name", "span"),
            "cat": s.get("kind", "span"),
            "ts": s.get("t0", 0.0) * 1e6,
            "dur": max(s.get("dur", 0.0), 1e-6) * 1e6,
            "pid": pid,
            "tid": s.get("tid", 0),
            "args": args,
        })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


# ----------------------------------------------------------------------
# Metrics
# ----------------------------------------------------------------------
class MetricsRegistry:
    """Bounded named counters / gauges / fixed-bucket histograms.

    Thread-safe; past MAX_METRICS distinct names new metrics are counted
    in ``dropped`` instead of growing without bound. ``snapshot()`` is the
    JSON-safe wire shape and ``merge()`` folds many snapshots (one per
    process) into one."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, float] = {}
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, List[Any]] = {}  # [counts, sum, count]
        self.dropped = 0

    def _room(self, store: Dict[str, Any], name: str) -> bool:
        if name in store:
            return True
        total = len(self._counters) + len(self._gauges) + len(self._hists)
        if total >= MAX_METRICS:
            self.dropped += 1
            return False
        return True

    def inc(self, name: str, v: float = 1.0) -> None:
        if not _state.enabled:
            return
        with self._lock:
            if self._room(self._counters, name):
                self._counters[name] = self._counters.get(name, 0.0) + v

    def gauge(self, name: str, v: float) -> None:
        if not _state.enabled:
            return
        with self._lock:
            if self._room(self._gauges, name):
                self._gauges[name] = float(v)

    def gauge_max(self, name: str, v: float) -> None:
        if not _state.enabled:
            return
        with self._lock:
            if self._room(self._gauges, name):
                self._gauges[name] = max(self._gauges.get(name, v), float(v))

    def observe(self, name: str, v: float) -> None:
        """Record into a fixed-bucket seconds histogram."""
        if not _state.enabled:
            return
        with self._lock:
            if not self._room(self._hists, name):
                return
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = [[0] * len(SECONDS_BUCKETS), 0.0, 0]
            counts, _, _ = h
            for i, edge in enumerate(SECONDS_BUCKETS):
                if v <= edge:
                    counts[i] += 1
                    break
            h[1] += v
            h[2] += 1

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "counters": dict(self._counters),
                "gauges": dict(self._gauges),
                "histograms": {
                    n: {"buckets": list(SECONDS_BUCKETS[:-1]) + ["inf"],
                        "counts": list(h[0]), "sum": h[1], "count": h[2]}
                    for n, h in self._hists.items()
                },
                "dropped": self.dropped,
                "pid": os.getpid(),
            }

    def flush(self, path: str) -> None:
        """Atomically write this process's snapshot to ``path``."""
        snap = self.snapshot()
        tmp = path + f".tmp{os.getpid()}"
        with open(tmp, "wb") as f:
            f.write(json_dumps(snap))
        os.replace(tmp, path)

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()
            self.dropped = 0

    @staticmethod
    def merge(snaps: Iterable[Dict[str, Any]]) -> Dict[str, Any]:
        counters: Dict[str, float] = {}
        gauges: Dict[str, float] = {}
        hists: Dict[str, Dict[str, Any]] = {}
        dropped = 0
        for s in snaps:
            for k, v in (s.get("counters") or {}).items():
                counters[k] = counters.get(k, 0.0) + v
            for k, v in (s.get("gauges") or {}).items():
                gauges[k] = max(gauges.get(k, v), v)  # gauges merge as max
            for k, h in (s.get("histograms") or {}).items():
                agg = hists.setdefault(k, {
                    "buckets": h.get("buckets"),
                    "counts": [0] * len(h.get("counts") or []),
                    "sum": 0.0, "count": 0})
                for i, c in enumerate(h.get("counts") or []):
                    agg["counts"][i] += c
                agg["sum"] += h.get("sum", 0.0)
                agg["count"] += h.get("count", 0)
            dropped += s.get("dropped", 0)
        return {"counters": counters, "gauges": gauges,
                "histograms": hists, "dropped": dropped}


_metrics = MetricsRegistry()


def metrics() -> MetricsRegistry:
    return _metrics


def metrics_spill_path(obs_dir: str) -> str:
    return os.path.join(obs_dir, f"metrics-{os.getpid()}.json")


def flush_metrics(obs_dir: str) -> None:
    os.makedirs(obs_dir, exist_ok=True)
    _metrics.flush(metrics_spill_path(obs_dir))


def merged_metrics(obs_dir: str) -> Dict[str, Any]:
    """Fold every per-process metrics spill in ``obs_dir`` together (plus
    the live in-process registry)."""
    snaps = [_metrics.snapshot()]
    if os.path.isdir(obs_dir):
        for fn in sorted(os.listdir(obs_dir)):
            if fn.startswith("metrics-") and fn.endswith(".json"):
                try:
                    with open(os.path.join(obs_dir, fn), "rb") as f:
                        snaps.append(json_loads(f.read()))
                except (OSError, ValueError):
                    continue
    return MetricsRegistry.merge(snaps)


def histogram_percentile(hist: Dict[str, Any], q: float) -> float:
    """Percentile estimate from a fixed-bucket histogram (upper-edge
    rule)."""
    counts = hist.get("counts") or []
    total = hist.get("count", 0)
    if not total:
        return 0.0
    rank = q * total
    seen = 0
    for i, c in enumerate(counts):
        seen += c
        if seen >= rank:
            edge = SECONDS_BUCKETS[i]
            return edge if edge != float("inf") else SECONDS_BUCKETS[-2]
    return SECONDS_BUCKETS[-2]
