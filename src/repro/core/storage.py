"""Columnar sample storage + streaming I/O + subset pre-splitting.

``SampleBlock`` is an Arrow-like unit: a list of sample dicts with a byte
estimate. Datasets are lists of blocks, pre-split to ~128 MB (paper §E.3) and
aligned to the worker count — the paper measured 2-3x end-to-end speedups
from exactly this (Fig. 4f: peak network I/O 160 -> 60 MB/s).

JSONL (orjson) with optional zstd compression; streaming readers never load
the whole file.
"""
from __future__ import annotations

import io
import os
from typing import Any, Callable, Dict, Iterable, Iterator, List, Optional

import orjson

try:
    import zstandard as zstd
except Exception:  # pragma: no cover
    zstd = None

DEFAULT_BLOCK_BYTES = 128 * 2**20


def sample_nbytes(sample: Dict[str, Any]) -> int:
    # fast estimate; exact enough for block splitting
    return len(orjson.dumps(sample))


class SampleBlock:
    __slots__ = ("samples", "nbytes")

    def __init__(self, samples: Optional[List[Dict[str, Any]]] = None, nbytes: int = -1):
        self.samples = samples if samples is not None else []
        self.nbytes = nbytes if nbytes >= 0 else sum(sample_nbytes(s) for s in self.samples)

    def __len__(self):
        return len(self.samples)

    def append(self, s: Dict[str, Any], nb: Optional[int] = None):
        self.samples.append(s)
        self.nbytes += nb if nb is not None else sample_nbytes(s)


def split_blocks(
    samples: Iterable[Dict[str, Any]],
    block_bytes: int = DEFAULT_BLOCK_BYTES,
    n_workers: int = 1,
    total_hint_bytes: Optional[int] = None,
) -> List[SampleBlock]:
    """Adaptive subset splitting: target min(block_bytes, total/n_workers)
    so every worker gets at least one block (paper §E.3)."""
    if total_hint_bytes and n_workers > 1:
        block_bytes = max(1, min(block_bytes, total_hint_bytes // n_workers))
    blocks: List[SampleBlock] = [SampleBlock()]
    for s in samples:
        nb = sample_nbytes(s)
        if blocks[-1].nbytes + nb > block_bytes and len(blocks[-1]) > 0:
            blocks.append(SampleBlock())
        blocks[-1].append(s, nb)
    return [b for b in blocks if len(b)]


# ---------------------------------------------------------------------------
# JSONL I/O (streaming; optional .zst)
# ---------------------------------------------------------------------------


def _open_read(path: str):
    if path.endswith(".zst"):
        if zstd is None:
            raise RuntimeError("zstandard unavailable")
        fh = open(path, "rb")
        return io.TextIOWrapper(zstd.ZstdDecompressor().stream_reader(fh), encoding="utf-8")
    return open(path, "r", encoding="utf-8")


def read_jsonl(path: str, limit: Optional[int] = None) -> Iterator[Dict[str, Any]]:
    """Streaming JSONL reader — O(1) memory (paper §E.3 'streaming loading')."""
    n = 0
    with _open_read(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            yield orjson.loads(line)
            n += 1
            if limit is not None and n >= limit:
                return


def write_jsonl(path: str, samples: Iterable[Dict[str, Any]]) -> int:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    n = 0
    if path.endswith(".zst"):
        if zstd is None:
            raise RuntimeError("zstandard unavailable")
        with open(path, "wb") as fh:
            with zstd.ZstdCompressor().stream_writer(fh) as w:
                for s in samples:
                    w.write(orjson.dumps(s) + b"\n")
                    n += 1
    else:
        with open(path, "wb") as f:
            for s in samples:
                f.write(orjson.dumps(s) + b"\n")
                n += 1
    return n


def presplit_jsonl(
    src: str, out_dir: str, block_bytes: int = DEFAULT_BLOCK_BYTES, n_workers: int = 1
) -> List[str]:
    """Pre-split a JSONL file into ~block_bytes shards on disk."""
    os.makedirs(out_dir, exist_ok=True)
    total = os.path.getsize(src)
    if n_workers > 1:
        block_bytes = max(1, min(block_bytes, total // n_workers))
    paths: List[str] = []
    buf: List[bytes] = []
    nb = 0

    def flush():
        nonlocal buf, nb
        if not buf:
            return
        p = os.path.join(out_dir, f"part-{len(paths):05d}.jsonl")
        with open(p, "wb") as f:
            f.write(b"".join(buf))
        paths.append(p)
        buf, nb = [], 0

    with _open_read(src) as f:
        for line in f:
            raw = line.encode("utf-8") if isinstance(line, str) else line
            if nb + len(raw) > block_bytes and buf:
                flush()
            buf.append(raw)
            nb += len(raw)
    flush()
    return paths
