"""Columnar sample storage + streaming I/O + subset pre-splitting.

``SampleBlock`` is an Arrow-like unit: a list of sample dicts with a byte
estimate. Datasets are lists of blocks, pre-split to ~128 MB (paper §E.3) and
aligned to the worker count — the paper measured 2-3x end-to-end speedups
from exactly this (Fig. 4f: peak network I/O 160 -> 60 MB/s).

JSONL (orjson when available, stdlib ``json`` otherwise) with optional zstd
compression; streaming readers never load the whole file.
"""
from __future__ import annotations

import io
import json as _stdlib_json
import os
from typing import Any, Callable, Dict, Iterable, Iterator, List, Optional, Union

try:
    import orjson as _orjson
except Exception:  # pragma: no cover — optional accelerator
    _orjson = None

try:
    import zstandard as zstd
except Exception:  # pragma: no cover
    zstd = None

DEFAULT_BLOCK_BYTES = 128 * 2**20


if _orjson is not None:

    def json_dumps(obj: Any, sort_keys: bool = False) -> bytes:
        """Compact JSON bytes via orjson when available, stdlib otherwise —
        the shared serializer for storage, checkpointing, recipes, server."""
        return _orjson.dumps(obj, option=_orjson.OPT_SORT_KEYS if sort_keys else 0)

    json_loads = _orjson.loads
else:
    # one encoder per flavor, reused across calls — json.dumps() builds a
    # fresh JSONEncoder every call, measurable at columnar-ingest call rates
    _enc = _stdlib_json.JSONEncoder(separators=(",", ":"), ensure_ascii=False).encode
    _enc_sorted = _stdlib_json.JSONEncoder(
        separators=(",", ":"), ensure_ascii=False, sort_keys=True).encode

    def json_dumps(obj: Any, sort_keys: bool = False) -> bytes:
        """Compact JSON bytes via orjson when available, stdlib otherwise —
        the shared serializer for storage, checkpointing, recipes, server."""
        return (_enc_sorted(obj) if sort_keys else _enc(obj)).encode("utf-8")

    json_loads = _stdlib_json.loads



def sample_nbytes(sample: Dict[str, Any]) -> int:
    # fast estimate; exact enough for block splitting
    return len(json_dumps(sample))


class SampleBlock:
    __slots__ = ("samples", "nbytes")

    def __init__(self, samples: Optional[List[Dict[str, Any]]] = None, nbytes: int = -1):
        self.samples = samples if samples is not None else []
        self.nbytes = nbytes if nbytes >= 0 else sum(sample_nbytes(s) for s in self.samples)

    def __len__(self):
        return len(self.samples)

    def append(self, s: Dict[str, Any], nb: Optional[int] = None):
        self.samples.append(s)
        self.nbytes += nb if nb is not None else sample_nbytes(s)


def split_blocks(
    samples: Iterable[Dict[str, Any]],
    block_bytes: int = DEFAULT_BLOCK_BYTES,
    n_workers: int = 1,
    total_hint_bytes: Optional[int] = None,
) -> List[SampleBlock]:
    """Adaptive subset splitting: target min(block_bytes, total/n_workers)
    so every worker gets at least one block (paper §E.3)."""
    return list(iter_sample_blocks(samples, block_bytes=block_bytes,
                                   n_workers=n_workers,
                                   total_hint_bytes=total_hint_bytes))


# ---------------------------------------------------------------------------
# JSONL I/O (streaming; optional .zst)
# ---------------------------------------------------------------------------


def _open_read(path: str):
    if path.endswith(".zst"):
        if zstd is None:
            raise RuntimeError("zstandard unavailable")
        fh = open(path, "rb")
        return io.TextIOWrapper(zstd.ZstdDecompressor().stream_reader(fh), encoding="utf-8")
    return open(path, "r", encoding="utf-8")


def read_jsonl(path: str, limit: Optional[int] = None) -> Iterator[Dict[str, Any]]:
    """Streaming JSONL reader — O(1) memory (paper §E.3 'streaming loading')."""
    n = 0
    with _open_read(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            yield json_loads(line)
            n += 1
            if limit is not None and n >= limit:
                return


def write_jsonl(path: str, samples: Iterable[Dict[str, Any]]) -> int:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    n = 0
    if path.endswith(".zst"):
        if zstd is None:
            raise RuntimeError("zstandard unavailable")
        with open(path, "wb") as fh:
            with zstd.ZstdCompressor().stream_writer(fh) as w:
                for s in samples:
                    w.write(json_dumps(s) + b"\n")
                    n += 1
    else:
        with open(path, "wb") as f:
            for s in samples:
                f.write(json_dumps(s) + b"\n")
                n += 1
    return n


# ---------------------------------------------------------------------------
# Streaming block source / sink / prefetch (paper §E.3 'streaming loading')
# ---------------------------------------------------------------------------


def _open_read_binary(path: str):
    if path.endswith(".zst"):
        if zstd is None:
            raise RuntimeError("zstandard unavailable")
        fh = open(path, "rb")
        return io.BufferedReader(zstd.ZstdDecompressor().stream_reader(fh))
    return open(path, "rb")


def _read_jsonl_sized(path: str, limit: Optional[int] = None,
                      row_range: Optional[tuple] = None) -> Iterator[tuple]:
    """Streaming (sample, nbytes) pairs — read in binary so the raw line
    length IS the (uncompressed) byte size; block sizing costs no
    re-serialization and no re-encoding of non-ASCII text.

    ``row_range=(lo, hi)`` scopes the stream to that half-open row window
    (how a shard task reads only its slice): rows before ``lo`` are skipped
    WITHOUT json-decoding, the iterator stops at ``hi``."""
    n = 0
    lo, hi = row_range if row_range else (0, None)
    idx = 0
    with _open_read_binary(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            i, idx = idx, idx + 1
            if i < lo:
                continue
            if hi is not None and i >= hi:
                return
            yield json_loads(line), len(line)
            n += 1
            if limit is not None and n >= limit:
                return


def iter_sample_blocks(
    source: Union[str, Iterable[Dict[str, Any]]],
    block_bytes: int = DEFAULT_BLOCK_BYTES,
    n_workers: int = 1,
    total_hint_bytes: Optional[int] = None,
    limit: Optional[int] = None,
    columnar: bool = False,
    row_range: Optional[tuple] = None,
) -> Iterator[SampleBlock]:
    """Lazy block source: stream samples (from a JSONL path or any sample
    iterable) into ~``block_bytes`` blocks, yielding each block as soon
    as it fills — O(one block) memory, never the whole dataset.

    With ``columnar`` each block is encoded into a struct-of-arrays
    ``ColumnBlock`` (``repro.core.columnar``) at ingest — JSONL becomes a
    pure import codec; rows that the encoder rejects fall back to a plain
    SampleBlock for that block only."""
    if isinstance(source, str):
        # .zst: getsize is the COMPRESSED size while per-line sizes are
        # uncompressed. Still use it as a conservative hint — it UNDERSTATES
        # the total, so the worker shrink at worst over-splits (more blocks
        # than workers keeps every worker busy), never under-splits to one
        # giant single-worker block.
        if total_hint_bytes is None:
            try:
                total_hint_bytes = os.path.getsize(source)
            except OSError:
                total_hint_bytes = None
        sized: Iterable[tuple] = _read_jsonl_sized(source, limit=limit,
                                                   row_range=row_range)
    else:
        sized = ((s, sample_nbytes(s)) for s in source)
        if row_range:
            import itertools

            sized = itertools.islice(sized, row_range[0], row_range[1])
    if total_hint_bytes and n_workers > 1:
        block_bytes = max(1, min(block_bytes, total_hint_bytes // n_workers))
    if columnar:
        from repro.core.columnar import ColumnBlock

        def encode(rows: List[Dict[str, Any]], nb: int):
            try:
                return ColumnBlock.from_samples(rows, nbytes=nb)
            except Exception:  # exotic rows: keep them, just not columnar
                return SampleBlock(rows, nbytes=nb)

        rows: List[Dict[str, Any]] = []
        acc = 0
        for s, nb in sized:
            if acc + nb > block_bytes and rows:
                yield encode(rows, acc)
                rows, acc = [], 0
            rows.append(s)
            acc += nb
        if rows:
            yield encode(rows, acc)
        return
    blk = SampleBlock()
    for s, nb in sized:
        if blk.nbytes + nb > block_bytes and len(blk):
            yield blk
            blk = SampleBlock()
        blk.append(s, nb)
    if len(blk):
        yield blk


def reservoir_sample(stream: Iterable[Any], k: int, seed: int = 0) -> List[Any]:
    """Uniform k-sample over a stream (Vitter's Algorithm R): O(k) memory,
    one pass, no full decode. Selected items are returned in first-seen
    order so downstream probing stays deterministic. Replaces the
    head-biased ``read_jsonl(limit=k)`` probe for streamed sources."""
    import random

    rng = random.Random(seed)
    sample: List[tuple] = []  # (stream_index, item)
    for i, item in enumerate(stream):
        if len(sample) < k:
            sample.append((i, item))
        else:
            j = rng.randrange(i + 1)
            if j < k:
                sample[j] = (i, item)
    sample.sort(key=lambda t: t[0])
    return [item for _, item in sample]


class BlockWriter:
    """Streaming block sink: appends blocks to one JSONL (optionally .zst)
    file as they arrive, holding at most one block in flight. Writes go to a
    ``.tmp`` sidecar published atomically on successful close, so a mid-run
    failure never clobbers a previous good export."""

    def __init__(self, path: str):
        import tempfile

        self.path = path
        if path.endswith(".zst") and zstd is None:
            raise RuntimeError("zstandard unavailable")
        parent = os.path.dirname(os.path.abspath(path))
        os.makedirs(parent, exist_ok=True)
        self.n = 0
        # unique sidecar: concurrent runs exporting to the same path must not
        # truncate each other's in-flight tmp file
        fd, self._tmp = tempfile.mkstemp(
            prefix=os.path.basename(path) + ".", suffix=".tmp", dir=parent)
        # mkstemp's 0600 would stick after publish; match what open() under
        # the caller's umask would have created
        um = os.umask(0)
        os.umask(um)
        os.chmod(self._tmp, 0o666 & ~um)
        self._fh = os.fdopen(fd, "wb")
        if path.endswith(".zst"):
            self._w = zstd.ZstdCompressor().stream_writer(self._fh)
        else:
            self._w = self._fh

    def write_block(self, block: SampleBlock) -> int:
        lines = getattr(block, "iter_json_lines", None)
        if lines is not None:
            # ColumnBlock export codec: canonical lines assembled straight
            # from the column buffers — no row dicts, byte-identical to the
            # json_dumps path below by the format's round-trip invariant
            for raw in lines():
                self._w.write(raw + b"\n")
                self.n += 1
            return len(block)
        for s in block.samples:
            self._w.write(json_dumps(s) + b"\n")
            self.n += 1
        return len(block)

    def close(self, success: bool = True) -> None:
        if self._fh is None:
            return
        fh, w = self._fh, self._w
        self._fh = None
        flush_err: Optional[BaseException] = None
        try:
            if w is not fh:
                w.close()
            fh.close()
        except Exception as e:  # e.g. zstd flush on a full disk
            flush_err = e
            try:
                fh.close()
            except Exception:
                pass
        if success and flush_err is None:
            os.replace(self._tmp, self.path)  # atomic publish
            return
        try:
            os.remove(self._tmp)
        except OSError:
            pass
        if success and flush_err is not None:
            raise flush_err  # flush failed: nothing was published
        # failure path swallows flush errors — never mask the original one

    def __enter__(self) -> "BlockWriter":
        return self

    def __exit__(self, exc_type, *exc) -> None:
        self.close(success=exc_type is None)


class BlockPrefetcher:
    """Bounded prefetch queue: a background thread decodes blocks from
    ``source`` into a queue of at most ``depth`` blocks, overlapping JSONL
    decode with downstream op compute while capping memory. ``max_depth``
    tracks the deepest the queue ever got (always <= ``depth``)."""

    _DONE = object()

    def __init__(self, source: Iterable[SampleBlock], depth: int = 4):
        import queue
        import threading

        self.depth = max(1, depth)
        self.max_depth = 0
        self._queue_mod = queue
        self._q: "queue.Queue" = queue.Queue(maxsize=self.depth)
        self._err: Optional[BaseException] = None
        self._stopped = False
        self._t = threading.Thread(target=self._fill, args=(iter(source),), daemon=True)
        self._t.start()

    def _put(self, item) -> bool:
        """Stop-aware put: never blocks forever on an abandoned consumer."""
        while not self._stopped:
            try:
                self._q.put(item, timeout=0.1)
                return True
            except self._queue_mod.Full:
                continue
        return False

    def _fill(self, source: Iterator[SampleBlock]) -> None:
        try:
            for blk in source:
                if not self._put(blk):
                    return
                self.max_depth = max(self.max_depth, self._q.qsize())
        except BaseException as e:  # propagate to the consumer
            self._err = e
        finally:
            self._put(self._DONE)

    def close(self) -> None:
        """Release the fill thread (and the blocks it holds) — called
        automatically when the consuming iterator is dropped."""
        self._stopped = True
        while True:  # drain so a blocked put wakes immediately
            try:
                self._q.get_nowait()
            except self._queue_mod.Empty:
                return

    def __iter__(self) -> Iterator[SampleBlock]:
        try:
            while True:
                item = self._q.get()
                if item is self._DONE:
                    if self._err is not None:
                        raise self._err
                    return
                yield item
        finally:
            self.close()


def presplit_jsonl(
    src: str, out_dir: str, block_bytes: int = DEFAULT_BLOCK_BYTES, n_workers: int = 1
) -> List[str]:
    """Pre-split a JSONL file into ~block_bytes shards on disk."""
    os.makedirs(out_dir, exist_ok=True)
    total = os.path.getsize(src)
    if n_workers > 1:
        block_bytes = max(1, min(block_bytes, total // n_workers))
    paths: List[str] = []
    buf: List[bytes] = []
    nb = 0

    def flush():
        nonlocal buf, nb
        if not buf:
            return
        p = os.path.join(out_dir, f"part-{len(paths):05d}.jsonl")
        with open(p, "wb") as f:
            f.write(b"".join(buf))
        paths.append(p)
        buf, nb = [], 0

    with _open_read(src) as f:
        for line in f:
            raw = line.encode("utf-8") if isinstance(line, str) else line
            if nb + len(raw) > block_bytes and buf:
                flush()
            buf.append(raw)
            nb += len(raw)
    flush()
    return paths
