"""OP insight mining (paper §5.2 'OP Insight Mining', Appendix F.3).

Tracks per-OP statistic distributions (numeric histograms + tag counts),
diffs consecutive OPs, and flags lineage-level shifts (volume drops,
mean/std moves) so recipe authors see each OP's real effect — beyond the
volume-only Sankey view of 1.0/Falcon.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class StatSummary:
    count: int
    mean: float
    std: float
    p5: float
    p50: float
    p95: float
    hist: List[int]
    edges: List[float]

    @classmethod
    def from_values(cls, vals: np.ndarray, bins: int = 20) -> "StatSummary":
        vals = np.asarray(vals, dtype=np.float64)
        if vals.size == 0:
            return cls(0, 0.0, 0.0, 0.0, 0.0, 0.0, [], [])
        hist, edges = np.histogram(vals, bins=bins)
        return cls(
            int(vals.size), float(vals.mean()), float(vals.std()),
            float(np.percentile(vals, 5)), float(np.percentile(vals, 50)),
            float(np.percentile(vals, 95)),
            hist.astype(int).tolist(), np.round(edges, 6).tolist(),
        )


def snapshot(samples: List[dict]) -> Dict[str, Any]:
    """Distributions of every numeric stat + counts of every tag."""
    numeric: Dict[str, List[float]] = {}
    tags: Dict[str, Dict[str, int]] = {}
    for s in samples:
        for k, v in (s.get("stats") or {}).items():
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                numeric.setdefault(k, []).append(float(v))
            elif isinstance(v, str):
                tags.setdefault(k, {})
                tags[k][v] = tags[k].get(v, 0) + 1
    return {
        "n": len(samples),
        "numeric": {k: StatSummary.from_values(np.asarray(v)) for k, v in numeric.items()},
        "tags": tags,
    }


class _NumericAcc:
    """Bounded accumulator for one numeric stat in one stage: exact
    count/mean/std from running sums, percentiles/histogram from a uniform
    reservoir — O(reservoir), never O(samples)."""

    __slots__ = ("n", "total", "sq", "reservoir", "cap", "_rng")

    def __init__(self, cap: int = 4096, seed: int = 0):
        self.n = 0
        self.total = 0.0
        self.sq = 0.0
        self.reservoir: List[float] = []
        self.cap = cap
        self._rng = np.random.default_rng(seed)

    def add(self, v: float) -> None:
        self.n += 1
        self.total += v
        self.sq += v * v
        if len(self.reservoir) < self.cap:  # Algorithm R
            self.reservoir.append(v)
        else:
            j = int(self._rng.integers(self.n))
            if j < self.cap:
                self.reservoir[j] = v

    def summary(self) -> StatSummary:
        s = StatSummary.from_values(np.asarray(self.reservoir))
        if self.n:
            # exact moments from the running sums; the reservoir only
            # approximates the order statistics / histogram
            s.count = self.n
            s.mean = self.total / self.n
            s.std = float(np.sqrt(max(0.0, self.sq / self.n - s.mean ** 2)))
        return s


class SegmentInsightRecorder:
    """Streaming-path insight mining (paper §F.3 without the barrier).

    The barriered path snapshots the WHOLE dataset after every op; a
    streaming run never materializes it. This recorder taps each segment's
    output block stream and accumulates the same signals incrementally:
    sample counts, exact numeric means/stds plus reservoir-sampled
    percentiles/histograms (:class:`_NumericAcc`), and tag counts — bounded
    memory regardless of dataset size. Each ``tap`` allocates its own stage
    (repeated labels get a ``#2`` suffix, so a recipe that legally uses the
    same op in two segments keeps two timeline entries). ``to_miner()``
    rebuilds an InsightMiner timeline (one entry per segment instead of per
    op) so ``diffs()``/``report()`` work unchanged on streamed runs.
    """

    def __init__(self):
        self._order: List[str] = []
        self._acc: Dict[str, Dict[str, Any]] = {}

    def tap(self, label: str, stream):
        """Wrap a block stream; observes every block that flows through.
        Registers a FRESH stage per call, even if no block ever arrives."""
        key, k = label, 2
        while key in self._acc:
            key, k = f"{label}#{k}", k + 1
        self._stage(key)

        def gen():
            for blk in stream:
                self.observe(key, blk.samples)
                yield blk
        return gen()

    def _stage(self, label: str) -> Dict[str, Any]:
        if label not in self._acc:
            self._order.append(label)
            self._acc[label] = {"n": 0, "numeric": {}, "tags": {}}
        return self._acc[label]

    def observe(self, label: str, samples: List[dict]) -> None:
        acc = self._stage(label)
        acc["n"] += len(samples)
        for s in samples:
            for k, v in (s.get("stats") or {}).items():
                if isinstance(v, (int, float)) and not isinstance(v, bool):
                    num = acc["numeric"].get(k)
                    if num is None:
                        num = acc["numeric"][k] = _NumericAcc()
                    num.add(float(v))
                elif isinstance(v, str):
                    tag = acc["tags"].setdefault(k, {})
                    tag[v] = tag.get(v, 0) + 1

    def to_miner(self) -> "InsightMiner":
        miner = InsightMiner()
        for label in self._order:
            acc = self._acc[label]
            miner.timeline.append({"op": label, "snap": {
                "n": acc["n"],
                "numeric": {k: num.summary()
                            for k, num in acc["numeric"].items()},
                "tags": acc["tags"],
            }})
        return miner

    def report(self) -> str:
        return self.to_miner().report()


class InsightMiner:
    def __init__(self, volume_flag: float = 0.5, mean_shift_flag: float = 0.25):
        self.volume_flag = volume_flag
        self.mean_shift_flag = mean_shift_flag
        self.timeline: List[Dict[str, Any]] = []

    def record(self, op_name: str, samples: List[dict]) -> None:
        self.timeline.append({"op": op_name, "snap": snapshot(samples)})

    def diffs(self) -> List[Dict[str, Any]]:
        out = []
        for prev, cur in zip(self.timeline, self.timeline[1:]):
            d: Dict[str, Any] = {
                "from": prev["op"], "to": cur["op"],
                "volume": (prev["snap"]["n"], cur["snap"]["n"]),
                "flags": [], "stat_shifts": {},
            }
            n0, n1 = prev["snap"]["n"], cur["snap"]["n"]
            if n0 and (n0 - n1) / n0 >= self.volume_flag:
                d["flags"].append(f"volume dropped {(n0 - n1) / n0:.0%} after {cur['op']}")
            for k, s1 in cur["snap"]["numeric"].items():
                s0 = prev["snap"]["numeric"].get(k)
                if s0 is None or s0.count == 0 or s1.count == 0:
                    continue
                denom = max(abs(s0.mean), 1e-9)
                shift = (s1.mean - s0.mean) / denom
                d["stat_shifts"][k] = shift
                if abs(shift) >= self.mean_shift_flag:
                    d["flags"].append(
                        f"stat '{k}' mean shifted {shift:+.0%} after {cur['op']}"
                    )
            out.append(d)
        return out

    def report(self) -> str:
        lines = ["== insight mining report =="]
        for d in self.diffs():
            lines.append(
                f"{d['from']} -> {d['to']}: volume {d['volume'][0]} -> {d['volume'][1]}"
            )
            for f in d["flags"]:
                lines.append(f"  !! {f}")
            for k, v in sorted(d["stat_shifts"].items()):
                lines.append(f"   {k}: mean shift {v:+.2%}")
        return "\n".join(lines)
