"""Operator-level checkpointing + fine-grained recovery (paper §5.1).

Ray-style engines only offer whole-job restarts; Data-Juicer 2.0 resumes
from the last successful OP STAGE. After every OP the dataset and a manifest
(recipe hash, op index, counts) are persisted; ``resume`` finds the deepest
stage whose prefix matches the current recipe and skips those OPs.
"""
from __future__ import annotations

import hashlib
import os
from typing import Any, Dict, List, Optional, Tuple

from repro.core.storage import json_dumps, json_loads, read_jsonl


def _op_sig(op_config: Dict[str, Any]) -> str:
    blob = json_dumps(op_config, sort_keys=True)
    return hashlib.sha1(blob).hexdigest()[:12]


def recipe_prefix_sigs(op_configs: List[Dict[str, Any]]) -> List[str]:
    """Cumulative signature after each OP (stage identity)."""
    sigs, h = [], hashlib.sha1()
    for cfg in op_configs:
        h.update(_op_sig(cfg).encode())
        sigs.append(h.hexdigest()[:16])
    return sigs


class CheckpointManager:
    def __init__(self, ckpt_dir: str):
        self.dir = ckpt_dir
        os.makedirs(ckpt_dir, exist_ok=True)

    def _stage_path(self, sig: str) -> str:
        return os.path.join(self.dir, f"stage-{sig}.jsonl")

    def _manifest_path(self) -> str:
        return os.path.join(self.dir, "manifest.json")

    def _write_manifest(self, manifest: Dict[str, Any]) -> None:
        # atomic publish: a crash (SIGKILL) mid-write must never leave a torn
        # manifest — cluster failover reads this file from a SURVIVING
        # process to decide where to resume
        tmp = f"{self._manifest_path()}.{os.getpid()}.tmp"
        with open(tmp, "wb") as f:
            f.write(json_dumps(manifest))
        os.replace(tmp, self._manifest_path())

    def save_stage(self, sig: str, op_index: int, samples: List[dict]) -> None:
        from repro.core.columnar import maybe_compress

        # stage payload = the JSONL bytes, zstd-compressed when the codec is
        # available (negotiated per stage and recorded in the manifest, so a
        # resume reads exactly what was written)
        raw = b"".join(json_dumps(s) + b"\n" for s in samples)
        codec, payload = maybe_compress(raw)
        tmp = self._stage_path(sig) + ".tmp"
        with open(tmp, "wb") as f:
            f.write(payload)
        os.replace(tmp, self._stage_path(sig))  # atomic publish
        manifest = self.load_manifest()
        manifest["stages"] = {**manifest.get("stages", {}), sig: {
            "op_index": op_index, "n": len(samples), "codec": codec}}
        self._write_manifest(manifest)

    def set_meta(self, key: str, value: Any) -> None:
        """Persist a run-level fact (e.g. original input size) in the manifest."""
        manifest = self.load_manifest()
        manifest[key] = value
        self._write_manifest(manifest)

    def get_meta(self, key: str, default: Any = None) -> Any:
        return self.load_manifest().get(key, default)

    def load_manifest(self) -> Dict[str, Any]:
        try:
            with open(self._manifest_path(), "rb") as f:
                return json_loads(f.read())
        except FileNotFoundError:
            return {"stages": {}}
        except ValueError:
            # torn/corrupt manifest (crash predating atomic writes, or a
            # mid-replace read on a lax shared filesystem): resuming from
            # nothing is always safe — restart beats a permanently dead job
            return {"stages": {}}

    def resume_point(
        self, op_configs: List[Dict[str, Any]],
        allowed: Optional[set] = None,
    ) -> Tuple[int, Optional[List[dict]]]:
        """Returns (n_ops_done, samples_at_that_stage|None).

        ``allowed`` restricts resume to specific op counts — the streaming
        executor passes its segment boundaries so recovery lands on a stage
        that was actually persisted (segments checkpoint as a unit)."""
        sigs = recipe_prefix_sigs(op_configs)
        stages = self.load_manifest().get("stages", {})
        for i in range(len(sigs) - 1, -1, -1):
            if allowed is not None and (i + 1) not in allowed:
                continue
            sig = sigs[i]
            if sig in stages and os.path.exists(self._stage_path(sig)):
                codec = stages[sig].get("codec", "raw")
                if codec == "raw":
                    # also covers stages written before payload compression
                    return i + 1, list(read_jsonl(self._stage_path(sig)))
                from repro.core.columnar import maybe_decompress

                with open(self._stage_path(sig), "rb") as f:
                    raw = maybe_decompress(codec, f.read())
                return i + 1, [json_loads(line)
                               for line in raw.splitlines() if line.strip()]
        return 0, None

    def gc(self, keep_last: int = 2) -> None:
        stages = self.load_manifest().get("stages", {})
        ordered = sorted(stages.items(), key=lambda kv: kv[1]["op_index"])
        for sig, _ in ordered[:-keep_last]:
            try:
                os.remove(self._stage_path(sig))
            except FileNotFoundError:
                pass
