"""Execution engines behind the DJDataset facade (paper §5.1, §E.1).

  * LocalEngine    — single-process (HF-Datasets-standalone analogue).
  * ParallelEngine — multi-worker host execution over pre-split blocks
    (Ray-mode analogue) with speculative re-dispatch of straggler blocks.
  * ShardedEngine  — vectorized OPs executed as jit'd SPMD programs over the
    jax device mesh (the TPU-native adaptation: per-sample numeric/stat OPs
    become data-parallel array programs; everything else falls back to the
    host path). Model-based OPs score batches through the model substrate.

Engines share one interface (``map_batches``), so OPs are engine-agnostic —
the Facade-pattern property the paper emphasises.
"""
from __future__ import annotations

import concurrent.futures as cf
import os
import queue
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.core.ops_base import Operator, OpError
from repro.core.storage import SampleBlock, split_blocks

Sample = Dict[str, Any]


class EngineStats(dict):
    pass


def _iter_batches(samples: List[Sample], batch_size: int):
    for i in range(0, len(samples), batch_size):
        yield i, samples[i : i + batch_size]


class LocalEngine:
    name = "local"

    def __init__(self, n_threads: int = 1):
        self.n_threads = n_threads

    def map_batches(
        self, op: Operator, blocks: List[SampleBlock], batch_size: int
    ) -> Tuple[List[SampleBlock], EngineStats]:
        op.setup()
        t0 = time.time()
        out_blocks: List[SampleBlock] = []
        n_in = 0
        threads = self.n_threads if op.io_intensive else 1
        for blk in blocks:
            results: List[List[Sample]] = []
            if threads > 1:
                # hierarchical parallelism: multithreading for I/O-bound OPs
                # overlaps I/O latency with compute (paper §F.2, Fig. 10b)
                with cf.ThreadPoolExecutor(threads) as pool:
                    futs = [
                        pool.submit(op.run_batch_safe, b, i)
                        for i, b in _iter_batches(blk.samples, batch_size)
                    ]
                    results = [f.result() for f in futs]
            else:
                for i, b in _iter_batches(blk.samples, batch_size):
                    results.append(op.run_batch_safe(b, i))
            merged: List[Sample] = [s for r in results for s in r]
            n_in += len(blk)
            out_blocks.append(SampleBlock(merged))
        dt = time.time() - t0
        return out_blocks, EngineStats(seconds=dt, samples=n_in, engine=self.name)


def _worker_apply(op_config: Dict[str, Any], samples: List[Sample], batch_size: int):
    """Runs in a worker process: rebuild the OP from config, apply safely."""
    from repro.core.registry import create_op

    op = create_op(op_config)
    op.setup()
    out: List[Sample] = []
    for i in range(0, len(samples), batch_size):
        out.extend(op.run_batch_safe(samples[i : i + batch_size], i))
    return out, [e.__dict__ for e in op.errors]


class ParallelEngine:
    """Multi-process engine with straggler re-dispatch.

    Speculative execution: once >=50% of blocks finish, any block running
    longer than ``straggler_factor`` x the median completion time gets a
    backup submission; first finisher wins.
    """

    name = "parallel"

    def __init__(self, n_workers: Optional[int] = None, straggler_factor: float = 3.0):
        self.n_workers = n_workers or max(1, (os.cpu_count() or 2) - 1)
        self.straggler_factor = straggler_factor
        self.redispatches = 0

    def map_batches(self, op, blocks, batch_size):
        try:
            cfg = op.config()
            from repro.core.registry import create_op
            create_op(cfg)  # picklability / reconstructibility probe
        except Exception:
            return LocalEngine().map_batches(op, blocks, batch_size)

        t0 = time.time()
        results: Dict[int, List[Sample]] = {}
        errors: List[dict] = []
        with cf.ProcessPoolExecutor(self.n_workers) as pool:
            futs = {
                pool.submit(_worker_apply, cfg, blk.samples, batch_size): idx
                for idx, blk in enumerate(blocks)
            }
            start = {idx: time.time() for idx in futs.values()}
            times: List[float] = []
            backups: Dict[int, cf.Future] = {}
            pending = set(futs)
            while pending or any(i not in results for i in range(len(blocks))):
                done, pending = cf.wait(pending, timeout=0.05, return_when=cf.FIRST_COMPLETED)
                for f in done:
                    idx = futs[f]
                    if idx not in results:
                        try:
                            out, errs = f.result()
                            results[idx] = out
                            errors.extend(errs)
                            times.append(time.time() - start[idx])
                        except Exception:
                            results[idx] = [s for s in blocks[idx].samples]
                if all(i in results for i in range(len(blocks))):
                    break
                # straggler mitigation
                if times and len(times) >= max(1, len(blocks) // 2):
                    med = float(np.median(times))
                    now = time.time()
                    for f, idx in list(futs.items()):
                        if (
                            idx not in results and idx not in backups
                            and now - start[idx] > self.straggler_factor * max(med, 0.05)
                        ):
                            b = pool.submit(_worker_apply, cfg, blocks[idx].samples, batch_size)
                            backups[idx] = b
                            futs[b] = idx
                            pending.add(b)
                            self.redispatches += 1
        out_blocks = [SampleBlock(results[i]) for i in range(len(blocks))]
        for e in errors:
            op.errors.append(OpError(**e))
        return out_blocks, EngineStats(
            seconds=time.time() - t0,
            samples=sum(len(b) for b in blocks),
            engine=self.name,
            redispatches=self.redispatches,
        )


class ShardedEngine:
    """SPMD engine: vectorized OPs run as jit'd array programs on the mesh.

    An OP opts in by implementing
    ``compute_stats_arrays(cols) -> (stat_name, np.ndarray)`` — the engine
    builds padded device arrays sharded over ``data`` and executes the OP's
    jitted kernel; non-vectorized OPs fall back to the host path.
    """

    name = "sharded"

    def __init__(self, mesh=None, fallback: Optional[LocalEngine] = None):
        self.mesh = mesh
        self.fallback = fallback or LocalEngine()

    def map_batches(self, op, blocks, batch_size):
        fn = getattr(op, "compute_stats_arrays", None)
        if fn is None or not hasattr(op, "keep"):
            return self.fallback.map_batches(op, blocks, batch_size)
        op.setup()
        t0 = time.time()
        out_blocks = []
        n = 0
        for blk in blocks:
            stat_name, values = fn(blk.samples)  # vectorized (numpy/jax)
            kept = []
            for s, v in zip(blk.samples, np.asarray(values)):
                s.setdefault("stats", {})[stat_name] = float(v)
                if op.keep(s):
                    kept.append(s)
            out_blocks.append(SampleBlock(kept))
            n += len(blk)
        return out_blocks, EngineStats(seconds=time.time() - t0, samples=n, engine=self.name)


def make_engine(kind: str = "local", **kw):
    return {"local": LocalEngine, "parallel": ParallelEngine, "sharded": ShardedEngine}[kind](**kw)
