"""Execution engines behind the DJDataset facade (paper §5.1, §E.1).

  * LocalEngine    — single-process (HF-Datasets-standalone analogue).
  * ParallelEngine — multi-worker host execution over pre-split blocks
    (Ray-mode analogue) with speculative re-dispatch of straggler blocks.
  * ShardedEngine  — vectorized OPs executed as jit'd SPMD programs over the
    jax device mesh (the TPU-native adaptation: per-sample numeric/stat OPs
    become data-parallel array programs; everything else falls back to the
    host path). Model-based OPs score batches through the model substrate.

Engines share one interface (``map_batches``), so OPs are engine-agnostic —
the Facade-pattern property the paper emphasises. All multi-worker dispatch
(ParallelEngine's batch and chain paths, LocalEngine's threaded chain
window) runs through the shared adaptive ``WindowedDispatcher``
(``repro.core.dispatch``): bounded adaptive in-flight window, speculative
straggler re-dispatch, failure retries, per-worker quarantine.
"""
from __future__ import annotations

import concurrent.futures as cf
import copy
import os
import threading
import time
from typing import Any, Dict, Iterable, Iterator, List, Optional, Tuple

import numpy as np

from repro.core import clock
from repro.core import schema as S
from repro.core.columnar import ColumnBlock
from repro.core.dispatch import (
    HealthRegistry, TaskPreempted, WindowedDispatcher, dispatch_policy,
)
from repro.core.ops_base import Operator, OpError
from repro.core.storage import SampleBlock, split_blocks

Sample = Dict[str, Any]


class EngineStats(dict):
    pass


class ChainOpFailure(Exception):
    """A hard failure (escaping the per-sample exception manager) while
    driving a block through op ``op_index`` of a chain. Picklable via the
    default (class, args) reduction, so worker processes can attribute the
    failing op instead of the consumer pinning errors to ``ops[0]``."""

    def __init__(self, op_index: int, op_name: str, message: str):
        super().__init__(op_index, op_name, message)
        self.op_index = op_index
        self.op_name = op_name
        self.message = message

    def __str__(self):
        return f"op[{self.op_index}] {self.op_name}: {self.message}"


def _iter_batches(samples: List[Sample], batch_size: int):
    for i in range(0, len(samples), batch_size):
        yield i, samples[i : i + batch_size]


def run_chain(
    ops: List[Operator], samples: List[Sample],
    batch_size: Optional[int] = None, drop_empty: bool = True,
    should_stop=None,
) -> Tuple[List[Sample], List[dict]]:
    """Drive one block's samples through a whole op chain in a single pass.

    This is the streaming executor's unit of work: one dispatch applies every
    op of a pipelineable segment to the block, instead of one dataset-wide
    barrier per op. Returns (out_samples, per-op stats) where each stats entry
    is {"op", "in", "out", "seconds", "errors"} for THIS block only — the
    caller aggregates across blocks so per-op lineage keeps working.

    ``should_stop`` is the dispatcher's preemption poll: checked between
    batches, a True result raises :class:`TaskPreempted` so a speculative
    loser frees its worker instead of draining the rest of the chain.
    """
    stats: List[dict] = []
    for k, op in enumerate(ops):
        t0 = time.perf_counter()
        n_in = len(samples)
        err0 = len(op.errors)
        try:
            bs = batch_size or op.default_batch_size
            out: List[Sample] = []
            for i in range(0, len(samples), bs):
                if should_stop is not None and should_stop():
                    raise TaskPreempted(f"chain preempted at op[{k}] {op.name}")
                out.extend(op.run_batch_safe(samples[i : i + bs], i))
            if drop_empty:
                out = [s for s in out if not S.is_empty(s)]
        except (ChainOpFailure, TaskPreempted):
            raise
        except Exception as e:  # escaped the per-sample exception manager
            raise ChainOpFailure(k, op.name, f"{type(e).__name__}: {e}") from e
        samples = out
        stats.append({
            "op": op.name, "in": n_in, "out": len(samples),
            "seconds": time.perf_counter() - t0,
            "errors": len(op.errors) - err0,
        })
    return samples, stats


def _columnar_ok(block) -> bool:
    """A block is eligible for the columnar fast path only while nobody has
    materialized its row dicts (after that the dicts are authoritative) and
    it carries no empty samples (columnar filters would keep rows that
    ``run_chain``'s drop_empty discards)."""
    return (isinstance(block, ColumnBlock) and not block.materialized
            and not block.may_have_empty)


def _columnar_prefix(
    ops: List[Operator], block, should_stop=None,
) -> Tuple[Any, List[dict], int]:
    """Run the longest columnar prefix of ``ops`` directly on the
    ColumnBlock — no row dicts. Returns (block, stats, k) where ``k`` is the
    number of ops consumed; the caller runs ``ops[k:]`` through the row-dict
    shim. Any exception inside an op's columnar path (exotic data shape,
    wrong column kind) just ends the prefix — the op reruns on rows, so
    opting in is always safe."""
    stats: List[dict] = []
    k = 0
    while k < len(ops) and _columnar_ok(block):
        op = ops[k]
        try:
            if not op.supports_columns():
                break
        except Exception:  # noqa: BLE001 — opt-in probe must never fail the chain
            break
        if should_stop is not None and should_stop():
            raise TaskPreempted(f"chain preempted at op[{k}] {op.name}")
        t0 = time.perf_counter()
        n_in = len(block)
        try:
            op.setup()
            nxt = op.process_columns(block)
        except TaskPreempted:
            raise
        except Exception:  # noqa: BLE001 — fall back to the row path from op k
            break
        stats.append({"op": op.name, "in": n_in, "out": len(nxt),
                      "seconds": time.perf_counter() - t0, "errors": 0})
        block = nxt
        k += 1
    return block, stats, k


def _chain_failure(ops: List[Operator], blk: SampleBlock, err: dict):
    """Pass-through outcome for a chain block whose every dispatch failed:
    synthesized per-op stats plus an OpError pinned to the op that actually
    failed (``err["op_index"]`` from ChainOpFailure, 0 when unattributable),
    so per-op lineage still accounts for the block's samples."""
    k = err.get("op_index", -1)
    k = k if 0 <= k < len(ops) else 0
    stats = [{"op": o.name, "in": len(blk.samples), "out": len(blk.samples),
              "seconds": 0.0, "errors": 1 if j == k else 0}
             for j, o in enumerate(ops)]
    ops[k].errors.append(OpError(
        ops[k].name, -1,
        f"worker failed on chain block ({err.get('attempts', 1)} attempts): "
        f"{err.get('error')}"))
    return list(blk.samples), stats


class LocalEngine:
    name = "local"

    def __init__(self, n_threads: int = 1, straggler_factor: float = 3.0,
                 speculate: bool = True, health_path: Optional[str] = None,
                 mem_budget: Optional[int] = None):
        self.n_threads = n_threads
        self.straggler_factor = straggler_factor
        self.speculate = speculate
        self.mem_budget = mem_budget  # resident in-flight block bytes cap
        self.redispatches = 0  # cumulative; per-call counts live in dispatch_log
        self.dispatch_log: List[dict] = []
        # cross-run worker-slot health (docs/runtime.md): quarantines persist
        # to health_path; previously-quarantined slots start on probation
        self.health = HealthRegistry(health_path) if health_path else None

    def dispatch_policy(self) -> dict:
        return {"engine": self.name,
                **dispatch_policy(self.n_threads, self.straggler_factor,
                                  self.speculate and self.n_threads > 1, 3)}

    def map_batches(
        self, op: Operator, blocks: List[SampleBlock], batch_size: int
    ) -> Tuple[List[SampleBlock], EngineStats]:
        op.setup()
        t0 = clock.now()
        out_blocks: List[SampleBlock] = []
        n_in = 0
        threads = self.n_threads if op.io_intensive else 1
        # hierarchical parallelism: multithreading for I/O-bound OPs overlaps
        # I/O latency with compute (paper §F.2, Fig. 10b); one pool serves
        # every block of the call
        pool = cf.ThreadPoolExecutor(threads) if threads > 1 else None
        try:
            for blk in blocks:
                results: List[List[Sample]] = []
                if pool is not None:
                    futs = [
                        pool.submit(op.run_batch_safe, b, i)
                        for i, b in _iter_batches(blk.samples, batch_size)
                    ]
                    results = [f.result() for f in futs]
                else:
                    for i, b in _iter_batches(blk.samples, batch_size):
                        results.append(op.run_batch_safe(b, i))
                merged: List[Sample] = [s for r in results for s in r]
                n_in += len(blk)
                out_blocks.append(SampleBlock(merged))
        finally:
            if pool is not None:
                pool.shutdown()
        dt = clock.now() - t0
        return out_blocks, EngineStats(seconds=dt, samples=n_in, engine=self.name)

    def map_block_chain(
        self, ops: List[Operator], blocks: Iterable[SampleBlock],
        batch_size: Optional[int] = None,
    ) -> Iterator[Tuple[SampleBlock, List[dict]]]:
        """Streaming: drive each block through the whole op chain, yielding
        (out_block, per-op block stats) as soon as the block completes.

        With ``n_threads > 1`` and an I/O-intensive op in the chain, blocks
        run through the chain concurrently in a bounded thread window
        (hierarchical parallelism, paper §F.2) — results stay in input order.
        Each thread gets its own op clones so error bookkeeping stays
        race-free; non-reconstructible ops fall back to the sequential path.
        """
        for op in ops:
            op.setup()
        threads = self.n_threads if any(op.io_intensive for op in ops) else 1
        cfgs = None
        if threads > 1:
            try:
                cfgs = [op.config() for op in ops]
                from repro.core.registry import create_op

                for c in cfgs:
                    create_op(c)  # reconstructibility probe
            except Exception:
                cfgs = None
        if threads <= 1 or cfgs is None:
            for blk in blocks:
                cur, cstats, k = _columnar_prefix(ops, blk)
                if k == len(ops):
                    # whole chain ran on columns: zero row dicts built
                    yield cur, cstats
                    continue
                out, stats = run_chain(ops[k:], cur.samples, batch_size)
                # nbytes left lazy (0): output blocks are consumed immediately
                # by the next segment or sink, never re-split by size
                yield SampleBlock(out, nbytes=0), cstats + stats
            return

        from repro.core.registry import create_op

        tls = threading.local()  # one clone chain per worker thread, not per block

        def work(blk, should_stop=None):
            local_ops = getattr(tls, "ops", None)
            if local_ops is None:
                local_ops = [create_op(c) for c in cfgs]
                for o in local_ops:
                    o.setup()
                tls.ops = local_ops
            for o in local_ops:
                # reused clones must not re-report past blocks; cleared on
                # entry (not after run_chain) so a hard chain failure can't
                # leak this block's errors into the thread's next block
                o.errors = []
            # thread pools share objects (the process pool's pickling copies
            # per dispatch): columnar transforms never mutate their input, so
            # the prefix can run on the SHARED block even under speculation;
            # the row remainder gets a private decode (or deep copy) so a
            # backup attempt never mutates dicts the original still writes.
            cur, cstats, k = _columnar_prefix(local_ops, blk, should_stop)
            if k == len(local_ops):
                return cur, cstats, []
            if isinstance(cur, ColumnBlock):
                samples = cur.decode_rows()  # private, uncached
            else:
                samples = copy.deepcopy(cur.samples)
            out, stats = run_chain(local_ops[k:], samples, batch_size,
                                   should_stop=should_stop)
            errs = [(j, e) for j, o in enumerate(local_ops) for e in o.errors]
            return out, cstats + stats, errs

        with cf.ThreadPoolExecutor(threads) as pool:
            disp = WindowedDispatcher(
                pool, threads, straggler_factor=self.straggler_factor,
                speculate=self.speculate,
                label="+".join(op.name for op in ops),
                log=self.dispatch_log, meta={"engine": self.name},
                # plain dict: thread-pool workers share the driver's heap
                preempt_board={}, health=self.health,
                mem_budget=self.mem_budget)
            gen = disp.run(blocks, work, lambda blk: (blk,))
            try:
                for blk, payload, err in gen:
                    if err is None:
                        out, stats, errs = payload
                        for k, e in errs:  # merged on the main thread — no races
                            ops[k].errors.append(e)
                    else:
                        out, stats = _chain_failure(ops, blk, err)
                    if isinstance(out, ColumnBlock):
                        yield out, stats
                    else:
                        yield SampleBlock(out, nbytes=0), stats
            finally:
                gen.close()
                if disp.summary is not None:
                    self.redispatches += disp.summary["redispatches"]


def _worker_apply(op_config: Dict[str, Any], samples: List[Sample], batch_size: int):
    """Runs in a worker process: rebuild the OP from config, apply safely."""
    from repro.core.registry import create_op

    op = create_op(op_config)
    op.setup()
    out: List[Sample] = []
    for i in range(0, len(samples), batch_size):
        out.extend(op.run_batch_safe(samples[i : i + batch_size], i))
    return out, [e.__dict__ for e in op.errors]


def _worker_apply_chain(
    op_configs: List[Dict[str, Any]], payload,
    batch_size: Optional[int] = None, should_stop=None,
):
    """Runs in a worker process: rebuild the whole segment chain from configs
    and drive the block through it in one dispatch. ``payload`` is either a
    raw sample list or a ColumnBlock (the parallel engine ships columns —
    one pickled buffer per column instead of N row dicts); the row-dict shim
    appears only past the chain's columnar prefix, and the output is
    re-encoded to columns so the return trip ships buffers too.
    ``should_stop`` is the dispatcher's preemption poll (a Manager-proxy
    read), threaded into ``run_chain`` so a losing speculative submission
    exits at the next batch boundary instead of draining."""
    from repro.core.registry import create_op

    ops = []
    for k, c in enumerate(op_configs):
        try:
            op = create_op(c)
            op.setup()
        except Exception as e:  # attribute rebuild/setup failures to op k too
            raise ChainOpFailure(k, str(c.get("name", "?")),
                                 f"{type(e).__name__}: {e}") from e
        ops.append(op)
    cstats: List[dict] = []
    columnar_in = isinstance(payload, ColumnBlock)
    if columnar_in:
        payload, cstats, kp = _columnar_prefix(ops, payload, should_stop)
        if kp == len(ops):
            return payload, cstats, []
        ops = ops[kp:]
        if isinstance(payload, ColumnBlock):
            payload = payload.samples
    out, stats = run_chain(ops, payload, batch_size, should_stop=should_stop)
    stats = cstats + stats
    # errors carry the op's index in the FULL chain (prefix ops report none)
    # — attribution by name would merge two instances of the same OP class
    off = len(cstats)
    errors = [(off + k, e.__dict__) for k, op in enumerate(ops) for e in op.errors]
    if columnar_in and cstats:
        # return trip ships column buffers too — but only when the columnar
        # prefix actually ran: a chain that fell straight to rows gains
        # nothing from re-encoding, it would just pay encode+decode
        try:
            out = ColumnBlock.from_samples(out)
        except Exception:  # noqa: BLE001 — exotic rows ship as row dicts
            pass
    return out, stats, errors


class ParallelEngine:
    """Multi-process engine; all dispatch runs through the shared
    :class:`~repro.core.dispatch.WindowedDispatcher`.

    Speculative execution: once ``min_completions`` blocks finish, any block
    running longer than ``straggler_factor`` x the median completion time
    gets a backup submission; first finisher wins, the loser is cancelled.
    A worker that fails ``worker_failure_limit`` tasks is quarantined (its
    blocks re-dispatch to healthy workers instead of passing through).
    """

    name = "parallel"

    def __init__(self, n_workers: Optional[int] = None, straggler_factor: float = 3.0,
                 speculate: bool = True, min_completions: Optional[int] = None,
                 worker_failure_limit: int = 3, health_path: Optional[str] = None,
                 mem_budget: Optional[int] = None):
        self.n_workers = n_workers or max(1, (os.cpu_count() or 2) - 1)
        self.straggler_factor = straggler_factor
        self.speculate = speculate
        self.mem_budget = mem_budget  # resident in-flight block bytes cap
        self.min_completions = min_completions
        self.worker_failure_limit = worker_failure_limit
        self.redispatches = 0  # cumulative; per-call counts in EngineStats/dispatch_log
        self.dispatch_log: List[dict] = []
        self.health = HealthRegistry(health_path) if health_path else None
        self._preempt_mgr: Any = None  # lazy Manager; False = unavailable
        self._preempt_dict: Any = None

    def _dispatcher(self, pool, label: str, preempt_board=None) -> WindowedDispatcher:
        return WindowedDispatcher(
            pool, self.n_workers, straggler_factor=self.straggler_factor,
            speculate=self.speculate, min_completions=self.min_completions,
            worker_failure_limit=self.worker_failure_limit,
            label=label, log=self.dispatch_log, meta={"engine": self.name},
            preempt_board=preempt_board, health=self.health,
            mem_budget=self.mem_budget)

    def _preempt_board(self):
        """Manager-backed shared dict readable from worker processes: the
        preemption channel for the chain path. ONE Manager per engine (its
        server process costs ~100ms to start — per-segment churn would pay
        that on every chain call), shared across dispatch calls; dispatcher
        key namespacing keeps sequential runs from colliding. Returns None
        when the Manager can't start (preemption then degrades to the old
        cancel-only behavior rather than failing the run); the Manager dies
        with the engine (its finalizer runs on GC / interpreter exit)."""
        if self._preempt_mgr is False:
            return None
        if self._preempt_mgr is None:
            try:
                import multiprocessing

                self._preempt_mgr = multiprocessing.Manager()
                self._preempt_dict = self._preempt_mgr.dict()
            except Exception:  # noqa: BLE001 — sandboxed envs without semaphores
                self._preempt_mgr = False
                return None
        return self._preempt_dict

    def dispatch_policy(self) -> dict:
        return {"engine": self.name,
                **dispatch_policy(self.n_workers, self.straggler_factor,
                                  self.speculate, self.worker_failure_limit)}

    def _fallback(self) -> "LocalEngine":
        # non-reconstructible op: host path, but any dispatch summaries it
        # logs still land in THIS engine's report
        fb = LocalEngine()
        fb.dispatch_log = self.dispatch_log
        return fb

    def map_batches(self, op, blocks, batch_size):
        try:
            cfg = op.config()
            from repro.core.registry import create_op
            create_op(cfg)  # picklability / reconstructibility probe
        except Exception:
            return self._fallback().map_batches(op, blocks, batch_size)

        t0 = clock.now()
        out_blocks: List[SampleBlock] = []
        with cf.ProcessPoolExecutor(self.n_workers) as pool:
            disp = self._dispatcher(pool, label=op.name)
            for idx, (blk, payload, err) in enumerate(disp.run(
                    blocks, _worker_apply,
                    lambda b: (cfg, b.samples, batch_size))):
                if err is None:
                    out, errs = payload
                    for e in errs:
                        op.errors.append(OpError(**e))
                    out_blocks.append(SampleBlock(out))
                else:
                    # every submission for this block failed: pass the input
                    # through so the run completes, but surface the failure —
                    # a silent pass-through resurrects rows a Filter should
                    # have dropped
                    out_blocks.append(SampleBlock(list(blk.samples)))
                    op.errors.append(OpError(
                        op.name, idx,
                        f"worker failed on block {idx} "
                        f"({err['attempts']} attempts): {err['error']}"))
        summary = disp.summary or {}
        self.redispatches += summary.get("redispatches", 0)
        return out_blocks, EngineStats(
            seconds=clock.now() - t0,
            samples=sum(len(b) for b in blocks),
            engine=self.name,
            # per-call delta (the cumulative count previously reported here
            # inflated later runs' stats)
            redispatches=summary.get("redispatches", 0),
            quarantined=len(summary.get("quarantined", ())),
        )

    def map_block_chain(
        self, ops: List[Operator], blocks: Iterable[SampleBlock],
        batch_size: Optional[int] = None,
    ) -> Iterator[Tuple[SampleBlock, List[dict]]]:
        """Streaming: one worker dispatch drives a block through the whole
        segment chain via the shared WindowedDispatcher — bounded adaptive
        in-flight window, speculative straggler re-dispatch, worker
        quarantine. Results are yielded in input order so outputs stay
        deterministic (a speculative backup computes the identical block)."""
        try:
            cfgs = [op.config() for op in ops]
            from repro.core.registry import create_op

            for c in cfgs:
                create_op(c)  # picklability / reconstructibility probe
        except Exception:
            yield from self._fallback().map_block_chain(ops, blocks, batch_size)
            return

        board = self._preempt_board() if self.speculate else None
        with cf.ProcessPoolExecutor(self.n_workers) as pool:
            disp = self._dispatcher(pool, label="+".join(op.name for op in ops),
                                    preempt_board=board)
            # columnar blocks ship whole: one pickled buffer per column, not
            # N row dicts (materialized blocks fall back to their row lists)
            gen = disp.run(
                blocks, _worker_apply_chain,
                lambda b: (cfgs,
                           b if _columnar_ok(b) else b.samples,
                           batch_size))
            try:
                for blk, payload, err in gen:
                    if err is None:
                        out, stats, errs = payload
                        for k, e in errs:
                            ops[k].errors.append(OpError(**e))
                    else:
                        out, stats = _chain_failure(ops, blk, err)
                    if isinstance(out, ColumnBlock):
                        yield out, stats
                    else:
                        yield SampleBlock(out, nbytes=0), stats
            finally:
                gen.close()
                if disp.summary is not None:
                    self.redispatches += disp.summary["redispatches"]


class ShardedEngine:
    """SPMD engine: vectorized OPs run as jit'd array programs on the mesh.

    An OP opts in by implementing
    ``compute_stats_arrays(cols) -> (stat_name, np.ndarray)`` — the engine
    builds padded device arrays sharded over ``data`` and executes the OP's
    jitted kernel; non-vectorized OPs fall back to the host path.
    """

    name = "sharded"

    # device-sized super-batch: consecutive blocks are merged until this many
    # rows are pending before a vectorized chain dispatch, so jit'd array
    # programs see a few large arrays instead of many block-sized ones
    SUPER_BATCH_ROWS = 4096

    def __init__(self, mesh=None, fallback: Optional[LocalEngine] = None,
                 super_batch_rows: Optional[int] = None):
        self.mesh = mesh
        self.fallback = fallback or LocalEngine()
        self.super_batch_rows = max(1, super_batch_rows or self.SUPER_BATCH_ROWS)

    @property
    def dispatch_log(self) -> List[dict]:
        return self.fallback.dispatch_log  # host-path dispatches land here

    def dispatch_policy(self) -> dict:
        # vectorized chains run in-process (no dispatch window); the host
        # fallback path inherits the fallback engine's adaptive policy
        return {"engine": self.name, "vectorized": "in-process",
                "fallback": self.fallback.dispatch_policy()}

    def map_batches(self, op, blocks, batch_size):
        fn = getattr(op, "compute_stats_arrays", None)
        if fn is None or not hasattr(op, "keep"):
            return self.fallback.map_batches(op, blocks, batch_size)
        op.setup()
        t0 = clock.now()
        out_blocks = []
        n = 0
        for blk in blocks:
            stat_name, values = fn(blk.samples)  # vectorized (numpy/jax)
            kept = []
            for s, v in zip(blk.samples, np.asarray(values)):
                s.setdefault("stats", {})[stat_name] = float(v)
                if op.keep(s):
                    kept.append(s)
            out_blocks.append(SampleBlock(kept))
            n += len(blk)
        return out_blocks, EngineStats(seconds=clock.now() - t0, samples=n, engine=self.name)

    def _chain_samples(
        self, ops: List[Operator], samples: List[Sample],
        batch_size: Optional[int],
    ) -> Tuple[List[Sample], List[dict]]:
        """Drive one batch of samples through the chain: vectorized OPs run
        as array programs, the rest fall back to the host chain."""
        stats: List[dict] = []
        for op in ops:
            fn = getattr(op, "compute_stats_arrays", None)
            if fn is not None and hasattr(op, "keep") and samples:
                t0 = time.perf_counter()
                n_in = len(samples)
                stat_name, values = fn(samples)
                kept = []
                for s, v in zip(samples, np.asarray(values)):
                    s.setdefault("stats", {})[stat_name] = float(v)
                    if op.keep(s):
                        kept.append(s)
                samples = kept
                stats.append({
                    "op": op.name, "in": n_in, "out": len(samples),
                    "seconds": time.perf_counter() - t0, "errors": 0,
                })
            else:
                samples, sub = run_chain([op], samples, batch_size)
                stats.extend(sub)
        return samples, stats

    def _full_columnar(self, ops: List[Operator], blk
                       ) -> Optional[Tuple[Any, List[dict]]]:
        """Zero-copy hand-off (ROADMAP carry-over): a ColumnBlock whose
        ENTIRE chain takes the columnar path skips the row-shim decode —
        columns flow straight through ``process_columns``, never touching
        the super-batch row buffer. All-or-nothing: if any op bails
        mid-prefix, return None and rerun the whole chain on rows (columnar
        transforms never mutate their input, so the rerun is safe) — a
        partial prefix must NOT feed survivors into ``pending``, where the
        remaining ops would be applied a second time."""
        if not _columnar_ok(blk):
            return None
        try:
            if not all(op.supports_columns() for op in ops):
                return None
        except Exception:  # noqa: BLE001 — opt-in probe must never fail the chain
            return None
        cur, cstats, k = _columnar_prefix(ops, blk)
        if k == len(ops):
            return cur, cstats
        return None

    def map_block_chain(
        self, ops: List[Operator], blocks: Iterable[SampleBlock],
        batch_size: Optional[int] = None,
    ) -> Iterator[Tuple[SampleBlock, List[dict]]]:
        """Streaming with super-batching (ROADMAP item): when the chain has a
        vectorized OP, consecutive blocks are accumulated into device-sized
        super-batches (``super_batch_rows``) before dispatch, so the jit'd
        array program runs over one large sharded array instead of once per
        host-sized block — fewer dispatches, full mesh occupancy. Chains with
        no vectorized OP keep per-block latency. ColumnBlocks whose whole
        chain is columnar bypass both paths zero-copy (``_full_columnar``);
        any pending super-batch flushes first so row order is preserved."""
        for op in ops:
            op.setup()
        vectorized = any(
            getattr(op, "compute_stats_arrays", None) is not None
            and hasattr(op, "keep") for op in ops)
        if not vectorized:
            for blk in blocks:
                res = self._full_columnar(ops, blk)
                if res is not None:
                    yield res
                    continue
                samples, stats = self._chain_samples(ops, blk.samples, batch_size)
                yield SampleBlock(samples, nbytes=0), stats
            return

        pending: List[Sample] = []
        for blk in blocks:
            res = self._full_columnar(ops, blk)
            if res is not None:
                if pending:  # flush BEFORE the direct yield: keep row order
                    samples, stats = self._chain_samples(ops, pending, batch_size)
                    pending = []
                    yield SampleBlock(samples, nbytes=0), stats
                yield res
                continue
            pending.extend(blk.samples)
            if len(pending) >= self.super_batch_rows:
                samples, stats = self._chain_samples(ops, pending, batch_size)
                pending = []
                yield SampleBlock(samples, nbytes=0), stats
        if pending:
            samples, stats = self._chain_samples(ops, pending, batch_size)
            yield SampleBlock(samples, nbytes=0), stats


def make_engine(kind: str = "local", **kw):
    return {"local": LocalEngine, "parallel": ParallelEngine, "sharded": ShardedEngine}[kind](**kw)
