"""Execution engines behind the DJDataset facade (paper §5.1, §E.1).

  * LocalEngine    — single-process (HF-Datasets-standalone analogue).
  * ParallelEngine — multi-worker host execution over pre-split blocks
    (Ray-mode analogue) with speculative re-dispatch of straggler blocks.
  * ShardedEngine  — vectorized OPs executed as jit'd SPMD programs over the
    jax device mesh (the TPU-native adaptation: per-sample numeric/stat OPs
    become data-parallel array programs; everything else falls back to the
    host path). Model-based OPs score batches through the model substrate.

Engines share one interface (``map_batches``), so OPs are engine-agnostic —
the Facade-pattern property the paper emphasises.
"""
from __future__ import annotations

import collections
import concurrent.futures as cf
import os
import queue
import threading
import time
from typing import Any, Dict, Iterable, Iterator, List, Optional, Tuple

import numpy as np

from repro.core import schema as S
from repro.core.ops_base import Operator, OpError
from repro.core.storage import SampleBlock, split_blocks

Sample = Dict[str, Any]


class EngineStats(dict):
    pass


def _iter_batches(samples: List[Sample], batch_size: int):
    for i in range(0, len(samples), batch_size):
        yield i, samples[i : i + batch_size]


def run_chain(
    ops: List[Operator], samples: List[Sample],
    batch_size: Optional[int] = None, drop_empty: bool = True,
) -> Tuple[List[Sample], List[dict]]:
    """Drive one block's samples through a whole op chain in a single pass.

    This is the streaming executor's unit of work: one dispatch applies every
    op of a pipelineable segment to the block, instead of one dataset-wide
    barrier per op. Returns (out_samples, per-op stats) where each stats entry
    is {"op", "in", "out", "seconds", "errors"} for THIS block only — the
    caller aggregates across blocks so per-op lineage keeps working.
    """
    stats: List[dict] = []
    for op in ops:
        t0 = time.perf_counter()
        n_in = len(samples)
        err0 = len(op.errors)
        bs = batch_size or op.default_batch_size
        out: List[Sample] = []
        for i in range(0, len(samples), bs):
            out.extend(op.run_batch_safe(samples[i : i + bs], i))
        if drop_empty:
            out = [s for s in out if not S.is_empty(s)]
        samples = out
        stats.append({
            "op": op.name, "in": n_in, "out": len(samples),
            "seconds": time.perf_counter() - t0,
            "errors": len(op.errors) - err0,
        })
    return samples, stats


class LocalEngine:
    name = "local"

    def __init__(self, n_threads: int = 1):
        self.n_threads = n_threads

    def map_batches(
        self, op: Operator, blocks: List[SampleBlock], batch_size: int
    ) -> Tuple[List[SampleBlock], EngineStats]:
        op.setup()
        t0 = time.time()
        out_blocks: List[SampleBlock] = []
        n_in = 0
        threads = self.n_threads if op.io_intensive else 1
        # hierarchical parallelism: multithreading for I/O-bound OPs overlaps
        # I/O latency with compute (paper §F.2, Fig. 10b); one pool serves
        # every block of the call
        pool = cf.ThreadPoolExecutor(threads) if threads > 1 else None
        try:
            for blk in blocks:
                results: List[List[Sample]] = []
                if pool is not None:
                    futs = [
                        pool.submit(op.run_batch_safe, b, i)
                        for i, b in _iter_batches(blk.samples, batch_size)
                    ]
                    results = [f.result() for f in futs]
                else:
                    for i, b in _iter_batches(blk.samples, batch_size):
                        results.append(op.run_batch_safe(b, i))
                merged: List[Sample] = [s for r in results for s in r]
                n_in += len(blk)
                out_blocks.append(SampleBlock(merged))
        finally:
            if pool is not None:
                pool.shutdown()
        dt = time.time() - t0
        return out_blocks, EngineStats(seconds=dt, samples=n_in, engine=self.name)

    def map_block_chain(
        self, ops: List[Operator], blocks: Iterable[SampleBlock],
        batch_size: Optional[int] = None,
    ) -> Iterator[Tuple[SampleBlock, List[dict]]]:
        """Streaming: drive each block through the whole op chain, yielding
        (out_block, per-op block stats) as soon as the block completes.

        With ``n_threads > 1`` and an I/O-intensive op in the chain, blocks
        run through the chain concurrently in a bounded thread window
        (hierarchical parallelism, paper §F.2) — results stay in input order.
        Each thread gets its own op clones so error bookkeeping stays
        race-free; non-reconstructible ops fall back to the sequential path.
        """
        for op in ops:
            op.setup()
        threads = self.n_threads if any(op.io_intensive for op in ops) else 1
        cfgs = None
        if threads > 1:
            try:
                cfgs = [op.config() for op in ops]
                from repro.core.registry import create_op

                for c in cfgs:
                    create_op(c)  # reconstructibility probe
            except Exception:
                cfgs = None
        if threads <= 1 or cfgs is None:
            for blk in blocks:
                out, stats = run_chain(ops, blk.samples, batch_size)
                # nbytes left lazy (0): output blocks are consumed immediately
                # by the next segment or sink, never re-split by size
                yield SampleBlock(out, nbytes=0), stats
            return

        from repro.core.registry import create_op

        tls = threading.local()  # one clone chain per worker thread, not per block

        def work(samples):
            local_ops = getattr(tls, "ops", None)
            if local_ops is None:
                local_ops = [create_op(c) for c in cfgs]
                for o in local_ops:
                    o.setup()
                tls.ops = local_ops
            out, stats = run_chain(local_ops, samples, batch_size)
            errs = [(k, e) for k, o in enumerate(local_ops) for e in o.errors]
            for o in local_ops:
                o.errors = []  # reused clones must not re-report past blocks
            return out, stats, errs

        blocks_it = iter(blocks)
        with cf.ThreadPoolExecutor(threads) as pool:
            inflight: "collections.deque" = collections.deque()

            def submit_next() -> bool:
                blk = next(blocks_it, None)
                if blk is None:
                    return False
                inflight.append(pool.submit(work, blk.samples))
                return True

            while len(inflight) < 2 * threads and submit_next():
                pass
            while inflight:
                out, stats, errs = inflight.popleft().result()
                for k, e in errs:  # merged on the main thread — no races
                    ops[k].errors.append(e)
                submit_next()
                yield SampleBlock(out, nbytes=0), stats


def _worker_apply(op_config: Dict[str, Any], samples: List[Sample], batch_size: int):
    """Runs in a worker process: rebuild the OP from config, apply safely."""
    from repro.core.registry import create_op

    op = create_op(op_config)
    op.setup()
    out: List[Sample] = []
    for i in range(0, len(samples), batch_size):
        out.extend(op.run_batch_safe(samples[i : i + batch_size], i))
    return out, [e.__dict__ for e in op.errors]


def _worker_apply_chain(
    op_configs: List[Dict[str, Any]], samples: List[Sample],
    batch_size: Optional[int] = None,
):
    """Runs in a worker process: rebuild the whole segment chain from configs
    and drive the block through it in one dispatch."""
    from repro.core.registry import create_op

    ops = [create_op(c) for c in op_configs]
    for op in ops:
        op.setup()
    out, stats = run_chain(ops, samples, batch_size)
    # errors carry the op's index in the chain — attribution by name would
    # merge two instances of the same OP class
    errors = [(k, e.__dict__) for k, op in enumerate(ops) for e in op.errors]
    return out, stats, errors


class ParallelEngine:
    """Multi-process engine with straggler re-dispatch.

    Speculative execution: once >=50% of blocks finish, any block running
    longer than ``straggler_factor`` x the median completion time gets a
    backup submission; first finisher wins.
    """

    name = "parallel"

    def __init__(self, n_workers: Optional[int] = None, straggler_factor: float = 3.0):
        self.n_workers = n_workers or max(1, (os.cpu_count() or 2) - 1)
        self.straggler_factor = straggler_factor
        self.redispatches = 0

    def map_batches(self, op, blocks, batch_size):
        try:
            cfg = op.config()
            from repro.core.registry import create_op
            create_op(cfg)  # picklability / reconstructibility probe
        except Exception:
            return LocalEngine().map_batches(op, blocks, batch_size)

        t0 = time.time()
        results: Dict[int, List[Sample]] = {}
        errors: List[dict] = []
        with cf.ProcessPoolExecutor(self.n_workers) as pool:
            futs = {
                pool.submit(_worker_apply, cfg, blk.samples, batch_size): idx
                for idx, blk in enumerate(blocks)
            }
            start = {idx: time.time() for idx in futs.values()}
            times: List[float] = []
            backups: Dict[int, cf.Future] = {}
            pending = set(futs)
            while pending or any(i not in results for i in range(len(blocks))):
                done, pending = cf.wait(pending, timeout=0.05, return_when=cf.FIRST_COMPLETED)
                for f in done:
                    idx = futs[f]
                    if idx not in results:
                        try:
                            out, errs = f.result()
                            results[idx] = out
                            errors.extend(errs)
                            times.append(time.time() - start[idx])
                        except Exception as e:
                            # worker died: pass the input block through so the
                            # run completes, but surface the failure — a
                            # silent pass-through resurrects rows a Filter
                            # should have dropped
                            results[idx] = [s for s in blocks[idx].samples]
                            errors.append({
                                "op": op.name, "index": idx,
                                "error": f"worker failed on block {idx}: "
                                         f"{type(e).__name__}: {e}",
                            })
                if all(i in results for i in range(len(blocks))):
                    break
                # straggler mitigation
                if times and len(times) >= max(1, len(blocks) // 2):
                    med = float(np.median(times))
                    now = time.time()
                    for f, idx in list(futs.items()):
                        if (
                            idx not in results and idx not in backups
                            and now - start[idx] > self.straggler_factor * max(med, 0.05)
                        ):
                            b = pool.submit(_worker_apply, cfg, blocks[idx].samples, batch_size)
                            backups[idx] = b
                            futs[b] = idx
                            pending.add(b)
                            self.redispatches += 1
        out_blocks = [SampleBlock(results[i]) for i in range(len(blocks))]
        for e in errors:
            op.errors.append(OpError(**e))
        return out_blocks, EngineStats(
            seconds=time.time() - t0,
            samples=sum(len(b) for b in blocks),
            engine=self.name,
            redispatches=self.redispatches,
        )

    def map_block_chain(
        self, ops: List[Operator], blocks: Iterable[SampleBlock],
        batch_size: Optional[int] = None,
    ) -> Iterator[Tuple[SampleBlock, List[dict]]]:
        """Streaming: one worker dispatch drives a block through the whole
        segment chain. A bounded in-flight window (2x workers) keeps every
        worker busy without materializing the block stream; results are
        yielded in input order so outputs are deterministic."""
        try:
            cfgs = [op.config() for op in ops]
            from repro.core.registry import create_op

            for c in cfgs:
                create_op(c)  # picklability / reconstructibility probe
        except Exception:
            yield from LocalEngine().map_block_chain(ops, blocks, batch_size)
            return

        window = max(2, 2 * self.n_workers)
        blocks_it = iter(blocks)
        with cf.ProcessPoolExecutor(self.n_workers) as pool:
            inflight: "collections.deque" = collections.deque()

            def submit_next() -> bool:
                blk = next(blocks_it, None)
                if blk is None:
                    return False
                try:
                    fut = pool.submit(_worker_apply_chain, cfgs, blk.samples, batch_size)
                except Exception:
                    # pool is broken (worker OOM-killed/segfaulted): keep the
                    # run alive by finishing this block in-process
                    fut = cf.Future()
                    try:
                        fut.set_result(_worker_apply_chain(cfgs, blk.samples, batch_size))
                    except Exception as e:  # noqa: BLE001 — surfaced below
                        fut.set_exception(e)
                inflight.append((fut, blk))
                return True

            while len(inflight) < window and submit_next():
                pass
            while inflight:
                fut, blk = inflight.popleft()
                try:
                    out, stats, errs = fut.result()
                    for k, e in errs:
                        ops[k].errors.append(OpError(**e))
                except Exception as e:
                    out = list(blk.samples)  # pass through, but recorded
                    # synthesize pass-through stats so per-op lineage still
                    # accounts for this block's samples
                    stats = [{"op": o.name, "in": len(blk.samples),
                              "out": len(blk.samples), "seconds": 0.0,
                              "errors": 1 if k == 0 else 0}
                             for k, o in enumerate(ops)]
                    ops[0].errors.append(OpError(
                        ops[0].name, -1,
                        f"worker failed on chain block: {type(e).__name__}: {e}",
                    ))
                submit_next()
                yield SampleBlock(out, nbytes=0), stats


class ShardedEngine:
    """SPMD engine: vectorized OPs run as jit'd array programs on the mesh.

    An OP opts in by implementing
    ``compute_stats_arrays(cols) -> (stat_name, np.ndarray)`` — the engine
    builds padded device arrays sharded over ``data`` and executes the OP's
    jitted kernel; non-vectorized OPs fall back to the host path.
    """

    name = "sharded"

    # device-sized super-batch: consecutive blocks are merged until this many
    # rows are pending before a vectorized chain dispatch, so jit'd array
    # programs see a few large arrays instead of many block-sized ones
    SUPER_BATCH_ROWS = 4096

    def __init__(self, mesh=None, fallback: Optional[LocalEngine] = None,
                 super_batch_rows: Optional[int] = None):
        self.mesh = mesh
        self.fallback = fallback or LocalEngine()
        self.super_batch_rows = max(1, super_batch_rows or self.SUPER_BATCH_ROWS)

    def map_batches(self, op, blocks, batch_size):
        fn = getattr(op, "compute_stats_arrays", None)
        if fn is None or not hasattr(op, "keep"):
            return self.fallback.map_batches(op, blocks, batch_size)
        op.setup()
        t0 = time.time()
        out_blocks = []
        n = 0
        for blk in blocks:
            stat_name, values = fn(blk.samples)  # vectorized (numpy/jax)
            kept = []
            for s, v in zip(blk.samples, np.asarray(values)):
                s.setdefault("stats", {})[stat_name] = float(v)
                if op.keep(s):
                    kept.append(s)
            out_blocks.append(SampleBlock(kept))
            n += len(blk)
        return out_blocks, EngineStats(seconds=time.time() - t0, samples=n, engine=self.name)

    def _chain_samples(
        self, ops: List[Operator], samples: List[Sample],
        batch_size: Optional[int],
    ) -> Tuple[List[Sample], List[dict]]:
        """Drive one batch of samples through the chain: vectorized OPs run
        as array programs, the rest fall back to the host chain."""
        stats: List[dict] = []
        for op in ops:
            fn = getattr(op, "compute_stats_arrays", None)
            if fn is not None and hasattr(op, "keep") and samples:
                t0 = time.perf_counter()
                n_in = len(samples)
                stat_name, values = fn(samples)
                kept = []
                for s, v in zip(samples, np.asarray(values)):
                    s.setdefault("stats", {})[stat_name] = float(v)
                    if op.keep(s):
                        kept.append(s)
                samples = kept
                stats.append({
                    "op": op.name, "in": n_in, "out": len(samples),
                    "seconds": time.perf_counter() - t0, "errors": 0,
                })
            else:
                samples, sub = run_chain([op], samples, batch_size)
                stats.extend(sub)
        return samples, stats

    def map_block_chain(
        self, ops: List[Operator], blocks: Iterable[SampleBlock],
        batch_size: Optional[int] = None,
    ) -> Iterator[Tuple[SampleBlock, List[dict]]]:
        """Streaming with super-batching (ROADMAP item): when the chain has a
        vectorized OP, consecutive blocks are accumulated into device-sized
        super-batches (``super_batch_rows``) before dispatch, so the jit'd
        array program runs over one large sharded array instead of once per
        host-sized block — fewer dispatches, full mesh occupancy. Chains with
        no vectorized OP keep per-block latency."""
        for op in ops:
            op.setup()
        vectorized = any(
            getattr(op, "compute_stats_arrays", None) is not None
            and hasattr(op, "keep") for op in ops)
        if not vectorized:
            for blk in blocks:
                samples, stats = self._chain_samples(ops, blk.samples, batch_size)
                yield SampleBlock(samples, nbytes=0), stats
            return

        pending: List[Sample] = []
        for blk in blocks:
            pending.extend(blk.samples)
            if len(pending) >= self.super_batch_rows:
                samples, stats = self._chain_samples(ops, pending, batch_size)
                pending = []
                yield SampleBlock(samples, nbytes=0), stats
        if pending:
            samples, stats = self._chain_samples(ops, pending, batch_size)
            yield SampleBlock(samples, nbytes=0), stats


def make_engine(kind: str = "local", **kw):
    return {"local": LocalEngine, "parallel": ParallelEngine, "sharded": ShardedEngine}[kind](**kw)
