"""OP fusion + workload-aware probe-based reordering (paper §F.1, Fig. 9).

Data-Juicer 1.0 fused commutative Filters greedily and always pushed the
fused OP last. 2.0 reorders *globally* using probed speeds: within each
commutativity group, faster OPs run first (so slower OPs see fewer samples),
and the fused OP's speed is the harmonic composition

    v_fused = 1 / sum(1 / v_i)                     (paper Eq. 1)

This module holds the list-level KERNELS (reorder / fuse_filters /
plan_segments / op_speed) plus the streaming Segment type. The optimizer
itself — which kernels run, in what order, with per-rule rewrite diffs —
is the ordered rule pipeline in ``repro.core.rules`` operating on the
logical-plan IR (``repro.core.plan``); ``optimize`` below delegates to it.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core.adapter import OpProbe
from repro.core.ops_base import (
    BARRIER_TYPES, Deduplicator, Filter, FusedOP, Mapper, Operator,
)


def is_stream_stage_op(op: Operator) -> bool:
    """Dataset-level op that opted into the incremental streaming protocol
    (``Deduplicator.supports_streaming``) — planned as a stateful stream
    stage, not a barrier."""
    return isinstance(op, Deduplicator) and op.supports_streaming()


def is_barrier_op(op: Operator) -> bool:
    return isinstance(op, BARRIER_TYPES) and not is_stream_stage_op(op)


@dataclasses.dataclass
class Segment:
    """A unit of the streaming plan: a chain of batch-level OPs
    (Mappers / Filters / FusedOPs) that one block can traverse end-to-end in
    a single worker dispatch, a single barrier OP, or a single *stateful*
    stream-stage OP (streaming-capable dedup) that consumes and emits blocks
    incrementally on the driver."""

    ops: List[Operator]
    barrier: bool = False
    stateful: bool = False
    # predicate pushdown (columnar): the first ``n_pushdown`` ops are
    # vectorized column-only filters (supports_columns + pushdown_safe) that
    # the executor applies driver-side at block decode, so dropped rows are
    # never shipped to workers; the dispatched chain is ``ops[n_pushdown:]``
    n_pushdown: int = 0

    def __len__(self):
        return len(self.ops)


def plan_segments(ops: Sequence[Operator]) -> List[Segment]:
    """Partition an (already optimized) op plan into pipelineable segments
    separated by barrier ops. Consecutive non-barrier ops form one segment;
    every barrier op is its own segment; a streaming-capable dedup op is its
    own NON-barrier (stateful) segment — blocks still flow through it."""
    segs: List[Segment] = []
    cur: List[Operator] = []

    def pushdown_depth(chain: List[Operator]) -> int:
        n = 0
        for op in chain:
            try:
                if not (op.pushdown_safe and op.supports_columns()):
                    break
            except Exception:  # noqa: BLE001 — opt-in probe must not fail planning
                break
            n += 1
        return n

    def cut():
        nonlocal cur
        if cur:
            segs.append(Segment(cur, n_pushdown=pushdown_depth(cur)))
            cur = []

    for op in ops:
        if is_stream_stage_op(op):
            cut()
            segs.append(Segment([op], stateful=True))
        elif is_barrier_op(op):
            cut()
            segs.append(Segment([op], barrier=True))
        else:
            cur.append(op)
    cut()
    return segs


def harmonic_speed(speeds: Sequence[float]) -> float:
    inv = sum(1.0 / max(v, 1e-9) for v in speeds)
    return 1.0 / max(inv, 1e-12)


def commutativity_groups(ops: Sequence[Operator]) -> List[List[Operator]]:
    """Maximal runs of commutative OPs (order across groups is fixed)."""
    groups: List[List[Operator]] = []
    cur: List[Operator] = []
    for op in ops:
        if op.commutative and isinstance(op, (Filter,)):
            cur.append(op)
        else:
            if cur:
                groups.append(cur)
                cur = []
            groups.append([op])
    if cur:
        groups.append(cur)
    return groups


def fuse_filters(ops: Sequence[Operator]) -> List[Operator]:
    """Greedy fusion of adjacent fusible Filters into a FusedOP (1.0
    behaviour, kept as the baseline for the reordering ablation)."""
    out: List[Operator] = []
    run: List[Operator] = []
    for op in ops:
        if isinstance(op, Filter) and op.fusible:
            run.append(op)
        else:
            if len(run) > 1:
                out.append(FusedOP(run))
            elif run:
                out.extend(run)
            run = []
            out.append(op)
    if len(run) > 1:
        out.append(FusedOP(run))
    elif run:
        out.extend(run)
    return out


def op_speed(op: Operator, probes: Optional[Dict[str, OpProbe]] = None) -> float:
    if isinstance(op, FusedOP):
        return harmonic_speed([op_speed(o, probes) for o in op.ops])
    if probes and op.name in probes:
        return probes[op.name].speed
    return op.probed_speed or 1.0


def reorder(ops: Sequence[Operator], probes: Optional[Dict[str, OpProbe]] = None) -> List[Operator]:
    """Workload-aware reordering: within each commutativity group sort by
    probed speed, fastest first (applies to fused AND unfused OPs — the 2.0
    improvement over 1.0's fused-last heuristic)."""
    out: List[Operator] = []
    for group in commutativity_groups(list(ops)):
        if len(group) > 1:
            group = sorted(group, key=lambda o: -op_speed(o, probes))
        out.extend(group)
    return out


def optimize(
    ops: Sequence[Operator],
    probes: Optional[Dict[str, OpProbe]] = None,
    do_fuse: bool = True,
    do_reorder: bool = True,
) -> List[Operator]:
    """Optimize an op list. Thin compatibility wrapper: the optimizer proper
    is the ordered rule pipeline in ``repro.core.rules`` (reorder -> fuse ->
    reorder + annotation rules) applied over the logical-plan IR; this keeps
    the historical list-in/list-out entry point for benchmarks and tests."""
    from repro.core.plan import LogicalPlan
    from repro.core.rules import optimize_plan

    plan, _ = optimize_plan(LogicalPlan.from_ops(ops), probes,
                            do_fuse=do_fuse, do_reorder=do_reorder)
    return plan.ops()
