"""Injectable clock — the single source of wall/monotonic time for the
runtime (ISSUE 8).

Every timestamp the runtime records (lease expiry, queue-wait, span
start/duration, SLO math) flows through :func:`now` / :func:`monotonic`
instead of bare ``time.time()`` / ``time.monotonic()``. That buys two
things:

* **Hermetic tests** — :class:`FakeClock` + :func:`install` let tier-1
  tests drive lease expiry or span timing deterministically without
  sleeping.
* **Deterministic span merging** — under failover two processes emit
  spans for the same trace; a single clock abstraction is the one place
  to reason about skew (same-host shared-filesystem clusters share a
  clock, which merge ordering relies on).

``time.perf_counter()`` (interval micro-timing inside a single process)
and ``time.sleep()`` are deliberately NOT wrapped: they never cross a
process boundary or land in persisted telemetry. ``tools/check_clock.py``
enforces the split in CI: bare ``time.time``/``time.monotonic`` are
forbidden in ``src/repro`` outside this module.
"""
from __future__ import annotations

import time as _time


class SystemClock:
    """Real wall/monotonic time (the default)."""

    def now(self) -> float:
        return _time.time()

    def monotonic(self) -> float:
        return _time.monotonic()


class FakeClock:
    """Manually advanced clock for tests. ``tick(dt)`` moves both the
    wall and monotonic readings forward together."""

    def __init__(self, start: float = 1_000_000.0):
        self._now = float(start)
        self._mono = 0.0

    def now(self) -> float:
        return self._now

    def monotonic(self) -> float:
        return self._mono

    def tick(self, dt: float) -> None:
        self._now += dt
        self._mono += dt


_clock = SystemClock()


def now() -> float:
    """Wall-clock seconds since the epoch (``time.time`` semantics)."""
    return _clock.now()


def monotonic() -> float:
    """Monotonic seconds (``time.monotonic`` semantics)."""
    return _clock.monotonic()


def install(clock) -> None:
    """Replace the process-global clock (tests). Pair with :func:`reset`."""
    global _clock
    _clock = clock


def reset() -> None:
    """Restore the real :class:`SystemClock`."""
    global _clock
    _clock = SystemClock()


def get() -> object:
    """The currently installed clock object."""
    return _clock
