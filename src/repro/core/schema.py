"""Token-aligned multimodal data schema (paper §5.1, Appendix D.2).

Samples are flat dicts with three field groups:
  * core contents  — "text" (pre-training) and/or "query"/"response"/
    "history" (post-tuning);
  * extra data     — modality path lists ("images", "videos", "audios"),
    referenced in order by special tokens inside "text";
  * "meta" / "stats" — provenance and per-OP computed statistics.

Text is chunked by ``EOC``; each chunk is a semantic unit whose modality
tokens align with the corresponding entries of the modality lists.
"""
from __future__ import annotations

import copy
from typing import Any, Dict, List, Optional, Tuple

IMAGE_TOKEN = "<__dj__image>"
VIDEO_TOKEN = "<__dj__video>"
AUDIO_TOKEN = "<__dj__audio>"
EOC = "<|__dj__eoc|>"

MODALITY_TOKENS = {"images": IMAGE_TOKEN, "videos": VIDEO_TOKEN, "audios": AUDIO_TOKEN}
MODALITY_KEYS = tuple(MODALITY_TOKENS)
CORE_KEYS = ("text", "query", "response", "history")


def new_sample(text: str = "", **fields) -> Dict[str, Any]:
    s: Dict[str, Any] = {"text": text, "meta": {}, "stats": {}}
    s.update(fields)
    return s


def chunks(sample: Dict[str, Any]) -> List[str]:
    return sample.get("text", "").split(EOC)


def modality_counts(sample: Dict[str, Any]) -> Dict[str, int]:
    text = sample.get("text", "")
    return {k: text.count(tok) for k, tok in MODALITY_TOKENS.items()}


def check_alignment(sample: Dict[str, Any]) -> Tuple[bool, str]:
    """Every modality token must reference an entry of its path list."""
    counts = modality_counts(sample)
    for key, n_tok in counts.items():
        n_paths = len(sample.get(key, []) or [])
        if n_tok != n_paths:
            return False, f"{key}: {n_tok} tokens vs {n_paths} paths"
    return True, ""


def empty_like(sample: Dict[str, Any]) -> Dict[str, Any]:
    """Schema-compatible empty sample (fault tolerance, paper §E.2)."""
    out: Dict[str, Any] = {}
    for k, v in sample.items():
        if isinstance(v, str):
            out[k] = ""
        elif isinstance(v, list):
            out[k] = []
        elif isinstance(v, dict):
            out[k] = {} if k not in ("meta", "stats") else {"__empty__": True}
        elif isinstance(v, bool):
            out[k] = False
        elif isinstance(v, int):
            out[k] = 0
        elif isinstance(v, float):
            out[k] = 0.0
        else:
            out[k] = None
    out.setdefault("meta", {"__empty__": True})
    out["meta"] = dict(out.get("meta") or {}, __empty__=True)
    return out


def is_empty(sample: Dict[str, Any]) -> bool:
    return bool((sample.get("meta") or {}).get("__empty__"))


class ValidationError(ValueError):
    pass


class DataValidator:
    """Pre-flight dataset validation (paper §5.1 'Reliable Data Loading').

    ``goal`` in {"pretrain", "post_tuning", "multimodal", None}.
    """

    def __init__(self, goal: Optional[str] = None, required_fields: Tuple[str, ...] = ()):
        self.goal = goal
        self.required_fields = required_fields

    def validate_sample(self, sample: Dict[str, Any]) -> None:
        if not isinstance(sample, dict):
            raise ValidationError(f"sample must be a dict, got {type(sample)}")
        for f in self.required_fields:
            if f not in sample:
                raise ValidationError(f"missing required field {f!r}")
        if self.goal == "pretrain" and not isinstance(sample.get("text", None), str):
            raise ValidationError("pretrain goal requires a string 'text' field")
        if self.goal == "post_tuning":
            if "query" not in sample or "response" not in sample:
                raise ValidationError("post_tuning goal requires query/response dialog fields")
        if self.goal == "multimodal":
            ok, why = check_alignment(sample)
            if not ok:
                raise ValidationError(f"modality misalignment: {why}")

    def validate(self, samples) -> int:
        n = 0
        for s in samples:
            self.validate_sample(s)
            n += 1
        return n


# ---------------------------------------------------------------------------
# Conversion tools (paper: bi-directional converters for training ecosystems)
# ---------------------------------------------------------------------------


def to_alpaca(sample: Dict[str, Any]) -> Dict[str, Any]:
    return {
        "instruction": sample.get("query", ""),
        "input": "",
        "output": sample.get("response", ""),
        "history": copy.deepcopy(sample.get("history", [])),
    }


def from_alpaca(rec: Dict[str, Any]) -> Dict[str, Any]:
    q = rec.get("instruction", "")
    if rec.get("input"):
        q = f"{q}\n{rec['input']}"
    return new_sample(
        text="", query=q, response=rec.get("output", ""),
        history=copy.deepcopy(rec.get("history", [])),
    )


def to_query_response(sample: Dict[str, Any]) -> List[Dict[str, str]]:
    """Flatten history + current turn into role/content messages."""
    msgs = []
    for q, r in sample.get("history", []) or []:
        msgs.append({"role": "user", "content": q})
        msgs.append({"role": "assistant", "content": r})
    if sample.get("query"):
        msgs.append({"role": "user", "content": sample["query"]})
    if sample.get("response"):
        msgs.append({"role": "assistant", "content": sample["response"]})
    return msgs
