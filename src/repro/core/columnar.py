"""Struct-of-arrays sample blocks (the Arrow-style block format).

A :class:`ColumnBlock` holds one block of samples as typed columns instead
of a list of per-row dicts: string fields live in offset-indexed UTF-8
buffers, homogeneous numeric fields in numpy arrays, and everything else in
per-row JSON fragments (or raw Python objects for non-JSON carriers such as
the minhash signature arrays). JSONL is demoted to an import/export codec:
``storage.iter_sample_blocks`` builds ColumnBlocks at ingest and
``BlockWriter`` serializes them back without materializing dicts.

The format is **canonical-ordering-stable**: per-row key order is recorded
in compact "plans" (one tuple of column indices per distinct ordering), so
``decode(encode(rows))`` reproduces ``json_dumps(row)`` byte-for-byte —
the invariant every streaming/barriered/failover byte-identity test rests
on. Columns of kind:

* ``str`` — ``(offsets int64[n+1], utf8 bytes)``; absent rows are
  zero-length slices (never read back — plans gate presence).
* ``f64`` / ``i64`` — dense numpy arrays (Python ``float``/``int`` only;
  ``bool`` is routed to ``obj`` so ``true`` never re-encodes as ``1``).
* ``obj`` — ``(offsets, bytes)`` of ``json_dumps`` fragments for nested
  dicts/lists, bools, None, mixed-type and out-of-int64 values. Fragments
  come from the canonical dumper, so splicing them verbatim into an export
  line is byte-identical to re-dumping the decoded value.
* ``py`` — plain list fallback for values ``json_dumps`` rejects (numpy
  arrays planted by the presign mapper); these never reach an export.

Blocks are immutable until ``.samples`` is first accessed: that decodes
once, caches, and makes the row dicts authoritative (ops may mutate them
in place — the dedup stage pops signature carriers, for example). All
columnar transforms (``take``, ``with_stat``, ``with_py_column``) build new
blocks and are only legal on non-materialized blocks, which is what lets
speculative re-dispatch share one input block across attempts.

Optional zstd compression for spill/checkpoint payloads is negotiated at
runtime (``maybe_compress``/``maybe_decompress``) — absent ``zstandard``
the bytes pass through unchanged with a ``raw`` tag.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.storage import json_dumps, json_loads

try:  # optional spill codec — CI installs zstandard, the floor build skips it
    import zstandard as _zstd
except ImportError:  # pragma: no cover - exercised on the floor build
    _zstd = None

_I64_MIN = -(1 << 63)
_I64_MAX = (1 << 63) - 1

Sample = Dict[str, Any]


def _is_empty_sample(s: Sample) -> bool:
    meta = s.get("meta")
    return bool(isinstance(meta, dict) and meta.get("__empty__"))


def _classify(v: Any) -> str:
    t = type(v)
    if t is str:
        return "str"
    if t is float:
        return "f64"
    if t is int:
        return "i64" if _I64_MIN <= v <= _I64_MAX else "obj"
    return "obj"  # dict/list/bool/None/mixed — json fragments (py fallback)


class ColumnBlock:
    """One block of samples in struct-of-arrays layout (see module doc)."""

    __slots__ = ("keys", "kinds", "data", "plans", "row_plan", "n",
                 "nbytes", "may_have_empty", "_samples")

    def __init__(self, keys, kinds, data, plans, row_plan, n, nbytes,
                 may_have_empty=False):
        self.keys = keys                # tuple[str] column names
        self.kinds = kinds              # tuple[str] column kinds
        self.data = data                # per-column payload (see module doc)
        self.plans = plans              # list[tuple[int]] distinct key orders
        self.row_plan = row_plan        # int32[n] plan index per row
        self.n = n
        self.nbytes = nbytes
        self.may_have_empty = may_have_empty
        self._samples: Optional[List[Sample]] = None

    # -- construction ------------------------------------------------------

    @classmethod
    def from_samples(cls, samples: Sequence[Sample],
                     nbytes: Optional[int] = None,
                     may_have_empty: Optional[bool] = None) -> "ColumnBlock":
        """Encode row dicts into columns. Raises ``TypeError`` on non-string
        keys (caller falls back to a row SampleBlock)."""
        n = len(samples)
        if n:
            blk = cls._from_uniform(samples, nbytes, may_have_empty)
            if blk is not None:
                return blk
        keys: List[str] = []
        kinds: List[Optional[str]] = []
        key_ix: Dict[str, int] = {}
        col_rows: List[List[int]] = []   # present row indices, ascending
        col_vals: List[List[Any]] = []   # present values, row order
        plans: List[Tuple[int, ...]] = []
        plan_ix: Dict[Tuple[int, ...], int] = {}
        row_plan = np.empty(n, np.int32)
        empties = False
        for i, s in enumerate(samples):
            pk: List[int] = []
            for k, v in s.items():
                if type(k) is not str:
                    raise TypeError(f"non-string sample key: {k!r}")
                ci = key_ix.get(k)
                if ci is None:
                    ci = key_ix[k] = len(keys)
                    keys.append(k)
                    kinds.append(None)
                    col_rows.append([])
                    col_vals.append([])
                col_rows[ci].append(i)
                col_vals[ci].append(v)
                nk = _classify(v)
                if kinds[ci] is None:
                    kinds[ci] = nk
                elif kinds[ci] != nk:
                    kinds[ci] = "obj"
                pk.append(ci)
            pt = tuple(pk)
            pi = plan_ix.get(pt)
            if pi is None:
                pi = plan_ix[pt] = len(plans)
                plans.append(pt)
            row_plan[i] = pi
            if may_have_empty is None and not empties:
                empties = _is_empty_sample(s)
        data: List[Any] = []
        for ci, kind in enumerate(kinds):
            rows, vals = col_rows[ci], col_vals[ci]
            if kind == "str":
                data.append(_ragged(n, rows, [v.encode("utf-8") for v in vals]))
            elif kind == "f64":
                arr = np.zeros(n, np.float64)
                arr[rows] = vals
                data.append(arr)
            elif kind == "i64":
                arr = np.zeros(n, np.int64)
                arr[rows] = vals
                data.append(arr)
            else:
                try:
                    frags = [json_dumps(v) for v in vals]
                except (TypeError, ValueError):
                    kinds[ci] = "py"
                    lst: List[Any] = [None] * n
                    for r, v in zip(rows, vals):
                        lst[r] = v
                    data.append(lst)
                    continue
                data.append(_ragged(n, rows, frags))
        blk = cls(tuple(keys), tuple(kinds), data, plans, row_plan, n, 0,
                  may_have_empty=empties if may_have_empty is None
                  else may_have_empty)
        blk.nbytes = nbytes if nbytes is not None else blk.buffer_bytes()
        return blk

    @classmethod
    def _from_uniform(cls, samples: Sequence[Sample], nbytes, may_have_empty
                      ) -> Optional["ColumnBlock"]:
        """Fast encode for the common shape — every row shares one key
        order — skipping the per-row plan bookkeeping the generic loop pays.
        Returns ``None`` when rows disagree (generic path takes over)."""
        n = len(samples)
        keys = list(samples[0].keys())
        for s in samples:
            if list(s.keys()) != keys:
                return None
        for k in keys:
            if type(k) is not str:
                raise TypeError(f"non-string sample key: {k!r}")
        kinds: List[str] = []
        data: List[Any] = []
        for k in keys:
            vals = [s[k] for s in samples]
            ts = set(map(type, vals))
            if ts == {str}:
                kind = "str"
            elif ts == {float}:
                kind = "f64"
            elif ts == {int}:
                kind = ("i64" if _I64_MIN <= min(vals) and max(vals) <= _I64_MAX
                        else "obj")
            else:
                kind = "obj"
            if kind == "str":
                data.append(_ragged_from_frags([v.encode("utf-8") for v in vals]))
            elif kind == "f64":
                data.append(np.asarray(vals, np.float64))
            elif kind == "i64":
                data.append(np.asarray(vals, np.int64))
            else:
                try:
                    data.append(_ragged_from_frags([json_dumps(v) for v in vals]))
                except (TypeError, ValueError):
                    kind = "py"
                    data.append(list(vals))
            kinds.append(kind)
        empties = (any(map(_is_empty_sample, samples))
                   if may_have_empty is None else may_have_empty)
        blk = cls(tuple(keys), tuple(kinds), data,
                  [tuple(range(len(keys)))], np.zeros(n, np.int32), n, 0,
                  may_have_empty=empties)
        blk.nbytes = nbytes if nbytes is not None else blk.buffer_bytes()
        return blk

    # -- introspection -----------------------------------------------------

    def __len__(self) -> int:
        return self.n

    @property
    def materialized(self) -> bool:
        return self._samples is not None

    def has_column(self, key: str) -> bool:
        return key in self.keys

    def buffer_bytes(self) -> int:
        """Actual resident bytes of the column buffers (the cheap,
        real memory-pressure signal the dispatcher consumes)."""
        total = self.row_plan.nbytes
        for kind, d in zip(self.kinds, self.data):
            if kind in ("str", "obj"):
                total += d[0].nbytes + len(d[1])
            elif kind == "py":
                total += 64 * self.n  # rough: object headers + pointers
            else:
                total += d.nbytes
        return total

    # -- row shim ----------------------------------------------------------

    def _value(self, ci: int, i: int) -> Any:
        kind = self.kinds[ci]
        d = self.data[ci]
        if kind == "str":
            offs, buf = d
            return buf[offs[i]:offs[i + 1]].decode("utf-8")
        if kind == "f64":
            return float(d[i])
        if kind == "i64":
            return int(d[i])
        if kind == "obj":
            offs, buf = d
            return json_loads(buf[offs[i]:offs[i + 1]])
        return d[i]

    def decode_rows(self) -> List[Sample]:
        """Fresh, private decode (never cached) — for concurrent consumers
        (speculative thread attempts) that must not share mutable rows."""
        rp, plans = self.row_plan.tolist(), self.plans
        return [
            {self.keys[ci]: self._value(ci, i) for ci in plans[rp[i]]}
            for i in range(self.n)]

    @property
    def samples(self) -> List[Sample]:
        """Row-dict shim for ops that haven't opted into columns. Decodes
        ONCE and caches — after this the dicts are authoritative (callers
        mutate them in place), so every later access sees the same list."""
        if self._samples is None:
            self._samples = self.decode_rows()
        return self._samples

    def column_values(self, key: str) -> List[Any]:
        """Per-row values of one column (``None`` where the row lacks the
        key) without materializing row dicts."""
        ci = self.keys.index(key)
        present = self._presence(ci)
        return [self._value(ci, i) if present[i] else None
                for i in range(self.n)]

    def string_values(self, key: str) -> List[str]:
        """Decoded strings of a ``str`` column, ``""`` for absent rows —
        matching the ``sample.get(key, "")`` row-path contract. Raises
        ``TypeError`` on a non-string column (caller falls back to rows)."""
        if key not in self.keys:
            return [""] * self.n
        ci = self.keys.index(key)
        if self.kinds[ci] != "str":
            raise TypeError(f"column {key!r} is {self.kinds[ci]}, not str")
        offs, buf = self.data[ci]
        bounds = offs.tolist()  # plain ints: numpy scalar slicing is slow
        return [buf[bounds[i]:bounds[i + 1]].decode("utf-8")
                for i in range(self.n)]

    def str_column(self, key: str) -> Optional[Tuple[np.ndarray, bytes]]:
        """Raw ``(offsets, utf8 buffer)`` of a string column for fully
        vectorized consumers; ``None`` if absent, ``TypeError`` if the
        column isn't ``str``-kind."""
        if key not in self.keys:
            return None
        ci = self.keys.index(key)
        if self.kinds[ci] != "str":
            raise TypeError(f"column {key!r} is {self.kinds[ci]}, not str")
        return self.data[ci]

    def _presence(self, ci: int) -> np.ndarray:
        m = np.zeros(len(self.plans), bool)
        for pi, plan in enumerate(self.plans):
            m[pi] = ci in plan
        return m[self.row_plan]

    # -- export codec ------------------------------------------------------

    def iter_json_lines(self, exclude: Tuple[str, ...] = ()) -> Iterator[bytes]:
        """Serialize rows to canonical JSONL bytes. On a non-materialized
        block this never builds dicts: key fragments are precomputed per
        column and ``obj`` fragments are spliced verbatim, so the line is
        byte-identical to ``json_dumps(row)`` by construction."""
        if self._samples is not None:
            for s in self._samples:
                if exclude:
                    s = {k: v for k, v in s.items() if k not in exclude}
                yield json_dumps(s)
            return
        skip = {self.keys.index(k) for k in exclude if k in self.keys}
        kf = [json_dumps(k) + b":" for k in self.keys]
        kinds, data = self.kinds, self.data
        rp = self.row_plan.tolist()
        for i in range(self.n):
            parts: List[bytes] = []
            for ci in self.plans[rp[i]]:
                if ci in skip:
                    continue
                kind = kinds[ci]
                d = data[ci]
                if kind == "str":
                    offs, buf = d
                    frag = json_dumps(buf[offs[i]:offs[i + 1]].decode("utf-8"))
                elif kind == "f64":
                    frag = json_dumps(float(d[i]))
                elif kind == "i64":
                    frag = json_dumps(int(d[i]))
                elif kind == "obj":
                    offs, buf = d
                    frag = buf[offs[i]:offs[i + 1]]
                else:
                    frag = json_dumps(d[i])  # py: raises like the row path
                parts.append(kf[ci] + frag)
            yield b"{" + b",".join(parts) + b"}"

    # -- columnar transforms (non-materialized blocks only) ----------------

    def _check_transform(self) -> None:
        if self._samples is not None:
            raise RuntimeError("columnar transform on a materialized block")

    def take(self, mask: np.ndarray) -> "ColumnBlock":
        """Select rows by boolean mask -> new block (filter output)."""
        self._check_transform()
        idx = np.flatnonzero(mask)
        data: List[Any] = []
        for kind, d in zip(self.kinds, self.data):
            if kind in ("str", "obj"):
                offs, buf = d
                starts = offs[idx]
                lens = offs[idx + 1] - starts
                new_offs = np.zeros(idx.size + 1, np.int64)
                np.cumsum(lens, out=new_offs[1:])
                total = int(new_offs[-1])
                if total == len(buf):
                    # every dropped row was zero-length: bytes are unchanged
                    new_buf = buf
                else:
                    # vectorized ragged gather: output byte p of row j reads
                    # source byte starts[j] + (p - new_offs[j])
                    src = np.frombuffer(buf, np.uint8)
                    gather = np.repeat(starts - new_offs[:-1], lens) \
                        + np.arange(total, dtype=np.int64)
                    new_buf = src[gather].tobytes()
                data.append((new_offs, new_buf))
            elif kind == "py":
                data.append([d[i] for i in idx])
            else:
                data.append(d[idx])
        blk = ColumnBlock(self.keys, self.kinds, data, self.plans,
                          self.row_plan[idx], int(idx.size), 0,
                          may_have_empty=self.may_have_empty)
        blk.nbytes = blk.buffer_bytes()
        return blk

    def with_stat(self, key: str, values: np.ndarray) -> "ColumnBlock":
        """Splice ``stats[key] = float(v)`` into every row, reproducing the
        row path's ``sample.setdefault("stats", {})[key] = v`` byte-exactly:
        existing ``stats`` dicts get the key appended (or updated in place
        if present), rows without ``stats`` grow it at the end of the row.
        Raises on any shape the fast path can't prove equivalent (non-dict
        stats, py-kind column) — the caller falls back to the row shim."""
        self._check_transform()
        qkey = json_dumps(key)
        ci = self.keys.index("stats") if "stats" in self.keys else None
        keys, kinds, data = list(self.keys), list(self.kinds), list(self.data)
        # one dumps call covers every value: a float fragment never contains
        # a comma, so the canonical list encoding splits back into exactly
        # the per-value fragments json_dumps(float(v)) would produce
        vfrags = (json_dumps([float(v) for v in values])[1:-1].split(b",")
                  if len(values) else [])
        if ci is None:
            ci = len(keys)
            keys.append("stats")
            kinds.append("obj")
            frags = [b"{" + qkey + b":" + vf + b"}" for vf in vfrags]
            data.append(_ragged_from_frags(frags))
        elif kinds[ci] == "obj":
            offs, buf = data[ci]
            # plain Python ints/bools: numpy scalar indexing is an order of
            # magnitude slower inside this per-row loop
            offs = offs.tolist()
            present = self._presence(ci).tolist()
            mv = memoryview(buf)
            # whole-buffer scan decides once whether any row might already
            # carry the key — the common append-only case skips the per-row
            # substring test and the exact-update decode entirely
            may_update = qkey in buf
            frags = []
            for i in range(self.n):
                vfrag = vfrags[i]
                if not present[i]:
                    frags.append(b"{" + qkey + b":" + vfrag + b"}")
                    continue
                f = bytes(mv[offs[i]:offs[i + 1]])
                if not f.startswith(b"{"):
                    raise ValueError("stats is not a JSON object")
                if may_update and qkey in f:  # key may already exist: exact update
                    dec = json_loads(f)
                    dec[key] = float(values[i])
                    frags.append(json_dumps(dec))
                elif f == b"{}":
                    frags.append(b"{" + qkey + b":" + vfrag + b"}")
                else:
                    frags.append(f[:-1] + b"," + qkey + b":" + vfrag + b"}")
            data[ci] = _ragged_from_frags(frags)
        else:
            raise TypeError(f"stats column is {kinds[ci]}, not obj")
        plans = [p if ci in p else p + (ci,) for p in self.plans]
        blk = ColumnBlock(tuple(keys), tuple(kinds), data, plans,
                          self.row_plan, self.n, 0,
                          may_have_empty=self.may_have_empty)
        blk.nbytes = blk.buffer_bytes()
        return blk

    def with_py_column(self, key: str, values: List[Any]) -> "ColumnBlock":
        """Append a raw-Python column present on every row (the presign
        mapper's signature carriers) — matches ``sample[key] = v`` appended
        at the end of each row dict."""
        self._check_transform()
        if key in self.keys:
            raise ValueError(f"column {key!r} already exists")
        ci = len(self.keys)
        plans = [p + (ci,) for p in self.plans]
        blk = ColumnBlock(self.keys + (key,), self.kinds + ("py",),
                          self.data + [list(values)], plans, self.row_plan,
                          self.n, self.nbytes,
                          may_have_empty=self.may_have_empty)
        return blk

    # -- IPC ---------------------------------------------------------------

    def __getstate__(self):
        return (self.keys, self.kinds, self.data, self.plans, self.row_plan,
                self.n, self.nbytes, self.may_have_empty)

    def __setstate__(self, state):
        (self.keys, self.kinds, self.data, self.plans, self.row_plan,
         self.n, self.nbytes, self.may_have_empty) = state
        self._samples = None


def _ragged(n: int, rows: List[int], frags: List[bytes]
            ) -> Tuple[np.ndarray, bytes]:
    """(offsets, buffer) with zero-length slices for absent rows."""
    lens = np.zeros(n, np.int64)
    lens[rows] = [len(f) for f in frags]
    offs = np.zeros(n + 1, np.int64)
    np.cumsum(lens, out=offs[1:])
    return offs, b"".join(frags)


def _ragged_from_frags(frags: List[bytes]) -> Tuple[np.ndarray, bytes]:
    offs = np.zeros(len(frags) + 1, np.int64)
    np.cumsum([len(f) for f in frags], out=offs[1:])
    return offs, b"".join(frags)


# ---------------------------------------------------------------------------
# vectorized helpers for columnar filters
# ---------------------------------------------------------------------------


def utf8_char_counts(offsets: np.ndarray, buf: bytes) -> np.ndarray:
    """Per-row Unicode code-point counts straight off a string column's
    UTF-8 buffer: a byte starts a code point iff it is not a continuation
    byte (``(b & 0xC0) != 0x80``), so the count equals ``len(str)`` exactly
    for any valid UTF-8 — one vectorized pass, no per-row decode."""
    if len(buf) == 0:
        return np.zeros(len(offsets) - 1, np.int64)
    arr = np.frombuffer(buf, np.uint8)
    # int32 running count halves the memory traffic; a block buffer is far
    # below the 2^31-char overflow point
    starts = np.zeros(len(arr) + 1, np.int32)
    np.cumsum((arr & 0xC0) != 0x80, out=starts[1:])
    return (starts[offsets[1:]] - starts[offsets[:-1]]).astype(np.int64)


# byte-class lookup tables (ASCII range; bytes >= 0x80 are continuation or
# lead bytes of multi-byte code points — rows containing any are recomputed
# per row by the caller, so the tables' False there is never load-bearing)
_WS_BYTE = np.zeros(256, bool)
_ALNUM_SP_BYTE = np.zeros(256, bool)
for _b in range(128):
    _WS_BYTE[_b] = chr(_b).isspace()
    _ALNUM_SP_BYTE[_b] = chr(_b).isalnum() or chr(_b).isspace()
del _b


def ascii_rows_mask(offsets: np.ndarray, buf: bytes) -> np.ndarray:
    """True for rows whose slice is pure ASCII — the rows where byte-level
    char classes match Python's per-character semantics exactly."""
    n = len(offsets) - 1
    if len(buf) == 0:
        return np.ones(n, bool)
    arr = np.frombuffer(buf, np.uint8)
    hi = np.zeros(len(arr) + 1, np.int32)
    np.cumsum(arr >= 0x80, out=hi[1:])
    return (hi[offsets[1:]] - hi[offsets[:-1]]) == 0


def ascii_word_counts(offsets: np.ndarray, buf: bytes) -> np.ndarray:
    """Per-row whitespace-delimited token counts — equals ``len(t.split())``
    exactly for pure-ASCII rows (``str.split`` and ``str.isspace`` share the
    same character class). Callers must recompute rows flagged non-ASCII by
    :func:`ascii_rows_mask`."""
    n = len(offsets) - 1
    if len(buf) == 0:
        return np.zeros(n, np.int64)
    arr = np.frombuffer(buf, np.uint8)
    nonws = ~_WS_BYTE[arr]
    # a word starts at a non-ws byte whose predecessor is ws (or buffer
    # start); count per row via running sum, then patch rows whose first
    # byte continues a "word" spilling over from the previous row's slice
    prev = np.empty_like(nonws)
    prev[0] = False
    prev[1:] = nonws[:-1]
    cum = np.zeros(len(arr) + 1, np.int32)
    np.cumsum(nonws & ~prev, out=cum[1:])
    counts = (cum[offsets[1:]] - cum[offsets[:-1]]).astype(np.int64)
    so = offsets[:-1]
    ie = np.flatnonzero(offsets[1:] > so)  # nonempty rows
    first_nonws = np.zeros(n, bool)
    first_nonws[ie] = nonws[so[ie]]
    prev_nonws = np.zeros(n, bool)
    ip = ie[so[ie] > 0]
    prev_nonws[ip] = nonws[so[ip] - 1]
    return counts + (first_nonws & prev_nonws)


def ascii_alnum_space_counts(offsets: np.ndarray, buf: bytes) -> np.ndarray:
    """Per-row counts of alphanumeric-or-whitespace bytes — equals the
    per-character count exactly for pure-ASCII rows."""
    n = len(offsets) - 1
    if len(buf) == 0:
        return np.zeros(n, np.int64)
    arr = np.frombuffer(buf, np.uint8)
    cum = np.zeros(len(arr) + 1, np.int32)
    np.cumsum(_ALNUM_SP_BYTE[arr], out=cum[1:])
    return (cum[offsets[1:]] - cum[offsets[:-1]]).astype(np.int64)


# ---------------------------------------------------------------------------
# optional zstd codec for spill / checkpoint payloads
# ---------------------------------------------------------------------------


def maybe_compress(raw: bytes, level: int = 3) -> Tuple[str, bytes]:
    """Codec negotiation for spill/checkpoint payloads: ``("zstd", ...)``
    when zstandard is importable, ``("raw", ...)`` passthrough otherwise."""
    if _zstd is None:
        return "raw", raw
    return "zstd", _zstd.ZstdCompressor(level=level).compress(raw)


def maybe_decompress(codec: str, payload: bytes) -> bytes:
    if codec == "raw":
        return payload
    if codec == "zstd":
        if _zstd is None:
            raise RuntimeError("zstd payload but zstandard is not installed")
        return _zstd.ZstdDecompressor().decompress(payload)
    raise ValueError(f"unknown block codec {codec!r}")
