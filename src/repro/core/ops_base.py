"""Operator taxonomy (paper §5.2, Appendix D.3).

Five atomic types — Formatter / Mapper / Filter / Deduplicator / Selector —
plus five compositional types — Grouper / Aggregator / FusedOP / ScriptOP /
HumanOP. A top-level abstract factory centralises parameter handling,
serialization, resource hints and the unified ``run()`` template method;
leaf OPs only implement their type's hook (``process_single``,
``compute_stats`` + ``keep``, ...), so each OP is self-contained and
individually testable.

Sample-level fault tolerance (paper §E.2): ``run()`` executes batches under
an exception manager; a failing batch is retried per-sample, and failing
samples are replaced by schema-compatible empty samples (dropped at the end
of the pipeline unless ``keep_failed``) while the error is recorded.
"""
from __future__ import annotations

import dataclasses
import time
import traceback
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence

from repro.core import clock
from repro.core import schema as S

Sample = Dict[str, Any]


@dataclasses.dataclass
class OpError:
    op: str
    index: int
    error: str


class Operator:
    """Abstract factory base for all OPs."""

    # resource hints used by the Adapter (paper §F.2)
    cpu_required: float = 1.0
    mem_required: int = 0  # bytes per worker
    gpu_mem_required: int = 0  # accelerator bytes per model instance (model OPs)
    uses_model: bool = False
    io_intensive: bool = False
    batched: bool = True
    default_batch_size: int = 1000

    # fusion metadata
    fusible: bool = False
    commutative: bool = True

    # columnar protocol: ops that opt in process whole ColumnBlocks without
    # the row-dict shim; ``pushdown_safe`` additionally marks ops cheap
    # enough (fully vectorized) to run driver-side at block decode
    # (fusion.plan_segments predicate pushdown)
    pushdown_safe: bool = False

    def __init__(self, **params):
        self.params = params
        # probed at runtime by the Adapter
        self.probed_speed: Optional[float] = None  # samples/sec
        self.errors: List[OpError] = []

    # ------------------------------------------------------------------
    @property
    def name(self) -> str:
        return getattr(self, "_name", type(self).__name__)

    def config(self) -> Dict[str, Any]:
        """Serialization: (name, params) round-trips through the registry."""
        return {"name": self.name, **self.params}

    def __repr__(self):
        return f"{type(self).__name__}({self.params})"

    # ------------------------------------------------------------------
    # per-type hooks
    # ------------------------------------------------------------------
    def process_batch(self, batch: List[Sample]) -> List[Sample]:
        raise NotImplementedError

    def setup(self) -> None:
        """Lazy init (model loading etc.) — called once before processing."""

    # ------------------------------------------------------------------
    # columnar protocol (struct-of-arrays blocks, repro.core.columnar)
    # ------------------------------------------------------------------
    def supports_columns(self) -> bool:
        """True when this op (as configured) can consume a ColumnBlock via
        :meth:`process_columns` with output equivalent to the row path.
        Engines run the longest columnar prefix of a chain before falling
        back to the row-dict shim; any exception inside the columnar path
        re-routes the block through the row path, so opting in never has to
        handle exotic data shapes — only raise."""
        return False

    def process_columns(self, block):
        """ColumnBlock -> ColumnBlock. Only called when
        :meth:`supports_columns` is True; must not mutate ``block``."""
        raise NotImplementedError(f"{self.name} has no columnar path")

    # ------------------------------------------------------------------
    # unified template method
    # ------------------------------------------------------------------
    def run(self, data, **kwargs):
        """Apply this OP to a DJDataset (or raw sample list)."""
        from repro.core.dataset import DJDataset

        if not isinstance(data, DJDataset):
            data = DJDataset.from_samples(list(data))
        return data.process(self, **kwargs)

    def run_batch_safe(self, batch: List[Sample], base_index: int = 0) -> List[Sample]:
        """Fault-tolerant batch execution (batch -> per-sample fallback)."""
        try:
            return self.process_batch(batch)
        except Exception:
            out: List[Sample] = []
            for j, s in enumerate(batch):
                try:
                    out.extend(self.process_batch([s]))
                except Exception as e:  # noqa: BLE001 — the exception manager
                    self.errors.append(
                        OpError(self.name, base_index + j, f"{type(e).__name__}: {e}")
                    )
                    out.append(S.empty_like(s))
            return out


class Formatter(Operator):
    """Loads / converts raw records into schema samples."""

    def format_single(self, record: Dict[str, Any]) -> Sample:
        raise NotImplementedError

    def process_batch(self, batch):
        return [self.format_single(r) for r in batch]


class Mapper(Operator):
    """Edits samples 1->1 (or 1->many when ``expands``)."""

    expands: bool = False

    def process_single(self, sample: Sample) -> Sample | List[Sample]:
        raise NotImplementedError

    def process_batch(self, batch):
        out: List[Sample] = []
        for s in batch:
            r = self.process_single(s)
            if self.expands and isinstance(r, list):
                out.extend(r)
            else:
                out.append(r)
        return out


CTX_KEY = "__ctx__"


def shared_words(sample: Sample) -> List[str]:
    """Per-sample shared context: tokenised words, computed ONCE per fused
    pass (the redundant work OP fusion eliminates — paper §F.1)."""
    ctx = sample.get(CTX_KEY)
    if ctx is None:
        ctx = {}
        sample[CTX_KEY] = ctx
    if "words" not in ctx:
        ctx["words"] = sample.get("text", "").split()
    return ctx["words"]


def clear_ctx(sample: Sample) -> Sample:
    sample.pop(CTX_KEY, None)
    return sample


class Filter(Operator):
    """compute_stats() fills sample['stats']; keep() decides retention."""

    fusible = True
    stats_keys: Sequence[str] = ()

    def compute_stats(self, sample: Sample) -> Sample:
        raise NotImplementedError

    def keep(self, sample: Sample) -> bool:
        raise NotImplementedError

    def process_batch(self, batch):
        out = []
        for s in batch:
            s = self.compute_stats(s)
            if self.keep(s):
                out.append(clear_ctx(s))
        return out

    def compute_stats_batch(self, batch: List[Sample]) -> List[Sample]:
        return [self.compute_stats(s) for s in batch]


class Deduplicator(Operator):
    """Dataset-level: computes hashes then drops duplicates (see dedup/).

    Streaming protocol: a Deduplicator that can run as an *incremental
    pipeline stage* (consuming and emitting blocks without a dataset-wide
    barrier) reports ``supports_streaming() -> True`` and provides a fresh
    per-run state object via ``streaming_state()`` (see
    ``repro.core.dedup.streaming``). ``fusion.plan_segments`` then plans it
    as a stateful stream segment instead of a barrier, and ``dedup()`` stays
    the barriered fallback.
    """

    dataset_level = True

    def dedup(self, samples: List[Sample]) -> List[Sample]:
        raise NotImplementedError

    def supports_streaming(self) -> bool:
        """True when this op (as configured) can run incrementally."""
        return False

    def streaming_state(self):
        """Fresh stateful stream-stage driver; consumed by ONE segment
        traversal (``state.stream_blocks(blocks, check_cancel)``)."""
        raise NotImplementedError(f"{self.name} has no streaming variant")

    def process_batch(self, batch):  # pragma: no cover — executed dataset-level
        return batch


class Selector(Operator):
    """Dataset-level rank/rule-based sampling."""

    dataset_level = True

    def select(self, samples: List[Sample]) -> List[Sample]:
        raise NotImplementedError

    def process_batch(self, batch):  # pragma: no cover
        return batch


class Grouper(Operator):
    """Dataset -> list of sample groups (feeds an Aggregator)."""

    dataset_level = True

    def group(self, samples: List[Sample]) -> List[List[Sample]]:
        raise NotImplementedError

    def process_batch(self, batch):  # pragma: no cover
        return batch


class Aggregator(Operator):
    """Combines a group of samples into one."""

    def aggregate(self, group: List[Sample]) -> Sample:
        raise NotImplementedError

    def process_batch(self, batch):
        # when run directly, treats the whole batch as one group
        return [self.aggregate(batch)]


# OPs that genuinely need the whole dataset before producing any output —
# pipeline barriers for the streaming executor (paper §E.3)
BARRIER_TYPES = (Deduplicator, Selector, Grouper, Aggregator)


class FusedOP(Operator):
    """Explicit batch-wise fusion of multiple OPs (paper Listing 4) plus the
    auto-fused Filter group produced by the optimizer (fusion.py)."""

    def __init__(self, ops: List[Operator], **params):
        super().__init__(**params)
        self.ops = ops
        self._name = "fused<" + ",".join(o.name for o in ops) + ">"

    def config(self):
        return {"name": "fused_op", "ops": [o.config() for o in self.ops], **self.params}

    def setup(self):
        for o in self.ops:
            o.setup()

    def supports_columns(self):
        return all(o.supports_columns() for o in self.ops)

    @property
    def pushdown_safe(self):  # type: ignore[override]
        return all(o.pushdown_safe for o in self.ops)

    def process_columns(self, block):
        # cascaded columnar filtering: each op sees only the survivors of
        # the previous ones — the same work-saving shape as process_batch
        for op in self.ops:
            block = op.process_columns(block)
        return block

    def process_batch(self, batch):
        # one batch traversal with CASCADED filtering: the ops arrive in
        # probed-speed order (fusion.optimize), each filter's stats are
        # computed only on the survivors of the previous ones, and shared
        # context (e.g. tokenised words) is cached on the sample across the
        # fused group — both halves of the paper's fusion+reordering win.
        for op in self.ops:
            if isinstance(op, Filter) and type(op).process_batch is Filter.process_batch:
                batch = [s for s in (op.compute_stats(x) for x in batch) if op.keep(s)]
            else:  # custom batched filters (e.g. model-based) / mappers
                batch = op.process_batch(batch)
        return [clear_ctx(s) for s in batch]


class ScriptOP(Operator):
    """Wraps a user function / lambda / python file path."""

    def __init__(self, fn: Optional[Callable[[Sample], Sample]] = None,
                 script_path: Optional[str] = None, fn_name: str = "process", **params):
        super().__init__(**params)
        if fn is None and script_path:
            ns: Dict[str, Any] = {}
            with open(script_path) as f:
                exec(compile(f.read(), script_path, "exec"), ns)  # noqa: S102
            fn = ns[fn_name]
        if fn is None:
            raise ValueError("ScriptOP needs fn or script_path")
        self.fn = fn
        self._name = f"script<{getattr(fn, '__name__', 'lambda')}>"

    def process_batch(self, batch):
        return [self.fn(s) for s in batch]


class HumanOP(Operator):
    """Asynchronous human-in-the-loop annotation (paper: Label-Studio-backed).

    Offline reproduction: an annotation queue with a pluggable annotator
    callback (a human stand-in). ``submit`` is non-blocking; ``collect``
    integrates finished annotations back into samples, preserving the
    asynchronous control flow used for RLHF-style pipelines.
    """

    batched = False

    def __init__(self, annotator: Optional[Callable[[Sample], Dict[str, Any]]] = None,
                 annotation_key: str = "human", **params):
        super().__init__(**params)
        self.annotator = annotator or (lambda s: {"label": "ok"})
        self.annotation_key = annotation_key
        self.queue: List[Sample] = []
        self.done: List[Sample] = []

    def submit(self, samples: Iterable[Sample]) -> int:
        n = 0
        for s in samples:
            self.queue.append(s)
            n += 1
        return n

    def poll(self, max_items: Optional[int] = None) -> int:
        """Process pending annotations (simulates annotators finishing)."""
        n = 0
        while self.queue and (max_items is None or n < max_items):
            s = self.queue.pop(0)
            ann = self.annotator(s)
            s = dict(s)
            s.setdefault("meta", {})
            s["meta"] = dict(s["meta"], **{self.annotation_key: ann, "annotated_at": clock.now()})
            self.done.append(s)
            n += 1
        return n

    def collect(self) -> List[Sample]:
        out, self.done = self.done, []
        return out

    def process_batch(self, batch):
        self.submit(batch)
        self.poll()
        return self.collect()
