"""Adapter: runtime probing + automatic adaptation (paper §5.2, Appendix F).

  * ``probe_small_batch`` — estimate per-OP speed & memory on
    min(1000, len(dataset)) random samples (paper default).
  * adaptive batch size   — saturation search (Fig. 10a: gains plateau
    >=100, default 1000).
  * automatic resource allocation — model-based OPs get parallelism
    ``min(cpu_budget, accel_mem // gpu_mem_required)`` (Table 4 semantics:
    prevents OOM while maximising occupancy); I/O-bound OPs get a thread
    multiplier (hierarchical parallelism, Fig. 10b).
"""
from __future__ import annotations

import copy
import dataclasses
import time
import tracemalloc
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro.core import clock
from repro.core.ops_base import Filter, Operator

PROBE_CAP = 1000


@dataclasses.dataclass
class OpProbe:
    name: str
    speed: float  # samples / sec
    mem_peak: int  # bytes
    retention: float  # fraction of samples kept (Filters)


@dataclasses.dataclass
class ResourcePlan:
    n_procs: int
    n_threads: int
    batch_size: int
    note: str = ""


class Adapter:
    def __init__(
        self,
        cpu_budget: Optional[int] = None,
        mem_budget: int = 8 * 2**30,
        accel_mem: int = 0,  # per-accelerator bytes (0 = host only)
        n_accel: int = 0,
        utilization_target: float = 0.9,
    ):
        import os

        self.cpu_budget = cpu_budget or max(1, (os.cpu_count() or 2) - 1)
        self.mem_budget = mem_budget
        self.accel_mem = accel_mem
        self.n_accel = n_accel
        self.utilization_target = utilization_target
        self.probes: Dict[str, OpProbe] = {}

    # ------------------------------------------------------------------
    def probe_small_batch(
        self, samples: Sequence[dict], ops: Sequence[Operator],
        cap: int = PROBE_CAP, seed: int = 0,
    ) -> Dict[str, OpProbe]:
        """Apply each OP to a small random subset; record speed/mem/retention."""
        n = min(cap, len(samples))
        rng = np.random.default_rng(seed)
        idx = rng.choice(len(samples), size=n, replace=False)
        # deep copies: a shallow dict() would share the nested "stats" dicts,
        # letting probe runs write stats into the real dataset samples
        subset = [copy.deepcopy(samples[int(i)]) for i in idx]
        for op in ops:
            op.setup()
            probe_in = [dict(s) for s in subset]
            tracemalloc.start()
            t0 = clock.now()
            out = op.run_batch_safe(probe_in)
            dt = max(clock.now() - t0, 1e-9)
            _, peak = tracemalloc.get_traced_memory()
            tracemalloc.stop()
            retention = len(out) / max(1, len(probe_in)) if isinstance(op, Filter) else 1.0
            p = OpProbe(op.name, n / dt, int(peak), retention)
            self.probes[op.name] = p
            op.probed_speed = p.speed
        return self.probes

    # ------------------------------------------------------------------
    def adaptive_batch_size(
        self, samples: Sequence[dict], op: Operator,
        candidates: Sequence[int] = (1, 10, 100, 1000),
        plateau: float = 1.10,
    ) -> int:
        """Pick the smallest batch size within 10% of the best throughput
        (Fig. 10a: 100+ saturates; 1000 default)."""
        n = min(PROBE_CAP, len(samples))
        subset = [dict(s) for s in samples[:n]]
        op.setup()
        speeds: Dict[int, float] = {}
        for bs in candidates:
            t0 = clock.now()
            for i in range(0, n, bs):
                op.run_batch_safe([dict(s) for s in subset[i : i + bs]], i)
            speeds[bs] = n / max(clock.now() - t0, 1e-9)
        best = max(speeds.values())
        for bs in sorted(speeds):
            if speeds[bs] * plateau >= best:
                return bs
        return max(speeds, key=speeds.get)

    # ------------------------------------------------------------------
    def resource_plan(self, op: Operator, batch_size: int = 1000) -> ResourcePlan:
        """OP-wise parallelism (paper §F.2 / Table 4)."""
        probe = self.probes.get(op.name)
        mem_per_proc = max(op.mem_required, probe.mem_peak if probe else 0, 1)
        n_by_mem = max(1, int(self.mem_budget * self.utilization_target // mem_per_proc))
        n_procs = min(self.cpu_budget, n_by_mem)
        note = "cpu/mem bound"
        if op.uses_model and self.n_accel > 0 and op.gpu_mem_required > 0:
            per_accel = max(1, int(self.accel_mem // op.gpu_mem_required))
            n_procs = min(n_procs, per_accel * self.n_accel)
            note = f"accel: {per_accel} instances x {self.n_accel} devices"
        n_threads = 4 if op.io_intensive else 1
        return ResourcePlan(n_procs=n_procs, n_threads=n_threads,
                            batch_size=batch_size, note=note)
