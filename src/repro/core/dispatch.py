"""Adaptive windowed block dispatcher (paper §5: adaptive execution).

One shared subsystem replaces the hand-rolled wait loops that used to live in
``ParallelEngine.map_batches``, ``ParallelEngine.map_block_chain`` and
``LocalEngine``'s threaded chain window. The :class:`WindowedDispatcher` owns:

* the **bounded in-flight window** — at most ``window`` blocks are submitted
  but not yet yielded, so results stream back in input order with bounded
  buffering;
* **per-block start/finish timing** and a running completion-time estimator
  (median over a recent-completions deque);
* **speculative re-dispatch** — once ``min_completions`` blocks have
  finished, any block running longer than ``straggler_factor`` x the median
  completion time gets ONE backup submission; the first finisher wins and the
  loser is cancelled (or its result discarded when already running);
* **failure retries** — a failed submission is retried while a backup is
  still in flight or attempts remain; only when *every* submission for a
  block has failed does the dispatcher surface an error outcome (the engine
  then decides pass-through);
* **adaptive window sizing** — the window grows when workers drain the queue
  faster than blocks arrive (observed queue-wait << compute) and shrinks when
  blocks pile up in the executor queue (queue-wait >> compute), bounded to
  ``[n_workers + 1, 4 x n_workers]`` (see :func:`window_bounds`);
* **per-worker health accounting** — a worker (process pid / thread ident)
  that fails ``worker_failure_limit`` tasks is *quarantined*: subsequent
  submissions carry the quarantine set and the worker-side guard bounces the
  task back (without running it) for re-dispatch to a healthy worker, instead
  of pass-through-ing the quarantined worker's blocks;
* **preemptive loser cancellation** — when a speculative race resolves while
  the losing submission is still running, the dispatcher flips the flight's
  entry on a shared *preempt board*; cooperative task functions (the engines'
  chain runners) poll it between batches and exit early with
  :class:`TaskPreempted`, so a sleeping straggler stops occupying its worker
  instead of draining to completion;
* **cross-run health persistence** — with a :class:`HealthRegistry`, worker
  quarantines are recorded per pool *slot* (arrival order) into a JSON file;
  a slot quarantined in one run starts the next run on *probation* (a single
  failure re-quarantines it) until it proves itself with a success.

The dispatcher is pool-agnostic: it drives any ``concurrent.futures``
executor. For process pools the task function and its arguments must be
picklable (the worker-side guard ``_guarded`` is module-level for exactly
that reason); thread pools may pass closures.
"""
from __future__ import annotations

import collections
import concurrent.futures as cf
import itertools
import os
import threading
import time
from typing import Any, Callable, Dict, Iterable, Iterator, List, Optional, Tuple

from repro.core import clock, obs

# completion-time floor: sub-50ms medians would make speculation fire on
# scheduler jitter alone
MEDIAN_FLOOR = 0.05

_END = object()  # iterator sentinel (None could be a legitimate item)

# per-process dispatcher sequence: namespaces preempt-board keys so a board
# shared across sequential dispatch calls (one Manager per engine) never
# lets run N's flight indices collide with run N+1's
_BOARD_SEQ = itertools.count()


class WorkerQuarantined(Exception):
    """Raised by the worker-side guard when a quarantined worker picks up a
    task: the payload is NOT executed; the dispatcher re-dispatches."""

    def __init__(self, worker_id: str):
        super().__init__(worker_id)
        self.worker_id = worker_id


class TaskPreempted(Exception):
    """Raised by a cooperative task function (via its ``should_stop`` poll)
    after the dispatcher resolved the flight to another submission: the
    partial work is discarded and the worker freed immediately."""


class HealthRegistry:
    """Cross-run worker-health persistence (JSON file, atomic rewrite).

    Worker ids (pid:tid) do not survive a run, so health is keyed by stable
    *slot* labels — the dispatcher maps worker ids to ``w0, w1, ...`` in
    arrival order, approximating "the Nth worker of this pool" the way a
    scheduler tracks node slots. Semantics:

    * ``note_quarantine(slot)`` marks the slot quarantined (sticky across
      save/load);
    * a quarantined slot is *on probation* in later runs: the dispatcher
      drops its failure allowance to one strike;
    * ``note_recovery(slot)`` (a probation worker completing a task) clears
      the flag; cumulative counters survive for placement scoring.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.slots: Dict[str, Dict[str, int]] = {}
        if path and os.path.exists(path):
            try:
                with open(path, "rb") as f:
                    from repro.core.storage import json_loads

                    data = json_loads(f.read())
                slots = data.get("slots", {}) if isinstance(data, dict) else {}
                self.slots = {
                    str(k): {"failures": int(v.get("failures", 0)),
                             "quarantines": int(v.get("quarantines", 0)),
                             "recoveries": int(v.get("recoveries", 0)),
                             "probation": int(v.get("probation", 0))}
                    for k, v in slots.items() if isinstance(v, dict)
                }
            except (ValueError, OSError):
                self.slots = {}  # torn/corrupt file: start fresh, not crash

    def _slot(self, key: str) -> Dict[str, int]:
        return self.slots.setdefault(
            key, {"failures": 0, "quarantines": 0, "recoveries": 0,
                  "probation": 0})

    def note_failure(self, key: str) -> None:
        self._slot(key)["failures"] += 1

    def note_quarantine(self, key: str) -> None:
        s = self._slot(key)
        s["quarantines"] += 1
        s["probation"] = 1

    def note_recovery(self, key: str) -> None:
        s = self._slot(key)
        if s["probation"]:
            s["recoveries"] += 1
            s["probation"] = 0

    def forgive(self, key: str) -> None:
        """Clear probation without counting a recovery — used when a
        whole-pool failure retroactively discredits the quarantines."""
        self._slot(key)["probation"] = 0

    def on_probation(self, key: str) -> bool:
        return bool(self.slots.get(key, {}).get("probation"))

    def total_quarantines(self) -> int:
        return sum(s["quarantines"] for s in self.slots.values())

    def snapshot(self) -> Dict[str, Any]:
        return {"slots": {k: dict(v) for k, v in self.slots.items()}}

    def save(self) -> None:
        if not self.path:
            return
        from repro.core.storage import json_dumps

        os.makedirs(os.path.dirname(os.path.abspath(self.path)), exist_ok=True)
        tmp = f"{self.path}.{os.getpid()}.tmp"
        with open(tmp, "wb") as f:
            f.write(json_dumps(self.snapshot()))
        os.replace(tmp, self.path)


class WorkerTaskFailure(Exception):
    """A task payload raised in the worker. Carries the worker id (health
    accounting) and, when the underlying exception exposes ``op_index`` (see
    ``engine.ChainOpFailure``), which op of a chain failed. Picklable via
    default (class, args) reduction."""

    def __init__(self, worker_id: str, message: str, op_index: int = -1):
        super().__init__(worker_id, message, op_index)
        self.worker_id = worker_id
        self.message = message
        self.op_index = op_index


def _worker_id() -> str:
    # pid distinguishes process-pool workers; thread ident distinguishes
    # thread-pool workers inside one process
    return f"{os.getpid()}:{threading.get_ident()}"


def _guarded(fn, args, quarantined, t_submit: float, bounce_pause: float,
             board=None, key=None, trace_ctx=None):
    """Worker-side wrapper: quarantine check + timing + failure attribution.

    Returns ``(worker_id, queue_wait, compute_seconds, payload, span)``. The
    pause before a quarantine bounce keeps an idle bad worker from starving the
    queue by bouncing every task faster than healthy workers can pick one up.

    ``trace_ctx`` is ``(trace_id, parent_span_id, label)`` or None. The block
    span is born HERE, in the worker process — its pid/tid identify where the
    block actually ran — and travels back to the driver in the result tuple
    (worker pools are created per dispatch call, so workers never flush spill
    files themselves; the driver records shipped spans into its own buffer).

    With a preempt ``board`` (any shared mapping — a plain dict for thread
    pools, a ``multiprocessing.Manager().dict()`` proxy for process pools),
    the task function is called with a trailing ``should_stop`` callable it
    may poll between batches; a True poll means the flight already resolved
    elsewhere and the function should raise :class:`TaskPreempted`.
    """
    wid = _worker_id()
    if wid in quarantined:
        if bounce_pause:
            time.sleep(bounce_pause)
        raise WorkerQuarantined(wid)
    t_start = clock.now()

    def _poll() -> bool:
        try:
            return bool(board.get(key))
        except Exception:  # noqa: BLE001 — Manager proxy torn down: the
            return True    # dispatch is over, stopping is the right answer

    try:
        if board is not None:
            payload = fn(*args, _poll)
        else:
            payload = fn(*args)
    except TaskPreempted:
        raise  # the dispatcher counts preempted losers, never wraps them
    except Exception as e:  # noqa: BLE001 — re-raised with attribution
        raise WorkerTaskFailure(
            wid, f"{type(e).__name__}: {e}", getattr(e, "op_index", -1)
        ) from None
    t_end = clock.now()
    wait = max(0.0, t_start - t_submit)
    span = None
    if trace_ctx is not None:
        span = {
            "trace_id": trace_ctx[0], "span_id": obs.new_id(),
            "parent_id": trace_ctx[1], "name": f"block:{trace_ctx[2]}",
            "kind": "block", "t0": t_start, "dur": t_end - t_start,
            "pid": os.getpid(), "tid": wid,
            "attrs": {"queue_wait": wait, "worker": wid},
        }
    return wid, wait, t_end - t_start, payload, span


class _Flight:
    """One block's dispatch state: all in-flight submissions + outcome."""

    __slots__ = ("idx", "item", "futures", "backups", "failures", "bounces",
                 "done", "payload", "error", "t_submit", "nbytes")

    def __init__(self, idx: int, item: Any):
        self.idx = idx
        self.item = item
        self.nbytes = 0
        self.futures: set = set()
        self.backups: set = set()
        self.failures = 0
        self.bounces = 0
        self.done = False
        self.payload: Any = None
        self.error: Optional[Dict[str, Any]] = None
        self.t_submit = clock.now()


def window_bounds(n_workers: int) -> Tuple[int, int, int]:
    """(start, min, max) of the adaptive in-flight window — the single
    source of truth shared by the dispatcher and ``explain()``'s policy.
    The floor keeps one block buffered beyond the worker count so in-order
    head-of-line draining can't leave a worker idle."""
    return max(2, 2 * n_workers), max(2, n_workers + 1), max(4, 4 * n_workers)


DISPATCH_COUNTERS = ("blocks", "redispatches", "retries", "speculation_wins",
                     "bounces", "pass_throughs", "preempt_signals", "preempted")


def aggregate_dispatch(summaries: Iterable[Dict[str, Any]]) -> Dict[str, int]:
    """Fold per-segment dispatch summaries (``RunReport.dispatch``) into one
    counter dict — the shape both single-node ``Job.status()`` and cluster
    ``ClusterQueue.status()`` expose under ``progress["dispatch"]``."""
    out = {k: 0 for k in DISPATCH_COUNTERS}
    for s in summaries or ():
        for k in DISPATCH_COUNTERS:
            out[k] += int(s.get(k, 0) or 0)
    return out


def dispatch_policy(n_workers: int, straggler_factor: float, speculate: bool,
                    worker_failure_limit: int) -> Dict[str, Any]:
    """Static description of the adaptive-dispatch knobs for ``explain()``."""
    start, lo, hi = window_bounds(n_workers)
    return {
        "speculation": bool(speculate),
        "straggler_factor": straggler_factor,
        "window": {"start": start, "min": lo, "max": hi, "adaptive": True},
        "quarantine_after_failures": worker_failure_limit,
    }


class WindowedDispatcher:
    """Drive an item iterator through a pool with a bounded adaptive window,
    yielding ``(item, payload, error)`` in input order.

    ``payload`` is whatever ``fn(*args_of(item))`` returned (None when the
    block failed); ``error`` is None on success, else
    ``{"error", "op_index", "attempts"}`` — surfaced only after every
    submission for the block failed, so a live backup always gets to win.
    """

    def __init__(self, pool, n_workers: int, *, straggler_factor: float = 3.0,
                 speculate: bool = True, min_completions: Optional[int] = None,
                 max_attempts: int = 2, worker_failure_limit: int = 3,
                 adaptive_window: bool = True, bounce_limit: Optional[int] = None,
                 bounce_pause: float = 0.02, poll: float = 0.05,
                 label: str = "", log: Optional[List[dict]] = None,
                 meta: Optional[Dict[str, Any]] = None,
                 preempt_board: Optional[Any] = None,
                 health: Optional[HealthRegistry] = None,
                 mem_budget: Optional[int] = None):
        self.pool = pool
        self.n_workers = max(1, n_workers)
        self.straggler_factor = straggler_factor
        self.speculate = speculate
        self.min_completions = min_completions or max(3, self.n_workers)
        self.max_attempts = max(1, max_attempts)
        self.worker_failure_limit = max(1, worker_failure_limit)
        self.adaptive_window = adaptive_window
        self.bounce_limit = bounce_limit if bounce_limit is not None else 2 * self.n_workers
        self.bounce_pause = bounce_pause
        self.poll = poll
        self.label = label
        self.log = log
        self.meta = meta or {}
        # shared mapping polled by cooperative task fns (dict for thread
        # pools, Manager().dict() proxy for process pools); None disables
        # preemptive loser cancellation
        self.preempt_board = preempt_board
        self._board_ns = f"d{next(_BOARD_SEQ)}:"
        self.health = health
        self._slots: Dict[str, str] = {}  # wid -> stable slot label
        self._run_quarantined_slots: set = set()

        self.window, self.min_window, self.max_window = window_bounds(self.n_workers)
        self._window_start = self.window

        # memory-pressure signal: cap on RESIDENT in-flight block bytes
        # (submitted but not yet yielded, measured via each item's ``nbytes``).
        # When exceeded, the fill loop stops admitting blocks (always keeping
        # one in flight) and the window shrinks toward its floor — memory
        # co-drives the window alongside the queue-wait/compute ratio.
        self.mem_budget = mem_budget if mem_budget and mem_budget > 0 else None
        self.resident_bytes = 0
        self.resident_peak = 0
        self.mem_shrinks = 0

        # health / outcome accounting
        self.quarantined: set = set()
        self._quarantine_disabled = False  # set when the WHOLE pool failed
        self.worker_failures: Dict[str, int] = collections.defaultdict(int)
        self.redispatches = 0        # speculative backups submitted
        self.retries = 0             # failure-driven resubmissions
        self.speculation_wins = 0    # backups that beat their original
        self.bounces = 0             # quarantine bounces
        self.pass_throughs = 0       # blocks whose every submission failed
        self.blocks = 0              # blocks yielded
        self.preempt_signals = 0     # losers told to stop (board flipped)
        self.preempted = 0           # losers observed exiting early

        # timing estimators
        self._times: collections.deque = collections.deque(maxlen=64)
        self._waits: collections.deque = collections.deque(maxlen=32)
        self._computes: collections.deque = collections.deque(maxlen=32)
        self._successes = 0

        self._pending: set = set()
        self._fut2idx: Dict[cf.Future, int] = {}
        self.summary: Optional[Dict[str, Any]] = None

        # tracing: the dispatch window is itself a span, parented to the
        # ambient span of the constructing thread (the executor's segment/run
        # span); workers receive (trace_id, window_span_id, label) and ship
        # block spans back through the result tuple
        cur = obs.current_span()
        self._span = obs.start_span(
            cur.trace_id if cur else None, f"dispatch:{label or 'chain'}",
            kind="dispatch", parent_id=cur.span_id if cur else None)
        self._trace_ctx = (
            (self._span.trace_id, self._span.span_id, label or "chain")
            if self._span is not None else None)

    # ------------------------------------------------------------------
    def _slot_key(self, wid: str) -> str:
        # stable per-run slot labels in arrival order; approximates "the Nth
        # worker of the pool" so HealthRegistry survives pid churn across runs
        if wid not in self._slots:
            self._slots[wid] = f"w{len(self._slots)}"
        return self._slots[wid]

    def _failure_limit(self, wid: str) -> int:
        if self.health is not None and self.health.on_probation(self._slot_key(wid)):
            return 1  # probation: one strike re-quarantines
        return self.worker_failure_limit

    def _submit(self, fl: _Flight, fn, args, quarantine: Optional[frozenset] = None,
                backup: bool = False) -> cf.Future:
        q = frozenset(self.quarantined) if quarantine is None else quarantine
        try:
            f = self.pool.submit(_guarded, fn, args, q, clock.now(),
                                 self.bounce_pause, self.preempt_board,
                                 f"{self._board_ns}{fl.idx}", self._trace_ctx)
        except Exception:
            # pool is broken (worker OOM-killed / segfaulted mid-run) or shut
            # down: keep the run alive by finishing this block in-process
            f = cf.Future()
            try:
                f.set_result(_guarded(fn, args, frozenset(), clock.now(), 0.0,
                                      trace_ctx=self._trace_ctx))
            except Exception as e:  # noqa: BLE001 — surfaced as outcome
                f.set_exception(e)
        fl.futures.add(f)
        if backup:
            fl.backups.add(f)
        self._fut2idx[f] = fl.idx
        self._pending.add(f)
        return f

    def _resolve(self, fl: _Flight, payload=None, error=None) -> None:
        fl.done = True
        fl.payload = payload
        fl.error = error
        signalled = False
        for other in fl.futures:
            if not other.cancel() and self.preempt_board is not None:
                # already running: cancel() can't stop it, but the preempt
                # board can — the loser's should_stop poll now reads True and
                # it exits with TaskPreempted at its next batch boundary
                # instead of draining (and occupying its worker) to the end
                self.preempt_board[f"{self._board_ns}{fl.idx}"] = True
                signalled = True
        if signalled:
            self.preempt_signals += 1
        fl.futures.clear()

    def _record_worker_failure(self, wid: Optional[str]) -> None:
        if not wid or self._quarantine_disabled:
            return
        self.worker_failures[wid] += 1
        if self.health is not None:
            self.health.note_failure(self._slot_key(wid))
        if self.worker_failures[wid] >= self._failure_limit(wid):
            self.quarantined.add(wid)
            if self.health is not None:
                slot = self._slot_key(wid)
                self.health.note_quarantine(slot)
                self._run_quarantined_slots.add(slot)
        if len(self.quarantined) >= self.n_workers:
            # the whole pool failing is an op/data problem, not worker
            # health — quarantining everyone would only add a bounce storm
            # on top of the per-block retry/pass-through handling
            self.quarantined.clear()
            self.worker_failures.clear()
            self._quarantine_disabled = True
            if self.health is not None:
                # don't poison the next run with probation for every slot
                for slot in self._run_quarantined_slots:
                    self.health.forgive(slot)
                self._run_quarantined_slots.clear()

    def _adapt_window(self) -> None:
        if not self.adaptive_window or self._successes % 8 != 0 or not self._waits:
            return
        wait = sum(self._waits) / len(self._waits)
        compute = max(sum(self._computes) / len(self._computes), 1e-6)
        ratio = wait / compute
        if ratio > 2.0:      # deep executor backlog: blocks queue far longer
            self.window = max(self.min_window, self.window - 1)   # than they compute
        elif ratio < 0.25:   # queue drains instantly: risk of idle workers
            self.window = min(self.max_window, self.window + 1)

    def _note_preempted(self, f: cf.Future) -> bool:
        try:
            preempted = (not f.cancelled()
                         and isinstance(f.exception(), TaskPreempted))
        except cf.CancelledError:
            return False
        if preempted:
            self.preempted += 1
        return preempted

    def _handle_done(self, f: cf.Future, flights: Dict[int, _Flight], fn, args_of) -> None:
        idx = self._fut2idx.pop(f, None)
        self._pending.discard(f)
        if idx is None or idx not in flights:
            self._note_preempted(f)  # loser of an already-yielded flight
            return
        fl = flights[idx]
        fl.futures.discard(f)
        if fl.done:
            self._note_preempted(f)
            return  # stale loser of a won race
        try:
            wid, wait, compute, payload, span = f.result()
        except WorkerQuarantined:
            self.bounces += 1
            fl.bounces += 1
            # after too many bounces (e.g. every worker quarantined), force
            # the run anywhere rather than ping-ponging forever
            q = frozenset() if fl.bounces > self.bounce_limit else None
            self._submit(fl, fn, args_of(fl.item), quarantine=q,
                         backup=f in fl.backups)
            return
        except Exception as e:  # noqa: BLE001 — WorkerTaskFailure or pool break
            self._record_worker_failure(getattr(e, "worker_id", None))
            fl.failures += 1
            err = {
                "error": getattr(e, "message", f"{type(e).__name__}: {e}"),
                "op_index": getattr(e, "op_index", -1),
                "attempts": fl.failures,
            }
            if fl.futures:
                return  # a backup is still in flight — it must get to win
            if fl.failures < self.max_attempts:
                self.retries += 1
                self._submit(fl, fn, args_of(fl.item))
                return
            self.pass_throughs += 1
            self._resolve(fl, error=err)
            return
        if f in fl.backups:
            self.speculation_wins += 1
        if self.health is not None:
            slot = self._slot_key(wid)
            # a success clears PRIOR-run probation (the worker proved
            # itself); a quarantine earned THIS run must survive to the
            # next one even if bounce-forced tasks later succeed here
            if slot not in self._run_quarantined_slots:
                self.health.note_recovery(slot)
        self._successes += 1
        self._times.append(wait + compute)
        self._waits.append(wait)
        self._computes.append(compute)
        obs.record_span_dict(span)  # block span shipped back over worker IPC
        m = obs.metrics()
        m.observe("dispatch.queue_wait_seconds", wait)
        m.observe("dispatch.block_compute_seconds", compute)
        self._adapt_window()
        self._resolve(fl, payload=payload)

    def _speculate(self, flights: Dict[int, _Flight], fn, args_of) -> None:
        # gate on the unbounded success counter: _times is a bounded deque
        # (maxlen 64), so comparing its length would permanently disable
        # speculation whenever min_completions exceeds the deque size
        # (e.g. the default max(3, n_workers) on a >64-core machine)
        if not self.speculate or self._successes < self.min_completions \
                or not self._times:
            return
        times = sorted(self._times)
        med = times[len(times) // 2]
        threshold = self.straggler_factor * max(med, MEDIAN_FLOOR)
        now = clock.now()
        for fl in flights.values():
            if (not fl.done and not fl.backups and fl.failures == 0
                    and fl.futures and now - fl.t_submit > threshold):
                self._submit(fl, fn, args_of(fl.item), backup=True)
                self.redispatches += 1

    # ------------------------------------------------------------------
    def run(self, items: Iterable[Any], fn: Callable,
            args_of: Callable[[Any], tuple]) -> Iterator[Tuple[Any, Any, Optional[dict]]]:
        """In-order generator over ``(item, payload, error)``. The summary is
        built (and appended to ``log``) even when the consumer abandons the
        stream early."""
        try:
            it = iter(items)
            flights: Dict[int, _Flight] = {}
            next_idx = 0
            next_yield = 0
            exhausted = False
            while True:
                # fill the window (submitted-but-not-yielded bounds buffering)
                while not exhausted and next_idx - next_yield < self.window:
                    if (self.mem_budget is not None and next_idx > next_yield
                            and self.resident_bytes >= self.mem_budget):
                        # over the resident-bytes budget: stop admitting and
                        # pull the window toward its floor so pressure also
                        # persists into the steady-state window size
                        if self.window > self.min_window:
                            self.window -= 1
                            self.mem_shrinks += 1
                        break
                    item = next(it, _END)
                    if item is _END:
                        exhausted = True
                        break
                    fl = _Flight(next_idx, item)
                    fl.nbytes = int(getattr(item, "nbytes", 0) or 0)
                    self.resident_bytes += fl.nbytes
                    self.resident_peak = max(self.resident_peak, self.resident_bytes)
                    flights[next_idx] = fl
                    next_idx += 1
                    self._submit(fl, fn, args_of(item))
                # drain resolved head-of-line flights in input order
                while next_yield in flights and flights[next_yield].done:
                    fl = flights.pop(next_yield)
                    next_yield += 1
                    self.blocks += 1
                    self.resident_bytes -= fl.nbytes
                    yield fl.item, fl.payload, fl.error
                if exhausted and not flights:
                    break
                if not self._pending:
                    continue  # flights resolved between the two drains above
                done, _ = cf.wait(self._pending, timeout=self.poll,
                                  return_when=cf.FIRST_COMPLETED)
                for f in done:
                    self._handle_done(f, flights, fn, args_of)
                self._speculate(flights, fn, args_of)
        finally:
            self._finalize()

    def _finalize(self) -> None:
        if self.summary is not None:
            return
        # sweep losers that exited (preempted or otherwise) after their
        # flight was yielded but before the consumer closed the stream
        for f in list(self._pending):
            if f.done():
                self._pending.discard(f)
                self._note_preempted(f)
        if self.health is not None:
            try:
                self.health.save()
            except OSError:
                pass  # health persistence must never fail a run
        self.summary = {
            "label": self.label,
            "blocks": self.blocks,
            "redispatches": self.redispatches,
            "retries": self.retries,
            "speculation_wins": self.speculation_wins,
            "bounces": self.bounces,
            "pass_throughs": self.pass_throughs,
            "preempt_signals": self.preempt_signals,
            "preempted": self.preempted,
            "quarantined": sorted(self.quarantined),
            "window_start": self._window_start,
            "window_final": self.window,
            "mem_shrinks": self.mem_shrinks,
            "resident_peak": self.resident_peak,
            **self.meta,
        }
        if self._span is not None:
            self._span.set(
                blocks=self.blocks, redispatches=self.redispatches,
                retries=self.retries, speculation_wins=self.speculation_wins,
                preempted=self.preempted, window_final=self.window,
                resident_peak=self.resident_peak).end()
        m = obs.metrics()
        m.inc("dispatch.blocks_total", self.blocks)
        m.inc("dispatch.redispatches_total", self.redispatches)
        m.inc("dispatch.retries_total", self.retries)
        m.inc("dispatch.preempted_total", self.preempted)
        m.gauge_max("dispatch.resident_peak_bytes", self.resident_peak)
        if self.log is not None:
            self.log.append(self.summary)
