"""Command-line tools (paper Listing 1): dj-process / dj-analyze analogues.

  python -m repro.interface.cli process --config recipe.{json,yaml}
  python -m repro.interface.cli analyze --dataset_path x.jsonl [--auto]
  python -m repro.interface.cli list-ops
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None):
    ap = argparse.ArgumentParser(prog="dj")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p_proc = sub.add_parser("process", help="run a recipe")
    p_proc.add_argument("--config", required=True)
    p_proc.add_argument("--np", type=int, default=0)

    p_an = sub.add_parser("analyze", help="compute default stats + report")
    p_an.add_argument("--dataset_path", required=True)
    p_an.add_argument("--auto", action="store_true")

    sub.add_parser("list-ops", help="print the OP registry")

    args = ap.parse_args(argv)

    if args.cmd == "list-ops":
        from repro.core.registry import list_ops, op_info

        for n in list_ops():
            info = op_info(n)
            print(f"{n:40s} {info['type']:12s} {info['doc'][:60]}")
        return 0

    if args.cmd == "process":
        from repro.core.executor import Executor
        from repro.core.recipes import Recipe

        recipe = Recipe.load(args.config)
        if args.np:
            recipe.np = args.np
        _, report = Executor(recipe).run()
        print(f"recipe={report.recipe} in={report.n_in} out={report.n_out} "
              f"seconds={report.seconds:.2f} plan={report.plan}")
        for row in report.per_op:
            print(f"  {row['op']:40s} {row['seconds']:.3f}s "
                  f"{row['in']}->{row['out']} ({row['speed']:.0f} samples/s)")
        if report.insight:
            print(report.insight)
        return 0

    if args.cmd == "analyze":
        from repro.core.dataset import DJDataset
        from repro.core.insight import snapshot
        from repro.core.registry import create_op

        ds = DJDataset.load(args.dataset_path)
        default_ops = [
            {"name": "text_length_filter"},
            {"name": "words_num_filter"},
            {"name": "alnum_ratio_filter"},
            {"name": "quality_score_filter"},
        ]
        for cfg in default_ops:
            op = create_op(cfg)
            for s in ds:
                op.compute_stats(s)
        snap = snapshot(ds.samples())
        print(f"n={snap['n']}")
        for k, st in snap["numeric"].items():
            print(f"  {k:24s} mean={st.mean:.3f} p50={st.p50:.3f} p95={st.p95:.3f}")
        return 0
    return 1


if __name__ == "__main__":
    sys.exit(main())
